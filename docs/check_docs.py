"""Docs gate for the CI `docs` job.

Two checks, both cheap and deterministic:

  1. LINK CHECK — every relative markdown link in README.md, docs/*.md
     and DESIGN.md must point at a file or directory that exists in the
     repo (external http(s)/mailto links and pure #anchors are skipped).
     The README's architecture map is only useful while its file
     pointers stay alive; this fails the build when a refactor moves one.

  2. QUICKSTART SMOKE — EVERY ```python fence in README.md is
     extracted verbatim and executed with PYTHONPATH=src.  The front
     door snippets (single-tier quickstart, tiered-pool quickstart)
     must keep working, not rot.

Run locally:  python docs/check_docs.py   (from the repo root)
"""
import os
import pathlib
import re
import subprocess
import sys
import tempfile

REPO = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = [REPO / "README.md", REPO / "DESIGN.md",
             *sorted((REPO / "docs").glob("*.md"))]

# [text](target) markdown links; images ![..](..) match the same way
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def check_links() -> list:
    errors = []
    for md in DOC_FILES:
        if not md.exists():
            errors.append(f"{md.relative_to(REPO)}: file missing")
            continue
        for target in _LINK.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#")[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(
                    f"{md.relative_to(REPO)}: dead pointer -> {target}")
    return errors


def run_quickstart() -> int:
    readme = (REPO / "README.md").read_text()
    fences = _FENCE.findall(readme)
    if not fences:
        print("[check_docs] no ```python fence in README.md")
        return 1
    env = {**os.environ, "PYTHONPATH": str(REPO / "src")}
    for i, body in enumerate(fences, 1):
        with tempfile.NamedTemporaryFile("w", suffix=".py",
                                         delete=False) as f:
            f.write(body)
            snippet = f.name
        print(f"[check_docs] running README snippet {i}/{len(fences)} ...")
        proc = subprocess.run([sys.executable, snippet], env=env,
                              cwd=str(REPO))
        if proc.returncode:
            return proc.returncode
    return 0


def main() -> int:
    errors = check_links()
    for e in errors:
        print(f"[check_docs] {e}")
    n_links = sum(len(_LINK.findall(p.read_text()))
                  for p in DOC_FILES if p.exists())
    print(f"[check_docs] checked {n_links} links across "
          f"{len(DOC_FILES)} files: {len(errors)} dead")
    if errors:
        return 1
    return run_quickstart()


if __name__ == "__main__":
    sys.exit(main())
