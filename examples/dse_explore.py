"""DSE walkthrough (paper Fig 15): sweep die groupings × quantization for a
model, print the latency heatmap with OOM blanks, and show how the winner
reconfigures the Track-B serving engine.

    PYTHONPATH=src python examples/dse_explore.py [arch]
"""
import math
import sys

from repro.configs import get_config
from repro.core import dse


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "llama3.1-70b"
    cfg = get_config(arch)
    seqs = [1_000, 5_000, 10_000, 50_000, 100_000]
    print(f"=== DSE heatmap: {arch}, 8 IFC dies, W4A16 "
          f"(ms/token; -- = OOM) ===")
    grid = dse.heatmap(cfg, seqs, total_dies=8, wbits=4, abits=16)
    header = "config".ljust(18) + "".join(f"{s:>10}" for s in seqs)
    print(header)
    for name, row in grid.items():
        cells = "".join(
            f"{'--':>10}" if math.isinf(row[s]) else f"{row[s]*1e3:10.1f}"
            for s in seqs)
        print(name.ljust(18) + cells)
    for seq in (1_000, 100_000):
        best = dse.best_config(cfg, seq, 8, 4, 16)
        print(f"best @ {seq}: {best.system}  "
              f"({best.latency * 1e3:.1f} ms/token)")
    print("\n=== engine reconfiguration (paper: software-defined) ===")
    for seq in (1_000, 100_000):
        eng = dse.recommend_engine_config(arch, seq)
        print(f"ctx {seq:>7}: variant={eng.variant:9s} quant={eng.quant} "
              f"hg_pipeline={eng.hg_pipeline}")
    t = dse.takeaways(get_config("opt-30b"), get_config("llama3.1-70b"))
    print("\npaper takeaways reproduced:", t)


if __name__ == "__main__":
    main()
