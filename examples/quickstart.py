"""Quickstart: train a tiny LM on synthetic data, then serve it through
the request-centric `KVNANDServer` API — the full loop in ~2 minutes on
CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import EngineConfig, get_config
from repro.data.pipeline import DataConfig, DataIterator, make_source
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.serving.api import KVNANDServer, SamplingParams, ServerConfig
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step


def main():
    cfg = get_config("qwen1.5-0.5b").reduced()
    rt = Runtime()
    model = Model(cfg, rt)
    params = model.init(jax.random.PRNGKey(0))
    print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.2f}M params)")

    # -- train ----------------------------------------------------------
    acfg = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=150)
    state = init_train_state(params, acfg)
    step = jax.jit(make_train_step(cfg, rt, acfg, EngineConfig()))
    it = DataIterator(make_source(DataConfig(
        seq_len=64, global_batch=16, vocab_size=cfg.vocab_size)))
    for i in range(150):
        state, metrics = step(state, {k: jnp.asarray(v)
                                      for k, v in next(it).items()})
        if i % 25 == 0:
            print(f"  step {i:3d}  loss {float(metrics['loss']):.3f}")
    print(f"  final loss {float(metrics['loss']):.3f} "
          f"(random = {jnp.log(cfg.vocab_size):.2f})")

    # -- serve the trained weights through the KVNAND engine -------------
    # KVNANDServer owns engine + scheduler construction; pass the freshly
    # trained params instead of letting it initialize its own
    server = KVNANDServer(
        ServerConfig(engine=EngineConfig(page_tokens=8,
                                         uniform_lengths=False),
                     batch_slots=1, max_context=64),
        cfg=cfg, params=state.params, rt=rt)
    out = server.generate([[5, 17, 42, 7]],
                          SamplingParams(max_new_tokens=24))[0]
    print(f"generated ({out.finish_reason}, "
          f"ttft {out.ttft * 1e3:.0f} ms): {out.token_ids}")
    # the synthetic stream is 80% next = perm[cur]; a trained model locks on
    src = it.source
    toks = out.token_ids
    follows = sum(int(src.perm[a]) == b for a, b in zip(toks, toks[1:]))
    print(f"{follows}/{len(toks) - 1} transitions follow the learned chain")


if __name__ == "__main__":
    main()
