"""Long-context decode with an attention-free arch (rwkv6 reduced):
O(1) recurrent state instead of a KV cache — decode cost is flat in
context length (the long_500k assignment cell at toy scale).

    PYTHONPATH=src python examples/longcontext_rwkv.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import EngineConfig, get_config
from repro.core.engine import KVNANDEngine
from repro.models.registry import Model
from repro.models.transformer import Runtime


def main():
    cfg = get_config("rwkv6-3b").reduced()
    rt = Runtime()
    model = Model(cfg, rt)
    params = model.init(jax.random.PRNGKey(0))
    engine = KVNANDEngine(cfg, EngineConfig(), rt)

    prompt = jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0,
                                cfg.vocab_size, jnp.int32)
    _, cache = engine.prefill(params, {"tokens": prompt}, 128)
    state_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree.leaves(cache))
    print(f"recurrent state: {state_bytes / 1024:.1f} KB "
          f"(CONSTANT in context length — no KV cache)")

    step = jax.jit(lambda p, c, t: engine.decode_step(p, c, t))
    tok = prompt[:, -1:]
    # decode cost at context 100 vs context 1100 is identical
    times = []
    for phase in range(2):
        logits, cache = step(params, cache, tok)   # warm
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        for _ in range(20):
            logits, cache = step(params, cache, tok)
        jax.block_until_ready(logits)
        times.append((time.perf_counter() - t0) / 20)
        if phase == 0:   # fast-forward the cursor by 1000 positions
            import dataclasses
            cache = dataclasses.replace(cache,
                                        lengths=cache.lengths + 1000)
    print(f"ms/token @ ctx~100: {times[0]*1e3:.2f}  "
          f"@ ctx~1100: {times[1]*1e3:.2f}  (flat = O(1) state)")
    assert times[1] < times[0] * 1.5
    print("longcontext_rwkv example complete")


if __name__ == "__main__":
    main()
