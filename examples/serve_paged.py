"""Serving example: mixed per-request sampling over the shared-pool
paged engine, streamed token by token through the `KVNANDServer` facade.

    PYTHONPATH=src python examples/serve_paged.py
"""
import numpy as np

from repro.configs import EngineConfig
from repro.serving.api import KVNANDServer, SamplingParams, ServerConfig


def main():
    server = KVNANDServer(ServerConfig(
        arch="qwen1.5-0.5b", reduced=True,
        engine=EngineConfig(page_tokens=16, uniform_lengths=False,
                            shared_pool=True),
        batch_slots=3, max_context=128, prefill_chunk_tokens=32))

    rng = np.random.default_rng(0)
    vocab = server.cfg.vocab_size
    sysp = rng.integers(1, vocab, 24).tolist()   # shared system prompt
    mixes = [SamplingParams(max_new_tokens=12),                  # greedy
             SamplingParams(max_new_tokens=12, temperature=0.8,
                            top_p=0.9, seed=7),                  # nucleus
             SamplingParams(max_new_tokens=12, temperature=1.2,
                            top_k=40, seed=11, logprobs=True)]   # top-k
    for i in range(6):
        tail = rng.integers(1, vocab, int(rng.integers(3, 10))).tolist()
        server.submit(sysp + tail, mixes[i % len(mixes)])

    streamed = {}
    for ev in server.stream():                   # tokens as they land
        streamed.setdefault(ev.uid, []).append(ev.token)

    outs = server.outputs()
    assert len(outs) == 6
    for o in outs:
        assert streamed[o.uid] == o.token_ids    # stream == final output
        print(f"req {o.uid}: {len(o.token_ids)} tokens "
              f"({o.finish_reason}, ttft {o.ttft * 1e3:.0f} ms) "
              f"-> {o.token_ids[:6]}...")
    st = server.stats
    print(f"prefix cache served {st['prefix_hit_pages']} of "
          f"{st['prompt_pages']} prompt pages; "
          f"{st['compiles']} compiles for 3 distinct sampling configs")
    print("serve_paged example complete")


if __name__ == "__main__":
    main()
