"""Serving example: batched requests with continuous batching over the
paged KVNAND engine, engine variant chosen by the Track-A DSE.

    PYTHONPATH=src python examples/serve_paged.py
"""
from repro.launch.serve import serve


def main():
    done = serve(["--arch", "qwen1.5-0.5b", "--reduced",
                  "--requests", "6", "--max-new", "12", "--slots", "3",
                  "--max-context", "128", "--temperature", "0.8"])
    assert len(done) == 6
    print("serve_paged example complete")


if __name__ == "__main__":
    main()
