"""Multi-replica serving example: a `ReplicaRouter` spreading requests
over a fleet of `KVNANDServer` replicas, then the same fleet running
disaggregated — prefill on replica 0, KV pages migrated as `KVEnvelope`
wire bytes into a decode replica, token-identical to a single server.

    PYTHONPATH=src python examples/serve_replicas.py
"""
import jax
import numpy as np

from repro.configs import EngineConfig, get_config
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.serving.api import KVNANDServer, SamplingParams, ServerConfig
from repro.serving.router import ReplicaRouter


def _fleet(n, cfg, params, rt):
    eng = EngineConfig(page_tokens=16, uniform_lengths=False,
                       shared_pool=True, total_pages=48)
    sc = ServerConfig(arch="qwen1.5-0.5b", reduced=True, engine=eng,
                      batch_slots=2, max_context=64,
                      prefill_chunk_tokens=16, seed=7)
    return [KVNANDServer(sc, cfg=cfg, params=params, rt=rt)
            for _ in range(n)]


def main():
    # one set of weights, shared by every replica (a real fleet would
    # device_put per accelerator — see replica.build_replica)
    cfg = get_config("qwen1.5-0.5b").reduced()
    rt = Runtime()
    params = Model(cfg, rt).init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    sysp = rng.integers(1, cfg.vocab_size, 20).tolist()
    prompts = [sysp + rng.integers(1, cfg.vocab_size,
                                   int(rng.integers(2, 8))).tolist()
               for _ in range(6)]
    sp = SamplingParams(max_new_tokens=6, temperature=0.8, seed=3)

    # --- routed mode: least-loaded spread + cross-replica prefix index
    router = ReplicaRouter(_fleet(3, cfg, params, rt))
    uids = [router.submit(p, sp) for p in prompts]
    router.run()
    homes = [router.replica_of(u) for u in uids]
    assert len(set(homes)) >= 2, "fleet never spread"
    print(f"routed: {len(uids)} requests over replicas {sorted(set(homes))}, "
          f"{router.stats['prefix_published_pages']} prefix pages published "
          f"to the cross-replica index")

    # --- disaggregated mode: prefill on replica 0, decode elsewhere
    fleet = _fleet(3, cfg, params, rt)
    disagg = ReplicaRouter(fleet, disaggregate=True)
    solo = _fleet(1, cfg, params, rt)[0]
    for i, p in enumerate(prompts):
        disagg.submit(p, sp, uid=i)
        solo.submit(p, sp, uid=i)
    disagg.run()
    solo.run()
    for i in range(len(prompts)):
        assert disagg.output(i).token_ids == solo.output(i).token_ids, \
            f"migrated request {i} diverged from single-server run"
    mig = disagg.stats
    print(f"disaggregated: {mig['migrations']} migrations, "
          f"{mig['migration_bytes'] // mig['migrations']} wire bytes each, "
          f"outputs token-identical to one server")
    print("serve_replicas example complete")


if __name__ == "__main__":
    main()
