"""End-to-end training driver example: a ~100M-class model with
checkpoint/restart, microbatching, remat, straggler monitoring.

Default flags are sized for this CPU container (~20M params, 60 steps,
a few minutes).  The full ~100M/300-step run is the same command with
--full (hours on CPU; minutes on a real accelerator):

    PYTHONPATH=src python examples/train_lm.py [--full] [--resume]

Kill it mid-run and re-invoke: it restores the newest checkpoint and the
exact data cursor (tests/test_multidevice.py covers elastic restore).
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="~100M params × 300 steps (accelerator-scale)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args, extra = ap.parse_known_args()

    if args.full:
        argv = ["--arch", "qwen1.5-0.5b",  # 463M as-configured ≈ 100M-class
                "--steps", "300", "--seq-len", "512",
                "--global-batch", "16", "--microbatches", "2",
                "--remat", "block", "--lr", "1e-3",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "25"]
    else:
        argv = ["--arch", "qwen1.5-0.5b", "--reduced",
                "--steps", "60", "--seq-len", "128",
                "--global-batch", "8", "--microbatches", "2",
                "--remat", "block", "--lr", "3e-3",
                "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "20"]
    losses = train(argv + extra)
    assert losses[-1] < losses[0], "training did not reduce the loss"
    print("train_lm example complete")


if __name__ == "__main__":
    main()
