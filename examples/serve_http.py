"""Async HTTP serving example: the OpenAI-style front door
(`repro.serving.async_server`, DESIGN.md §14) end-to-end over a real
socket with nothing but the standard library on the client side —
one-shot and SSE-streamed `POST /v1/completions`, a saturated queue
answering 429, and a live Prometheus `/metrics` scrape.

    PYTHONPATH=src python examples/serve_http.py
"""
import http.client
import json

from repro.configs import EngineConfig
from repro.serving.api import ServerConfig
from repro.serving.async_server import AsyncServerConfig, BackgroundServer

PROMPT = list(range(1, 14))


def post(addr, payload):
    conn = http.client.HTTPConnection(*addr, timeout=120)
    try:
        conn.request("POST", "/v1/completions", json.dumps(payload),
                     {"Content-Type": "application/json"})
        r = conn.getresponse()
        return r.status, r.read()
    finally:
        conn.close()


def main():
    config = ServerConfig(
        arch="qwen1.5-0.5b", reduced=True,
        engine=EngineConfig(page_tokens=16, uniform_lengths=False,
                            shared_pool=True),
        batch_slots=2, max_context=96, prefill_chunk_tokens=16)
    with BackgroundServer(config,
                          AsyncServerConfig(max_queue=8)) as srv:
        host, port = srv.address
        print(f"serving on http://{host}:{port} (overlap on)")

        # one-shot completion
        status, body = post(srv.address, {"prompt": PROMPT,
                                          "max_tokens": 8, "seed": 3})
        assert status == 200, status
        choice = json.loads(body)["choices"][0]
        print(f"one-shot: {len(choice['token_ids'])} tokens "
              f"({choice['finish_reason']}) -> {choice['token_ids']}")

        # the same request streamed over SSE: frames concatenate to the
        # one-shot answer (per-request determinism via the seed)
        conn = http.client.HTTPConnection(host, port, timeout=120)
        try:
            conn.request("POST", "/v1/completions",
                         json.dumps({"prompt": PROMPT, "max_tokens": 8,
                                     "seed": 3, "stream": True}),
                         {"Content-Type": "application/json"})
            resp = conn.getresponse()
            assert resp.status == 200
            assert resp.getheader("Content-Type") == "text/event-stream"
            frames = [f for f in resp.read().decode().split("\n\n")
                      if f.startswith("data: ")]
        finally:
            conn.close()
        assert frames[-1] == "data: [DONE]"
        streamed = [json.loads(f[len("data: "):])["choices"][0]["token"]
                    for f in frames[:-1]]
        assert streamed == choice["token_ids"], (streamed, choice)
        print(f"SSE stream: {len(frames) - 1} frames + [DONE], "
              "tokens match the one-shot answer")

    # saturation: with no queue at all, excess load answers 429 with
    # Retry-After instead of queuing unboundedly
    with BackgroundServer(config,
                          AsyncServerConfig(max_queue=0)) as srv:
        status, body = post(srv.address, {"prompt": PROMPT,
                                          "max_tokens": 4})
        assert status == 429, status
        print(f"saturated queue -> HTTP {status} ({body.decode().strip()})")

        status, metrics = get(srv.address, "/metrics")
        assert status == 200
        text = metrics.decode()
        for name in ("kvnand_ttft_seconds", "kvnand_rejected_total",
                     "kvnand_pool_util", "kvnand_device_idle_fraction"):
            assert name in text, name
        rejected = [line for line in text.splitlines()
                    if line.startswith("kvnand_rejected_total")]
        print(f"/metrics live: {rejected[0]}")
    print("serve_http example complete")


def get(addr, path):
    conn = http.client.HTTPConnection(*addr, timeout=30)
    try:
        conn.request("GET", path)
        r = conn.getresponse()
        return r.status, r.read()
    finally:
        conn.close()


if __name__ == "__main__":
    main()
