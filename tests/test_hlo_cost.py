"""HLO cost analyzer: trip-count multiplication, dot flops, collectives."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_cost import analyze_text


def _flops(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    return analyze_text(c.as_text()).flops


def test_scan_equals_unroll_flops():
    D = 128
    w = jax.ShapeDtypeStruct((8, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((4, D), jnp.float32)

    def f_scan(w, x):
        def body(c, wl):
            return jnp.tanh(c @ wl), None
        y, _ = jax.lax.scan(body, x, w)
        return y

    def f_unroll(w, x):
        y = x
        for i in range(8):
            y = jnp.tanh(y @ w[i])
        return y

    expected = 8 * 2 * 4 * D * D
    assert abs(_flops(f_scan, w, x) - expected) / expected < 0.01
    assert abs(_flops(f_unroll, w, x) - expected) / expected < 0.01


def test_nested_scan_multiplies():
    D = 64
    w = jax.ShapeDtypeStruct((4, 3, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((2, D), jnp.float32)

    def f(w, x):
        def outer(c, wo):
            def inner(ci, wi):
                return jnp.tanh(ci @ wi), None
            c2, _ = jax.lax.scan(inner, c, wo)
            return c2, None
        y, _ = jax.lax.scan(outer, x, w)
        return y

    expected = 12 * 2 * 2 * D * D
    assert abs(_flops(f, w, x) - expected) / expected < 0.01


def test_fusible_hint_separates_score_traffic():
    S, dh = 64, 32

    def attn(q, k):
        s = q @ k.T                    # [S, S] score matrix
        return jax.nn.softmax(s, -1)

    q = jax.ShapeDtypeStruct((S, dh), jnp.float32)
    k = jax.ShapeDtypeStruct((S, dh), jnp.float32)
    c = jax.jit(attn).lower(q, k).compile()
    plain = analyze_text(c.as_text())
    hinted = analyze_text(c.as_text(), frozenset({(S, S)}))
    assert hinted.fusible_bytes > 0
    assert hinted.bytes_accessed < plain.bytes_accessed
    assert abs((hinted.bytes_accessed + hinted.fusible_bytes)
               - (plain.bytes_accessed + plain.fusible_bytes)) < 1.0


def test_bytes_scale_with_trip_count():
    D = 128

    def f(w, x):
        def body(c, wl):
            return jnp.tanh(c @ wl), None
        return jax.lax.scan(body, x, w)[0]

    b8 = analyze_text(jax.jit(f).lower(
        jax.ShapeDtypeStruct((8, D, D), jnp.float32),
        jax.ShapeDtypeStruct((4, D), jnp.float32)).compile().as_text())
    b16 = analyze_text(jax.jit(f).lower(
        jax.ShapeDtypeStruct((16, D, D), jnp.float32),
        jax.ShapeDtypeStruct((4, D), jnp.float32)).compile().as_text())
    assert 1.5 < b16.bytes_accessed / b8.bytes_accessed < 2.5
