"""Quantized KV pages (kv8/kv4): pack/unpack round trips, paged-attention
parity vs the bf16 reference (ref + Pallas interpret), scale round-trip
through the decode append paths, and engine-level prefill+decode fidelity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import EngineConfig, get_config
from repro.core import paged_kv
from repro.core.engine import KVNANDEngine
from repro.core.quant import (dequantize_kv_page, kv_page_tokens_stored,
                              kv_quant_bits, pack_int4_tokens,
                              quantize_kv_page, unpack_int4_tokens)
from repro.kernels.paged_attention import paged_attention_partial

# output-tolerance per format vs the bf16 pool on unit-normal data
TOL = {"kv8": 0.05, "kv4": 0.5}


def _build(B, K, NP, T, dh, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    kd = jax.random.normal(ks[0], (B, NP * T, K, dh), jnp.float32)
    vd = jax.random.normal(ks[1], (B, NP * T, K, dh), jnp.float32)
    k_pages = kd.reshape(B, NP, T, K, dh).transpose(0, 3, 1, 2, 4)
    v_pages = vd.reshape(B, NP, T, K, dh).transpose(0, 3, 1, 2, 4)
    base = jnp.broadcast_to((jnp.arange(NP) * T)[None], (B, NP)
                            ).astype(jnp.int32)
    q = jax.random.normal(ks[2], (B, K, dh), jnp.float32)
    return k_pages, v_pages, base


# ---------------------------------------------------------------------------
# format primitives
# ---------------------------------------------------------------------------

def test_int4_token_pack_roundtrip():
    q = jax.random.randint(jax.random.PRNGKey(0), (3, 2, 16, 8), 0, 16
                           ).astype(jnp.int8)
    packed = pack_int4_tokens(q)
    assert packed.shape == (3, 2, 8, 8) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(unpack_int4_tokens(packed)),
                                  np.asarray(q) - 8)


@pytest.mark.parametrize("fmt,rel", [("kv8", 1 / 127), ("kv4", 1 / 7)])
def test_page_quant_roundtrip_error_bound(fmt, rel):
    """|x - deq(quant(x))| ≤ scale/2 per element, scale = amax/qmax."""
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 4, 16, 32))
    q, s = quantize_kv_page(x, fmt)
    assert s.shape == (2, 3, 4)
    back = dequantize_kv_page(q, s, fmt)
    amax = jnp.max(jnp.abs(x), axis=(-2, -1))
    bound = (amax * rel / 2 + 1e-6)[..., None, None]
    assert bool(jnp.all(jnp.abs(back - x) <= bound))


def test_storage_geometry():
    assert kv_quant_bits("none") == 16
    assert kv_quant_bits("kv8") == 8
    assert kv_quant_bits("kv4") == 4
    assert kv_page_tokens_stored(64, "kv4") == 32
    assert kv_page_tokens_stored(64, "kv8") == 64
    with pytest.raises(ValueError):
        kv_page_tokens_stored(9, "kv4")
    with pytest.raises(ValueError):
        EngineConfig(kv_quant="kv4", page_tokens=9)
    with pytest.raises(ValueError):
        EngineConfig(kv_quant="int3")


# ---------------------------------------------------------------------------
# paged-attention parity
# ---------------------------------------------------------------------------

SWEEP = [
    # B, K, G, NP, T, dh, lengths, window
    (2, 3, 4, 8, 16, 32, (100, 37), None),
    (2, 3, 4, 8, 16, 32, (100, 37), 24),
    (1, 2, 8, 16, 8, 16, (128,), None),
    (2, 4, 2, 8, 32, 64, (200, 256), None),
]


@pytest.mark.parametrize("case", SWEEP)
@pytest.mark.parametrize("fmt", ["kv8", "kv4"])
def test_quant_parity_vs_bf16_ref(case, fmt):
    """Quantized attention ≈ bf16-pool attention (tolerance-gated), and the
    Pallas-interpret kernel matches the quantized jnp ref bit-tightly."""
    B, K, G, NP, T, dh, lengths, window = case
    kp, vp, base = _build(B, K, NP, T, dh)
    q = jax.random.normal(jax.random.PRNGKey(9), (B, K * G, dh))
    length = jnp.asarray(lengths, jnp.int32)

    o_ref, m_ref, l_ref = paged_attention_partial(
        q, kp, vp, base, length, window=window, impl="ref")

    qk, sk = quantize_kv_page(kp, fmt)
    qv, sv = quantize_kv_page(vp, fmt)
    o_q, m_q, l_q = paged_attention_partial(
        q, qk, qv, base, length, window=window, impl="ref",
        kv_quant=fmt, k_scale=sk, v_scale=sv)
    assert float(jnp.abs(o_q - o_ref).max()) < TOL[fmt]

    o_i, m_i, l_i = paged_attention_partial(
        q, qk, qv, base, length, window=window, impl="interpret",
        kv_quant=fmt, k_scale=sk, v_scale=sv, pages_per_block=4)
    np.testing.assert_allclose(np.asarray(o_i), np.asarray(o_q),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(m_i), np.asarray(m_q),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(l_i), np.asarray(l_q),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("fmt", ["kv8", "kv4"])
def test_quant_partial_stats_merge(fmt):
    """Cross-shard (m, ℓ) merge is format-agnostic: splitting a quantized
    pool across two 'devices' reproduces the unsplit result."""
    from repro.core.seqpar import merge_two
    B, K, G, NP, T, dh = 1, 2, 2, 8, 8, 32
    kp, vp, base = _build(B, K, NP, T, dh)
    qk, sk = quantize_kv_page(kp, fmt)
    qv, sv = quantize_kv_page(vp, fmt)
    q = jax.random.normal(jax.random.PRNGKey(5), (B, K * G, dh))
    length = jnp.asarray([60], jnp.int32)
    o_full, _, _ = paged_attention_partial(
        q, qk, qv, base, length, kv_quant=fmt, k_scale=sk, v_scale=sv,
        impl="ref")
    half = NP // 2
    parts = []
    for sl in (slice(None, half), slice(half, None)):
        parts.append(paged_attention_partial(
            q, qk[:, :, sl], qv[:, :, sl], base[:, sl], length,
            kv_quant=fmt, k_scale=sk[:, :, sl], v_scale=sv[:, :, sl],
            impl="ref"))
    o, _, _ = merge_two(*parts[0], *parts[1])
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_full),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# scale round-trip through the append paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["kv8", "kv4"])
@pytest.mark.parametrize("uniform", [True, False])
def test_append_requantizes_only_touched_page(fmt, uniform):
    L, B, K, NP, T, dh = 2, 2, 3, 4, 8, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (L, B, K, NP, T, dh))
    pool, scale = quantize_kv_page(x, fmt)
    layer = jnp.asarray(1, jnp.int32)
    lengths = (jnp.asarray([12, 12], jnp.int32) if uniform
               else jnp.asarray([12, 19], jnp.int32))
    phys, slot = lengths // T, lengths % T
    val = jax.random.normal(jax.random.PRNGKey(1), (B, K, dh))
    fn = (paged_kv.append_token_quant_uniform if uniform
          else paged_kv.append_token_quant)
    pool2, scale2 = jax.jit(fn, static_argnames=("fmt",))(
        pool, scale, layer, phys, slot, val, fmt=fmt)

    deq = dequantize_kv_page(pool2, scale2, fmt)
    rel = {"kv8": 1 / 127, "kv4": 1 / 7}[fmt]
    for b in range(B):
        p, sl = int(phys[b]), int(slot[b])
        # the new token reads back within one quantization step
        amax = float(jnp.abs(deq[1, b, :, p]).max())
        err = float(jnp.abs(deq[1, b, :, p, sl] - val[b]).max())
        assert err <= amax * rel / 2 + 1e-5, (b, err)
        # untouched pages: codes AND scales bit-identical
        for pp in range(NP):
            if pp == p:
                continue
            np.testing.assert_array_equal(np.asarray(pool2[1, b, :, pp]),
                                          np.asarray(pool[1, b, :, pp]))
            np.testing.assert_array_equal(np.asarray(scale2[1, b, :, pp]),
                                          np.asarray(scale[1, b, :, pp]))
    # other layers fully untouched
    np.testing.assert_array_equal(np.asarray(pool2[0]), np.asarray(pool[0]))
    np.testing.assert_array_equal(np.asarray(scale2[0]),
                                  np.asarray(scale[0]))


@pytest.mark.parametrize("fmt", ["kv8", "kv4"])
@pytest.mark.parametrize("uniform", [True, False])
def test_append_ignores_stale_page_garbage(fmt, uniform):
    """A recycled page holding a previous occupant's 50×-larger K/V must
    not inflate the new scale: dead slots (> slot) are zeroed before
    requantization, so the real token keeps full format precision."""
    L, B, K, NP, T, dh = 1, 2, 2, 2, 8, 8
    stale = 50.0 * jax.random.normal(jax.random.PRNGKey(0),
                                     (L, B, K, NP, T, dh))
    pool, scale = quantize_kv_page(stale, fmt)
    layer = jnp.asarray(0, jnp.int32)
    lengths = jnp.asarray([0, 0], jnp.int32)   # fresh sequence, slot 0
    phys, slot = lengths // T, lengths % T
    val = jax.random.normal(jax.random.PRNGKey(1), (B, K, dh))  # O(1) data
    fn = (paged_kv.append_token_quant_uniform if uniform
          else paged_kv.append_token_quant)
    pool2, scale2 = fn(pool, scale, layer, phys, slot, val, fmt)
    deq = dequantize_kv_page(pool2, scale2, fmt)
    rel = {"kv8": 1 / 127, "kv4": 1 / 7}[fmt]
    err = float(jnp.abs(deq[0, :, :, 0, 0] - val).max())
    amax = float(jnp.abs(val).max())
    # the touched page's scale reflects the NEW token only, not the 50×
    # stale occupant (untouched pages keep their stale scale by design)
    assert err <= amax * rel / 2 + 1e-5, err
    assert float(scale2[0, :, :, 0].max()) < \
        float(scale[0, :, :, 0].max()) / 10


def test_dse_kv_format_fidelity_guard():
    """recommend_engine_config only drops KV bits when it buys real
    latency: short context (weight-bound) keeps full-width KV, long
    context (KV-bound) picks a low-bit page format."""
    from repro.core import dse
    short = dse.recommend_engine_config("llama3.1-70b", 128)
    long = dse.recommend_engine_config("llama3.1-70b", 100_000)
    assert short.kv_quant == "none", short
    assert long.kv_quant in ("kv8", "kv4"), long


@pytest.mark.parametrize("fmt", ["kv8", "kv4"])
def test_prefill_fill_quant_roundtrip(fmt):
    B, S, K, dh, T, NP, L = 2, 50, 3, 8, 16, 8, 4
    kv = jax.random.normal(jax.random.PRNGKey(0), (B, S, K, dh))
    Ts = kv_page_tokens_stored(T, fmt)
    pool = jnp.zeros((L, B, K, NP, Ts, dh),
                     jnp.int8 if fmt == "kv8" else jnp.uint8)
    scale = jnp.zeros((L, B, K, NP), jnp.float32)
    pool, scale = paged_kv.fill_prefill_at_quant(pool, scale, kv,
                                                 jnp.asarray(2), fmt)
    deq = dequantize_kv_page(pool[2], scale[2], fmt)     # [B, K, NP, T, dh]
    dense = deq.transpose(0, 2, 3, 1, 4).reshape(B, NP * T, K, dh)[:, :S]
    tol = {"kv8": 0.02, "kv4": 0.35}[fmt]
    assert float(jnp.abs(dense - kv).max()) < tol
    # other layers untouched (still the all-zero init codes)
    assert float(jnp.abs(pool[1].astype(jnp.float32)).max()) == 0.0
    assert float(jnp.abs(scale[1]).max()) == 0.0


# ---------------------------------------------------------------------------
# engine-level fidelity (prefill + decode, both pools, both variants)
# ---------------------------------------------------------------------------

def _golden_err(arch, variant, fmt, n_decode=3, S=21, T=8):
    from repro.models.registry import Model
    from repro.models.transformer import Runtime
    cfg = get_config(arch).reduced()
    cap = (cfg.n_experts / cfg.top_k) if cfg.is_moe else 1.25
    rt = Runtime(moe_capacity=cap)
    m = Model(cfg, rt)
    params = m.init(jax.random.PRNGKey(0))
    eng = KVNANDEngine(cfg, EngineConfig(variant=variant, page_tokens=T,
                                         kv_quant=fmt, kv_dtype="float32"),
                       rt)
    B = 2
    toks = jax.random.randint(jax.random.PRNGKey(42), (B, S + n_decode), 0,
                              cfg.vocab_size, jnp.int32)
    logits_full, _ = m.forward(params, {"tokens": toks})
    lg, cache = eng.prefill(params, {"tokens": toks[:, :S]},
                            max_context=S + n_decode + 2)
    errs = [float(jnp.abs(lg - logits_full[:, S - 1]).max())]
    for t in range(n_decode):
        lg, cache = eng.decode_step(params, cache,
                                    toks[:, S + t:S + t + 1])
        errs.append(float(jnp.abs(lg - logits_full[:, S + t]).max()))
    return max(errs) / float(jnp.abs(logits_full).max())


@pytest.mark.parametrize("fmt", ["kv8", "kv4"])
@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "gemma3-12b"])
def test_engine_decode_quant_close_to_forward(arch, fmt):
    """Quantized decode (global + window pools) tracks the full forward
    within format tolerance; scales survive append across pages."""
    assert _golden_err(arch, "compact", fmt) < TOL[fmt]


def test_engine_decode_quant_discrete_matches_compact():
    """Head-group slicing of pools AND scales: discrete == compact."""
    e_c = _golden_err("qwen1.5-0.5b", "compact", "kv8")
    e_d = _golden_err("qwen1.5-0.5b", "discrete", "kv8")
    assert abs(e_c - e_d) < 1e-6


def test_cache_spec_quant_leaves():
    cfg = get_config("gemma3-12b").reduced()
    spec = paged_kv.cache_spec(cfg, EngineConfig(page_tokens=16,
                                                 kv_quant="kv4"), 2, 128)
    assert spec["k_pages_g"][1] == jnp.uint8
    assert spec["k_pages_g"][0][4] == 8                   # packed token dim
    assert spec["k_scale_g"][0] == spec["k_pages_g"][0][:4]
    assert spec["k_scale_w"][1] == jnp.float32
    # bf16 default untouched
    spec0 = paged_kv.cache_spec(cfg, EngineConfig(page_tokens=16), 2, 128)
    assert "k_scale_g" not in spec0
    assert spec0["k_pages_g"][0][4] == 16
