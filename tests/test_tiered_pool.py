"""Tiered flash KV hierarchy (DESIGN.md §13): hot/capacity page tiers.

A two-wave trace (drain a set of shared-prefix prompts, then re-submit
the same prompts after their cache pages were demoted) must produce
token output bit-identical to the single-tier shared pool — with the
prefetcher on AND off — while actually exercising demotion, demand
promotion, and the prefetch path.  Plus the admission guards: a prompt
whose pinned footprint cannot fit the hot tier is rejected at submit,
and one-shot engine prefill refuses tiered pools outright.
"""
import jax
import numpy as np
import pytest

from repro.configs import EngineConfig, get_config
from repro.core.engine import KVNANDEngine
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.serving.scheduler import ContinuousBatcher, Request

N_UNIQ = 6
TOTAL_PAGES = 64
HOT_PAGES = 12


def _model(arch="qwen1.5-0.5b"):
    cfg = get_config(arch).reduced()
    rt = Runtime()
    return cfg, rt, Model(cfg, rt).init(jax.random.PRNGKey(0))


def _trace(vocab):
    """Shared 32-token system prompt + unique tails: pages out to more
    flash pages than HOT_PAGES, so wave 2 re-maps demoted pages."""
    rng = np.random.default_rng(23)
    sysp = rng.integers(1, vocab, 32).tolist()
    return [sysp + rng.integers(1, vocab, 9).tolist()
            for _ in range(N_UNIQ)]


def _eng(hot_pages=0):
    return EngineConfig(page_tokens=16, uniform_lengths=False,
                        shared_pool=True, total_pages=TOTAL_PAGES,
                        hot_pages=hot_pages)


def _drain_two_wave(cfg, params, eng, prompts, *, prefetch=True,
                    max_new=8):
    """One batcher, two submission waves of the SAME prompts: wave 1
    populates the prefix cache, its pages demote under slot pressure,
    wave 2's cached map-ins promote them back."""
    b = ContinuousBatcher(cfg, params, batch_slots=3, max_context=64,
                          temperature=0.0, eng=eng,
                          prefill_chunk_tokens=16,
                          tier_prefetch=prefetch)
    outs = {}
    for wave in range(2):
        for i, p in enumerate(prompts):
            b.submit(Request(wave * len(prompts) + i, list(p),
                             max_new=max_new))
        done = b.run_to_completion()
        outs.update({u: r.output for u, r in done.items()})
    return outs, b


# ---------------------------------------------------------------------------
# token parity with the single-tier pool, demotion actually exercised
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("prefetch", [True, False],
                         ids=["prefetch", "noprefetch"])
def test_tiered_matches_flat_pool(prefetch):
    cfg, rt, params = _model()
    prompts = _trace(cfg.vocab_size)
    o_flat, _ = _drain_two_wave(cfg, params, _eng(), prompts)
    o_tier, b = _drain_two_wave(cfg, params, _eng(HOT_PAGES), prompts,
                                prefetch=prefetch)
    assert o_tier == o_flat
    st = b.stats
    assert st["tier_demotes"] > 0, "trace never pressured the hot tier"
    assert st["tier_promotes"] > 0
    assert st["tier_hit_pages"] + st["tier_miss_pages"] > 0
    b.alloc.check()
    b.tier.check()
    # at drain no slot maps pages: every resident must be demotable
    assert b.tier.pinned_count == 0
    assert b.tier.resident_count <= HOT_PAGES


def test_prefetch_reduces_stall_tokens():
    """Identical outputs, strictly fewer demand faults with the
    queue-ahead prefetch stage enabled."""
    cfg, rt, params = _model()
    prompts = _trace(cfg.vocab_size)
    o_on, b_on = _drain_two_wave(cfg, params, _eng(HOT_PAGES), prompts)
    o_off, b_off = _drain_two_wave(cfg, params, _eng(HOT_PAGES), prompts,
                                   prefetch=False)
    assert o_on == o_off
    on, off = b_on.stats, b_off.stats
    assert on["tier_prefetch_pages"] > 0
    assert on["tier_stall_tokens"] < off["tier_stall_tokens"]
    assert off["tier_prefetch_pages"] == 0


def test_tiered_per_request_stats_through_server():
    """RequestOutput carries per-request hot-tier hit/stall counts."""
    from repro.serving.api import (KVNANDServer, SamplingParams,
                                   ServerConfig)
    cfg, rt, params = _model()
    prompts = _trace(cfg.vocab_size)
    server = KVNANDServer(
        ServerConfig(scheduler="interleaved", engine=_eng(HOT_PAGES),
                     batch_slots=3, max_context=64,
                     prefill_chunk_tokens=16),
        cfg=cfg, params=params)
    sp = SamplingParams(max_new_tokens=4)
    totals = [0, 0]
    for _ in range(2):
        uids = [server.submit(p, sp) for p in prompts]
        server.run()
        for u in uids:
            o = server.output(u)
            assert o.tier_hit_pages >= 0 and o.tier_stall_tokens >= 0
            totals[0] += o.tier_hit_pages
            totals[1] += o.tier_stall_tokens
            server.release(u)
    st = server.stats
    assert totals[0] == st["tier_hit_pages"]
    assert totals[1] == st["tier_stall_tokens"]
    assert st["tier_hit_pages"] + st["tier_miss_pages"] > 0


# ---------------------------------------------------------------------------
# admission guards
# ---------------------------------------------------------------------------

def test_submit_rejects_footprint_over_hot_tier():
    """A request whose pinned pages can never fit the hot tier must be
    rejected at submit, not deadlock in the admit loop."""
    cfg, rt, params = _model()
    b = ContinuousBatcher(cfg, params, batch_slots=1, max_context=128,
                          temperature=0.0, eng=_eng(2),
                          prefill_chunk_tokens=16)
    with pytest.raises(ValueError, match="hot tier"):
        b.submit(Request(0, list(range(1, 100)), max_new=4))


def test_oneshot_prefill_refuses_tiered_pool():
    cfg, rt, params = _model()
    engine = KVNANDEngine(cfg, _eng(HOT_PAGES), rt)
    toks = np.arange(1, 22, dtype=np.int32)[None, :]
    with pytest.raises(ValueError, match="TIERED"):
        engine.prefill(params, {"tokens": toks}, 64)
