"""Property test: PageAllocator against a reference-counting model.

Random op sequences — alloc, share (ref), free, COW-fork, abort (free a
whole request's references at once), and MIGRATE-IMPORT (drop a
request's references on allocator A, re-allocate its footprint on
allocator B, the refcount shape of `replica.import_request` +
`finish_migrated`) — must keep the real allocator bit-identical to a
trivial model: same refcounts, same live/free partition, no leak, no
double-free, conservation after every abort.  Runs under
`tests/_hypothesis_compat` (seeded sweeps when hypothesis is absent).
"""
import random

import pytest

from _hypothesis_compat import given, settings, st
from repro.core.page_alloc import OutOfPages, PageAllocator

TOTAL = 16
OPS = ("alloc", "share", "free_one", "cow_fork", "abort",
       "migrate", "check")


class ModelAlloc:
    """The obviously-correct model: a refcount dict, nothing else."""

    def __init__(self, total):
        self.total = total
        self.ref = {}

    def alloc(self):
        if len(self.ref) == self.total:
            raise OutOfPages("model full")
        return None         # page identity is the real allocator's call

    def bind(self, page):
        assert page not in self.ref
        self.ref[page] = 1

    def share(self, page):
        assert self.ref.get(page, 0) > 0
        self.ref[page] += 1

    def free(self, page):
        assert self.ref.get(page, 0) > 0
        self.ref[page] -= 1
        if self.ref[page] == 0:
            del self.ref[page]


def _assert_same(real: PageAllocator, model: ModelAlloc):
    real.check()
    live = {p for p in range(real.total) if real.refcount[p] > 0}
    assert live == set(model.ref), (live, set(model.ref))
    for p in model.ref:
        assert int(real.refcount[p]) == model.ref[p], \
            (p, int(real.refcount[p]), model.ref[p])
    assert real.free_count == real.total - len(model.ref)


def _run_trace(seed, n_ops):
    rng = random.Random(seed)
    pools = [(PageAllocator(TOTAL), ModelAlloc(TOTAL)),
             (PageAllocator(TOTAL), ModelAlloc(TOTAL))]
    # requests: (pool_idx, [page refs]) — one list entry per reference
    requests = []
    for _ in range(n_ops):
        op = rng.choice(OPS)
        side = rng.randrange(2)
        real, model = pools[side]
        if op == "alloc":
            k = rng.randint(1, 4)
            if real.free_count < k:
                with pytest.raises(OutOfPages):
                    for _ in range(real.free_count + 1):
                        real.alloc()
                # un-do the partial allocs of the overflow probe
                freed = [p for p in range(real.total)
                         if real.refcount[p] > 0
                         and model.ref.get(p, 0) == 0]
                real.free(freed)
            else:
                pages = [real.alloc_for_logical(j) for j in range(k)]
                for p in pages:
                    model.bind(p)
                requests.append((side, pages))
        elif op == "share" and requests:
            side2, pages = rng.choice(requests)
            real2, model2 = pools[side2]
            p = rng.choice(pages)
            real2.share([p])
            model2.share(p)
            requests.append((side2, [p]))
        elif op == "free_one" and requests:
            idx = rng.randrange(len(requests))
            side2, pages = requests[idx]
            real2, model2 = pools[side2]
            p = pages.pop(rng.randrange(len(pages)))
            real2.free([p])
            model2.free(p)
            if not pages:
                requests.pop(idx)
        elif op == "cow_fork" and requests:
            # fork: share every page, then COW one shared page of the
            # fork (exclusive ownership moves to a fresh page)
            side2, pages = rng.choice(requests)
            real2, model2 = pools[side2]
            if real2.free_count == 0 or not pages:
                continue
            real2.share(pages)
            for p in pages:
                model2.share(p)
            fork = list(pages)
            j = rng.randrange(len(fork))
            fresh = real2.cow(fork[j])
            if fresh != fork[j]:
                model2.free(fork[j])
                model2.bind(fresh)
            fork[j] = fresh
            requests.append((side2, fork))
        elif op == "abort" and requests:
            idx = rng.randrange(len(requests))
            side2, pages = requests.pop(idx)
            real2, model2 = pools[side2]
            real2.free(pages)
            for p in pages:
                model2.free(p)
            _assert_same(real2, model2)     # conservation after abort
        elif op == "migrate" and requests:
            # import on the destination FIRST (it may refuse), release
            # the source only after — the router's ordering
            idx = rng.randrange(len(requests))
            src_side, pages = requests[idx]
            dst_side = 1 - src_side
            reald, modeld = pools[dst_side]
            if reald.free_count < len(pages):
                continue        # destination backpressure: retry later
            imported = [reald.alloc_for_logical(j)
                        for j in range(len(pages))]
            for p in imported:
                modeld.bind(p)
            reals, models = pools[src_side]
            reals.free(pages)
            for p in pages:
                models.free(p)
            requests[idx] = (dst_side, imported)
        elif op == "check":
            _assert_same(real, model)
    for side, (real, model) in enumerate(pools):
        for side2, pages in requests:
            if side2 == side:
                real.free(pages)
                for p in pages:
                    model.free(p)
        _assert_same(real, model)
        assert real.free_count == TOTAL, "leaked pages at drain"


@settings(max_examples=25)
@given(seed=st.integers(min_value=0, max_value=2**32 - 1),
       n_ops=st.integers(min_value=1, max_value=120))
def test_page_alloc_refcount_conservation(seed, n_ops):
    _run_trace(seed, n_ops)


def test_double_free_raises():
    a = PageAllocator(4)
    p = a.alloc()
    a.free([p])
    with pytest.raises(ValueError, match="double free"):
        a.free([p])


def test_share_dead_page_raises():
    a = PageAllocator(4)
    p = a.alloc()
    a.free([p])
    with pytest.raises(ValueError, match="dead page"):
        a.share([p])
