"""Overlapped host/device decode pipeline (DESIGN.md §14).

The pipelined schedule — dispatch step N+1 before collecting step N —
must be TOKEN-IDENTICAL to the synchronous loop: same per-request
fold_in PRNG streams, same emit order, same finish reasons, across
striped / shared / tiered pools, speculation on and off, and mixed
SamplingParams.  Plus the pipeline-specific hazards: phantom rows
(slots that finish or abort between dispatch and collect) are
discarded with shared-pool conservation intact, TTFT/TPOT timestamps
come from collect() (submit <= first <= finish in both modes), and
priority / deadline shape the admission order.
"""
import time

import jax
import pytest

from repro.configs import EngineConfig, get_config
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.serving.api import KVNANDServer, SamplingParams, ServerConfig

ARCH = "qwen1.5-0.5b"

_CACHE = {}


def _model():
    if "m" not in _CACHE:
        cfg = get_config(ARCH).reduced()
        _CACHE["m"] = (cfg, Model(cfg, Runtime()).init(jax.random.PRNGKey(0)))
    return _CACHE["m"]


POOLS = {
    "striped": dict(),
    "shared": dict(shared_pool=True),
    "tiered": dict(shared_pool=True, total_pages=64, hot_pages=12),
}


def _server(pool="striped", *, overlap, spec_k=0, slots=2, ctx=96,
            chunk=16, **kw):
    cfg, params = _model()
    eng = EngineConfig(page_tokens=16, uniform_lengths=False, **POOLS[pool])
    return KVNANDServer(
        ServerConfig(engine=eng, batch_slots=slots, max_context=ctx,
                     prefill_chunk_tokens=chunk, overlap=overlap,
                     speculation_k=spec_k, **kw),
        cfg=cfg, params=params)


PROMPTS = [list(range(1, 8)), list(range(3, 24)), list(range(2, 13)),
           [5, 4, 3], list(range(4, 20))]

# mixed params: greedy, seeded-hot, top-k/p, stop tokens, logprobs
MIXED = [SamplingParams(max_new_tokens=6, logprobs=True),
         SamplingParams(max_new_tokens=8, temperature=0.9, seed=3),
         SamplingParams(max_new_tokens=7, temperature=1.2, top_k=5,
                        seed=9),
         SamplingParams(max_new_tokens=5, temperature=0.8, top_p=0.9,
                        top_k=7, seed=11),
         SamplingParams(max_new_tokens=9, stop_token_ids=(2, 7))]


def _signature(outs):
    return [(o.token_ids, o.logprobs, o.finish_reason) for o in outs]


# ---------------------------------------------------------------------------
# parity matrix: overlap == sync, every pool, spec on/off, mixed params
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("pool", sorted(POOLS))
@pytest.mark.parametrize("spec_k", [0, 4], ids=["seq", "spec4"])
def test_overlap_matches_sync(pool, spec_k):
    sync = _server(pool, overlap=False, spec_k=spec_k)
    o_sync = sync.generate(PROMPTS, MIXED)
    over = _server(pool, overlap=True, spec_k=spec_k)
    o_over = over.generate(PROMPTS, MIXED)
    assert _signature(o_over) == _signature(o_sync)
    if spec_k == 0:
        # the pipelined drain really ran ahead of its collects
        assert over.stats["steps"] > 0
    else:
        # speculative steps are host-data-dependent: dispatch() degrades
        # to the synchronous schedule, but acceptance still fires
        assert over.stats["spec_accepted"] == sync.stats["spec_accepted"]


def test_overlap_stream_events_identical_per_request():
    """Not just final outputs: each request's event stream (token,
    index, finish_reason) matches event for event, in-order and
    gapless.  Only the cross-request interleaving may shift — a
    prefill-handoff token is host-sampled inside dispatch(N+1), so it
    can surface one collect earlier relative to other requests."""
    def trace(overlap):
        srv = _server("shared", overlap=overlap)
        uids = [srv.submit(p, sp) for p, sp in zip(PROMPTS, MIXED)]
        per = {u: [] for u in uids}
        for ev in srv.stream():
            assert ev.index == len(per[ev.uid])     # in-order, gapless
            per[ev.uid].append((ev.token, ev.index, ev.finish_reason))
        return per
    assert trace(True) == trace(False)


def test_overlap_capacity_finish_parity():
    """Capacity finishes are PREDICTED at dispatch (cap_finish) so the
    pipeline never dispatches a doomed row; tokens still match."""
    kw = dict(ctx=64, slots=1)
    prompts = [list(range(1, 41))]
    sp = SamplingParams(max_new_tokens=100)
    o_sync = _server("shared", overlap=False, **kw).generate(prompts, sp)
    o_over = _server("shared", overlap=True, **kw).generate(prompts, sp)
    assert _signature(o_over) == _signature(o_sync)
    assert o_over[0].finish_reason == "capacity"


# ---------------------------------------------------------------------------
# timing: timestamps taken at collect(), monotone in both modes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("overlap", [False, True], ids=["sync", "overlap"])
def test_timing_monotonic(overlap):
    """Regression for the pipelined path: first_token_time is stamped
    when the token MATERIALIZES at collect(), never at dispatch —
    submit <= first <= finish must hold in both modes."""
    srv = _server("shared", overlap=overlap)
    outs = srv.generate(PROMPTS[:3], SamplingParams(max_new_tokens=5))
    for o in outs:
        assert o.submit_time <= o.first_token_time <= o.finish_time
        assert o.ttft > 0.0 and o.tpot > 0.0


def test_device_idle_accounting():
    """The scheduler tracks host-observed device-idle time: a sync drain
    accumulates it (every collect empties the pipeline); it only ever
    grows and stays a float."""
    srv = _server("striped", overlap=False)
    srv.generate(PROMPTS[:2], SamplingParams(max_new_tokens=6))
    assert srv.stats["device_idle_s"] >= 0.0
    assert srv.stats["steps"] > 0


# ---------------------------------------------------------------------------
# phantom rows: abort between dispatch and collect, pages conserved
# ---------------------------------------------------------------------------

def _cache_refs(pc):
    refs = {}
    for p in pc._full.values():
        refs[p] = refs.get(p, 0) + 1
    for e in pc._exact.values():
        for p in e.pages:
            refs[p] = refs.get(p, 0) + 1
    return refs


def _assert_pool_clean(b):
    b.alloc.check()
    refs = _cache_refs(b.prefix_cache) if b.prefix_cache else {}
    for p, r in refs.items():
        assert b.alloc.refcount[p] >= r, (p, int(b.alloc.refcount[p]), r)
    assert b.alloc.live_count == len(refs), \
        (b.alloc.live_count, len(refs))
    assert int(b._resv.sum()) == 0 and b._outstanding == 0


def test_abort_between_dispatch_and_collect():
    """The hardest phantom: a slot aborted while its step is in flight.
    collect() must discard the stale row (no token credited to the dead
    request, no token credited to any successor in the slot) and the
    shared pool must balance through the drain."""
    srv = _server("shared", overlap=True, slots=2)
    b = srv._batcher
    u0 = srv.submit(list(range(1, 30)), SamplingParams(max_new_tokens=20))
    u1 = srv.submit(list(range(2, 12)), SamplingParams(max_new_tokens=6))
    # drive both into decode synchronously, then leave one step in flight
    while not (srv._requests[u0].output and srv._requests[u1].output):
        srv.step()
    srv.dispatch()
    assert srv.pending_steps() == 1
    n0 = len(srv._requests[u0].output)
    assert srv.abort(u0)                  # mid-flight: row becomes phantom
    b.alloc.check()                       # conservation before the collect
    events = srv.collect()
    assert srv.stats["phantom_tokens"] >= 1
    assert len(srv._requests[u0].output) == n0    # no post-abort token
    assert all(ev.uid != u0 or ev.token is None for ev in events)
    events += srv.run()
    out0, out1 = srv.output(u0), srv.output(u1)
    assert out0.finish_reason == "aborted"
    assert out1.finish_reason == "length" and len(out1.token_ids) == 6
    # exactly one terminal event each, aborted one token-free
    terms = {}
    for ev in events:
        if ev.finish_reason is not None:
            assert ev.uid not in terms
            terms[ev.uid] = ev
    assert terms[u0].token is None
    _assert_pool_clean(b)


def test_abort_whole_pipeline_then_resubmit():
    """Abort EVERY in-flight request, then reuse the same server: the
    phantom steps drain away and fresh traffic decodes normally."""
    srv = _server("shared", overlap=True, slots=2)
    us = [srv.submit(p, SamplingParams(max_new_tokens=30))
          for p in PROMPTS[:2]]
    while not all(srv._requests[u].output for u in us):
        srv.step()
    srv.dispatch()
    for u in us:
        srv.abort(u)
    srv.run()
    assert all(srv.output(u).finish_reason == "aborted" for u in us)
    ref = _server("shared", overlap=False).generate(
        PROMPTS[:1], SamplingParams(max_new_tokens=4))
    got = srv.generate(PROMPTS[:1], SamplingParams(max_new_tokens=4))
    assert _signature(got) == _signature(ref)
    _assert_pool_clean(srv._batcher)


def test_dispatch_depth_is_bounded():
    """Driver misuse — dispatch() hammered without collect() — must not
    grow the pipeline unboundedly: the scheduler self-collects past
    depth 2 (and speculation keeps depth <= 1 by auto-draining)."""
    srv = _server("striped", overlap=True, slots=1)
    srv.submit(PROMPTS[0], SamplingParams(max_new_tokens=20))
    for _ in range(6):
        srv.dispatch()
    assert srv.pending_steps() <= 2
    srv.run()
    assert len(srv.output(0).token_ids) == 20


# ---------------------------------------------------------------------------
# admission order: priority and deadlines
# ---------------------------------------------------------------------------

def test_priority_orders_admission():
    """With one slot occupied, the waiting queue admits by (priority,
    deadline, submit order) — a later high-priority submit overtakes an
    earlier low-priority one."""
    srv = _server("striped", overlap=False, slots=1)
    u_run = srv.submit(PROMPTS[0], SamplingParams(max_new_tokens=12))
    u_low = srv.submit(PROMPTS[1], SamplingParams(max_new_tokens=3),
                       priority=5)
    u_high = srv.submit(PROMPTS[2], SamplingParams(max_new_tokens=3),
                        priority=0)
    srv.run()
    o = {u: srv.output(u) for u in (u_run, u_low, u_high)}
    assert all(x.finish_reason == "length" for x in o.values())
    assert o[u_high].first_token_time < o[u_low].first_token_time


def test_ties_fall_back_to_submit_order():
    srv = _server("striped", overlap=False, slots=1)
    us = [srv.submit(p, SamplingParams(max_new_tokens=2))
          for p in PROMPTS[:3]]
    srv.run()
    firsts = [srv.output(u).first_token_time for u in us]
    assert firsts == sorted(firsts)


def test_deadline_expiry_drops_queued_request():
    """A request still queued past its deadline finishes as "deadline"
    without consuming pages or steps; the running request is untouched."""
    srv = _server("shared", overlap=True, slots=1)
    u0 = srv.submit(PROMPTS[0], SamplingParams(max_new_tokens=10))
    u1 = srv.submit(PROMPTS[1], SamplingParams(max_new_tokens=10),
                    deadline=1e-4)
    time.sleep(2e-3)                      # let the deadline lapse
    events = srv.run()
    out = srv.output(u1)
    assert out.finish_reason == "deadline"
    assert out.token_ids == [] and out.ttft is None
    assert srv.stats["deadline_drops"] == 1
    assert len(srv.output(u0).token_ids) == 10
    term = [ev for ev in events if ev.uid == u1]
    assert len(term) == 1 and term[0].token is None
    _assert_pool_clean(srv._batcher)


def test_deadline_validation():
    srv = _server("striped", overlap=False)
    with pytest.raises(ValueError, match="deadline"):
        srv.submit(PROMPTS[0], deadline=0.0)
