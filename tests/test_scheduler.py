"""Continuous-batching scheduler: jitted slot splice (vs the old eager
full-pool copy), power-of-two prompt bucketing, end-to-end decode
equivalence across both repairs, and the admission hardening (capacity
rejection, bucket clamp, stuck-drain diagnostics)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import EngineConfig, get_config
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.serving.scheduler import (ContinuousBatcher, Request,
                                     SpliceBatcher, bucket_length,
                                     _splice_slot, _splice_slot_ref)

ARCH = "qwen1.5-0.5b"


def _model(arch=ARCH):
    cfg = get_config(arch).reduced()
    rt = Runtime()
    m = Model(cfg, rt)
    return cfg, rt, m.init(jax.random.PRNGKey(0))


def test_bucket_length():
    assert bucket_length(1) == 16
    assert bucket_length(16) == 16
    assert bucket_length(17) == 32
    assert bucket_length(100) == 128
    # near-capacity prompts must not round past the slot stripe
    assert bucket_length(100, hi=120) == 120
    assert bucket_length(100, hi=128) == 128


def test_submit_rejects_oversized_and_empty_prompts():
    cfg, rt, params = _model()
    b = ContinuousBatcher(cfg, params, batch_slots=2, max_context=64)
    b.submit(Request(0, list(range(1, 64)), max_new=1))    # 63 == capacity
    with pytest.raises(ValueError, match="exceeds the slot capacity"):
        b.submit(Request(1, list(range(1, 65)), max_new=1))  # 64 > capacity
    with pytest.raises(ValueError, match="empty prompt"):
        b.submit(Request(2, [], max_new=1))


def test_run_to_completion_raises_on_exhausted_steps():
    cfg, rt, params = _model()
    b = ContinuousBatcher(cfg, params, batch_slots=1, max_context=64)
    b.submit(Request(7, [1, 2, 3], max_new=8))
    b.submit(Request(9, [4, 5], max_new=8))
    with pytest.raises(RuntimeError, match=r"uids \[7, 9\]"):
        b.run_to_completion(max_steps=1)


def test_jitted_splice_identical_to_eager():
    """The dynamic_update_slice splice produces a cache bit-identical to
    the old `.at[:, i].set` path, for every leaf and several slots."""
    cfg, rt, params = _model()
    b = ContinuousBatcher(cfg, params, batch_slots=3, max_context=64)
    eng = b.engine
    _, c1 = eng.prefill(params,
                        {"tokens": jnp.arange(1, 12)[None].astype(jnp.int32)},
                        64)
    for i in (0, 2):
        jitted = _splice_slot(eng.init_cache(3, 64), c1,
                              jnp.asarray(i, jnp.int32))
        eager = _splice_slot_ref(eng.init_cache(3, 64), c1, i)
        for f in dataclasses.fields(jitted):
            a, e = getattr(jitted, f.name), getattr(eager, f.name)
            if a is None:
                continue
            np.testing.assert_array_equal(np.asarray(a), np.asarray(e),
                                          err_msg=f.name)


def test_jitted_splice_is_single_dynamic_update_per_leaf():
    """Admit must not lower to a whole-pool gather/scatter: the jaxpr of
    the splice contains only dynamic_update_slice writes (no scatter)."""
    cfg, rt, params = _model()
    b = ContinuousBatcher(cfg, params, batch_slots=3, max_context=64)
    _, c1 = b.engine.prefill(
        params, {"tokens": jnp.arange(1, 12)[None].astype(jnp.int32)}, 64)
    jaxpr = jax.make_jaxpr(_splice_slot)(b.cache, c1,
                                         jnp.asarray(1, jnp.int32))
    prims = {eqn.primitive.name for eqn in jaxpr.jaxpr.eqns}
    assert "dynamic_update_slice" in prims
    assert "scatter" not in prims and "gather" not in prims


def _run(cfg, params, prompts, *, bucket, max_new=5, slots=2, ctx=96,
         eng=None, cls=SpliceBatcher):
    """Bucketing lives in the splice path (the interleaved scheduler uses
    the chunk grid instead), so the bucket-parity tests run SpliceBatcher."""
    b = cls(cfg, params, batch_slots=slots, max_context=ctx,
            temperature=0.0, bucket_prompts=bucket, eng=eng)
    for uid, p in enumerate(prompts):
        b.submit(Request(uid, list(p), max_new=max_new))
    done = b.run_to_completion()
    return {u: r.output for u, r in done.items()}


PROMPTS = [list(range(1, 8)), list(range(3, 24)), list(range(2, 13)),
           [5, 4, 3]]


def test_bucketed_prefill_matches_exact_dense():
    cfg, rt, params = _model()
    assert _run(cfg, params, PROMPTS, bucket=False) == \
        _run(cfg, params, PROMPTS, bucket=True)


def test_bucketed_prefill_matches_exact_window():
    """gemma3 reduced: the window-ring dyn fill must keep live pages even
    when the padded prompt spans more source pages than the ring holds."""
    cfg, rt, params = _model("gemma3-12b")
    assert _run(cfg, params, PROMPTS, bucket=False, max_new=4) == \
        _run(cfg, params, PROMPTS, bucket=True, max_new=4)


def test_recurrent_family_falls_back_to_exact():
    cfg, rt, params = _model("rwkv6-3b")
    b = ContinuousBatcher(cfg, params, batch_slots=2, max_context=64,
                          bucket_prompts=True)
    assert not b.bucket_prompts            # silently disabled, still runs
    b.submit(Request(0, [1, 2, 3, 4, 5], max_new=3))
    done = b.run_to_completion()
    assert len(done[0].output) == 3


def test_scheduler_with_quantized_kv():
    """Continuous batching over kv8 pools: ragged requantizing appends +
    jitted splice of the scale leaves."""
    cfg, rt, params = _model()
    eng = EngineConfig(page_tokens=16, uniform_lengths=False,
                       kv_quant="kv8")
    outs = _run(cfg, params, PROMPTS[:2], bucket=True, max_new=4, eng=eng)
    assert sorted(outs) == [0, 1]
    assert all(len(v) == 4 for v in outs.values())
