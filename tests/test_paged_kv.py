"""Paged-KV substrate: layout, ring recycling, fills — incl. hypothesis
property tests over the page-mapping invariants (paper §IV-D)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs import EngineConfig, get_config
from repro.core import paged_kv
from repro.kernels.paged_attention import paged_to_dense


def test_layer_pattern_uniform():
    cfg = get_config("qwen2.5-32b")
    period, pattern = paged_kv.layer_pattern(cfg)
    assert period == 1 and pattern == (True,)


def test_layer_pattern_gemma3():
    cfg = get_config("gemma3-12b")
    period, pattern = paged_kv.layer_pattern(cfg)
    assert period == 6
    assert pattern == (False, False, False, False, False, True)


def test_layer_pattern_hymba():
    cfg = get_config("hymba-1.5b")
    period, pattern = paged_kv.layer_pattern(cfg)
    assert period == 16 and sum(pattern) == 1


@settings(max_examples=40, deadline=None)
@given(s=st.integers(1, 300), np_=st.integers(2, 12), t=st.integers(2, 16))
def test_window_page_positions_properties(s, np_, t):
    """Ring invariants: bases are page-aligned, distinct, cover the newest
    min(NP, ceil(S/T)) pages, and the newest page base == last page start."""
    vals = paged_kv.window_page_positions(s, np_, t)
    live = vals[vals >= 0]
    n_src = -(-s // t)
    assert len(live) == min(np_, n_src)
    assert np.all(live % t == 0)
    assert len(np.unique(live)) == len(live)
    assert (n_src - 1) * t in live                 # newest page present


def test_fill_prefill_at_roundtrip():
    B, S, K, dh, T, NP, L = 2, 50, 3, 8, 16, 8, 4
    kv = jax.random.normal(jax.random.PRNGKey(0), (B, S, K, dh))
    pool = jnp.zeros((L, B, K, NP, T, dh))
    pool = paged_kv.fill_prefill_at(pool, kv, jnp.asarray(2))
    base = jnp.broadcast_to((jnp.arange(NP) * T)[None], (B, NP))
    dense = paged_to_dense(pool[2], base, S)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(kv), atol=1e-6)
    assert float(jnp.abs(pool[1]).max()) == 0.0    # other layers untouched


def test_fill_window_at_keeps_newest():
    B, S, K, dh, T, NP, L = 1, 100, 2, 4, 8, 4, 2
    kv = jax.random.normal(jax.random.PRNGKey(0), (B, S, K, dh))
    pool = jnp.zeros((L, B, K, NP, T, dh))
    pool = paged_kv.fill_window_at(pool, kv, jnp.asarray(0))
    vals = paged_kv.window_page_positions(S, NP, T)
    base = jnp.broadcast_to(jnp.asarray(vals)[None], (B, NP))
    dense = paged_to_dense(pool[0], base, S)
    # newest NP*T window must match; everything older is zero
    keep_from = (int(np.max(vals)) // T - NP + 1) * T
    np.testing.assert_allclose(np.asarray(dense[:, max(keep_from, 0):]),
                               np.asarray(kv[:, max(keep_from, 0):]),
                               atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(ctx=st.integers(10, 200), t=st.sampled_from([8, 16, 32]),
       shards=st.sampled_from([1, 4, 16]))
def test_cache_spec_page_rounding(ctx, t, shards):
    cfg = get_config("qwen1.5-0.5b").reduced()
    spec = paged_kv.cache_spec(cfg, EngineConfig(page_tokens=t), 2, ctx,
                               page_shards_g=shards)
    NP = spec["k_pages_g"][0][3]
    assert NP % shards == 0
    assert NP * t >= ctx
