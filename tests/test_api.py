"""KVNANDServer facade: request-centric serving API.

Covers the PR's acceptance criteria: decode-step compile count invariant
to the number of distinct SamplingParams in flight (params are traced
arrays), streamed tokens concatenating exactly to the final
RequestOutput, per-request determinism independent of batch composition
/ admission order / scheduler, mixed-params batches leaving greedy rows
bit-identical, stop-token + capacity finish reasons, and abort()
restoring the shared-pool allocator conservation invariant from every
lifecycle stage."""
import pathlib
import re

import jax
import pytest

from repro.configs import EngineConfig, get_config
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.serving.api import (KVNANDServer, RequestOutput, SamplingParams,
                               ServerConfig)

ARCH = "qwen1.5-0.5b"

_CACHE = {}


def _model():
    if "m" not in _CACHE:
        cfg = get_config(ARCH).reduced()
        _CACHE["m"] = (cfg, Model(cfg, Runtime()).init(jax.random.PRNGKey(0)))
    return _CACHE["m"]


def _server(scheduler="interleaved", eng_kw=None, slots=2, ctx=96,
            chunk=16, **kw):
    cfg, params = _model()
    eng = EngineConfig(page_tokens=16, uniform_lengths=False,
                       **(eng_kw or {}))
    return KVNANDServer(
        ServerConfig(scheduler=scheduler, engine=eng, batch_slots=slots,
                     max_context=ctx, prefill_chunk_tokens=chunk, **kw),
        cfg=cfg, params=params)


PROMPTS = [list(range(1, 8)), list(range(3, 24)), list(range(2, 13)),
           [5, 4, 3]]


# ---------------------------------------------------------------------------
# basic lifecycle: generate(), finish reasons, timing counters
# ---------------------------------------------------------------------------

def test_generate_lengths_reasons_and_timing():
    srv = _server()
    outs = srv.generate(PROMPTS, SamplingParams(max_new_tokens=5))
    assert [o.uid for o in outs] == [0, 1, 2, 3]
    for o in outs:
        assert isinstance(o, RequestOutput)
        assert len(o.token_ids) == 5
        assert o.finish_reason == "length"
        assert o.submit_time <= o.first_token_time <= o.finish_time
        assert o.ttft > 0.0 and o.tpot > 0.0


def test_generate_per_prompt_params_and_logprobs():
    srv = _server()
    outs = srv.generate(
        PROMPTS[:2],
        [SamplingParams(max_new_tokens=3, logprobs=True),
         SamplingParams(max_new_tokens=6, temperature=0.8, seed=1)])
    assert len(outs[0].token_ids) == 3 and len(outs[1].token_ids) == 6
    assert len(outs[0].logprobs) == 3
    assert all(lp <= 0.0 for lp in outs[0].logprobs)
    assert outs[1].logprobs is None


def test_capacity_finish_reason():
    srv = _server(ctx=64)
    out = srv.generate([list(range(1, 41))],
                       SamplingParams(max_new_tokens=100))[0]
    assert out.finish_reason == "capacity"
    assert len(out.token_ids) == 64 - 40


def test_stop_tokens_finish_within_one_step():
    ref = _server().generate(PROMPTS[:1],
                             SamplingParams(max_new_tokens=8))[0]
    stop = ref.token_ids[2]
    j = ref.token_ids.index(stop)          # first occurrence
    out = _server().generate(
        PROMPTS[:1],
        SamplingParams(max_new_tokens=8, stop_token_ids=(stop,)))[0]
    assert out.finish_reason == "stop"
    assert out.token_ids == ref.token_ids[:j + 1]   # stop id included


# ---------------------------------------------------------------------------
# acceptance: decode compile count invariant to the SamplingParams mix
# ---------------------------------------------------------------------------

MIXED = [SamplingParams(max_new_tokens=5),
         SamplingParams(max_new_tokens=5, temperature=0.7, seed=3),
         SamplingParams(max_new_tokens=5, temperature=1.3, top_k=4,
                        seed=9),
         SamplingParams(max_new_tokens=5, temperature=0.9, top_p=0.8,
                        top_k=7, seed=11)]


def test_decode_compiles_invariant_to_params_mix():
    """Four distinct SamplingParams combinations in flight must compile
    exactly what a uniform all-greedy run compiles: the params enter the
    jitted step as traced per-slot arrays, never as static args."""
    uniform = _server()
    uniform.generate(PROMPTS, SamplingParams(max_new_tokens=5))
    mixed = _server()
    mixed.generate(PROMPTS, MIXED)
    assert mixed.stats["compiles"] == uniform.stats["compiles"]
    # the decode executable itself: ONE entry in the jit cache
    cache_size = mixed._batcher._decode._cache_size()
    assert cache_size == 1, cache_size


# ---------------------------------------------------------------------------
# acceptance: streamed tokens == final token_ids, token for token
# ---------------------------------------------------------------------------

def test_stream_concatenates_to_final_output():
    """Mixed interleaved-prefill/decode trace: the per-step events of
    each request concatenate exactly to its RequestOutput.token_ids."""
    srv = _server(slots=2, chunk=16)
    prompts = [list(range(1, 40)), list(range(2, 9)), list(range(3, 30)),
               [7, 8, 9], list(range(4, 20))]
    uids = [srv.submit(p, SamplingParams(max_new_tokens=4 + i))
            for i, p in enumerate(prompts)]
    got = {u: [] for u in uids}
    reasons = {}
    for ev in srv.stream():
        assert ev.index == len(got[ev.uid])     # in-order, gapless
        got[ev.uid].append(ev.token)
        if ev.finish_reason is not None:
            reasons[ev.uid] = ev.finish_reason
    for u in uids:
        out = srv.output(u)
        assert got[u] == out.token_ids
        assert reasons[u] == out.finish_reason == "length"


def test_stream_events_carry_logprobs_when_asked():
    srv = _server()
    srv.submit(PROMPTS[0], SamplingParams(max_new_tokens=3,
                                          logprobs=True))
    evs = list(srv.stream())
    assert len(evs) == 3
    assert all(ev.logprob is not None and ev.logprob <= 0.0 for ev in evs)


# ---------------------------------------------------------------------------
# mixed-params batches: greedy rows unperturbed by hot neighbors
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("eng_kw", [dict(kv_dtype="float32"),
                                    dict(kv_quant="kv8")],
                         ids=["f32", "kv8"])
def test_greedy_rows_identical_next_to_hot_neighbors(eng_kw):
    all_greedy = _server(eng_kw=eng_kw).generate(
        PROMPTS, SamplingParams(max_new_tokens=5))
    hot = [SamplingParams(max_new_tokens=5),
           SamplingParams(max_new_tokens=5, temperature=2.0, seed=5),
           SamplingParams(max_new_tokens=5),
           SamplingParams(max_new_tokens=5, temperature=1.5, top_k=3,
                          seed=8)]
    mixed = _server(eng_kw=eng_kw).generate(PROMPTS, hot)
    assert mixed[0].token_ids == all_greedy[0].token_ids
    assert mixed[2].token_ids == all_greedy[2].token_ids


# ---------------------------------------------------------------------------
# determinism: seeded output independent of batch / order / scheduler
# ---------------------------------------------------------------------------

def test_seeded_output_independent_of_everything():
    """SamplingParams(seed=s) pins the request's PRNG stream to
    (seed, position): the same prompt yields bit-identical tokens alone,
    crowded, admitted last, under the splice scheduler, and on the
    shared pool."""
    prompt = list(range(5, 26))
    sp = SamplingParams(max_new_tokens=6, temperature=1.0, top_k=8,
                        top_p=0.9, seed=123)
    alone = _server(slots=1).generate([prompt], sp)[0].token_ids

    crowd = _server(slots=2)
    for p in PROMPTS[:3]:           # admitted first, different neighbors
        crowd.submit(p, SamplingParams(max_new_tokens=7, temperature=0.6,
                                       seed=4))
    uid = crowd.submit(prompt, sp)
    crowd.run()
    assert crowd.output(uid).token_ids == alone

    splice = _server(scheduler="splice", slots=2)
    for p in PROMPTS[:2]:
        splice.submit(p, SamplingParams(max_new_tokens=5))
    uid = splice.submit(prompt, sp)
    splice.run()
    assert splice.output(uid).token_ids == alone

    shared = _server(eng_kw=dict(shared_pool=True), slots=2)
    uid = shared.submit(prompt, sp)
    shared.submit(PROMPTS[1], SamplingParams(max_new_tokens=5))
    shared.run()
    assert shared.output(uid).token_ids == alone


# ---------------------------------------------------------------------------
# abort(): every lifecycle stage, allocator conservation, cache floors
# ---------------------------------------------------------------------------

def _cache_refs(pc):
    """Pages the prefix cache references -> reference count."""
    refs = {}
    for p in pc._full.values():
        refs[p] = refs.get(p, 0) + 1
    for e in pc._exact.values():
        for p in e.pages:
            refs[p] = refs.get(p, 0) + 1
    return refs


def _assert_pool_clean(b):
    """All slots empty: conservation holds and the only live pages are
    the prefix cache's, each at/above its pinned floor."""
    b.alloc.check()
    refs = _cache_refs(b.prefix_cache) if b.prefix_cache else {}
    for p, r in refs.items():
        assert b.alloc.refcount[p] >= r, (p, int(b.alloc.refcount[p]), r)
    cache_live = sum(1 for p in refs)
    assert b.alloc.live_count == cache_live, \
        (b.alloc.live_count, cache_live)
    assert int(b._resv.sum()) == 0 and b._outstanding == 0


def test_abort_queued_request():
    srv = _server(slots=1)
    srv.submit(PROMPTS[0], SamplingParams(max_new_tokens=30))
    u = srv.submit(PROMPTS[1], SamplingParams(max_new_tokens=4))
    assert srv.abort(u)
    events = srv.run()
    out = srv.output(u)
    assert out.finish_reason == "aborted" and out.token_ids == []
    assert out.ttft is None and out.tpot is None
    assert len(srv.output(0).token_ids) == 30
    assert not srv.abort(u)                   # already finished
    # the aborted request still surfaced exactly one terminal event
    term = [ev for ev in events if ev.uid == u]
    assert len(term) == 1
    assert term[0].token is None and term[0].finish_reason == "aborted"


def test_every_request_gets_exactly_one_terminal_event():
    """Completion, mid-flight abort, and abort-after-drain all surface
    exactly one finish_reason-bearing event per request."""
    srv = _server(slots=2)
    u0 = srv.submit(PROMPTS[0], SamplingParams(max_new_tokens=3))
    u1 = srv.submit(list(range(1, 40)), SamplingParams(max_new_tokens=9))
    events = list(srv.step())
    srv.abort(u1)                             # mid-flight
    events += srv.run()
    terminals = {}
    for ev in events:
        if ev.finish_reason is not None:
            assert ev.uid not in terminals
            terminals[ev.uid] = ev.finish_reason
    assert terminals == {u0: "length", u1: "aborted"}


def test_release_bounds_host_bookkeeping():
    srv = _server()
    outs = srv.generate(PROMPTS, SamplingParams(max_new_tokens=3))
    assert len(outs) == 4
    # generate() released its own requests: nothing retained host-side
    assert not srv._requests and not srv._batcher.completed
    assert srv.outputs() == []
    # uids keep advancing, previous outputs unaffected
    more = srv.generate(PROMPTS[:1], SamplingParams(max_new_tokens=2))
    assert more[0].uid == 4
    u = srv.submit(PROMPTS[0], SamplingParams(max_new_tokens=50))
    with pytest.raises(ValueError, match="in flight"):
        srv.release(u)


def test_abort_mid_chunked_prefill_restores_shared_pool():
    srv = _server(eng_kw=dict(shared_pool=True), slots=2, chunk=16)
    b = srv._batcher
    u0 = srv.submit(list(range(1, 60)), SamplingParams(max_new_tokens=4))
    u1 = srv.submit(list(range(2, 40)), SamplingParams(max_new_tokens=4))
    srv.step()                                 # first chunks only
    assert any(ps.req.uid == u0 for ps in b._prefill_live.values())
    assert b.alloc.live_count > 0
    assert srv.abort(u0)
    b.alloc.check()                            # conservation mid-flight
    srv.run()                                  # survivor drains normally
    assert srv.output(u0).finish_reason == "aborted"
    assert srv.output(u1).finish_reason == "length"
    _assert_pool_clean(b)


def test_abort_mid_decode_restores_shared_pool_and_cache_floor():
    """Abort a decoding request whose prompt pages the prefix cache
    pinned: its refcounts drop by the slot's references ONLY — the cache
    keeps its floor — and conservation holds through the drain."""
    srv = _server(eng_kw=dict(shared_pool=True), slots=2, chunk=16)
    b = srv._batcher
    sysp = list(range(1, 33))                  # two full shared pages
    u0 = srv.submit(sysp + [40, 41], SamplingParams(max_new_tokens=20))
    while not srv._requests[u0].output:        # drive into decode
        srv.step()
    floor = _cache_refs(b.prefix_cache)
    assert floor                               # prompt pages registered
    # a second request maps the cached prefix read-only, then is aborted
    u1 = srv.submit(sysp + [50, 51], SamplingParams(max_new_tokens=20))
    while not srv._requests[u1].output:
        srv.step()
    srv.step()
    assert srv.abort(u1)
    b.alloc.check()
    for p, r in floor.items():
        assert b.alloc.refcount[p] >= r        # floor intact
    srv.run()
    assert srv.output(u1).finish_reason == "aborted"
    assert len(srv.output(u0).token_ids) == 20
    _assert_pool_clean(b)


def test_abort_unknown_uid_is_false():
    srv = _server()
    assert not srv.abort(99)


# ---------------------------------------------------------------------------
# facade is the sole front door
# ---------------------------------------------------------------------------

def test_no_direct_batcher_construction_outside_serving():
    """launch/, examples/ and benchmarks/ must build serving through
    KVNANDServer — never by hand-wiring the batchers."""
    root = pathlib.Path(__file__).resolve().parent.parent
    offenders = []
    for d in ("src/repro/launch", "examples", "benchmarks"):
        for f, text in ((f, f.read_text())
                        for f in (root / d).rglob("*.py")):
            if re.search(r"(ContinuousBatcher|SpliceBatcher)\s*\(", text):
                offenders.append(str(f))
    assert not offenders, offenders


def test_server_config_validates_scheduler():
    with pytest.raises(ValueError, match="unknown scheduler"):
        ServerConfig(scheduler="fifo")
