"""Shared-pool paged KV (§IV-D FTL mapping): token parity with the
stripe layout across formats/archs, capacity-proportional admission,
prefix-cache sharing with COW, and the table-indexed kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import EngineConfig, get_config
from repro.core import paged_kv
from repro.core.engine import KVNANDEngine
from repro.kernels.paged_attention import paged_attention_partial
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.serving.scheduler import (ContinuousBatcher, Request,
                                     SpliceBatcher)

PROMPTS = [list(range(1, 8)), list(range(3, 24)), list(range(2, 13)),
           [5, 4, 3]]


def _model(arch="qwen1.5-0.5b"):
    cfg = get_config(arch).reduced()
    rt = Runtime()
    return cfg, rt, Model(cfg, rt).init(jax.random.PRNGKey(0))


def _drain(cfg, params, eng, prompts, *, slots=2, ctx=96, chunk=16,
           max_new=4):
    b = ContinuousBatcher(cfg, params, batch_slots=slots, max_context=ctx,
                          temperature=0.0, eng=eng,
                          prefill_chunk_tokens=chunk)
    for uid, p in enumerate(prompts):
        b.submit(Request(uid, list(p), max_new=max_new))
    done = b.run_to_completion()
    return {u: r.output for u, r in done.items()}, b


def _engs(**kw):
    stripe = EngineConfig(page_tokens=16, uniform_lengths=False, **kw)
    shared = EngineConfig(page_tokens=16, uniform_lengths=False,
                          shared_pool=True, **kw)
    return stripe, shared


# ---------------------------------------------------------------------------
# token parity: shared pool == stripe layout, all formats + window ring
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [dict(kv_dtype="float32"),
                                dict(kv_quant="kv8"),
                                dict(kv_quant="kv4")],
                         ids=["f32", "kv8", "kv4"])
def test_shared_matches_stripe_formats(kw):
    cfg, rt, params = _model()
    stripe, shared = _engs(**kw)
    o1, _ = _drain(cfg, params, stripe, PROMPTS)
    o2, b2 = _drain(cfg, params, shared, PROMPTS)
    assert o1 == o2
    b2.alloc.check()
    # at drain only the prefix cache still holds pages — all of them
    # reclaimable, so the pool conserves capacity across request waves
    assert b2.alloc.live_count == b2.prefix_cache.evictable_pages()


def test_shared_matches_stripe_window_ring():
    """gemma3 local:global mix: both pools shared, ring through table_w."""
    cfg, rt, params = _model("gemma3-12b")
    prompts = PROMPTS + [list(range(1, 78))]     # > reduced window of 64
    stripe, shared = _engs(kv_dtype="float32")
    o1, _ = _drain(cfg, params, stripe, prompts)
    o2, b2 = _drain(cfg, params, shared, prompts)
    assert o1 == o2
    b2.alloc.check()
    b2.alloc_w.check()
    assert b2.alloc_w.live_count == 0            # rings fully reclaimed


def test_shared_matches_stripe_recurrent_prefix_archs():
    """hymba (meta-token prefix + hybrid state) via whole-prompt chunks."""
    cfg, rt, params = _model("hymba-1.5b")
    stripe, shared = _engs(kv_dtype="float32")
    o1, _ = _drain(cfg, params, stripe, PROMPTS[:2])
    o2, b2 = _drain(cfg, params, shared, PROMPTS[:2])
    assert o1 == o2
    assert b2.prefix_cache is None               # prefix sharing gated off


def test_oneshot_prefill_shared_matches_stripe():
    """Engine-level one-shot prefill + decode through the table."""
    cfg, rt, params = _model()
    toks = jnp.tile(jnp.arange(1, 22, dtype=jnp.int32)[None], (2, 1))
    outs = []
    for shared in (False, True):
        eng = KVNANDEngine(cfg, EngineConfig(
            page_tokens=16, uniform_lengths=False, kv_dtype="float32",
            shared_pool=shared), rt)
        lg, cache = eng.prefill(params, {"tokens": toks}, 96)
        seq = [np.asarray(lg)]
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        for _ in range(3):
            lg, cache = eng.decode_step(params, cache, tok)
            seq.append(np.asarray(lg))
            tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        outs.append(seq)
    for a, b in zip(*outs):
        np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# capacity-proportional admission
# ---------------------------------------------------------------------------

def test_capacity_proportional_admission():
    """6 slots whose summed max_context stripes (6·8 = 48 pages) can NOT
    fit the 16-page pool are admitted concurrently and drain with outputs
    identical to the stripe layout."""
    cfg, rt, params = _model()
    shared = EngineConfig(page_tokens=16, uniform_lengths=False,
                          kv_dtype="float32", shared_pool=True,
                          total_pages=16)
    prompts = [list(range(1 + i, 12 + i)) for i in range(6)]
    o2, b = _drain(cfg, params, shared, prompts, slots=6, ctx=128)
    assert len(o2) == 6
    assert b.stats["pool_total_pages"] == 16
    npg = -(-128 // 16)
    assert 6 * npg > b.stats["pool_total_pages"]   # old layout: impossible
    assert b.stats["pool_peak_pages"] <= 16
    b.alloc.check()
    stripe = EngineConfig(page_tokens=16, uniform_lengths=False,
                          kv_dtype="float32")
    o1, _ = _drain(cfg, params, stripe, prompts, slots=6, ctx=128)
    assert o1 == o2


def test_admission_waits_for_pages_then_drains():
    """A pool two requests wide: the third waits, no deadlock, FIFO kept."""
    cfg, rt, params = _model()
    shared = EngineConfig(page_tokens=16, uniform_lengths=False,
                          kv_dtype="float32", shared_pool=True,
                          total_pages=4)
    prompts = [list(range(1, 18))] * 3          # 2 pages each incl. max_new
    o, b = _drain(cfg, params, shared, prompts, slots=3, ctx=96)
    assert sorted(o) == [0, 1, 2]
    b.alloc.check()


def test_admission_discounts_pinned_cache_pages():
    """A prefix hit PINS the cached pages it maps, so admission must not
    count them as evictable slack: an exact repeat whose growth does not
    fit must WAIT (not crash the allocator mid-flight)."""
    cfg, rt, params = _model()
    shared = EngineConfig(page_tokens=16, uniform_lengths=False,
                          kv_dtype="float32", shared_pool=True,
                          total_pages=10)
    b = ContinuousBatcher(cfg, params, batch_slots=2, max_context=160,
                          temperature=0.0, eng=shared,
                          prefill_chunk_tokens=16)
    prompt_a = list(range(1, 73))               # 72 tokens -> 5 cached pages
    b.submit(Request(0, prompt_a, max_new=8))
    b.run_to_completion()
    assert b.prefix_cache.evictable_pages() == 5
    # a live request holds the remaining free pages...
    b.submit(Request(1, list(range(200, 270)), max_new=8))
    # ...and an exact repeat with large growth cannot fund its fresh
    # pages from the cache pages it itself maps — it must defer
    b.submit(Request(2, prompt_a, max_new=32))
    done = b.run_to_completion()
    assert sorted(done) == [0, 1, 2]
    assert done[2].output[:8] == done[0].output
    b.alloc.check()


def test_submit_rejects_impossible_footprint():
    cfg, rt, params = _model()
    shared = EngineConfig(page_tokens=16, uniform_lengths=False,
                          kv_dtype="float32", shared_pool=True,
                          total_pages=2)
    b = ContinuousBatcher(cfg, params, batch_slots=2, max_context=96,
                          eng=shared, prefill_chunk_tokens=16)
    with pytest.raises(ValueError, match="shared pool"):
        b.submit(Request(0, list(range(60)), max_new=8))


# ---------------------------------------------------------------------------
# prefix cache: shared-prefix trace, exact-repeat fork, COW
# ---------------------------------------------------------------------------

def test_prefix_cache_hits_with_unchanged_outputs():
    cfg, rt, params = _model()
    sysp = list(range(100, 132))                # 2 full shared pages
    prompts = [sysp + list(range(i * 7, i * 7 + 9)) for i in range(3)]
    prompts.append(list(prompts[0]))            # exact whole-prompt repeat
    stripe, shared = _engs(kv_dtype="float32")
    o1, _ = _drain(cfg, params, stripe, prompts, ctx=128)
    o2, b = _drain(cfg, params, shared, prompts, ctx=128)
    assert o1 == o2
    assert b.stats["prefix_hit_pages"] > 0
    assert b.stats["cow_copies"] > 0            # partial-page single-writer
    b.alloc.check()


def test_exact_repeat_skips_prefill_and_cows_partial_page():
    cfg, rt, params = _model()
    _, shared = _engs(kv_dtype="float32")
    b = ContinuousBatcher(cfg, params, batch_slots=2, max_context=96,
                          temperature=0.0, eng=shared,
                          prefill_chunk_tokens=16)
    p = list(range(1, 22))                      # 21 tokens: partial page 1
    b.submit(Request(0, p, max_new=4))
    b.run_to_completion()
    chunks_before = b.stats["prefill_chunks"]
    b.submit(Request(1, p, max_new=4))
    done = b.run_to_completion()
    assert done[0].output == done[1].output
    assert b.stats["prefill_chunks"] == chunks_before   # no recompute
    assert b.stats["cow_copies"] >= 2          # register COW + fork COW
    b.alloc.check()


def test_splice_batcher_fails_fast_on_shared_pool():
    cfg, rt, params = _model()
    _, shared = _engs(kv_dtype="float32")
    with pytest.raises(ValueError, match="stripe"):
        SpliceBatcher(cfg, params, batch_slots=2, max_context=96,
                      eng=shared)


# ---------------------------------------------------------------------------
# table-indexed kernel: shared Pallas index map == gathered oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["none", "kv8", "kv4"])
def test_shared_kernel_matches_gather_ref(fmt):
    from repro.core import quant

    B, K, G, NP, T, dh = 3, 2, 2, 4, 8, 16
    P = B * NP
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, K * G, dh))
    table = jnp.asarray(
        np.random.default_rng(0).permutation(P).reshape(B, NP), jnp.int32)
    base = jnp.broadcast_to((jnp.arange(NP) * T)[None], (B, NP))
    length = jnp.array([5, 17, 32], jnp.int32)
    kd = jax.random.normal(ks[1], (K, P, T, dh))
    vd = jax.random.normal(ks[2], (K, P, T, dh))
    ksc = vsc = None
    if fmt != "none":
        kd, ksc = quant.quantize_kv_page(kd, fmt)
        vd, vsc = quant.quantize_kv_page(vd, fmt)
    kw = dict(page_table=table, kv_quant=fmt, k_scale=ksc, v_scale=vsc)
    for window in (None, 12):
        o_ref, m_ref, l_ref = paged_attention_partial(
            q, kd, vd, base, length, impl="ref", window=window, **kw)
        o_pl, m_pl, l_pl = paged_attention_partial(
            q, kd, vd, base, length, impl="interpret", window=window, **kw)
        np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_ref),
                                   atol=3e-5, rtol=3e-5)
        np.testing.assert_allclose(np.asarray(l_pl), np.asarray(l_ref),
                                   atol=3e-5, rtol=3e-5)


def test_shared_chunk_fill_matches_stripe_chunk_fill():
    """Table-indirected chunk fills produce the same page bytes as the
    stripe fills (the slot's pages, gathered, are bit-identical)."""
    L, B, K, NP, T, dh = 2, 3, 2, 6, 8, 16
    S, slot, layer = 40, 1, 1
    kv = jax.random.normal(jax.random.PRNGKey(0), (B, S, K, dh))
    tb = jnp.asarray(
        np.random.default_rng(1).permutation(B * NP).reshape(B, NP),
        jnp.int32)
    for fmt in ("none", "kv8"):
        dt = paged_kv.quant.kv_storage_dtype(fmt) if fmt != "none" \
            else jnp.float32
        pool_a = jnp.zeros((L, B, K, NP, T, dh), dt)
        pool_b = jnp.zeros((L, K, B * NP, T, dh), dt)
        sc_a = jnp.zeros((L, B, K, NP), jnp.float32)
        sc_b = jnp.zeros((L, K, B * NP), jnp.float32)
        for c0 in range(0, S, 16):
            cl = min(16, S - c0)
            args = (jnp.asarray(layer), jnp.asarray(slot),
                    jnp.asarray(c0 // T), jnp.asarray(cl))
            argsh = (jnp.asarray(layer), tb[slot],
                     jnp.asarray(c0 // T), jnp.asarray(cl))
            if fmt == "none":
                pool_a = paged_kv.fill_chunk_global_at(
                    pool_a, kv[slot:slot + 1, c0:c0 + 16], *args)
                pool_b = paged_kv.fill_chunk_global_at_shared(
                    pool_b, kv[slot:slot + 1, c0:c0 + 16], argsh[0],
                    argsh[1], argsh[2], argsh[3])
            else:
                pool_a, sc_a = paged_kv.fill_chunk_global_at(
                    pool_a, kv[slot:slot + 1, c0:c0 + 16], *args,
                    scale=sc_a, kv_quant=fmt)
                pool_b, sc_b = paged_kv.fill_chunk_global_at_shared(
                    pool_b, kv[slot:slot + 1, c0:c0 + 16], argsh[0],
                    argsh[1], argsh[2], argsh[3], scale=sc_b,
                    kv_quant=fmt)
        np.testing.assert_array_equal(np.asarray(pool_b[:, :, tb[slot]]),
                                      np.asarray(pool_a[:, slot]))
        if fmt != "none":
            np.testing.assert_array_equal(np.asarray(sc_b[:, :, tb[slot]]),
                                          np.asarray(sc_a[:, slot]))
