"""Logical-axis sharding rules + divisibility fallback."""
import jax
from jax.sharding import PartitionSpec as P

from repro.distributed import sharding as shd


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_logical_to_spec_basic():
    rules = shd.make_rules()
    assert shd.logical_to_spec(("embed", "mlp"), rules) == P(None, "model")
    assert shd.logical_to_spec(("vocab", "embed"), rules) == P("model", None)
    assert shd.logical_to_spec((None, "heads"), rules) == P(None, "model")


def test_axis_used_once():
    rules = shd.make_rules()
    spec = shd.logical_to_spec(("mlp", "heads"), rules)  # both -> model
    assert spec == P("model", None) or spec == P(None, "model") \
        or spec == P("model")


def test_divisibility_fallback():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = shd.make_rules()
    # 51865 (whisper vocab) doesn't divide 16 -> falls back to replicated
    spec = shd.spec_for_shape((51865, 512), ("vocab", "embed"), rules, mesh)
    assert spec == P(None, None)
    spec2 = shd.spec_for_shape((51968, 512), ("vocab", "embed"), rules,
                               mesh)
    assert spec2 == P("model", None)


def test_fsdp_rules_shard_embed_over_data():
    mesh = FakeMesh({"data": 16, "model": 16})
    rules = shd.make_rules(fsdp=True)
    spec = shd.spec_for_shape((4096, 14336), ("embed", "mlp"), rules, mesh)
    assert spec == P("data", "model")


def test_multipod_batch_axes():
    rules = shd.make_rules(multi_pod=True)
    assert shd.logical_to_spec(("batch", None), rules)[0] == ("pod", "data")


def test_quantized_weight_shardings():
    import jax.numpy as jnp
    from repro.core.quant import quantize_weight, QuantizedWeight
    from repro.distributed.sharding import make_mesh_compat
    mesh = make_mesh_compat((1, 1), ("data", "model"),
                            devices=jax.devices()[:1])
    w = jnp.ones((64, 32))
    qw = quantize_weight(w, "w4a16")
    specs = QuantizedWeight(("embed", "mlp"), ("mlp",), "w4a16", (64, 32))
    sh = shd.tree_shardings({"x_w": qw}, {"x_w": specs},
                            shd.make_rules(), mesh)
    assert isinstance(sh["x_w"], QuantizedWeight)
    assert sh["x_w"].q.spec == P(None, None) or True  # structure intact
