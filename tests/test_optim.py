"""AdamW vs a literal numpy reference; clipping; schedule; bf16 moments."""
import jax.numpy as jnp
import numpy as np

from repro.training import optimizer as opt


def _np_adamw(p, g, m, v, step, lr, b1=0.9, b2=0.95, eps=1e-8, wd=0.1):
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mhat = m / (1 - b1 ** step)
    vhat = v / (1 - b2 ** step)
    delta = mhat / (np.sqrt(vhat) + eps)
    if p.ndim >= 2:
        delta = delta + wd * p
    return p - lr * delta, m, v


def test_adamw_matches_numpy_reference():
    cfg = opt.AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=10 ** 9,
                          min_lr_ratio=1.0)
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(3,)), jnp.float32)}
    state = opt.init_adamw(params, cfg)
    g = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(3,)), jnp.float32)}
    new_p, new_s, lr = opt.adamw_update(params, g, state, cfg)
    for key in ("w", "b"):
        ref, _, _ = _np_adamw(np.asarray(params[key]), np.asarray(g[key]),
                              np.zeros_like(params[key]),
                              np.zeros_like(params[key]), 1, 1e-2)
        np.testing.assert_allclose(np.asarray(new_p[key]), ref, rtol=1e-5)
    assert abs(float(lr) - 1e-2) < 1e-9


def test_lr_schedule_shape():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(opt.lr_schedule(cfg, jnp.asarray(s))) for s in
           (0, 5, 10, 55, 100, 200)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6          # mid-warmup
    assert abs(lrs[2] - 1.0) < 1e-6          # peak
    assert lrs[2] > lrs[3] > lrs[4]          # cosine decay
    assert abs(lrs[4] - 0.1) < 1e-6          # floor
    assert abs(lrs[5] - 0.1) < 1e-6


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = opt.clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - np.sqrt(90 + 160)) < 1e-4
    assert abs(float(opt.global_norm(clipped)) - 1.0) < 1e-5


def test_bf16_moments_track_f32():
    cfg32 = opt.AdamWConfig(lr=1e-3)
    cfg16 = opt.AdamWConfig(lr=1e-3, moment_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((8, 8))}
    s32, s16 = opt.init_adamw(params, cfg32), opt.init_adamw(params, cfg16)
    p32, p16 = params, params
    for i in range(5):
        g = {"w": jnp.full((8, 8), 0.1 * (i + 1))}
        p32, s32, _ = opt.adamw_update(p32, g, s32, cfg32)
        p16, s16, _ = opt.adamw_update(p16, g, s16, cfg16)
    assert float(jnp.abs(p32["w"] - p16["w"]).max()) < 5e-3
