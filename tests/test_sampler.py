"""Vectorized sampler: per-row greedy/temperature/top-k/top-p masking,
exact no-op neutrals inside mixed batches, pad-id exclusion at any
temperature (property-swept), and per-request PRNG streams."""
import jax
import jax.numpy as jnp
import numpy as np

from tests._hypothesis_compat import given, settings, st

from repro.serving.sampler import (SamplingParams, request_keys, sample,
                                   sample_with_logprobs)

V, TRUE_V = 48, 40


def _logits(seed, b=4, v=V, tempting_pad=True):
    lg = jax.random.normal(jax.random.PRNGKey(seed), (b, v)) * 3.0
    if tempting_pad:
        # make the padding lanes the LARGEST raw logits: any masking slip
        # would sample them immediately
        lg = lg.at[:, TRUE_V:].set(50.0)
    return lg


def _keys(b, pos=0):
    return request_keys(np.arange(1, b + 1, dtype=np.uint32),
                        np.full(b, pos, np.int32))


def test_sampling_params_validation():
    import pytest
    SamplingParams()                       # defaults are valid
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
    with pytest.raises(ValueError):
        SamplingParams(max_new_tokens=0)
    assert SamplingParams(stop_token_ids=[3, 7]).stop_token_ids == (3, 7)


def test_topk_zero_rows_are_exact_noops_in_vectorized_batch():
    """A top_k=0 row in a batch whose neighbors use top-k must sample the
    IDENTICAL token to a run with no top-k at all (same keys)."""
    lg, keys = _logits(0), _keys(4)
    temps = jnp.ones(4)
    mixed = sample(lg, keys, true_vocab=TRUE_V, temperature=temps,
                   top_k=jnp.array([0, 5, 0, 2], jnp.int32))
    plain = sample(lg, keys, true_vocab=TRUE_V, temperature=temps, top_k=0)
    assert int(mixed[0]) == int(plain[0])
    assert int(mixed[2]) == int(plain[2])


def test_topp_one_rows_are_exact_noops_in_vectorized_batch():
    lg, keys = _logits(1), _keys(4)
    temps = jnp.ones(4)
    mixed = sample(lg, keys, true_vocab=TRUE_V, temperature=temps,
                   top_p=jnp.array([1.0, 0.3, 1.0, 0.5]))
    plain = sample(lg, keys, true_vocab=TRUE_V, temperature=temps)
    assert int(mixed[0]) == int(plain[0])
    assert int(mixed[2]) == int(plain[2])


def test_greedy_rows_ignore_noise_and_neighbors():
    """temperature=0 rows take the raw argmax even when every neighbor
    runs hot."""
    lg, keys = _logits(2), _keys(4)
    toks = sample(lg, keys, true_vocab=TRUE_V,
                  temperature=jnp.array([0.0, 2.0, 0.0, 5.0]))
    ref = jnp.argmax(jnp.where(jnp.arange(V) >= TRUE_V, -1e9, lg), axis=-1)
    assert int(toks[0]) == int(ref[0])
    assert int(toks[2]) == int(ref[2])


def test_topk_restricts_to_k_largest():
    lg = _logits(3, b=64)
    keys = _keys(64, pos=5)
    k = 3
    toks = np.asarray(sample(lg, keys, true_vocab=TRUE_V, temperature=1.5,
                             top_k=k))
    top3 = np.argsort(-np.asarray(lg[:, :TRUE_V]), axis=-1)[:, :k]
    for b in range(64):
        assert toks[b] in top3[b], (b, toks[b], top3[b])


def test_topp_keeps_minimal_nucleus():
    """A hand-built distribution: p = [.5, .3, .15, .05]; top_p=0.7 keeps
    exactly {0, 1} (mass before token 2 is 0.8 >= 0.7)."""
    probs = np.array([0.5, 0.3, 0.15, 0.05])
    lg = jnp.broadcast_to(jnp.log(jnp.asarray(probs))[None], (256, 4))
    keys = _keys(256, pos=9)
    toks = np.asarray(sample(lg, keys, true_vocab=4, temperature=1.0,
                             top_p=0.7))
    assert set(toks.tolist()) <= {0, 1}
    assert len(set(toks.tolist())) == 2    # genuinely samples, not argmax

    # tiny top_p degenerates to argmax for every row
    toks = np.asarray(sample(lg, keys, true_vocab=4, temperature=1.0,
                             top_p=1e-6))
    assert set(toks.tolist()) == {0}


def test_topk_then_topp_compose_sequentially():
    """Standard composition: top-p runs on the RENORMALIZED top-k
    survivors.  p = [.4, .3, .2, .1] with top_k=2, top_p=0.5: top-2
    renormalizes to [.571, .429], whose nucleus at 0.5 is {0} alone —
    token 1 must never appear (an independent-masks implementation
    would sample it ~43% of the time)."""
    probs = np.array([0.4, 0.3, 0.2, 0.1])
    lg = jnp.broadcast_to(jnp.log(jnp.asarray(probs))[None], (256, 4))
    keys = _keys(256, pos=3)
    toks = np.asarray(sample(lg, keys, true_vocab=4, temperature=1.0,
                             top_k=2, top_p=0.5))
    assert set(toks.tolist()) == {0}


@settings(max_examples=25)
@given(seed=st.integers(0, 2**16),
       temp=st.floats(0.0, 4.0),
       tk=st.integers(0, 12),
       tp=st.floats(0.05, 1.0))
def test_pad_ids_never_sampled(seed, temp, tk, tp):
    """Vocab padding (ids >= true_vocab) is unsampleable at ANY
    temperature / filter combination, even when the pad lanes hold the
    largest raw logits."""
    lg = _logits(seed, b=8)
    keys = _keys(8, pos=seed % 97)
    toks = np.asarray(sample(lg, keys, true_vocab=TRUE_V,
                             temperature=jnp.full(8, temp),
                             top_k=jnp.full(8, tk, jnp.int32),
                             top_p=jnp.full(8, tp)))
    assert (toks < TRUE_V).all(), (temp, tk, tp, toks)


def test_pad_ids_never_sampled_at_extreme_temperature():
    """Huge temperatures flatten real logits toward 0; the pad floor must
    stay temperature-independent (masked after scaling) or noise would
    lift padding into range."""
    lg = _logits(11, b=16)
    keys = _keys(16, pos=1)
    for temp in (1e-4, 1.0, 1e4, 1e9):
        toks = np.asarray(sample(lg, keys, true_vocab=TRUE_V,
                                 temperature=temp))
        assert (toks < TRUE_V).all(), temp


def test_request_streams_independent_of_batch_composition():
    """Row i's draw depends only on (seed, position): the same request
    sampled alone or inside a crowd gets the same token."""
    lg = _logits(4, b=3, tempting_pad=False)
    seeds = np.array([7, 7, 9], np.uint32)
    pos = np.array([2, 5, 2], np.int32)
    keys = request_keys(seeds, pos)
    batch = sample(lg, keys, true_vocab=TRUE_V, temperature=1.0)
    for i in range(3):
        solo = sample(lg[i:i + 1], request_keys(seeds[i:i + 1],
                                                pos[i:i + 1]),
                      true_vocab=TRUE_V, temperature=1.0)
        assert int(solo[0]) == int(batch[i])
    # same seed, different position -> a fresh draw (a stream, not a
    # constant); rows 0 and 1 share a seed yet may differ
    k2 = request_keys(seeds[:1], np.array([6], np.int32))
    assert k2.shape == (1, 2)


def test_single_key_matches_legacy_categorical_stream():
    """The legacy surface (one batch-shared key, scalar knobs) must keep
    its exact token stream: gumbel-argmax == jax.random.categorical."""
    lg = _logits(5, tempting_pad=False)
    key = jax.random.PRNGKey(42)
    got = sample(lg, key, true_vocab=TRUE_V, temperature=0.7)
    want = jax.random.categorical(
        key, jnp.where(jnp.arange(V) >= TRUE_V, -1e9,
                       lg.astype(jnp.float32)) / 0.7, axis=-1)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_logprobs_are_raw_distribution_scores():
    """Returned logprobs come from the pad-masked RAW distribution —
    invariant to temperature/filters — and match log_softmax exactly."""
    lg = _logits(6)
    keys = _keys(4)
    toks, lps = sample_with_logprobs(lg, keys, true_vocab=TRUE_V,
                                     temperature=jnp.array([0.0, 1.0,
                                                            2.0, 0.5]))
    masked = jnp.where(jnp.arange(V) >= TRUE_V, -1e9,
                       lg.astype(jnp.float32))
    ref = jax.nn.log_softmax(masked, axis=-1)
    for i in range(4):
        assert float(lps[i]) == float(ref[i, int(toks[i])])
        assert float(lps[i]) <= 0.0
