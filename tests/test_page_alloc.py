"""Property tests for the shared-pool page allocator (§IV-D FTL host half).

Random alloc/free/fork/COW sequences must preserve the conservation
invariant (free + live == total, refcounts never negative), never hand
two writers the same physical page, and never let a decode-after-fork
mutate a page the fork still shares.  Runs under `tests/_hypothesis_compat`
(seeded sweeps when hypothesis is absent).
"""
import random

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import paged_kv
from repro.core.page_alloc import (HotTier, OutOfHotSlots, OutOfPages,
                                   PageAllocator, PrefixCache)


# ---------------------------------------------------------------------------
# random operation sequences: conservation + single-writer
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(total=st.integers(4, 32), seed=st.integers(0, 10_000),
       n_ops=st.integers(10, 120))
def test_alloc_free_fork_cow_conservation(total, seed, n_ops):
    rng = random.Random(seed)
    alloc = PageAllocator(total)
    # tables: writer -> list of (page, exclusive?) it maps
    tables = {}
    next_uid = 0
    for _ in range(n_ops):
        op = rng.choice(["alloc", "free", "fork", "cow", "write"])
        if op == "alloc":
            try:
                p = alloc.alloc(rng.randrange(4))
            except OutOfPages:
                assert alloc.free_count == 0
                continue
            tables.setdefault(next_uid, []).append(p)
            next_uid += 1
        elif op == "free" and tables:
            uid = rng.choice(list(tables))
            alloc.free(tables.pop(uid))
        elif op == "fork" and tables:
            uid = rng.choice(list(tables))
            alloc.share(tables[uid])
            tables[next_uid] = list(tables[uid])
            next_uid += 1
        elif op == "cow" and tables:
            uid = rng.choice(list(tables))
            if not tables[uid]:
                continue
            j = rng.randrange(len(tables[uid]))
            old = tables[uid][j]
            try:
                fresh = alloc.cow(old)
            except OutOfPages:
                assert alloc.free_count == 0
                continue
            tables[uid][j] = fresh
            if alloc.refcount[old] == 0:   # impossible: cow never frees
                raise AssertionError("cow dropped the last reference")
        elif op == "write" and tables:
            # single-writer rule: a write target must have refcount 1
            uid = rng.choice(list(tables))
            for p in tables[uid]:
                if alloc.refcount[p] == 1:
                    writers = [u for u, ps in tables.items()
                               if p in ps and u != uid]
                    assert not writers, "exclusive page mapped twice"
        alloc.check()
    # teardown: free everything, pool must drain to fully free
    for pages in tables.values():
        alloc.free(pages)
    alloc.check()
    assert alloc.free_count == total
    assert alloc.live_count == 0


@settings(max_examples=25, deadline=None)
@given(total=st.integers(2, 24), seed=st.integers(0, 10_000))
def test_never_double_map_exclusive_page(total, seed):
    """alloc() never returns a page that is still referenced."""
    rng = random.Random(seed)
    alloc = PageAllocator(total)
    held = []
    for _ in range(60):
        if rng.random() < 0.6:
            try:
                p = alloc.alloc()
            except OutOfPages:
                continue
            assert p not in held
            held.append(p)
        elif held:
            alloc.free([held.pop(rng.randrange(len(held)))])
    assert len(set(held)) == len(held)


def test_shard_striping_and_fallback():
    alloc = PageAllocator(8, n_shards=4)
    pages = [alloc.alloc_for_logical(j) for j in range(4)]
    assert [alloc.shard_of(p) for p in pages] == [0, 1, 2, 3]
    # drain shard 0; logical 4 (prefers shard 0) falls back elsewhere
    alloc.alloc_for_logical(0)
    p = alloc.alloc_for_logical(4)
    assert alloc.shard_of(p) != 0 or True  # falls back without raising
    alloc.check()


def test_cow_semantics():
    alloc = PageAllocator(4)
    p = alloc.alloc()
    assert alloc.cow(p) == p               # exclusive: no copy
    alloc.share([p])                       # fork
    fresh = alloc.cow(p)
    assert fresh != p
    assert alloc.refcount[p] == 1 and alloc.refcount[fresh] == 1
    alloc.check()


# ---------------------------------------------------------------------------
# decode-after-fork never mutates a shared page (device-level COW)
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_decode_after_fork_never_mutates_shared_page(seed):
    """Model the scheduler's COW protocol against a real pool: fork a
    table row, run 'decode appends' on the fork with COW-before-write,
    and assert the parent's page bytes never change."""
    rng = random.Random(seed)
    L, K, P, T, dh = 2, 2, 8, 4, 8
    alloc = PageAllocator(P)
    pool = jnp.asarray(np.arange(L * K * P * T * dh, dtype=np.float32)
                       .reshape(L, K, P, T, dh))
    parent = [alloc.alloc_for_logical(j) for j in range(2)]
    parent_bytes = np.asarray(pool[:, :, parent]).copy()
    # fork
    alloc.share(parent)
    fork = list(parent)
    shared = set(range(len(fork)))
    pos = rng.randrange(1, 2 * T)          # fork decodes from mid-sequence
    for step in range(4):
        lp = (pos + step) // T
        if lp >= len(fork):                # growth page
            fork.append(alloc.alloc_for_logical(lp))
        elif lp in shared:
            fresh = alloc.cow(fork[lp])
            assert fresh != fork[lp]
            pool = paged_kv.copy_page_shared(pool, fork[lp], fresh)
            fork[lp] = fresh
            shared.discard(lp)
        # the fork writes its (now exclusive) page
        assert alloc.refcount[fork[lp]] == 1
        pool = pool.at[:, :, fork[lp], (pos + step) % T].set(-1.0)
        alloc.check()
    np.testing.assert_array_equal(np.asarray(pool[:, :, parent]),
                                  parent_bytes)


# ---------------------------------------------------------------------------
# prefix cache: refcounts, eviction, lookup chains
# ---------------------------------------------------------------------------

def _register(cache, alloc, prompt, T):
    n_pages = -(-len(prompt) // T)
    pages = [alloc.alloc_for_logical(j) for j in range(n_pages)]
    cache.register(prompt, pages, np.zeros(4, np.float32))
    alloc.free(pages)                      # slot completes; cache holds on
    return pages


def test_prefix_cache_lookup_and_eviction():
    T = 4
    alloc = PageAllocator(16)
    cache = PrefixCache(alloc, T)
    prompt = list(range(10))               # 2 full pages + 1 partial
    pages = _register(cache, alloc, prompt, T)
    alloc.check()
    assert all(alloc.refcount[p] >= 1 for p in pages)

    hit = cache.lookup(prompt)             # exact
    assert hit.exact is not None and hit.exact.pages == pages
    hit2 = cache.lookup(prompt[:9] + [99])  # full-page chain only
    assert hit2.exact is None
    assert hit2.full_pages == pages[:2]
    hit3 = cache.lookup([7] + prompt[1:])  # no shared first page
    assert hit3.full_pages == [] and hit3.exact is None

    while cache.evict_lru():
        pass
    alloc.check()
    assert alloc.free_count == alloc.total  # everything reclaimed


def test_prefix_cache_strict_hit_shorter_than_prompt():
    """A full-page chain hit never covers the whole prompt (the caller
    must always compute at least the last token for logits)."""
    T = 4
    alloc = PageAllocator(16)
    cache = PrefixCache(alloc, T)
    _register(cache, alloc, list(range(8)), T)
    hit = cache.lookup(list(range(8)) + [42, 43])
    assert len(hit.full_pages) * T < 10
    hit_exact_len = cache.lookup(list(range(8)))
    assert hit_exact_len.exact is not None  # exact entry handles n == h·T


# ---------------------------------------------------------------------------
# hot tier (tiered flash KV hierarchy, DESIGN.md §13): conservation
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(hot=st.integers(1, 8), extra=st.integers(0, 24),
       seed=st.integers(0, 10_000), n_ops=st.integers(10, 150))
def test_hot_tier_conservation_under_random_traces(hot, extra, seed,
                                                   n_ops):
    """Arbitrary bind/pin/unpin/touch/release traces preserve tier
    conservation (free slots + residents == hot_slots), never demote a
    pinned page, and raise OutOfHotSlots only when nothing is
    demotable."""
    total = hot + extra
    rng = random.Random(seed)
    tier = HotTier(hot, total)
    pins = {}                               # page -> pin count (mirror)
    for _ in range(n_ops):
        op = rng.choice(["bind", "pin", "unpin", "touch", "release"])
        resident = [p for p in range(total) if tier.is_resident(p)]
        if op == "bind":
            cold = [p for p in range(total) if not tier.is_resident(p)]
            if not cold:
                continue
            page = rng.choice(cold)
            try:
                slot, victim = tier.bind(page)
            except OutOfHotSlots:
                assert tier.free_slot_count == 0
                assert tier.demotable_count == 0
                continue
            if victim is not None:
                assert pins.get(victim, 0) == 0, "pinned page demoted"
                assert not tier.is_resident(victim)
            assert tier.slot_of(page) == slot
        elif op == "pin" and resident:
            page = rng.choice(resident)
            tier.pin(page)
            pins[page] = pins.get(page, 0) + 1
        elif op == "unpin":
            pinned = [p for p, c in pins.items() if c > 0]
            if not pinned:
                continue
            page = rng.choice(pinned)
            tier.unpin(page)
            pins[page] -= 1
        elif op == "touch" and resident:
            tier.touch(rng.choice(resident))
        elif op == "release" and resident:
            unpinned = [p for p in resident if pins.get(p, 0) == 0]
            if not unpinned:
                continue
            tier.release(rng.choice(unpinned))
        tier.check()
        assert tier.free_slot_count + tier.resident_count == hot
        assert (tier.pinned_count + tier.demotable_count
                == tier.resident_count)
    # teardown: unpin + release everything; every slot must come back
    for p, c in pins.items():
        for _ in range(c):
            tier.unpin(p)
    for p in range(total):
        if tier.is_resident(p):
            tier.release(p)
    tier.check()
    assert tier.free_slot_count == hot


def test_hot_tier_lru_order_and_avoid():
    tier = HotTier(2, 8)
    tier.bind(0)
    tier.bind(1)                           # LRU: 0, 1
    tier.touch(0)                          # LRU: 1, 0
    _, victim = tier.bind(2)
    assert victim == 1                     # least-recently-touched
    _, victim = tier.bind(3, avoid=frozenset({0}))
    assert victim == 2                     # 0 excluded -> next LRU
    tier.check()


def test_hot_tier_pinned_never_victim():
    tier = HotTier(1, 4)
    tier.bind(0)
    tier.pin(0)
    with pytest.raises(OutOfHotSlots):
        tier.bind(1)                       # sole slot is pinned
    tier.unpin(0)                          # joins LRU, demotable again
    _, victim = tier.bind(1)
    assert victim == 0
    assert tier.entry(0) == HotTier.CAPACITY    # tier bit
    assert tier.entry(1) == tier.slot_of(1)
    tier.check()


def test_hot_tier_release_hook_frees_slot():
    """The allocator's release hook retires residency on every free
    path without the caller knowing about tiers."""
    alloc = PageAllocator(8)
    tier = HotTier(2, 8)
    alloc.add_release_hook(tier.release)
    p = alloc.alloc()
    tier.bind(p)
    alloc.free([p])                        # refcount 0 fires the hook
    assert not tier.is_resident(p)
    assert tier.free_slot_count == 2
    alloc.check()
    tier.check()


def test_hot_tier_rejects_bad_shapes():
    with pytest.raises(ValueError):
        HotTier(0, 4)                      # no slots
    with pytest.raises(ValueError):
        HotTier(8, 4)                      # hot tier larger than flash
    tier = HotTier(2, 4)
    tier.bind(1)
    with pytest.raises(ValueError):
        tier.bind(1)                       # double bind
    tier.pin(1)
    with pytest.raises(ValueError):
        tier.unpin(0)                      # unpin of unpinned page


def test_prefix_cache_peek_has_no_side_effects():
    """lookup(record=False) — the prefetcher's peek — must not touch
    hit/lookup counters or LRU order, or prefetch would distort the
    hit-rate stats and keep cold entries artificially warm."""
    T = 4
    alloc = PageAllocator(16)
    cache = PrefixCache(alloc, T)
    prompt = list(range(8))
    _register(cache, alloc, prompt, T)
    before = (cache.hits, cache.lookups)
    peek = cache.lookup(prompt, record=False)
    assert peek.exact is not None
    assert (cache.hits, cache.lookups) == before
    hit = cache.lookup(prompt)             # recorded lookup still works
    assert hit.exact is not None
    assert cache.hits > before[0] and cache.lookups > before[1]


def test_allocator_rejects_bad_ops():
    alloc = PageAllocator(4)
    p = alloc.alloc()
    alloc.free([p])
    with pytest.raises(ValueError):
        alloc.free([p])                    # double free
    with pytest.raises(ValueError):
        alloc.share([p])                   # share of dead page
    with pytest.raises(ValueError):
        PageAllocator(9, n_shards=4)       # uneven shard split
