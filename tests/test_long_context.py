"""100K-context decode through the shared-pool kv8 path.

The scenario the split-page walk exists for: a single sequence whose KV
pool (1568 pages × 64 tokens ≈ 100K context) would blow the memory /
cache budget as one monolithic score tensor.  Prefilling 100K tokens for
real is out of tier-1 budget, so the cache state is fabricated — an
identity page table over a fully-allocated shared pool of random kv8
codes — which exercises exactly the same decode path (table walk,
dequant, partitioned attention, append) as a real prefill would.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import EngineConfig, get_config
from repro.core.engine import KVNANDEngine
from repro.kernels.paged_attention import resolve_partitions
from repro.models.registry import Model
from repro.models.transformer import Runtime

CTX = 100_352          # 1568 pages of 64 tokens; 16 | 1568
PAGE_T = 64
LENGTH = 100_000


def _fabricate_cache(eng_api, cfg, seed=0):
    """Fill an init_cache skeleton as if ~100K tokens were resident."""
    cache = eng_api.init_cache(1, CTX)
    rng = np.random.default_rng(seed)
    NP = cache.page_table_g.shape[1]
    repl = {
        "k_pages_g": rng.integers(-127, 128, cache.k_pages_g.shape,
                                  dtype=np.int8),
        "v_pages_g": rng.integers(-127, 128, cache.v_pages_g.shape,
                                  dtype=np.int8),
        "k_scale_g": rng.uniform(0.005, 0.02, cache.k_scale_g.shape),
        "v_scale_g": rng.uniform(0.005, 0.02, cache.v_scale_g.shape),
        # identity logical->physical mapping over the whole pool
        "page_table_g": np.arange(NP, dtype=np.int32)[None],
        "lengths": np.array([LENGTH], np.int32),
    }
    for name, val in repl.items():
        leaf = getattr(cache, name)
        object.__setattr__(cache, name,
                           jnp.asarray(val, dtype=leaf.dtype))
    return cache


def test_100k_decode_shared_kv8():
    cfg = get_config("qwen1.5-0.5b").reduced()
    rt = Runtime()
    params = Model(cfg, rt).init(jax.random.PRNGKey(0))
    eng = EngineConfig(shared_pool=True, kv_quant="kv8",
                       page_tokens=PAGE_T, uniform_lengths=False)
    api = KVNANDEngine(cfg, eng, rt)

    # the auto ladder actually splits at this page count
    assert resolve_partitions(eng.attn_partitions,
                              CTX // PAGE_T) > 1

    cache = _fabricate_cache(api, cfg)
    tok = jnp.array([[7]], jnp.int32)
    for step in range(3):
        logits, cache = api.decode_step(params, cache, tok)
        assert logits.shape == (1, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits))), f"step {step}"
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    assert int(cache.lengths[0]) == LENGTH + 3


def test_100k_decode_partition_count_invariant():
    """The split is a pure reassociation: explicit partitions=1 and the
    auto 16-way split produce the same logits at 100K context."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    rt = Runtime()
    params = Model(cfg, rt).init(jax.random.PRNGKey(0))
    logits = []
    for parts in (1, 0):           # monolithic vs auto (16 at 1568 pages)
        eng = EngineConfig(shared_pool=True, kv_quant="kv8",
                           page_tokens=PAGE_T, uniform_lengths=False,
                           attn_partitions=parts)
        api = KVNANDEngine(cfg, eng, rt)
        cache = _fabricate_cache(api, cfg)
        lg, _ = api.decode_step(params, cache, jnp.array([[7]], jnp.int32))
        logits.append(np.asarray(lg, np.float32))
    np.testing.assert_allclose(logits[0], logits[1], atol=2e-3, rtol=2e-3)
