"""Per-arch REDUCED-config smoke tests (assignment requirement): one
forward + one train step on CPU asserting output shapes + no NaNs."""
import math

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, EngineConfig, get_config
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step


def _batch(cfg, B=2, S=32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    b = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size,
                                      jnp.int32),
         "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size,
                                      jnp.int32)}
    if cfg.family == "vlm":
        b["patches"] = jax.random.normal(ks[2], (B, 8, cfg.d_model))
    if cfg.is_encoder_decoder:
        b["frames"] = jax.random.normal(ks[3], (B, 8, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    m = Model(cfg, Runtime())
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = jax.jit(lambda p, b: m.forward(p, b))(params, batch)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    rt = Runtime()
    m = Model(cfg, rt)
    params = m.init(jax.random.PRNGKey(0))
    acfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=10)
    state = init_train_state(params, acfg)
    step = jax.jit(make_train_step(cfg, rt, acfg, EngineConfig()))
    state, metrics = step(state, _batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert abs(float(metrics["loss"]) - math.log(cfg.vocab_size)) < 2.5
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(state.params)))
    assert moved


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "rwkv6-3b", "hymba-1.5b"])
def test_remat_matches_no_remat(arch):
    cfg = get_config(arch).reduced()
    m = Model(cfg, Runtime())
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    l0, _ = jax.jit(lambda p, b: m.loss(p, b, remat="none"))(params, batch)
    l1, _ = jax.jit(lambda p, b: m.loss(p, b, remat="block"))(params, batch)
    assert abs(float(l0) - float(l1)) < 1e-4
