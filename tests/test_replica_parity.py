"""Token-identity parity for disaggregated prefill/decode (ISSUE 10).

A request that chunk-prefills on one replica, migrates as a
`KVEnvelope` (through the real wire bytes), and decodes on another
replica must emit EXACTLY the token stream and logprobs of the same
request run end-to-end on one server — across every paged-KV format
(fp/kv8/kv4) and both pool residencies (flat, tiered hot/capacity).
Bit-identity follows from PR 4's fold_in PRNG streams plus page-byte
equality; these tests are the enforcement.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import EngineConfig, get_config
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.serving import replica as replica_mod
from repro.serving.api import KVNANDServer, ServerConfig
from repro.serving.replica import KVEnvelope, export_request
from repro.serving.router import ReplicaRouter
from repro.serving.sampler import SamplingParams

TOTAL_PAGES = 64
HOT_PAGES = 12


@pytest.fixture(scope="module")
def model():
    cfg = get_config("qwen1.5-0.5b").reduced()
    rt = Runtime()
    return cfg, rt, Model(cfg, rt).init(jax.random.PRNGKey(0))


def _server(model, kv_quant="none", hot_pages=0, slots=3):
    cfg, rt, params = model
    eng = EngineConfig(page_tokens=16, uniform_lengths=False,
                       shared_pool=True, total_pages=TOTAL_PAGES,
                       hot_pages=hot_pages, kv_quant=kv_quant)
    sc = ServerConfig(arch="qwen1.5-0.5b", reduced=True, engine=eng,
                      batch_slots=slots, max_context=64,
                      prefill_chunk_tokens=16, seed=7)
    return KVNANDServer(sc, cfg=cfg, params=params, rt=rt)


def _prompts(vocab):
    rng = np.random.default_rng(11)
    sysp = rng.integers(1, vocab, 18).tolist()
    return [sysp + rng.integers(1, vocab, k).tolist() for k in (3, 9, 1)]


PARAMS = [SamplingParams(max_new_tokens=8, temperature=0.0,
                         logprobs=True),
          SamplingParams(max_new_tokens=8, temperature=0.9, top_k=20,
                         logprobs=True),
          SamplingParams(max_new_tokens=6, temperature=0.7, top_p=0.9,
                         logprobs=True, seed=123)]


def _reference(model, kv_quant, hot_pages, prompts):
    srv = _server(model, kv_quant, hot_pages)
    uids = [srv.submit(p, sp, uid=100 + i)
            for i, (p, sp) in enumerate(zip(prompts, PARAMS))]
    srv.run()
    return {u: srv.output(u) for u in uids}


@pytest.mark.parametrize("hot_pages", [0, HOT_PAGES],
                         ids=["flat", "tiered"])
@pytest.mark.parametrize("kv_quant", ["none", "kv8", "kv4"])
def test_disaggregated_token_identity(model, kv_quant, hot_pages):
    prompts = _prompts(model[0].vocab_size)
    ref = _reference(model, kv_quant, hot_pages, prompts)

    servers = [_server(model, kv_quant, hot_pages) for _ in range(3)]
    router = ReplicaRouter(servers, disaggregate=True)
    uids = [router.submit(p, sp, uid=100 + i)
            for i, (p, sp) in enumerate(zip(prompts, PARAMS))]
    router.run()

    assert router.stats["migrations"] == len(uids)
    assert router.stats["migration_bytes"] > 0
    for u in uids:
        out, want = router.output(u), ref[u]
        assert router.replica_of(u) in (1, 2)      # decoded off-replica
        assert out.token_ids == want.token_ids
        assert out.logprobs == want.logprobs
        assert out.finish_reason == want.finish_reason
    # page conservation on every replica after drain
    for s in servers:
        b = s._batcher
        b.alloc.check()
        if b.alloc_w is not None:
            b.alloc_w.check()
        if b.tier is not None:
            b.tier.check()
            assert b.tier.pinned_count == 0


def test_envelope_wire_roundtrip(model):
    """from_bytes(to_bytes(env)) reproduces every leaf and the header;
    the envelope covers quantized pages + scales (kv8) so the scale
    leaves demonstrably travel."""
    prompts = _prompts(model[0].vocab_size)
    srv = _server(model, kv_quant="kv8")
    uid = srv.submit(prompts[1], PARAMS[1], uid=5)
    srv._requests[uid].hold = True
    steps = 0
    b = srv._batcher
    while not (b.slots and any(r is not None and r.output
                               for r in b.slots)):
        srv.step()
        steps += 1
        assert steps < 50
    env = export_request(b, uid)
    assert any(k.endswith("k_scale_g") for k in env.arrays), \
        "kv8 scales missing from envelope"
    env2 = KVEnvelope.from_bytes(env.to_bytes())
    assert env2.meta == env.meta
    assert set(env2.arrays) == set(env.arrays)
    for k in env.arrays:
        np.testing.assert_array_equal(env2.arrays[k], env.arrays[k])
    assert len(env.to_bytes()) >= env.nbytes()


def test_import_backpressure_retries_then_lands(model):
    """A decode replica with no free slot refuses the import (source
    keeps its pages); the migration lands once a slot frees."""
    prompts = _prompts(model[0].vocab_size)
    pre = _server(model, slots=2)
    dec = _server(model, slots=1)
    router = ReplicaRouter([pre, dec], disaggregate=True)
    sp = dataclasses.replace(PARAMS[0], max_new_tokens=12)
    uids = [router.submit(p, sp, uid=i) for i, p in enumerate(prompts)]
    router.run()
    assert router.stats["migrations"] == len(uids)
    assert router.stats["migration_retries"] > 0, \
        "1-slot decode replica never exerted backpressure"
    # baseline run with the same per-uid params
    srv = _server(model)
    base_uids = [srv.submit(p, sp, uid=i) for i, p in enumerate(prompts)]
    srv.run()
    for u in uids:
        assert router.output(u).token_ids == srv.output(u).token_ids
    pre._batcher.alloc.check()
    dec._batcher.alloc.check()


def test_abort_held_request_conserves_pages(model):
    """Aborting a request while it sits HELD awaiting migration frees
    its source pages; nothing ever reaches the decode replica."""
    prompts = _prompts(model[0].vocab_size)
    pre = _server(model, slots=2)
    dec = _server(model, slots=1)
    router = ReplicaRouter([pre, dec], disaggregate=True)
    uids = [router.submit(p, PARAMS[0], uid=i)
            for i, p in enumerate(prompts)]
    # step the prefill replica only, so handoffs complete but nothing
    # migrates; then abort one held request
    for _ in range(30):
        pre.step()
    held = [r.uid for r in pre._batcher.slots
            if r is not None and r.hold and r.output]
    assert held, "no request reached the held state"
    assert router.abort(held[0])
    router.run()
    assert router.output(held[0]).finish_reason == "aborted"
    for u in uids:
        if u != held[0]:
            assert router.output(u).finish_reason in ("stop", "length")
    pre._batcher.alloc.check()
    dec._batcher.alloc.check()
    assert dec._batcher.stats.get("migrations_in", 0) == len(uids) - 1


def test_cross_replica_prefix_index(model):
    """Routed mode: pages warmed on one replica admit as prefix hits on
    another via the PrefixPageIndex, token-identically."""
    cfg = model[0]
    rng = np.random.default_rng(3)
    sysp = rng.integers(1, cfg.vocab_size, 32).tolist()
    prompts = [sysp + rng.integers(1, cfg.vocab_size, 5).tolist()
               for _ in range(4)]
    sp = SamplingParams(max_new_tokens=6)

    ref = {}
    solo = _server(model)
    for i, p in enumerate(prompts):
        u = solo.submit(p, sp, uid=i)
        solo.run()
        ref[u] = solo.output(u).token_ids

    servers = [_server(model), _server(model)]
    router = ReplicaRouter(servers, share_prefix=True)
    assert router.index is not None
    # drain one prompt at a time so the finished prompt publishes its
    # chain before the next submit warms the other replica
    for i, p in enumerate(prompts):
        router.submit(p, sp, uid=i)
        router.run()
    for i in range(len(prompts)):
        assert router.output(i).token_ids == ref[i]
    assert router.stats["prefix_published_pages"] > 0
    assert router.stats["prefix_warmed_pages"] > 0, \
        "warm path never imported a page cross-replica"
    hits = sum(s.stats.get("prefix_hit_pages", 0) for s in servers)
    assert hits > 0, "warmed pages never produced a prefix hit"
    for s in servers:
        s._batcher.alloc.check()


def test_import_rejects_layout_mismatch(model):
    prompts = _prompts(model[0].vocab_size)
    pre = _server(model, kv_quant="kv8")
    dec = _server(model, kv_quant="kv4")
    uid = pre.submit(prompts[0], PARAMS[0], uid=0)
    pre._requests[uid].hold = True
    for _ in range(30):
        pre.step()
        if any(r is not None and r.output for r in pre._batcher.slots):
            break
    env = export_request(pre._batcher, uid)
    with pytest.raises(ValueError, match="kv_quant"):
        replica_mod.import_request(dec._batcher, env)
