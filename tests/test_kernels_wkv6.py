"""wkv6 kernel package: chunked jnp + Pallas-interpret vs recurrent oracle,
swept over shapes/chunks (+ hypothesis on the bounded-decay domain)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.wkv6 import wkv6
from repro.models.rwkv6 import wkv_chunked, wkv_recurrent

SWEEP = [
    # B, S, H, dh, chunk
    (2, 77, 3, 32, 32),
    (1, 64, 2, 64, 16),
    (3, 33, 1, 16, 32),
    (1, 128, 4, 64, 32),   # chunk > 32 overflows the cumprod (ops clamps)
]


def _inputs(B, S, H, dh, seed=1):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = jax.random.normal(ks[0], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh))
    v = jax.random.normal(ks[2], (B, S, H, dh))
    logw = -0.05 - 4.0 * jax.nn.sigmoid(
        jax.random.normal(ks[3], (B, S, H, dh)))
    u = jax.random.normal(ks[4], (H, dh)) * 0.5
    s0 = jax.random.normal(ks[5], (B, H, dh, dh)) * 0.1
    return r, k, v, logw, u, s0


@pytest.mark.parametrize("case", SWEEP)
def test_chunked_matches_recurrent(case):
    B, S, H, dh, chunk = case
    r, k, v, logw, u, s0 = _inputs(B, S, H, dh)
    o1, s1 = wkv_recurrent(r, k, v, logw, u, s0)
    o2, s2 = wkv_chunked(r, k, v, logw, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("case", SWEEP)
def test_pallas_interpret_matches_recurrent(case):
    B, S, H, dh, chunk = case
    r, k, v, logw, u, s0 = _inputs(B, S, H, dh)
    o1, s1 = wkv_recurrent(r, k, v, logw, u, s0)
    o2, s2 = wkv6(r, k, v, logw, u, s0, impl="interpret", chunk=chunk)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=5e-4, rtol=5e-4)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(3, 90), seed=st.integers(0, 999))
def test_state_chaining_property(s, seed):
    """Splitting a sequence at any point and chaining states == one shot."""
    B, H, dh = 1, 2, 16
    r, k, v, logw, u, s0 = _inputs(B, s, H, dh, seed)
    o_full, s_full = wkv_recurrent(r, k, v, logw, u, s0)
    cut = max(1, s // 3)
    o1, sm = wkv_chunked(r[:, :cut], k[:, :cut], v[:, :cut],
                         logw[:, :cut], u, s0, chunk=16)
    o2, s2 = wkv_chunked(r[:, cut:], k[:, cut:], v[:, cut:],
                         logw[:, cut:], u, sm, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(o_full), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=1e-3, rtol=1e-3)
