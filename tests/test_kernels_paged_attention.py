"""Paged decode attention: ref + Pallas-interpret vs dense oracle, sweeping
page geometry, GQA widths, windows, ragged lengths, dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import dense_attention_ref
from repro.kernels.paged_attention import paged_attention_partial

SWEEP = [
    # B, K, G, NP, T, dh, lengths, window, dtype
    (2, 3, 4, 8, 16, 32, (100, 37), None, jnp.float32),
    (2, 3, 4, 8, 16, 32, (100, 37), 24, jnp.float32),
    (1, 8, 1, 4, 8, 64, (30,), None, jnp.float32),
    (2, 2, 8, 16, 8, 16, (128, 5), None, jnp.float32),
    (1, 5, 5, 8, 16, 64, (99,), 40, jnp.float32),
    (2, 4, 2, 8, 32, 128, (200, 256), None, jnp.bfloat16),
]


def _build(B, K, NP, T, dh, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    kd = jax.random.normal(ks[0], (B, NP * T, K, dh), jnp.float32)
    vd = jax.random.normal(ks[1], (B, NP * T, K, dh), jnp.float32)
    k_pages = kd.reshape(B, NP, T, K, dh).transpose(0, 3, 1, 2, 4)
    v_pages = vd.reshape(B, NP, T, K, dh).transpose(0, 3, 1, 2, 4)
    base = jnp.broadcast_to((jnp.arange(NP) * T)[None], (B, NP)
                            ).astype(jnp.int32)
    return (kd.astype(dtype), vd.astype(dtype),
            k_pages.astype(dtype), v_pages.astype(dtype), base)


@pytest.mark.parametrize("case", SWEEP)
@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_vs_dense(case, impl):
    B, K, G, NP, T, dh, lengths, window, dtype = case
    H = K * G
    kd, vd, kp, vp, base = _build(B, K, NP, T, dh, dtype)
    q = jax.random.normal(jax.random.PRNGKey(9), (B, H, dh), jnp.float32
                          ).astype(dtype)
    length = jnp.asarray(lengths, jnp.int32)
    o, m, l = paged_attention_partial(q, kp, vp, base, length,
                                      window=window, impl=impl,
                                      pages_per_block=4)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    for b in range(B):
        L = int(lengths[b])
        ref = dense_attention_ref(
            q[b:b + 1, None].astype(jnp.float32),
            kd[b:b + 1, :L].astype(jnp.float32),
            vd[b:b + 1, :L].astype(jnp.float32),
            causal=True, window=window, q_offset=L - 1)
        np.testing.assert_allclose(np.asarray(o[b], np.float32),
                                   np.asarray(ref[0, 0]), atol=tol, rtol=tol)


def test_partial_stats_merge():
    """Splitting the page pool across two 'devices' and merging (m, l)
    reproduces the full attention — the paper's NPU aggregation."""
    from repro.core.seqpar import merge_two
    B, K, G, NP, T, dh = 1, 2, 2, 8, 8, 32
    H = K * G
    kd, vd, kp, vp, base = _build(B, K, NP, T, dh, jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(5), (B, H, dh))
    length = jnp.asarray([60], jnp.int32)
    o_full, _, _ = paged_attention_partial(q, kp, vp, base, length)
    half = NP // 2
    o1, m1, l1 = paged_attention_partial(q, kp[:, :, :half],
                                         vp[:, :, :half], base[:, :half],
                                         length)
    o2, m2, l2 = paged_attention_partial(q, kp[:, :, half:],
                                         vp[:, :, half:], base[:, half:],
                                         length)
    o, _, _ = merge_two(o1, m1, l1, o2, m2, l2)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_full),
                               atol=2e-5, rtol=2e-5)


def test_empty_shard_is_safe():
    """A shard holding no valid pages contributes zero weight."""
    from repro.core.seqpar import merge_two
    B, K, G, NP, T, dh = 1, 2, 2, 4, 8, 16
    kd, vd, kp, vp, base = _build(B, K, NP, T, dh, jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(5), (B, K * G, dh))
    length = jnp.asarray([20], jnp.int32)
    o_full, m_full, l_full = paged_attention_partial(q, kp, vp, base, length)
    empty_base = jnp.full_like(base, -(10 ** 9))
    o2, m2, l2 = paged_attention_partial(q, kp, vp, empty_base, length)
    assert float(l2.max()) == 0.0
    o, _, _ = merge_two(o_full, m_full, l_full, o2, m2, l2)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_full),
                               atol=1e-6)
