"""Paged decode attention: ref + Pallas-interpret vs dense oracle, sweeping
page geometry, GQA widths, windows, ragged lengths, dtypes — and the
split-page `partitions` axis against the monolithic walk."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import quantize_kv_page
from repro.kernels.flash_attention import dense_attention_ref
from repro.kernels.paged_attention import (paged_attention_partial,
                                           paged_chunk_attention)

SWEEP = [
    # B, K, G, NP, T, dh, lengths, window, dtype
    (2, 3, 4, 8, 16, 32, (100, 37), None, jnp.float32),
    (2, 3, 4, 8, 16, 32, (100, 37), 24, jnp.float32),
    (1, 8, 1, 4, 8, 64, (30,), None, jnp.float32),
    (2, 2, 8, 16, 8, 16, (128, 5), None, jnp.float32),
    (1, 5, 5, 8, 16, 64, (99,), 40, jnp.float32),
    (2, 4, 2, 8, 32, 128, (200, 256), None, jnp.bfloat16),
]


def _build(B, K, NP, T, dh, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    kd = jax.random.normal(ks[0], (B, NP * T, K, dh), jnp.float32)
    vd = jax.random.normal(ks[1], (B, NP * T, K, dh), jnp.float32)
    k_pages = kd.reshape(B, NP, T, K, dh).transpose(0, 3, 1, 2, 4)
    v_pages = vd.reshape(B, NP, T, K, dh).transpose(0, 3, 1, 2, 4)
    base = jnp.broadcast_to((jnp.arange(NP) * T)[None], (B, NP)
                            ).astype(jnp.int32)
    return (kd.astype(dtype), vd.astype(dtype),
            k_pages.astype(dtype), v_pages.astype(dtype), base)


@pytest.mark.parametrize("case", SWEEP)
@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_vs_dense(case, impl):
    B, K, G, NP, T, dh, lengths, window, dtype = case
    H = K * G
    kd, vd, kp, vp, base = _build(B, K, NP, T, dh, dtype)
    q = jax.random.normal(jax.random.PRNGKey(9), (B, H, dh), jnp.float32
                          ).astype(dtype)
    length = jnp.asarray(lengths, jnp.int32)
    o, m, l = paged_attention_partial(q, kp, vp, base, length,
                                      window=window, impl=impl,
                                      pages_per_block=4)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    for b in range(B):
        L = int(lengths[b])
        ref = dense_attention_ref(
            q[b:b + 1, None].astype(jnp.float32),
            kd[b:b + 1, :L].astype(jnp.float32),
            vd[b:b + 1, :L].astype(jnp.float32),
            causal=True, window=window, q_offset=L - 1)
        np.testing.assert_allclose(np.asarray(o[b], np.float32),
                                   np.asarray(ref[0, 0]), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# split-page `partitions` parity: every entry point, every pool format,
# every layout, partitions in {1, 4, NP} — identical math to the
# monolithic walk (partitions resolve through the same merge core the
# cross-device combine uses).

def _quantize(kp, vp, fmt):
    if fmt == "none":
        return kp, vp, None, None
    kq, ks = quantize_kv_page(kp, fmt)
    vq, vs = quantize_kv_page(vp, fmt)
    return kq, vq, ks, vs


def _shared_pool(kp, vp, ks, vs, seed=3):
    """Scatter a striped [B,K,NP,...] pool into a shared [K,P_total,...]
    pool behind a random per-slot page table."""
    B, K, NP = kp.shape[:3]
    Pt = B * NP + 4
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.permutation(Pt)[:B * NP].reshape(B, NP),
                        jnp.int32)
    def scatter(pages):
        pool = jnp.zeros((K, Pt) + pages.shape[3:], pages.dtype)
        for b in range(B):
            pool = pool.at[:, table[b]].set(pages[b])
        return pool
    kpool, vpool = scatter(kp), scatter(vp)
    kspool = None if ks is None else scatter(ks)
    vspool = None if vs is None else scatter(vs)
    return kpool, vpool, kspool, vspool, table


PARITY_FMTS = ["none", "kv8", "kv4"]


@pytest.mark.parametrize("fmt", PARITY_FMTS)
@pytest.mark.parametrize("layout", ["striped", "shared"])
@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_decode_partitions_parity(fmt, layout, impl):
    B, K, G, NP, T, dh = 2, 2, 4, 16, 8, 32
    H = K * G
    window = 40 if fmt == "none" else None
    _, _, kp, vp, base = _build(B, K, NP, T, dh, jnp.float32)
    kp, vp, ks, vs = _quantize(kp, vp, fmt)
    table = None
    if layout == "shared":
        kp, vp, ks, vs, table = _shared_pool(kp, vp, ks, vs)
    q = jax.random.normal(jax.random.PRNGKey(9), (B, H, dh))
    length = jnp.asarray([NP * T - 3, NP * T // 2 + 1], jnp.int32)
    kw = dict(window=window, impl=impl, kv_quant=fmt,
              k_scale=ks, v_scale=vs, page_table=table)
    ref = paged_attention_partial(q, kp, vp, base, length,
                                  partitions=1, **kw)
    for P in (4, NP):
        got = paged_attention_partial(q, kp, vp, base, length,
                                      partitions=P, **kw)
        for a, b in zip(ref, got):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("fmt", PARITY_FMTS)
@pytest.mark.parametrize("layout", ["striped", "shared"])
@pytest.mark.parametrize("mode", ["chunk", "verify", "one_shot"])
def test_chunk_partitions_parity(fmt, layout, mode):
    """The three multi-token shapes: chunked prefill (scalar start),
    speculative verify (per-row start, per-row q_pos) and one-shot
    prefill from position 0."""
    B, K, G, NP, T, dh, S = 2, 2, 2, 8, 8, 16, 4
    H = K * G
    _, _, kp, vp, base = _build(B, K, NP, T, dh, jnp.float32)
    kp, vp, ks, vs = _quantize(kp, vp, fmt)
    table = None
    if layout == "shared":
        kp, vp, ks, vs, table = _shared_pool(kp, vp, ks, vs)
    q = jax.random.normal(jax.random.PRNGKey(11), (B, S, H, dh))
    if mode == "chunk":
        start = jnp.int32(NP * T // 2)
        q_pos = start + jnp.arange(S)
    elif mode == "verify":
        start = jnp.asarray([NP * T - S - 1, NP * T // 3], jnp.int32)
        q_pos = start[:, None] + jnp.arange(S)[None, :]
    else:
        start = jnp.int32(0)
        q_pos = jnp.arange(S)
    kw = dict(window=None, kv_quant=fmt, k_scale=ks, v_scale=vs,
              page_table=table)
    ref = paged_chunk_attention(q, kp, vp, base, start, q_pos,
                                partitions=1, **kw)
    for P in (4, NP):
        got = paged_chunk_attention(q, kp, vp, base, start, q_pos,
                                    partitions=P, **kw)
        for a, b in zip(ref, got):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=5e-4, rtol=5e-4)


def test_unknown_impl_raises():
    B, K, G, NP, T, dh = 1, 2, 2, 4, 8, 16
    _, _, kp, vp, base = _build(B, K, NP, T, dh, jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(0), (B, K * G, dh))
    length = jnp.asarray([20], jnp.int32)
    with pytest.raises(ValueError, match="unknown attention impl"):
        paged_attention_partial(q, kp, vp, base, length, impl="oracle")
    with pytest.raises(ValueError, match="unknown attention impl"):
        paged_chunk_attention(q[:, None], kp, vp, base, jnp.int32(0),
                              jnp.arange(1), impl="chunked")


def test_pages_per_block_degradation_is_loud():
    """A blocking request the page count cannot honor raises instead of
    silently serializing page-at-a-time; explicit ppb=1 still works."""
    B, K, G, NP, T, dh = 1, 2, 2, 7, 8, 16   # 7 pages: no even divisor
    _, _, kp, vp, base = _build(B, K, NP, T, dh, jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(0), (B, K * G, dh))
    length = jnp.asarray([50], jnp.int32)
    with pytest.raises(ValueError, match="pages_per_block"):
        paged_attention_partial(q, kp, vp, base, length, impl="interpret",
                                pages_per_block=4)
    o, m, l = paged_attention_partial(q, kp, vp, base, length,
                                      impl="interpret", pages_per_block=1)
    o_ref, m_ref, l_ref = paged_attention_partial(q, kp, vp, base, length,
                                                  impl="ref")
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)


def test_partial_stats_merge():
    """Splitting the page pool across two 'devices' and merging (m, l)
    reproduces the full attention — the paper's NPU aggregation."""
    from repro.core.seqpar import merge_two
    B, K, G, NP, T, dh = 1, 2, 2, 8, 8, 32
    H = K * G
    kd, vd, kp, vp, base = _build(B, K, NP, T, dh, jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(5), (B, H, dh))
    length = jnp.asarray([60], jnp.int32)
    o_full, _, _ = paged_attention_partial(q, kp, vp, base, length)
    half = NP // 2
    o1, m1, l1 = paged_attention_partial(q, kp[:, :, :half],
                                         vp[:, :, :half], base[:, :half],
                                         length)
    o2, m2, l2 = paged_attention_partial(q, kp[:, :, half:],
                                         vp[:, :, half:], base[:, half:],
                                         length)
    o, _, _ = merge_two(o1, m1, l1, o2, m2, l2)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_full),
                               atol=2e-5, rtol=2e-5)


def test_empty_shard_is_safe():
    """A shard holding no valid pages contributes zero weight."""
    from repro.core.seqpar import merge_two
    B, K, G, NP, T, dh = 1, 2, 2, 4, 8, 16
    kd, vd, kp, vp, base = _build(B, K, NP, T, dh, jnp.float32)
    q = jax.random.normal(jax.random.PRNGKey(5), (B, K * G, dh))
    length = jnp.asarray([20], jnp.int32)
    o_full, m_full, l_full = paged_attention_partial(q, kp, vp, base, length)
    empty_base = jnp.full_like(base, -(10 ** 9))
    o2, m2, l2 = paged_attention_partial(q, kp, vp, empty_base, length)
    assert float(l2.max()) == 0.0
    o, _, _ = merge_two(o_full, m_full, l_full, o2, m2, l2)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_full),
                               atol=1e-6)
