"""Shared test fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see
the real single CPU device; multi-device tests spawn subprocesses."""
import jax
import pytest

from _jit_guard import failures


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "allow_recompile: opt out of the jit-cache guard for tests that "
        "legitimately compile several signatures of one step callable")


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True)
def _jit_cache_guard(request):
    """Snapshot jit cache sizes on every decode/verify callable built
    during the test; fail on silent recompilation (>1 signature)."""
    from repro.serving import scheduler

    watched = []
    prev = scheduler.JIT_WATCH
    scheduler.JIT_WATCH = watched
    try:
        yield watched
    finally:
        scheduler.JIT_WATCH = prev
    if request.node.get_closest_marker("allow_recompile"):
        return
    bad = failures(watched)
    if bad:
        pytest.fail("silent recompilation detected — "
                    + "; ".join(bad), pytrace=False)
