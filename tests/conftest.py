"""Shared test fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see
the real single CPU device; multi-device tests spawn subprocesses."""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
