"""Self-speculative decoding (DESIGN.md §11): prompt-lookup drafting,
the sampler's accept rule, token parity of greedy (and seeded
stochastic) spec-decode vs sequential decode across every KV layout, and
the shared-pool allocator's accept/rollback conservation invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import EngineConfig, get_config
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.serving.draft import propose_draft
from repro.serving.sampler import (SamplingParams, speculative_accept)
from repro.serving.scheduler import ContinuousBatcher, Request

ARCH = "qwen1.5-0.5b"
# repetitive + mixed prompts: lookup drafting must actually accept on the
# first, and must stay harmless on the random ones
REP = [7, 8, 9, 10] * 5
PROMPTS = [REP, list(range(1, 20)), [5, 4, 3]]


def _model(arch=ARCH):
    cfg = get_config(arch).reduced()
    rt = Runtime()
    return cfg, rt, Model(cfg, rt).init(jax.random.PRNGKey(0))


def _drain(cfg, params, eng, prompts, *, spec_k, max_new=8, slots=2,
           ctx=96, chunk=16, sp=None):
    b = ContinuousBatcher(cfg, params, batch_slots=slots, max_context=ctx,
                          temperature=0.0, eng=eng,
                          prefill_chunk_tokens=chunk,
                          speculation_k=spec_k)
    for uid, p in enumerate(prompts):
        r = Request(uid, list(p), max_new=max_new)
        if sp is not None:
            r.params = sp
        b.submit(r)
    done = b.run_to_completion()
    return {u: r.output for u, r in done.items()}, b


# ---------------------------------------------------------------------------
# drafter: prompt lookup
# ---------------------------------------------------------------------------

def test_propose_draft_lookup_and_fallback():
    # trailing [3, 4] recurs: the draft continues from its last earlier
    # occurrence
    assert propose_draft([1, 2, 3, 4, 9, 3, 4], 3) == [9, 3, 4]
    # no recurrence: repeat the last token
    assert propose_draft([1, 2, 3], 2) == [3, 3]
    # match near the end pads by repeating the last token
    assert propose_draft([5, 6, 5, 6], 4) == [5, 6, 6, 6]
    assert propose_draft([1], 0) == []
    assert propose_draft([], 3) == []


# ---------------------------------------------------------------------------
# sampler: accept rule (greedy-exact, allowed-gated)
# ---------------------------------------------------------------------------

def test_speculative_accept_greedy_counts_leading_matches():
    B, S, V = 2, 4, 11
    lg = jax.random.normal(jax.random.PRNGKey(0), (B, S, V))
    arg = np.asarray(jnp.argmax(lg, -1))
    drafts = arg[:, :-1].copy()
    drafts[0, 1] = (drafts[0, 1] + 1) % V        # row 0 mismatch at j=1
    toks, lps, acc = speculative_accept(
        jnp.asarray(lg), jnp.asarray(drafts),
        np.zeros(B, np.uint32), np.zeros(B, np.int32),
        np.full(B, S - 1, np.int32), true_vocab=V)
    np.testing.assert_array_equal(np.asarray(toks), arg)  # greedy == argmax
    assert list(np.asarray(acc)) == [1, S - 1]
    # allowed caps acceptance without changing the sampled tokens
    toks2, _, acc2 = speculative_accept(
        jnp.asarray(lg), jnp.asarray(drafts),
        np.zeros(B, np.uint32), np.zeros(B, np.int32),
        np.zeros(B, np.int32), true_vocab=V)
    np.testing.assert_array_equal(np.asarray(toks2), arg)
    assert list(np.asarray(acc2)) == [0, 0]


# ---------------------------------------------------------------------------
# token parity: speculative == sequential, every layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [dict(kv_dtype="float32"),
                                dict(kv_quant="kv8")],
                         ids=["f32", "kv8"])
def test_spec_matches_sequential_formats(kw):
    cfg, rt, params = _model()
    eng = EngineConfig(page_tokens=16, uniform_lengths=False, **kw)
    o0, _ = _drain(cfg, params, eng, PROMPTS, spec_k=0)
    o4, b4 = _drain(cfg, params, eng, PROMPTS, spec_k=4)
    assert o0 == o4
    assert b4.stats["spec_accepted"] > 0     # the repetitive prompt pays
    assert b4.stats["spec_steps"] < b4.stats["decode_tokens"]


def test_spec_matches_sequential_window_ring():
    """gemma3 local:global mix — span appends through the ring, accepted
    tokens only advance the ring bases."""
    cfg, rt, params = _model("gemma3-12b")
    prompts = PROMPTS + [list(range(1, 78))]     # > reduced window of 64
    eng = EngineConfig(page_tokens=16, uniform_lengths=False,
                       kv_dtype="float32")
    o0, _ = _drain(cfg, params, eng, prompts, spec_k=4, max_new=4)
    o1, _ = _drain(cfg, params, eng, prompts, spec_k=0, max_new=4)
    assert o0 == o1


def test_spec_matches_sequential_shared_pool():
    cfg, rt, params = _model()
    eng = EngineConfig(page_tokens=16, uniform_lengths=False,
                       shared_pool=True, kv_dtype="float32")
    o0, _ = _drain(cfg, params, eng, PROMPTS, spec_k=0)
    o4, b4 = _drain(cfg, params, eng, PROMPTS, spec_k=4)
    assert o0 == o4
    assert b4.stats["spec_accepted"] > 0
    b4.alloc.check()
    assert b4._outstanding == 0
    assert b4.alloc.live_count == b4.prefix_cache.evictable_pages()


def test_spec_seeded_stochastic_stream_parity():
    """Sampling rows draw every span position from the request's own
    fold_in(seed, position) stream, so seeded outputs are identical with
    speculation on or off."""
    cfg, rt, params = _model()
    eng = EngineConfig(page_tokens=16, uniform_lengths=False,
                       kv_dtype="float32")
    sp = SamplingParams(temperature=0.9, top_k=8, seed=123,
                        max_new_tokens=8)
    o0, _ = _drain(cfg, params, eng, PROMPTS, spec_k=0, sp=sp)
    o4, _ = _drain(cfg, params, eng, PROMPTS, spec_k=4, sp=sp)
    assert o0 == o4


def test_spec_per_request_opt_out():
    """SamplingParams.speculation=0 keeps the request out of drafting
    (no drafts offered) without changing its tokens."""
    cfg, rt, params = _model()
    eng = EngineConfig(page_tokens=16, uniform_lengths=False,
                       kv_dtype="float32")
    sp = SamplingParams(max_new_tokens=8, speculation=0)
    o0, _ = _drain(cfg, params, eng, [REP], spec_k=0)
    o4, b4 = _drain(cfg, params, eng, [REP], spec_k=4, sp=sp)
    assert o0 == o4
    assert b4.stats["spec_drafted"] == 0
    assert b4.stats["spec_accepted"] == 0
    # opted-out rows are not counted as verify steps either, so their
    # accepted_tokens_per_step stays None instead of a misleading 1.0
    assert b4.stats["spec_steps"] == 0
    assert all(r.spec_steps == 0 for r in b4.completed.values())


def test_spec_rejected_unsupported_archs():
    cfg, rt, params = _model("rwkv6-3b")
    with pytest.raises(ValueError, match="speculat"):
        ContinuousBatcher(cfg, params, batch_slots=2, max_context=96,
                          speculation_k=2)


# ---------------------------------------------------------------------------
# accepted-tokens-per-step surfaces through the API
# ---------------------------------------------------------------------------

def test_request_output_acceptance_stats():
    from repro.serving.api import KVNANDServer, ServerConfig
    cfg, rt, params = _model()
    server = KVNANDServer(
        ServerConfig(batch_slots=2, max_context=96,
                     prefill_chunk_tokens=16, speculation_k=4,
                     engine=EngineConfig(page_tokens=16,
                                         uniform_lengths=False,
                                         kv_dtype="float32")),
        cfg=cfg, params=params)
    [out] = server.generate([REP], SamplingParams(max_new_tokens=12))
    assert out.spec_steps > 0
    assert out.accepted_tokens_per_step is not None
    # the repetitive prompt must actually amortize: > 1 token per step
    assert out.accepted_tokens_per_step > 1.0
    # first token from the prefill handoff, then verify steps; steps
    # whose budget cannot accept anything (e.g. the last max_new token)
    # fall back to sequential decode and carry no spec counters
    assert len(out.token_ids) >= 1 + out.spec_accepted + out.spec_steps
    assert out.spec_drafted >= out.spec_accepted


def test_spec_stop_token_truncates_span_and_stats():
    """A stop token accepted mid-span truncates emission there, and the
    acceptance counters reflect EMITTED tokens only (every finish reason
    keeps len(output) == 1 + spec_accepted + spec_steps)."""
    cfg, rt, params = _model()
    eng = EngineConfig(page_tokens=16, uniform_lengths=False,
                       kv_dtype="float32")
    # learn greedy continuation, then stop on its 3rd emitted token
    ref, _ = _drain(cfg, params, eng, [REP], spec_k=4, max_new=10)
    stop = ref[0][2]
    sp = SamplingParams(max_new_tokens=10, stop_token_ids=(stop,))
    out, b = _drain(cfg, params, eng, [REP], spec_k=4, sp=sp)
    req = b.completed[0]
    assert req.finish_reason == "stop"
    assert out[0] == ref[0][:out[0].index(stop) + 1]
    assert len(out[0]) == 1 + req.spec_accepted + req.spec_steps


# ---------------------------------------------------------------------------
# rollback: allocator conservation under arbitrary draft/accept traces
# ---------------------------------------------------------------------------

def _shared_eng(total_pages=0):
    return EngineConfig(page_tokens=4, uniform_lengths=False,
                        kv_dtype="float32", shared_pool=True,
                        total_pages=total_pages)


def test_rollback_returns_speculated_pages():
    """A span that crosses into a freshly allocated page whose drafts
    are all rejected must hand the page straight back: free count,
    refcounts and reservations exactly as if it was never allocated."""
    cfg, rt, params = _model()
    eng = _shared_eng()
    b = ContinuousBatcher(cfg, params, batch_slots=1, max_context=32,
                          temperature=0.0, eng=eng,
                          prefill_chunk_tokens=4, speculation_k=6)
    # non-repetitive prompt: lookup drafts miss, so most steps accept 0
    b.submit(Request(0, list(range(1, 6)), max_new=6))
    while b.queue or any(r is not None for r in b.slots):
        b.step()
        b.alloc.check()
        assert b._outstanding == int(b._resv.sum())
        # pages taken for the span beyond the accepted extent came back:
        # a DECODING slot never keeps a mapping past its written length
        # (mid-prefill slots hold pages ahead of `_lengths` by design)
        if b.slots[0] is not None and 0 not in b._prefill_live:
            last = (int(b._lengths[0]) - 1) // 4
            assert all(lp <= last for lp in b._slot_pages[0])
    b.alloc.check()
    assert b._outstanding == 0


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), spec_k=st.integers(1, 5),
       total_pages=st.sampled_from([16, 24]))
def test_spec_shared_pool_conservation_property(seed, spec_k, total_pages):
    """Hypothesis: ANY draft/accept trace (prompts drawn from a small
    alphabet so acceptance varies organically) drains with exact
    refcounts — no orphaned pages, reservations fully released, and
    token outputs identical to sequential decode."""
    import random
    rng = random.Random(seed)
    cfg, rt, params = _model()
    prompts = [[rng.randrange(3, 9) for _ in range(rng.randrange(3, 14))]
               for _ in range(3)]
    eng = _shared_eng(total_pages=total_pages)
    o_seq, _ = _drain(cfg, params, eng, prompts, spec_k=0, ctx=48,
                      chunk=4, max_new=6)
    b = ContinuousBatcher(cfg, params, batch_slots=2, max_context=48,
                          temperature=0.0, eng=eng,
                          prefill_chunk_tokens=4, speculation_k=spec_k)
    for uid, p in enumerate(prompts):
        b.submit(Request(uid, list(p), max_new=6))
    while b.queue or any(r is not None for r in b.slots):
        b.step()
        b.alloc.check()                       # conservation every step
        assert b._outstanding == int(b._resv.sum()) >= 0
    assert {u: r.output for u, r in b.completed.items()} == o_seq
    b.alloc.check()
    assert b._outstanding == 0
    # every live page is a prefix-cache reference — nothing orphaned
    assert b.alloc.live_count == b.prefix_cache.evictable_pages()


def test_spec_abort_mid_flight_conserves_pages():
    cfg, rt, params = _model()
    b = ContinuousBatcher(cfg, params, batch_slots=2, max_context=48,
                          temperature=0.0, eng=_shared_eng(),
                          prefill_chunk_tokens=4, speculation_k=3)
    b.submit(Request(0, [2, 3, 4, 2, 3, 4, 2, 3], max_new=16))
    b.submit(Request(1, list(range(1, 9)), max_new=16))
    for _ in range(3):
        b.step()
    assert b.abort(0)
    b.alloc.check()
    assert b._outstanding == int(b._resv.sum())
    b.run_to_completion()
    b.alloc.check()
    assert b._outstanding == 0
