"""Run a python snippet in a subprocess with N fake XLA host devices.

jax pins the device count at first initialization, so multi-device tests
cannot run in the pytest process (which must keep 1 device for the smoke
tests).  Each snippet runs `python -c` with XLA_FLAGS set first.
"""
import os
import subprocess
import sys

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 480):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                        f"{n_devices}")
    env["PYTHONPATH"] = REPO_SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"multidevice subprocess failed\n--- stdout ---\n"
            f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}")
    return proc.stdout
