"""Optional-`hypothesis` shim.

The container image may not ship hypothesis; property tests then run as
seeded random sweeps (bounded example count) instead of failing at import.
Only the strategy surface the test suite actually uses is stubbed:
``st.integers`` (+ ``.map``), ``st.sampled_from``, ``@given(**kw)``,
``@settings``.
"""
import random

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def map(self, fn):
            return _Strategy(lambda r: fn(self._draw(r)))

        def example(self, rng):
            return self._draw(rng)

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def sampled_from(xs):
            elems = list(xs)
            return _Strategy(lambda r: r.choice(elems))

        @staticmethod
        def floats(min_value, max_value, **kw):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.getrandbits(1)))

    def settings(max_examples=20, **kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = min(getattr(wrapper, "_max_examples",
                                getattr(fn, "_max_examples", 20)), 25)
                rng = random.Random(0)
                for _ in range(n):
                    draws = {k: s.example(rng)
                             for k, s in strategies.items()}
                    fn(*args, **draws, **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
