"""Config registry: exact hyperparameters, counts, sharding divisibility."""
import pytest

from repro.configs import (
    ASSIGNED_ARCHS, PAPER_ARCHS, SHAPES, get_config, list_configs,
    shape_applicable,
)

EXPECTED_PARAMS_B = {
    "dbrx-132b": (125, 140),
    "kimi-k2-1t-a32b": (950, 1100),
    "pixtral-12b": (11, 14),
    "qwen1.5-4b": (3.2, 4.5),
    "qwen2.5-32b": (30, 35),
    "gemma3-12b": (10.5, 13),
    "qwen1.5-0.5b": (0.4, 0.7),
    "whisper-base": (0.05, 0.12),
    "rwkv6-3b": (2.8, 4.0),
    "hymba-1.5b": (1.0, 1.8),
    "opt-30b": (28, 33),
    "llama2-7b": (6, 7.5),
    "llama3.1-8b": (7.5, 8.7),
    "llama3.1-70b": (68, 73),
    "mixtral-8x7b": (45, 48),
}


def test_all_registered():
    cfgs = list_configs()
    for a in ASSIGNED_ARCHS + PAPER_ARCHS:
        assert a in cfgs


@pytest.mark.parametrize("arch", list(EXPECTED_PARAMS_B))
def test_param_counts(arch):
    lo, hi = EXPECTED_PARAMS_B[arch]
    n = get_config(arch).param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.1f}B not in [{lo}, {hi}]"


def test_kimi_active_params():
    cfg = get_config("kimi-k2-1t-a32b")
    assert 25 <= cfg.active_param_count() / 1e9 <= 40  # ~32B active


def test_mixtral_kv_per_token_matches_paper():
    # §III-B: 128 KB at BF16
    assert get_config("mixtral-8x7b").kv_bytes_per_token(2) == 128 * 1024


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_tp16_divisibility(arch):
    """Every TP-sharded dim must divide the 16-wide model axis."""
    cfg = get_config(arch)
    assert cfg.d_model % 16 == 0
    assert cfg.d_ff % 16 == 0 or cfg.is_moe
    assert cfg.padded_vocab % 16 == 0
    if cfg.n_heads:
        assert (cfg.group_size * cfg.d_head) % 16 == 0  # wq columns
        assert cfg.d_head % 16 == 0 or cfg.d_head % 16 in (7, 0) or \
            cfg.d_head * cfg.n_kv_heads % 16 == 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_configs_valid(arch):
    r = get_config(arch).reduced()
    assert r.param_count() < 5e6 or r.is_moe
    if r.n_heads:
        assert r.n_heads % r.n_kv_heads == 0


def test_long_500k_applicability():
    longs = {a: shape_applicable(get_config(a), SHAPES["long_500k"])[0]
             for a in ASSIGNED_ARCHS}
    assert longs == {
        "dbrx-132b": False, "kimi-k2-1t-a32b": False, "pixtral-12b": False,
        "qwen1.5-4b": False, "qwen2.5-32b": False, "gemma3-12b": True,
        "qwen1.5-0.5b": False, "whisper-base": False, "rwkv6-3b": True,
        "hymba-1.5b": True,
    }


def test_gemma3_local_global_pattern():
    cfg = get_config("gemma3-12b")
    flags = [cfg.is_global_layer(i) for i in range(12)]
    assert flags == [False] * 5 + [True] + [False] * 5 + [True]


def test_engine_hot_pages_validation():
    """Tiered-pool knob (DESIGN.md §13): hot_pages needs the shared
    pool and must fit inside the flash pool."""
    from repro.configs import EngineConfig
    eng = EngineConfig(shared_pool=True, total_pages=64, hot_pages=12)
    assert eng.hot_pages == 12
    with pytest.raises(ValueError):
        EngineConfig(hot_pages=8)              # tiering the stripes
    with pytest.raises(ValueError):
        EngineConfig(shared_pool=True, total_pages=8, hot_pages=16)
    with pytest.raises(ValueError):
        EngineConfig(shared_pool=True, hot_pages=-1)
