"""Fault-tolerance: atomic checkpoints, integrity, keep-K, async, restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ck
from repro.training.optimizer import AdamWConfig, init_adamw


def _state():
    params = {"layers": {"w": jnp.arange(12.0).reshape(3, 4)},
              "emb": jnp.ones((5, 2))}
    return {"params": params,
            "opt": init_adamw(params, AdamWConfig())}


def test_roundtrip_exact(tmp_path):
    state = _state()
    ck.save_checkpoint(str(tmp_path), 7, state, extra={"cursor": 99})
    restored, extra = ck.restore_checkpoint(str(tmp_path), 7, state)
    assert extra["cursor"] == 99
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_retention(tmp_path):
    state = _state()
    for s in range(6):
        ck.save_checkpoint(str(tmp_path), s, state, keep=3)
    assert ck.list_steps(str(tmp_path)) == [3, 4, 5]


def test_corrupted_checkpoint_skipped(tmp_path):
    state = _state()
    ck.save_checkpoint(str(tmp_path), 1, state)
    ck.save_checkpoint(str(tmp_path), 2, state)
    # corrupt the newest
    with open(os.path.join(str(tmp_path), "step_00000002", "arrays.npz"),
              "r+b") as f:
        f.seek(10)
        f.write(b"\x00" * 32)
    assert ck.latest_step(str(tmp_path)) == 1
    with pytest.raises(ValueError):
        ck.restore_checkpoint(str(tmp_path), 2, state)


def test_partial_write_invisible(tmp_path):
    """A crash mid-write (tmp dir never renamed) is never listed."""
    state = _state()
    ck.save_checkpoint(str(tmp_path), 1, state)
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp-abc"))
    assert ck.list_steps(str(tmp_path)) == [1]


def test_async_checkpointer(tmp_path):
    state = _state()
    ac = ck.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        ac.save(s, state, extra={"cursor": s})
    ac.wait()
    assert ck.latest_step(str(tmp_path)) == 3
    _, extra = ck.restore_checkpoint(str(tmp_path), 3, state)
    assert extra["cursor"] == 3


def test_elastic_restore_dtype_preserved(tmp_path):
    """Restore into a like-tree with bf16 leaves keeps dtypes (re-shard on
    a different topology is exercised in test_multidevice)."""
    state = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    ck.save_checkpoint(str(tmp_path), 0, state)
    restored, _ = ck.restore_checkpoint(str(tmp_path), 0, state)
    assert restored["w"].dtype == jnp.bfloat16
