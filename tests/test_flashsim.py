"""Track-A flash simulator vs the paper's own numbers (§III-B, §V)."""
import math


from repro.configs import get_config
from repro.core import flashsim as fs


def geomean(xs):
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


MODELS = ["opt-30b", "llama2-7b", "llama3.1-8b", "llama3.1-70b",
          "mixtral-8x7b"]


def _best_kvnand_tp(cfg, seq, W=16, A=16):
    cands = [fs.kvnand_c(16, W, A)] + \
        [fs.kvnand_d(g1, 8 - g1, W, A) for g1 in range(1, 8)]
    return max(fs.decode_throughput(s, cfg, seq) for s in cands)


def test_mixtral_kv_per_token():
    # §III-B: KV_per_tk = 128 KB in BF16
    assert fs.kv_bytes_per_token(get_config("mixtral-8x7b"), 16) \
        == 128 * 1024


def test_naive_kv_read_6_9ms():
    # §III-B: 1K-ctx KV read over 4 dies' external BW ≈ 6.9 ms
    mix = get_config("mixtral-8x7b")
    die = fs.FlashDie()
    t = fs.kv_bytes_layer(mix, 1024, 16) * mix.n_layers / (4 * die.ext_bw)
    assert abs(t - 6.9e-3) < 0.4e-3


def test_ffn_read_44ms():
    # §III-B: Mixtral INT4 FFN (2 active experts) over 4 dies internal ≈ 44ms
    mix = get_config("mixtral-8x7b")
    die = fs.FlashDie()
    expert = 3 * mix.d_model * mix.d_ff * 4 / 8
    t = mix.n_layers * expert * 2 / (4 * die.int_bw)
    assert abs(t - 44e-3) < 3e-3


def test_internal_bandwidth_32gbs():
    assert abs(fs.FlashDie().int_bw - 32e9) < 1.5e9


def test_die_capacity_16gb():
    # Table I: 132.75 Gb per die
    assert abs(fs.FlashDie().capacity - 132.75e9 / 8) < 0.5e9


def test_geomean_speedups_short_ctx():
    """Fig 12 headline: 1.98×/1.94× geomean vs Base-1 at 128/1K (±15%)."""
    for seq, target in ((128, 1.98), (1_000, 1.94)):
        sp = []
        for m in MODELS:
            cfg = get_config(m)
            b1 = fs.decode_throughput(fs.base1(16, 16), cfg, seq)
            bb = _best_kvnand_tp(cfg, seq)
            if b1 > 0:
                sp.append(bb / b1)
        g = geomean(sp)
        assert abs(g - target) / target < 0.15, (seq, g, target)


def test_geomean_speedup_10k_direction():
    """At 10K the advantage grows (paper 2.05×; our bandwidth model is
    within ~25% — divergence documented in EXPERIMENTS.md)."""
    sp = []
    for m in MODELS:
        cfg = get_config(m)
        b1 = fs.decode_throughput(fs.base1(16, 16), cfg, 10_000)
        bb = _best_kvnand_tp(cfg, 10_000)
        if b1 > 0:
            sp.append(bb / b1)
    g = geomean(sp)
    assert 1.9 < g < 2.7


def test_base1_oom_at_100k():
    for m in MODELS:
        assert fs.is_oom(fs.base1(16, 16), get_config(m), 100_000), m


def test_kvnand_resolves_100k():
    for m in MODELS:
        cfg = get_config(m)
        ok = any(not fs.is_oom(s, cfg, 100_000)
                 for s in [fs.kvnand_c(16, 4, 16)]
                 + [fs.kvnand_d(g, 16 - g, 4, 16) for g in range(4, 13)])
        assert ok, m


def test_8b_100k_throughput_order():
    tp = _best_kvnand_tp(get_config("llama3.1-8b"), 100_000)
    assert 5 <= tp <= 35          # paper: ~10 tokens/s


def test_hg_pipeline_ablation_direction():
    """Fig 14a: HG pipelining reduces latency (paper 82.4% @10K)."""
    cfg = get_config("llama3.1-8b")
    on = fs.decode_token_latency(fs.kvnand_d(4, 4, 16, 16, hg=True),
                                 cfg, 10_000).total
    off = fs.decode_token_latency(fs.kvnand_d(4, 4, 16, 16, hg=False),
                                  cfg, 10_000).total
    assert 0.75 < on / off < 0.97


def test_page_mapping_ablation_matches_paper():
    """Fig 14b: MHA-30B @100K attention-read time collapses to ~1.9%."""
    cfg = get_config("opt-30b")
    on = fs._attn_terms(fs.kvnand_c(16, 16, 16, mapping=True), cfg,
                        100_000)[0]
    off = fs._attn_terms(fs.kvnand_c(16, 16, 16, mapping=False), cfg,
                         100_000)[0]
    assert 0.01 < on / off < 0.035


def test_energy_improves_with_context():
    """Fig 16 trend: KVNAND energy advantage grows with context."""
    cfg = get_config("llama2-7b")
    ratios = []
    for seq in (1_000, 10_000, 30_000):
        e_kv = fs.decode_token_energy(fs.kvnand_c(16, 16, 16), cfg,
                                      seq)["total"]
        e_b1 = fs.decode_token_energy(fs.base1(16, 16), cfg, seq)["total"]
        ratios.append(e_kv / e_b1)
    assert ratios[0] > ratios[-1]
    assert ratios[-1] < 1.0


def test_hot_tier_pages_from_sram_budget():
    """Hot-tier sizing (DESIGN.md §13): the SRAM staging buffer in KV
    pages, growing as KV quantization shrinks pages."""
    cfg = get_config("llama3.1-8b")
    kv8 = fs.kvnand_d(8, 8, 4, 16, kv_bits=8)
    b = fs.kv_page_bytes(cfg, 8, 64)
    assert b == fs.kv_bytes_per_token(cfg, 8) * 64
    assert fs.hot_tier_pages(kv8, cfg) == int(kv8.npu.sram_kv_buffer // b)
    kv4 = fs.kvnand_d(8, 8, 4, 16, kv_bits=4)
    assert fs.hot_tier_pages(kv4, cfg) >= fs.hot_tier_pages(kv8, cfg)
    # rwkv6's recurrent state is modeled as heavy per-token "KV": one
    # 64-token page overflows the SRAM buffer -> 0 (no SRAM hot tier)
    assert fs.hot_tier_pages(kv8, get_config("rwkv6-3b")) == 0


def test_page_promote_time_and_stall_model():
    """A demand promotion pays a page-granular flash read (tR) plus the
    transfer over the KV medium's external interface; Base-1 stages
    from DRAM (no tR); stall time is linear in demand faults."""
    cfg = get_config("llama3.1-8b")
    sysd = fs.kvnand_d(8, 8, 4, 16, kv_bits=8)
    t = fs.page_promote_time(sysd, cfg)
    assert t > sysd.die.tR
    s1 = fs.base1()
    assert fs.page_promote_time(s1, cfg) == \
        fs.kv_page_bytes(cfg, s1.kv_bits_eff) / s1.dram.bw
    assert fs.tier_stall_time(sysd, cfg, 7) == 7 * t
    assert fs.tier_stall_time(sysd, cfg, 0) == 0.0


def test_serving_step_time_overlap_hides_host_work():
    """Serving step model (DESIGN.md §14): the synchronous loop pays
    device + host serially; the pipelined loop pays max of the two."""
    import pytest
    cfg = get_config("llama3.1-8b")
    sysd = fs.kvnand_d(8, 8, 4, 16, kv_bits=8)
    dev = fs.serving_step_time(sysd, cfg, 10_000, 0.0, overlap=False)
    assert fs.serving_step_time(sysd, cfg, 10_000, 0.0, overlap=True) == dev
    host = 3 * dev
    sync = fs.serving_step_time(sysd, cfg, 10_000, host, overlap=False)
    piped = fs.serving_step_time(sysd, cfg, 10_000, host, overlap=True)
    assert sync == dev + host
    assert piped == max(dev, host) == host      # host-bound: fully hidden
    # speedup is sync/piped, 1.0 at either extreme, capped at 2.0 when
    # host and device are perfectly balanced
    assert fs.overlap_speedup(sysd, cfg, 10_000, 0.0) == 1.0
    s = fs.overlap_speedup(sysd, cfg, 10_000, dev)
    assert s == pytest.approx(2.0)
    for h in (0.1 * dev, dev, 10 * dev):
        assert 1.0 <= fs.overlap_speedup(sysd, cfg, 10_000, h) <= 2.0
    with pytest.raises(ValueError):
        fs.serving_step_time(sysd, cfg, 10_000, -1e-3, overlap=True)
