"""Jit-cache guard: detect silent recompilation of step callables.

`repro.serving.scheduler` registers each batcher's `_decode`/`_verify`
callable on the module-level ``JIT_WATCH`` list when one is set.  The
autouse fixture in conftest installs a fresh list per test and, at
teardown, fails the test if any watched callable compiled more than one
signature — the one-compiled-signature invariant (DESIGN.md §10) is what
keeps steady-state decode off the trace/compile path.
"""
from typing import Iterable, List, Tuple


def cache_size(fn) -> int:
    """Compiled-signature count of a jitted callable (0 if untraced or
    the jax version exposes no counter)."""
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return 0
    try:
        return int(probe())
    except Exception:
        return 0


def failures(watched: Iterable[Tuple[str, object]],
             limit: int = 1) -> List[str]:
    """Human-readable violations: watched callables whose compile cache
    exceeds `limit` signatures."""
    out = []
    for name, fn in watched:
        n = cache_size(fn)
        if n > limit:
            out.append(f"{name}: {n} compiled signatures (expected "
                       f"<= {limit})")
    return out
