"""ReplicaRouter fuzz/soak (ISSUE 10 satellite).

Randomized submit/abort/deadline mixes over 3 FAKE-CLOCK replicas:
the queue must fully drain, no priority class may starve (every
surviving request finishes with a real reason and its full output), and
page conservation must hold per replica at drain — in routed AND
disaggregated mode, where aborts can land while a request sits held
awaiting migration.
"""
import random

import jax
import pytest

import repro.serving.api as api_mod
import repro.serving.scheduler as sched_mod
from repro.configs import EngineConfig, get_config
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.serving.api import KVNANDServer, ServerConfig
from repro.serving.router import ReplicaRouter
from repro.serving.sampler import SamplingParams

TOTAL_PAGES = 48


class FakeClock:
    """Deterministic stand-in for the `time` module: the scheduler and
    server only call `monotonic()`."""

    def __init__(self):
        self.t = 1000.0

    def monotonic(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture(scope="module")
def model():
    cfg = get_config("qwen1.5-0.5b").reduced()
    rt = Runtime()
    return cfg, rt, Model(cfg, rt).init(jax.random.PRNGKey(0))


@pytest.fixture()
def clock(monkeypatch):
    clk = FakeClock()
    monkeypatch.setattr(sched_mod, "time", clk)
    monkeypatch.setattr(api_mod, "time", clk)
    return clk


def _server(model, slots=2):
    cfg, rt, params = model
    eng = EngineConfig(page_tokens=16, uniform_lengths=False,
                       shared_pool=True, total_pages=TOTAL_PAGES)
    sc = ServerConfig(arch="qwen1.5-0.5b", reduced=True, engine=eng,
                      batch_slots=slots, max_context=64,
                      prefill_chunk_tokens=16, seed=7)
    return KVNANDServer(sc, cfg=cfg, params=params, rt=rt)


def _conserved(server):
    b = server._batcher
    assert not b.queue and all(r is None for r in b.slots)
    b.alloc.check()
    if b.tier is not None:
        b.tier.check()
        assert b.tier.pinned_count == 0
    # every page still live must belong to the prefix cache: evict it
    # all and the pool must be whole again (nothing leaked)
    if b.prefix_cache is not None:
        while b.prefix_cache.evict_lru():
            pass
    b.alloc.check()
    assert b.alloc.free_count == b.alloc.total, "leaked pages at drain"


def _soak(model, clock, *, disaggregate, seed, n_requests=18):
    rng = random.Random(seed)
    vocab = model[0].vocab_size
    servers = [_server(model) for _ in range(3)]
    router = ReplicaRouter(servers, disaggregate=disaggregate)
    meta = {}           # uid -> (priority, deadline, max_new)
    submitted = []
    aborted = set()
    steps = 0
    while len(meta) < n_requests or router._busy():
        if len(meta) < n_requests and rng.random() < 0.6:
            prompt = [rng.randrange(1, vocab)
                      for _ in range(rng.randint(1, 30))]
            prio = rng.randrange(3)
            deadline = rng.choice([None, None, 0.02, 300.0])
            max_new = rng.randint(1, 5)
            uid = router.submit(
                prompt, SamplingParams(max_new_tokens=max_new),
                priority=prio, deadline=deadline)
            meta[uid] = (prio, deadline, max_new)
            submitted.append(uid)
        if submitted and rng.random() < 0.15:
            uid = rng.choice(submitted)
            if router.abort(uid):
                aborted.add(uid)
        router.step()
        clock.advance(0.01)
        steps += 1
        assert steps < 3000, "soak failed to drain"

    finished = {u: router.output(u) for u in meta}
    for u, out in finished.items():
        prio, deadline, max_new = meta[u]
        assert out.finish_reason in ("stop", "length", "aborted",
                                     "deadline")
        if out.finish_reason == "deadline":
            assert deadline is not None and out.token_ids == []
        if out.finish_reason == "length":
            assert len(out.token_ids) == max_new
        # NO STARVED CLASS: every request that was neither aborted nor
        # deadline-bound ran to a real finish, whatever its priority
        if u not in aborted and deadline is None:
            assert out.finish_reason in ("stop", "length"), \
                f"uid {u} (priority {prio}) starved: {out.finish_reason}"
    for s in servers:
        _conserved(s)
    return router


@pytest.mark.parametrize("seed", [0, 1])
def test_routed_fuzz_soak(model, clock, seed):
    router = _soak(model, clock, disaggregate=False, seed=seed)
    # the fleet actually spread: more than one replica did work
    assert sum(1 for s in router.servers
               if s.stats["admits"] > 0) >= 2


@pytest.mark.parametrize("seed", [2])
def test_disaggregated_fuzz_soak(model, clock, seed):
    router = _soak(model, clock, disaggregate=True, seed=seed)
    assert router.stats["migrations"] > 0
    # prefill replica never decoded past the handoff token; decode
    # replicas never admitted from their own queues
    pre = router.servers[0]
    assert pre.stats.get("migrations_out", 0) == router.stats["migrations"]


def test_replicas_on_distinct_devices(model):
    """Fleet placement: one replica per (forced host) device; the
    migration host bounce crosses real device boundaries under CI's
    ``--xla_force_host_platform_device_count=4`` shard, and the test
    still passes on a single device (every replica lands on it)."""
    from repro.serving.replica import build_replica

    cfg, rt, params = model
    devs = jax.devices()
    eng = EngineConfig(page_tokens=16, uniform_lengths=False,
                       shared_pool=True, total_pages=TOTAL_PAGES)
    sc = ServerConfig(arch="qwen1.5-0.5b", reduced=True, engine=eng,
                      batch_slots=2, max_context=64,
                      prefill_chunk_tokens=16, seed=7)
    servers = [build_replica(sc, cfg=cfg, params=params, rt=rt,
                             device=devs[k % len(devs)])
               for k in range(3)]
    router = ReplicaRouter(servers, disaggregate=True)

    rng = random.Random(5)
    prompts = [[rng.randrange(1, cfg.vocab_size)
                for _ in range(rng.randint(4, 25))] for _ in range(3)]
    sp = SamplingParams(max_new_tokens=5)
    solo = _server(model)
    for i, p in enumerate(prompts):
        router.submit(p, sp, uid=i)
        solo.submit(p, sp, uid=i)
    router.run()
    solo.run()
    assert router.stats["migrations"] == len(prompts)
    for i in range(len(prompts)):
        assert router.output(i).token_ids == solo.output(i).token_ids
    for s in servers:
        _conserved(s)


def test_deadline_expires_only_queued(model, clock):
    """A queued request expires at its fake-clock deadline; a running
    one does not."""
    servers = [_server(model, slots=1) for _ in range(2)]
    router = ReplicaRouter(servers, disaggregate=True)
    vocab = model[0].vocab_size
    rng = random.Random(9)
    long_p = [rng.randrange(1, vocab) for _ in range(20)]
    u_run = router.submit(long_p, SamplingParams(max_new_tokens=6),
                          deadline=30.0)
    # admission is (priority, nearest-deadline) — park u_queued in a
    # LOWER priority class so u_run's slot claim wins despite the
    # farther deadline
    u_queued = router.submit(long_p[:5],
                             SamplingParams(max_new_tokens=2),
                             priority=1, deadline=0.05)
    router.step()               # u_run admits into the only slot
    clock.advance(1.0)          # u_queued's deadline passes in queue
    router.run()
    assert router.output(u_run).finish_reason in ("stop", "length")
    assert router.output(u_queued).finish_reason == "deadline"
    for s in servers:
        _conserved(s)
