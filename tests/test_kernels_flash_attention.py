"""Flash-attention kernel: Pallas-interpret + blocked-ref vs dense oracle,
swept over shapes/dtypes/GQA/causal/window (assignment kernel contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import (
    dense_attention_ref, flash_attention, flash_attention_ref,
)

SWEEP = [
    # B, Sq, Sk, H, K, dh, causal, window, dtype
    (2, 128, 128, 4, 2, 64, True, None, jnp.float32),
    (1, 96, 96, 8, 8, 32, True, 32, jnp.float32),
    (2, 64, 64, 6, 3, 48, False, None, jnp.float32),
    (1, 64, 64, 2, 1, 128, True, None, jnp.bfloat16),
    (3, 32, 32, 5, 5, 16, True, 16, jnp.float32),
    (1, 256, 256, 2, 2, 64, True, None, jnp.float32),
]


def _mk(B, Sq, Sk, H, K, dh, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, dh), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, K, dh), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, K, dh), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("case", SWEEP)
def test_ref_vs_dense(case):
    B, Sq, Sk, H, K, dh, causal, window, dtype = case
    q, k, v = _mk(B, Sq, Sk, H, K, dh, dtype)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    out = flash_attention_ref(q, k, v, causal=causal, window=window,
                              chunk_k=32)
    ref = dense_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("case", SWEEP)
def test_pallas_interpret_vs_dense(case):
    B, Sq, Sk, H, K, dh, causal, window, dtype = case
    q, k, v = _mk(B, Sq, Sk, H, K, dh, dtype)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    out = flash_attention(q, k, v, causal=causal, window=window,
                          impl="interpret", block_q=32, block_k=32)
    ref = dense_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_unaligned_seq_padding():
    """Sequence not a multiple of the block size exercises the pad+mask."""
    q, k, v = _mk(1, 70, 70, 2, 2, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=True, impl="interpret",
                          block_q=32, block_k=32)
    ref = dense_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_query_offset_decode_semantics():
    """q_offset places queries mid-context (decode-style)."""
    q, k, v = _mk(1, 4, 64, 2, 2, 32, jnp.float32)
    out = flash_attention_ref(q, k, v, causal=True, q_offset=60)
    ref = dense_attention_ref(q, k, v, causal=True, q_offset=60)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
