"""Data pipeline: determinism, skip-ahead resume, learnable structure."""
import numpy as np

from repro.data.pipeline import DataConfig, DataIterator, make_source


def test_batches_deterministic():
    cfg = DataConfig(seq_len=16, global_batch=4, vocab_size=97, seed=3)
    a = make_source(cfg).batch(5)
    b = make_source(cfg).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(seq_len=16, global_batch=2, vocab_size=97)
    b = make_source(cfg).batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_resume_skip_ahead_exact():
    cfg = DataConfig(seq_len=8, global_batch=2, vocab_size=50, seed=1)
    it = DataIterator(make_source(cfg))
    seen = [next(it) for _ in range(5)]
    cursor = it.state()
    assert cursor == 5
    it2 = DataIterator(make_source(cfg))
    it2.restore(3)
    np.testing.assert_array_equal(next(it2)["tokens"], seen[3]["tokens"])


def test_markov_structure_learnable():
    """80% of transitions follow the permutation — the structure a model
    must learn (checked directly on the stream)."""
    cfg = DataConfig(seq_len=256, global_batch=8, vocab_size=64, seed=0)
    src = make_source(cfg)
    b = src.batch(0)
    follows = 0
    total = 0
    for row in b["tokens"]:
        nxt = src.perm[row[:-1]]
        follows += int(np.sum(nxt == row[1:]))
        total += len(row) - 1
    assert 0.7 < follows / total < 0.9
