"""Interleaved chunked prefill: token parity with the splice baseline
(f32 + kv8, dense + window-ring + recurrent + prefix archs), freedom from
decode starvation under a full admission queue, chunked quant fill parity
with the one-shot prefill fill, and the engine-level chunk oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import EngineConfig, get_config
from repro.core import paged_kv
from repro.core.engine import KVNANDEngine
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.serving.scheduler import (ContinuousBatcher, Request,
                                     SpliceBatcher, _splice_slot_ref)

ARCH = "qwen1.5-0.5b"

F32 = dict(page_tokens=16, uniform_lengths=False, kv_dtype="float32")
KV8 = dict(page_tokens=16, uniform_lengths=False, kv_quant="kv8")

PROMPTS = [list(range(1, 8)), list(range(3, 24)), list(range(2, 13)),
           [5, 4, 3]]


def _model(arch=ARCH):
    cfg = get_config(arch).reduced()
    rt = Runtime()
    return cfg, rt, Model(cfg, rt).init(jax.random.PRNGKey(0))


def _drain(cls, cfg, params, prompts, *, eng=None, max_new=5, slots=2,
           ctx=96, chunk=16):
    b = cls(cfg, params, batch_slots=slots, max_context=ctx,
            temperature=0.0, eng=eng, prefill_chunk_tokens=chunk)
    for uid, p in enumerate(prompts):
        b.submit(Request(uid, list(p), max_new=max_new))
    done = b.run_to_completion()
    return {u: r.output for u, r in done.items()}, b


# ---------------------------------------------------------------------------
# scheduler-level parity: interleaved == splice baseline, token for token
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("eng_kw", [F32, KV8], ids=["f32", "kv8"])
def test_interleaved_matches_splice(eng_kw):
    """Golden-engine configs (f32 and kv8): the interleaved scheduler must
    produce token-identical outputs to the splice-based path."""
    cfg, rt, params = _model()
    o1, b1 = _drain(ContinuousBatcher, cfg, params, PROMPTS,
                    eng=EngineConfig(**eng_kw))
    o2, b2 = _drain(SpliceBatcher, cfg, params, PROMPTS,
                    eng=EngineConfig(**eng_kw))
    assert o1 == o2
    assert b1.stats["decode_stall_tokens"] == 0
    assert b2.stats["decode_stall_tokens"] > 0
    assert b1.stats["prefill_chunks"] > len(PROMPTS)  # genuinely chunked


def test_interleaved_matches_splice_window():
    """gemma3: window-ring chunk fills + past-window partials across
    chunk boundaries (prompt longer than the ring)."""
    cfg, rt, params = _model("gemma3-12b")
    prompts = PROMPTS + [list(range(1, 78))]       # > reduced window of 64
    o1, _ = _drain(ContinuousBatcher, cfg, params, prompts, max_new=4)
    o2, _ = _drain(SpliceBatcher, cfg, params, prompts, max_new=4)
    assert o1 == o2


@pytest.mark.parametrize("arch", ["rwkv6-3b", "hymba-1.5b"])
def test_interleaved_recurrent_and_prefix(arch):
    """ssm/hybrid (and meta-token prefix) archs prefill as ONE exact
    whole-prompt chunk — still spliceless, still in place."""
    cfg, rt, params = _model(arch)
    o1, b1 = _drain(ContinuousBatcher, cfg, params, PROMPTS, max_new=4)
    o2, _ = _drain(SpliceBatcher, cfg, params, PROMPTS, max_new=4)
    assert o1 == o2
    assert b1.stats["prefill_chunks"] == len(PROMPTS)


def test_splice_never_called_from_interleaved_step(monkeypatch):
    """The interleaved scheduler must not touch the splice path at all."""
    import repro.serving.scheduler as sched

    def boom(*a, **k):
        raise AssertionError("_splice_slot reached from interleaved step")

    monkeypatch.setattr(sched, "_splice_slot", boom)
    cfg, rt, params = _model()
    outs, _ = _drain(ContinuousBatcher, cfg, params, PROMPTS[:2])
    assert sorted(outs) == [0, 1]


# ---------------------------------------------------------------------------
# no decode starvation: a full queue cannot stall active decoders
# ---------------------------------------------------------------------------

def test_no_decode_starvation_under_full_queue():
    cfg, rt, params = _model()
    b = ContinuousBatcher(cfg, params, batch_slots=2, max_context=96,
                          temperature=0.0, prefill_chunk_tokens=16)
    for uid in range(6):
        b.submit(Request(uid, list(range(1, 40)), max_new=6))
    overlapped = 0
    while b.queue or any(r is not None for r in b.slots):
        ready = {i: len(b.slots[i].output) for i, r in enumerate(b.slots)
                 if r is not None and i not in b._prefill_live}
        uid_of = {i: b.slots[i].uid for i in ready}
        chunks_before = b.stats["prefill_chunks"]
        b.step()
        did_chunk = b.stats["prefill_chunks"] > chunks_before
        for i, n0 in ready.items():
            req = (b.slots[i] if b.slots[i] is not None
                   and b.slots[i].uid == uid_of[i]
                   else b.completed[uid_of[i]])
            # every decode-ready slot advanced this step, prefill or not
            assert len(req.output) == n0 + 1
            if did_chunk:
                overlapped += 1
    assert overlapped > 0           # prefill genuinely shared steps
    assert b.stats["decode_stall_tokens"] == 0
    assert len(b.completed) == 6


# ---------------------------------------------------------------------------
# chunked quantized fills == one-shot prefill fills (page for page)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["kv8", "kv4"])
def test_chunked_quant_fill_matches_oneshot(fmt):
    """Page-aligned chunk fills must reproduce `fill_prefill_at_quant`
    bit-for-bit on every page holding real tokens (same codes + scales):
    requantization granularity is the page, not the chunk."""
    L, B, K, NP, T, dh = 2, 3, 2, 6, 8, 16
    Ts = T // 2 if fmt == "kv4" else T
    S, slot, layer, chunk = 40, 1, 1, 16
    kv = jax.random.normal(jax.random.PRNGKey(0), (B, S, K, dh))
    dt = paged_kv.quant.kv_storage_dtype(fmt)

    pool_a = jnp.zeros((L, B, K, NP, Ts, dh), dt)
    scale_a = jnp.zeros((L, B, K, NP), jnp.float32)
    pool_a, scale_a = paged_kv.fill_prefill_at_quant(
        pool_a, scale_a, kv, jnp.asarray(layer), fmt)

    pool_b = jnp.zeros((L, B, K, NP, Ts, dh), dt)
    scale_b = jnp.zeros((L, B, K, NP), jnp.float32)
    for c0 in range(0, S, chunk):
        cl = min(chunk, S - c0)
        pool_b, scale_b = paged_kv.fill_chunk_global_at(
            pool_b, kv[slot:slot + 1, c0:c0 + chunk], jnp.asarray(layer),
            jnp.asarray(slot), jnp.asarray(c0 // T), jnp.asarray(cl),
            scale=scale_b, kv_quant=fmt)

    n_pages = -(-S // T)
    np.testing.assert_array_equal(
        np.asarray(pool_a[layer, slot, :, :n_pages]),
        np.asarray(pool_b[layer, slot, :, :n_pages]))
    np.testing.assert_array_equal(
        np.asarray(scale_a[layer, slot, :, :n_pages]),
        np.asarray(scale_b[layer, slot, :, :n_pages]))
    # other slots' stripes untouched by the chunk fills
    assert float(jnp.abs(pool_b[:, 0].astype(jnp.float32)).max()) == 0.0
    assert float(jnp.abs(pool_b[:, 2].astype(jnp.float32)).max()) == 0.0


def test_chunk_window_fill_matches_ring():
    """Ring chunk fills reproduce the one-shot window fill for the pages
    still inside the ring (newest NP source pages)."""
    L, B, K, NP, T, dh = 2, 2, 2, 3, 8, 16
    S, slot, layer = 40, 0, 1
    kv = jax.random.normal(jax.random.PRNGKey(1), (B, S, K, dh))
    pool_a = jnp.zeros((L, B, K, NP, T, dh))
    pool_a = paged_kv.fill_window_at(pool_a, kv, jnp.asarray(layer))
    pool_b = jnp.zeros((L, B, K, NP, T, dh))
    for c0 in range(0, S, 16):
        cl = min(16, S - c0)
        pool_b = paged_kv.fill_chunk_window_at(
            pool_b, kv[slot:slot + 1, c0:c0 + 16], jnp.asarray(layer),
            jnp.asarray(slot), jnp.asarray(c0 // T), jnp.asarray(cl))
    np.testing.assert_allclose(np.asarray(pool_a[layer, slot]),
                               np.asarray(pool_b[layer, slot]), atol=0)


def test_chunk_window_fill_padded_chunk_wider_than_ring():
    """A mostly-padding chunk spanning more pages than the ring must still
    land its few VALID pages (a trailing padding page may not shadow the
    valid page NP positions older in the ring)."""
    L, B, K, NP, T, dh = 1, 1, 1, 3, 8, 4
    C, cl = 48, 1                      # 6 chunk pages, only page 0 valid
    kv = jax.random.normal(jax.random.PRNGKey(2), (1, C, K, dh))
    pool = jnp.zeros((L, B, K, NP, T, dh))
    pool = paged_kv.fill_chunk_window_at(
        pool, kv, jnp.asarray(0), jnp.asarray(0), jnp.asarray(0),
        jnp.asarray(cl))
    np.testing.assert_allclose(np.asarray(pool[0, 0, :, 0, :1]),
                               np.asarray(kv[0, :1].transpose(1, 0, 2)),
                               atol=0)
    # padding pages (never valid) left the rest of the ring untouched
    assert float(jnp.abs(pool[0, 0, :, 1:]).max()) == 0.0


# ---------------------------------------------------------------------------
# engine level: chunked prefill == full prefill + splice, then decode
# ---------------------------------------------------------------------------

def test_engine_prefill_chunk_matches_full():
    cfg, rt, params = _model()
    eng = KVNANDEngine(cfg, EngineConfig(page_tokens=8, kv_dtype="float32",
                                         uniform_lengths=False), rt)
    B, ctx, n, C = 3, 64, 21, 16
    prompt = jnp.arange(1, n + 1, dtype=jnp.int32)[None]
    lg_ref, c1 = eng.prefill(params, {"tokens": prompt}, ctx)
    cache_ref = _splice_slot_ref(eng.init_cache(B, ctx), c1, 1)
    cache = eng.init_cache(B, ctx)
    padded = -(-n // C) * C
    toks = jnp.concatenate([prompt[0], jnp.zeros(padded - n, jnp.int32)])
    for c0 in range(0, padded, C):
        cl = min(C, n - c0)
        lg, cache = eng.prefill_chunk(
            params, cache, {"tokens": toks[None, c0:c0 + C]},
            jnp.asarray(1), jnp.asarray(c0), jnp.asarray(cl),
            first=(c0 == 0))
    scale = float(jnp.abs(lg_ref).max())
    assert float(jnp.abs(lg - lg_ref).max()) / scale < 2e-4
    # decode continues identically from both caches (slot 1 active only)
    act = jnp.array([False, True, False])
    toks_d = jnp.array([[3], [11], [4]], jnp.int32)
    for _ in range(3):
        l1, cache = eng.decode_step(params, cache, toks_d, active=act)
        l2, cache_ref = eng.decode_step(params, cache_ref, toks_d)
        assert float(jnp.abs(l1[1] - l2[1]).max()) / scale < 2e-4


def test_engine_active_mask_freezes_inactive_slots():
    """A decode step with an active mask must leave inactive slots'
    stripes and lengths bit-identical."""
    cfg, rt, params = _model()
    eng = KVNANDEngine(cfg, EngineConfig(page_tokens=8, kv_dtype="float32",
                                         uniform_lengths=False), rt)
    cache = eng.init_cache(2, 64)
    _, cache = eng.prefill_chunk(
        params, cache, {"tokens": jnp.arange(1, 17, dtype=jnp.int32)[None]},
        jnp.asarray(0), jnp.asarray(0), jnp.asarray(16), first=True)
    before_k = np.asarray(cache.k_pages_g[:, 1]).copy()
    toks = jnp.array([[3], [9]], jnp.int32)
    _, cache2 = eng.decode_step(params, cache, toks,
                                active=jnp.array([True, False]))
    np.testing.assert_array_equal(np.asarray(cache2.k_pages_g[:, 1]),
                                  before_k)
    assert int(cache2.lengths[1]) == int(cache.lengths[1])
    assert int(cache2.lengths[0]) == int(cache.lengths[0]) + 1
