"""Property tests for the N-partial LSE merge core (`merge_partials`).

The core's contract: merging any grouping/ordering of locally-normalized
partials equals the one-shot softmax over the union of their pages, and
empty partials (m = NEG_INF, l = 0) are the identity.  Runs under
`tests/_hypothesis_compat` (seeded sweeps when hypothesis is absent).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.quant import quantize_kv_page
from repro.kernels.paged_attention import (
    merge_partials,
    paged_attention_partial,
    paged_attention_partial_ref,
    resolve_partitions,
)
from repro.kernels.paged_attention.merge import NEG_INF


def _partials(rng, n, shape=(2, 8)):
    """n random locally-normalized partials: o [n,*shape,dh], m/l [n,*shape]."""
    dh = 16
    o = jnp.asarray(rng.normal(size=(n, *shape, dh)), jnp.float32)
    m = jnp.asarray(rng.normal(size=(n, *shape)) * 3.0, jnp.float32)
    l = jnp.asarray(rng.uniform(0.1, 50.0, size=(n, *shape)), jnp.float32)
    return o, m, l


def _close(a, b, tol=1e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=tol, atol=tol)


@settings(max_examples=15)
@given(n=st.integers(2, 7), seed=st.integers(0, 1000))
def test_merge_associativity(n, seed):
    """Folding a prefix first, then merging its result with the rest,
    equals one flat N-way merge (re-bracketing invariance)."""
    rng = np.random.default_rng(seed)
    o, m, l = _partials(rng, n)
    flat = merge_partials(o, m, l, axis=0)
    k = max(1, n // 2)
    head = merge_partials(o[:k], m[:k], l[:k], axis=0)
    regrouped = tuple(
        jnp.concatenate([h[None], t], axis=0)
        for h, t in zip(head, (o[k:], m[k:], l[k:])))
    nested = merge_partials(*regrouped, axis=0)
    for a, b in zip(flat, nested):
        _close(a, b)


@settings(max_examples=15)
@given(n=st.integers(2, 8), seed=st.integers(0, 1000))
def test_merge_permutation_invariance(n, seed):
    rng = np.random.default_rng(seed)
    o, m, l = _partials(rng, n)
    ref = merge_partials(o, m, l, axis=0)
    perm = rng.permutation(n)
    got = merge_partials(o[perm], m[perm], l[perm], axis=0)
    for a, b in zip(ref, got):
        _close(a, b)


@settings(max_examples=15)
@given(n=st.integers(1, 6), n_empty=st.integers(1, 4),
       seed=st.integers(0, 1000))
def test_empty_partition_is_identity(n, n_empty, seed):
    """Partials over zero valid tokens (m = NEG_INF, l = 0) contribute
    nothing, wherever they sit in the stack."""
    rng = np.random.default_rng(seed)
    o, m, l = _partials(rng, n)
    ref = merge_partials(o, m, l, axis=0)
    eo = jnp.zeros((n_empty,) + o.shape[1:], o.dtype)
    em = jnp.full((n_empty,) + m.shape[1:], NEG_INF, m.dtype)
    el = jnp.zeros((n_empty,) + l.shape[1:], l.dtype)
    perm = rng.permutation(n + n_empty)
    got = merge_partials(jnp.concatenate([o, eo])[perm],
                         jnp.concatenate([m, em])[perm],
                         jnp.concatenate([l, el])[perm], axis=0)
    for a, b in zip(ref, got):
        _close(a, b)
    assert np.all(np.isfinite(np.asarray(got[0])))


def test_all_empty_merge_is_empty():
    """Merging only empty partials returns the empty partial: zero
    output, zero mass, finite everywhere — same as a single walk over an
    empty page set."""
    shape = (3, 4)
    o = jnp.zeros((5, *shape, 8))
    m = jnp.full((5, *shape), NEG_INF)
    l = jnp.zeros((5, *shape))
    oo, mm, ll = merge_partials(o, m, l, axis=0)
    assert np.all(np.asarray(oo) == 0.0)
    assert np.all(np.asarray(ll) == 0.0)
    assert np.all(np.isfinite(np.asarray(oo)))


def test_merge_axis_argument():
    rng = np.random.default_rng(0)
    o, m, l = _partials(rng, 4)
    ref = merge_partials(o, m, l, axis=0)
    got = merge_partials(jnp.moveaxis(o, 0, 2), jnp.moveaxis(m, 0, 2),
                         jnp.moveaxis(l, 0, 2), axis=2)
    for a, b in zip(ref, got):
        _close(a, b)


@pytest.mark.parametrize("kv_quant", ["none", "kv8", "kv4"])
@pytest.mark.parametrize("window", [None, 37])
def test_nway_merge_matches_one_shot_softmax(kv_quant, window):
    """Per-partition ref partials, merged through the core, reproduce the
    monolithic walk — for every pool format and the windowed layout."""
    rng = np.random.default_rng(7)
    B, K, G, NP, T, dh = 2, 2, 2, 8, 8, 16
    H = K * G
    q = jnp.asarray(rng.normal(size=(B, H, dh)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(B, K, NP, T, dh)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(B, K, NP, T, dh)), jnp.float32)
    base = jnp.arange(NP)[None, :].repeat(B, 0) * T
    length = jnp.array([NP * T - 3, NP * T // 2 + 1])
    ks = vs = None
    if kv_quant != "none":
        kp, ks = quantize_kv_page(kp, kv_quant)
        vp, vs = quantize_kv_page(vp, kv_quant)
    one_shot = paged_attention_partial_ref(
        q, kp, vp, base, length, window=window,
        kv_quant=kv_quant, k_scale=ks, v_scale=vs)
    for P in (2, 4, NP):
        npp = NP // P
        parts = []
        for i in range(P):
            sl = slice(i * npp, (i + 1) * npp)
            parts.append(paged_attention_partial_ref(
                q, kp[:, :, sl], vp[:, :, sl], base[:, sl], length,
                window=window, kv_quant=kv_quant,
                k_scale=None if ks is None else ks[:, :, sl],
                v_scale=None if vs is None else vs[:, :, sl]))
        merged = merge_partials(*map(jnp.stack, zip(*parts)), axis=0)
        for a, b in zip(one_shot, merged):
            _close(a, b, tol=3e-4)
    # and the public op's partitioned walk is the same computation
    o, m, l = paged_attention_partial(
        q, kp, vp, base, length, window=window, impl="ref",
        kv_quant=kv_quant, k_scale=ks, v_scale=vs, partitions=4)
    _close(one_shot[0].reshape(B, H, dh), o, tol=3e-4)


def test_resolve_partitions_contract():
    assert resolve_partitions(4, 16) == 4
    assert resolve_partitions(0, 64) == 1       # short walk stays whole
    assert resolve_partitions(0, 1568) == 16    # long walk splits
    assert resolve_partitions(0, 300) == 4      # halved to a divisor
    with pytest.raises(ValueError):
        resolve_partitions(5, 16)               # non-divisor is loud
    with pytest.raises(ValueError):
        resolve_partitions(-1, 16)
    with pytest.raises(ValueError):
        resolve_partitions(0, 0)
