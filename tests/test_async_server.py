"""Asyncio HTTP front door (serving/async_server.py, DESIGN.md §14).

End-to-end over a real socket via `BackgroundServer`: one-shot and SSE
``POST /v1/completions`` (tokens must match what `KVNANDServer` decodes
for the same prompt), request validation, ``GET /healthz`` and
``GET /metrics`` (Prometheus text with live latency/lifecycle series),
admission backpressure at ``max_queue`` (HTTP 429 + Retry-After), and
priority/deadline fields passing through to the scheduler.
"""
import http.client
import json
import threading
import time

import jax
import pytest

from repro.configs import EngineConfig, get_config
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.serving.api import KVNANDServer, SamplingParams, ServerConfig
from repro.serving.async_server import AsyncServerConfig, BackgroundServer

ARCH = "qwen1.5-0.5b"

_CACHE = {}


def _model():
    if "m" not in _CACHE:
        cfg = get_config(ARCH).reduced()
        _CACHE["m"] = (cfg, Model(cfg, Runtime()).init(jax.random.PRNGKey(0)))
    return _CACHE["m"]


def _configs(slots=2, max_queue=32, overlap=True):
    cfg, params = _model()
    return dict(
        config=ServerConfig(
            engine=EngineConfig(page_tokens=16, uniform_lengths=False,
                                shared_pool=True, total_pages=64),
            batch_slots=slots, max_context=96, prefill_chunk_tokens=16),
        async_config=AsyncServerConfig(max_queue=max_queue,
                                       overlap=overlap),
        cfg=cfg, params=params)


def _post(addr, payload, timeout=60):
    conn = http.client.HTTPConnection(*addr, timeout=timeout)
    try:
        conn.request("POST", "/v1/completions", json.dumps(payload),
                     {"Content-Type": "application/json"})
        r = conn.getresponse()
        return r.status, dict(r.getheaders()), r.read()
    finally:
        conn.close()


def _get(addr, path, timeout=30):
    conn = http.client.HTTPConnection(*addr, timeout=timeout)
    try:
        conn.request("GET", path)
        r = conn.getresponse()
        return r.status, r.read()
    finally:
        conn.close()


PROMPT = list(range(1, 12))


@pytest.fixture(scope="module")
def srv():
    with BackgroundServer(**_configs()) as s:
        yield s


# ---------------------------------------------------------------------------
# completions: one-shot and SSE, token-identical to the facade
# ---------------------------------------------------------------------------

def test_oneshot_completion_matches_facade(srv):
    cfg, params = _model()
    ref = KVNANDServer(_configs()["config"], cfg=cfg, params=params) \
        .generate([PROMPT], SamplingParams(max_new_tokens=6))[0]
    status, _, body = _post(srv.address,
                            {"prompt": PROMPT, "max_tokens": 6})
    assert status == 200
    out = json.loads(body)
    assert out["object"] == "text_completion"
    choice = out["choices"][0]
    assert choice["token_ids"] == ref.token_ids
    assert choice["finish_reason"] == "length"
    assert out["usage"] == {"prompt_tokens": len(PROMPT),
                            "completion_tokens": 6,
                            "total_tokens": len(PROMPT) + 6}


def test_sse_stream_concatenates_to_oneshot(srv):
    status, _, body = _post(srv.address,
                            {"prompt": PROMPT, "max_tokens": 5})
    oneshot = json.loads(body)["choices"][0]["token_ids"]
    conn = http.client.HTTPConnection(*srv.address, timeout=60)
    try:
        conn.request("POST", "/v1/completions",
                     json.dumps({"prompt": PROMPT, "max_tokens": 5,
                                 "stream": True}),
                     {"Content-Type": "application/json"})
        r = conn.getresponse()
        assert r.status == 200
        assert r.getheader("Content-Type") == "text/event-stream"
        raw = r.read().decode()
    finally:
        conn.close()
    frames = [f for f in raw.split("\n\n") if f.startswith("data: ")]
    assert frames[-1] == "data: [DONE]"
    chunks = [json.loads(f[len("data: "):])["choices"][0]
              for f in frames[:-1]]
    assert [c["token"] for c in chunks] == oneshot
    assert [c["position"] for c in chunks] == list(range(5))
    assert [c["finish_reason"] for c in chunks] == \
        [None] * 4 + ["length"]


def test_sampling_params_pass_through(srv):
    status, _, body = _post(srv.address, {
        "prompt": PROMPT, "max_tokens": 4, "temperature": 0.8,
        "top_k": 5, "seed": 7, "logprobs": True})
    assert status == 200
    choice = json.loads(body)["choices"][0]
    assert len(choice["token_ids"]) == 4
    assert len(choice["logprobs"]) == 4
    assert all(lp <= 0.0 for lp in choice["logprobs"])


def test_stop_token_finish_over_http(srv):
    status, _, body = _post(srv.address,
                            {"prompt": PROMPT, "max_tokens": 8})
    toks = json.loads(body)["choices"][0]["token_ids"]
    status, _, body = _post(srv.address, {
        "prompt": PROMPT, "max_tokens": 8, "stop_token_ids": [toks[1]]})
    choice = json.loads(body)["choices"][0]
    assert choice["finish_reason"] == "stop"
    assert choice["token_ids"] == toks[:2]


# ---------------------------------------------------------------------------
# validation and routing
# ---------------------------------------------------------------------------

def test_bad_requests(srv):
    status, _, body = _post(srv.address, {"prompt": "not tokens"})
    assert status == 400 and b"token ids" in body
    status, _, body = _post(srv.address, {"prompt": [1, True, 3]})
    assert status == 400
    status, _, body = _post(srv.address, {"prompt": []})
    assert status == 400                  # facade rejects empty prompts
    conn = http.client.HTTPConnection(*srv.address, timeout=30)
    try:
        conn.request("POST", "/v1/completions", b"{nope",
                     {"Content-Type": "application/json"})
        assert conn.getresponse().status == 400
    finally:
        conn.close()


def test_healthz_and_unknown_route(srv):
    status, body = _get(srv.address, "/healthz")
    assert (status, body) == (200, b"ok\n")
    status, _ = _get(srv.address, "/nope")
    assert status == 404


# ---------------------------------------------------------------------------
# metrics: live Prometheus text after real traffic
# ---------------------------------------------------------------------------

def test_metrics_exposition(srv):
    _post(srv.address, {"prompt": PROMPT, "max_tokens": 3})
    status, body = _get(srv.address, "/metrics")
    assert status == 200
    text = body.decode()
    for name in ("kvnand_ttft_seconds", "kvnand_tpot_seconds",
                 "kvnand_requests_finished_total",
                 "kvnand_rejected_total",
                 "kvnand_scheduler_steps_total",
                 "kvnand_decode_tokens_total",
                 "kvnand_device_idle_fraction",
                 "kvnand_queue_depth", "kvnand_pending_steps",
                 "kvnand_pool_util"):
        assert name in text, name
    assert 'kvnand_requests_finished_total{reason="length"}' in text
    counts = {line.split()[0]: float(line.split()[1])
              for line in text.splitlines()
              if line and not line.startswith("#")
              and "{" not in line.split()[0]}
    assert counts["kvnand_ttft_seconds_count"] >= 1
    assert counts["kvnand_decode_tokens_total"] >= 3
    assert 0.0 <= counts["kvnand_device_idle_fraction"] <= 1.0


# ---------------------------------------------------------------------------
# backpressure: saturation answers 429, never queues unboundedly
# ---------------------------------------------------------------------------

def test_zero_queue_rejects_everything():
    with BackgroundServer(**_configs(max_queue=0)) as s:
        status, headers, body = _post(s.address,
                                      {"prompt": PROMPT, "max_tokens": 2})
        assert status == 429
        assert headers.get("Retry-After") == "1"
        assert b"retry" in body.lower()
        _, text = _get(s.address, "/metrics")
        assert b"kvnand_rejected_total 1" in text


def test_saturation_mixes_429_and_service():
    """A burst far past slots + max_queue: some requests serve, the
    overflow is rejected with 429 — nothing hangs or errors out."""
    with BackgroundServer(**_configs(slots=1, max_queue=2)) as s:
        results = []
        lock = threading.Lock()

        def fire():
            st, _, _ = _post(s.address,
                             {"prompt": PROMPT, "max_tokens": 24})
            with lock:
                results.append(st)

        threads = [threading.Thread(target=fire) for _ in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120)
        assert len(results) == 8
        assert set(results) <= {200, 429}
        assert 200 in results
        assert 429 in results


# ---------------------------------------------------------------------------
# priority / deadline pass-through
# ---------------------------------------------------------------------------

def test_deadline_expires_queued_request_over_http():
    with BackgroundServer(**_configs(slots=1)) as s:
        done = threading.Event()

        def long_one():
            _post(s.address, {"prompt": PROMPT, "max_tokens": 48})
            done.set()

        t = threading.Thread(target=long_one)
        t.start()
        time.sleep(0.3)                   # let it occupy the only slot
        status, _, body = _post(s.address, {
            "prompt": list(range(2, 9)), "max_tokens": 8,
            "deadline_s": 0.001})
        assert status == 200
        choice = json.loads(body)["choices"][0]
        assert choice["finish_reason"] == "deadline"
        assert choice["token_ids"] == []
        done.wait(timeout=120)
        t.join(timeout=5)
        _, text = _get(s.address, "/metrics")
        assert b'kvnand_requests_finished_total{reason="deadline"} 1' \
            in text


def test_bad_deadline_is_400(srv):
    status, _, _ = _post(srv.address, {
        "prompt": PROMPT, "max_tokens": 2, "deadline_s": -1})
    assert status == 400
