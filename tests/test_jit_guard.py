"""The recompile guard itself: cache-size probing, failure formatting,
scheduler registration hooks, and end-to-end detection of a second
compiled signature on a watched callable."""
import jax
import jax.numpy as jnp
import pytest

from _jit_guard import cache_size, failures
from repro.serving import scheduler


class _Stub:
    def __init__(self, n):
        self._n = n

    def _cache_size(self):
        return self._n


def test_failures_reports_only_over_limit():
    watched = [("a", _Stub(1)), ("b", _Stub(2)), ("c", _Stub(0))]
    bad = failures(watched)
    assert len(bad) == 1
    assert bad[0].startswith("b: 2 compiled signatures")


def test_cache_size_handles_missing_probe():
    assert cache_size(object()) == 0


def test_watch_jit_registers_only_when_enabled(monkeypatch):
    monkeypatch.setattr(scheduler, "JIT_WATCH", None)
    scheduler._watch_jit("x", lambda: None)     # disabled: no-op

    lst = []
    monkeypatch.setattr(scheduler, "JIT_WATCH", lst)

    def fn():
        return None

    scheduler._watch_jit("x", fn)
    scheduler._watch_jit("y", None)             # absent callables skipped
    assert lst == [("x", fn)]


@pytest.mark.allow_recompile
def test_guard_detects_second_signature(_jit_cache_guard):
    f = jax.jit(lambda x: x * 2)
    scheduler._watch_jit("toy._decode", f)
    f(jnp.zeros((2,)))
    assert failures(_jit_cache_guard) == []
    f(jnp.zeros((3,)))                          # new shape -> new signature
    bad = failures(_jit_cache_guard)
    assert len(bad) == 1
    assert "toy._decode" in bad[0]
