"""DSE framework: heatmaps, OOM blanks, paper takeaways, engine coupling."""
from repro.configs import get_config
from repro.core import dse


def test_heatmap_shape_and_oom_blanks():
    cfg = get_config("opt-30b")
    grid = dse.heatmap(cfg, [1_000, 50_000, 100_000], total_dies=8,
                       wbits=8, abits=8)
    assert len(grid) == 8                       # 7 D-splits + C
    # MHA at 100K with W8A8 KV overflows small G2 allocations -> blanks
    import math
    blanks = [name for name, row in grid.items()
              if math.isinf(row[100_000])]
    assert blanks, "expected OOM blanks for G2-starved configs"


def test_weights_must_fit_g1():
    """Large models are incompatible with too-few G1 dies (Fig 15 text)."""
    cfg = get_config("llama3.1-70b")
    p = [x for x in dse.sweep(cfg, [1_000], 8, 8, 8)
         if x.system.startswith("KVNAND-D-(1+")]
    assert all(x.oom for x in p)                # 70B W8 > 1 die capacity


def test_takeaways():
    t = dse.takeaways(get_config("opt-30b"), get_config("llama3.1-70b"))
    assert all(t.values()), t


def test_recommend_engine_config():
    eng_long = dse.recommend_engine_config("llama3.1-70b", 100_000)
    eng_short = dse.recommend_engine_config("llama3.1-70b", 128)
    assert eng_long.quant in ("w4a16", "w8a8")
    assert eng_short.variant in ("compact", "discrete")


def test_recommend_attn_partitions_by_context():
    """Split-page attention is a long-context knob: the softmax stream
    only has something to hide under when the KV walk dominates, so the
    DSE keeps partitions = 1 at short context and splits at 100K."""
    eng_long = dse.recommend_engine_config("llama3.1-70b", 100_000)
    eng_short = dse.recommend_engine_config("llama3.1-70b", 1_000)
    assert eng_long.attn_partitions > 1
    assert eng_short.attn_partitions == 1
    # the recommended count comes from the swept ladder
    assert eng_long.attn_partitions in dse.ATTN_PARTITIONS


def test_attn_partitions_latency_monotone_gain():
    """partitions > 1 never makes the model slower at long context and
    the gain itself grows with context (more walk to hide under)."""
    from repro.core import flashsim as fs
    cfg = get_config("llama3.1-70b")
    sys = fs.kvnand_d(8, 8, 4, 16, kv_bits=8)
    gains = []
    for seq in (16_000, 50_000, 100_000):
        base = fs.decode_token_latency(sys, cfg, seq).total
        split = fs.decode_token_latency(sys, cfg, seq, partitions=16).total
        gains.append(base / split)
    assert all(g >= 1.0 for g in gains)
    assert gains == sorted(gains)


def test_best_config_prefers_bigger_g2_at_longer_ctx():
    cfg = get_config("llama3.1-70b")
    b_short = dse.best_discrete(cfg, 1_000, 8, 4, 16)
    b_long = dse.best_discrete(cfg, 100_000, 8, 4, 16)
    assert b_long.g2 > b_short.g2               # paper: 4 dies in G2 @100K


def test_recommend_hot_pages():
    """Tiered hot-tier sizing (DESIGN.md §13): SRAM-derived floor,
    pinned-working-set floor, and the degenerate single-tier case."""
    import pytest
    from repro.core import flashsim as fs
    cfg = get_config("llama3.1-8b")
    sys = fs.kvnand_d(8, 8, 4, 16, kv_bits=8)
    base = fs.hot_tier_pages(sys, cfg, 64)
    # short context: max(SRAM pages, working set of one 128-tok slot)
    assert dse.recommend_hot_pages(sys, cfg, 128) == max(base, 2)
    # long context, many slots: the pinned working set dominates (a
    # mapped hot page is never demoted, so admission needs the room)
    hp = dse.recommend_hot_pages(sys, cfg, 100_000, slots=4)
    assert hp == 4 * -(-100_000 // 64)
    assert hp > base
    # hot tier >= whole flash pool: tiering buys nothing -> 0
    assert dse.recommend_hot_pages(sys, cfg, 128,
                                   total_pages=max(base, 2)) == 0
    with pytest.raises(ValueError):
        dse.recommend_hot_pages(sys, cfg, 128, slots=0)


def test_recommend_overlap():
    """Pipelined stepping is a host-overhead knob (DESIGN.md §14): the
    DSE only recommends it when measured host time is worth hiding."""
    from repro.core import flashsim as fs
    cfg = get_config("llama3.1-8b")
    sys = fs.kvnand_d(8, 8, 4, 16, kv_bits=8)
    dev = fs.serving_step_time(sys, cfg, 10_000, 0.0, overlap=False)
    # host work comparable to device time: overlap wins
    assert dse.recommend_overlap(sys, cfg, 10_000, dev)
    # negligible host work: speedup < min_speedup, keep the simple loop
    assert not dse.recommend_overlap(sys, cfg, 10_000, 1e-3 * dev)
    assert not dse.recommend_overlap(sys, cfg, 10_000, 0.0)
