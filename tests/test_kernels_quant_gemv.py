"""Quantized GEMV kernel: sweep + hypothesis error bounds."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.quant import dequantize, quantize_weight
from repro.kernels.quant_gemv import quant_gemv

SWEEP = [
    # M, D, F, scheme
    (4, 256, 384, "w8a8"),
    (4, 256, 384, "w4a16"),
    (1, 512, 512, "w8a8"),
    (1, 512, 512, "w4a16"),
    (8, 128, 1024, "w4a16"),
]


@pytest.mark.parametrize("case", SWEEP)
def test_pallas_interpret_matches_ref(case):
    M, D, F, scheme = case
    w = jax.random.normal(jax.random.PRNGKey(1), (D, F)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(2), (M, D))
    qw = quantize_weight(w, scheme)
    y_ref = quant_gemv(x, qw, impl="ref")
    y_pal = quant_gemv(x, qw, impl="interpret")
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pal),
                               atol=6e-2, rtol=6e-2)


@pytest.mark.parametrize("case", SWEEP)
def test_quant_error_bound(case):
    M, D, F, scheme = case
    w = jax.random.normal(jax.random.PRNGKey(1), (D, F)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(2), (M, D))
    qw = quantize_weight(w, scheme)
    exact = x @ w
    approx = quant_gemv(x, qw, impl="ref")
    rel = float(jnp.abs(approx - exact).max() / jnp.abs(exact).max())
    assert rel < (0.05 if scheme == "w8a8" else 0.25), rel


@settings(max_examples=25, deadline=None)
@given(d=st.integers(2, 32).map(lambda x: 2 * x),
       f=st.integers(1, 32),
       scheme=st.sampled_from(["w8a8", "w4a16"]),
       seed=st.integers(0, 2 ** 16))
def test_dequant_roundtrip_bound(d, f, scheme, seed):
    """Property: per-channel dequant error ≤ half an LSB of that channel."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (d, f))
    qw = quantize_weight(w, scheme)
    wd = dequantize(qw, jnp.float32)
    amax = jnp.max(jnp.abs(w), axis=0)
    lsb = amax / (127.0 if scheme == "w8a8" else 7.0)
    err = jnp.max(jnp.abs(wd - w), axis=0)
    assert bool(jnp.all(err <= 0.51 * lsb + 1e-7))


def test_3d_headgroup_weights_roundtrip():
    """Attention projections are [K, D, f]; per-(K, f) channel scales."""
    w = jax.random.normal(jax.random.PRNGKey(3), (4, 64, 32)) * 0.1
    qw = quantize_weight(w, "w4a16")
    assert qw.scale.shape == (4, 32)
    wd = dequantize(qw, jnp.float32)
    assert float(jnp.abs(wd - w).max() / jnp.abs(w).max()) < 0.12
