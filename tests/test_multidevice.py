"""Multi-device correctness (8 fake host devices via subprocess):
ring attention, sharded paged decode + in-shard appends, compressed-DP
train step, elastic checkpoint restore across topologies."""
import jax
import pytest

from tests._mp import run_multidevice

COMMON = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.distributed.sharding import make_mesh_compat
mesh = make_mesh_compat((2, 4), ("data", "model"))
assert len(jax.devices()) == 8
"""


@pytest.mark.slow
def test_ring_attention_matches_flash():
    run_multidevice(COMMON + """
from repro.core.seqpar import ring_attention
from repro.kernels.flash_attention import flash_attention
B, S, H, K, dh = 4, 128, 6, 3, 32
ks = jax.random.split(jax.random.PRNGKey(0), 3)
q = jax.random.normal(ks[0], (B, S, H, dh))
k = jax.random.normal(ks[1], (B, S, K, dh))
v = jax.random.normal(ks[2], (B, S, K, dh))
for causal, window in ((True, None), (True, 40), (False, None)):
    with mesh:
        out = jax.jit(lambda q, k, v: ring_attention(
            q, k, v, mesh, causal=causal, window=window))(q, k, v)
    ref = flash_attention(q, k, v, causal=causal, window=window, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)
print("ring OK")
""")


@pytest.mark.slow
def test_sharded_paged_decode_and_append():
    run_multidevice(COMMON + """
from repro.core import seqpar
from repro.kernels.paged_attention import paged_attention_partial
B, K, G, NP, T, dh, L = 4, 2, 3, 8, 16, 32, 2
H = K * G
ks = jax.random.split(jax.random.PRNGKey(1), 5)
kd = jax.random.normal(ks[0], (B, NP*T, K, dh))
vd = jax.random.normal(ks[1], (B, NP*T, K, dh))
kp = kd.reshape(B, NP, T, K, dh).transpose(0, 3, 1, 2, 4)
vp = vd.reshape(B, NP, T, K, dh).transpose(0, 3, 1, 2, 4)
base = jnp.broadcast_to((jnp.arange(NP)*T)[None], (B, NP)).astype(jnp.int32)
q = jax.random.normal(ks[2], (B, H, dh))
length = jnp.full((B,), 100, jnp.int32)
# sharded partial+combine == single-device full
with mesh:
    o_sh = jax.jit(lambda *a: seqpar.paged_decode_attention_sharded(
        *a, mesh, batch_axes=("data",), page_axes=("model",)))(
        q, kp, vp, base, length)
o_ref, _, _ = paged_attention_partial(q, kp, vp, base, length, impl="ref")
np.testing.assert_allclose(np.asarray(o_sh), np.asarray(o_ref),
                           atol=3e-5, rtol=3e-5)
# in-shard uniform append == direct write
pool_k = jnp.zeros((L, B, K, NP, T, dh))
pool_v = jnp.zeros((L, B, K, NP, T, dh))
k1 = jax.random.normal(ks[3], (B, K, dh))
v1 = jax.random.normal(ks[4], (B, K, dh))
phys = jnp.full((B,), 5, jnp.int32)   # page 5 -> owned by shard 2 of 4
slot = jnp.full((B,), 7, jnp.int32)
with mesh:
    nk, nv = jax.jit(lambda *a: seqpar.sharded_append_uniform(
        *a, mesh, batch_axes=("data",), page_axes=("model",)))(
        pool_k, pool_v, 1, k1, v1, phys, slot)
expect = pool_k.at[1, :, :, 5, 7].set(k1)
np.testing.assert_allclose(np.asarray(nk), np.asarray(expect), atol=1e-6)
assert float(jnp.abs(nv[0]).max()) == 0.0
print("paged sharded OK")
""")


@pytest.mark.slow
def test_prefill_fill_sharded_matches_reference():
    run_multidevice(COMMON + """
from repro.core import seqpar, paged_kv
L, B, K, NP, T, dh = 2, 4, 2, 8, 8, 16
S = 50
kv = jax.random.normal(jax.random.PRNGKey(0), (B, S, K, dh))
pool = jnp.zeros((L, B, K, NP, T, dh))
with mesh:
    out = jax.jit(lambda p, kv: seqpar.sharded_prefill_fill(
        p, kv, 1, mesh, batch_axes=("data",), page_axes=("model",)))(
        pool, kv)
ref = paged_kv.fill_prefill_at(pool, kv, jnp.asarray(1))
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
print("prefill fill OK")
""")


@pytest.mark.slow
def test_engine_decode_sharded_matches_single_device():
    run_multidevice(COMMON + """
from repro.configs import get_config, EngineConfig
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.core.engine import KVNANDEngine
cfg = get_config("qwen2.5-32b").reduced()
rt = Runtime()
m = Model(cfg, rt)
params = m.init(jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 20), 0,
                          cfg.vocab_size, jnp.int32)
eng1 = KVNANDEngine(cfg, EngineConfig(page_tokens=4, kv_dtype="float32"),
                    rt, mesh=None)
lg1, c1 = eng1.prefill(params, {"tokens": toks[:, :16]}, 28)
for t in range(3):
    lg1, c1 = eng1.decode_step(params, c1, toks[:, 16+t:17+t])
engN = KVNANDEngine(cfg, EngineConfig(page_tokens=4, kv_dtype="float32"),
                    rt, mesh=mesh)
with mesh:
    lgN, cN = jax.jit(lambda p, b: engN.prefill(p, b, 28))(
        params, {"tokens": toks[:, :16]})
    step = jax.jit(lambda p, c, t: engN.decode_step(p, c, t))
    for t in range(3):
        lgN, cN = step(params, cN, toks[:, 16+t:17+t])
np.testing.assert_allclose(np.asarray(lg1), np.asarray(lgN),
                           atol=5e-4, rtol=5e-4)
print("engine sharded == single device OK")
""", timeout=900)


@pytest.mark.slow
def test_engine_decode_sharded_quantized_matches_single_device():
    """kv8 pools: sharded prefill quantization, in-shard requantizing
    appends, and scale-carrying sharded attention == single-device quant."""
    run_multidevice(COMMON + """
from repro.configs import get_config, EngineConfig
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.core.engine import KVNANDEngine
cfg = get_config("qwen2.5-32b").reduced()
rt = Runtime()
m = Model(cfg, rt)
params = m.init(jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 20), 0,
                          cfg.vocab_size, jnp.int32)
ec = EngineConfig(page_tokens=4, kv_dtype="float32", kv_quant="kv8")
eng1 = KVNANDEngine(cfg, ec, rt, mesh=None)
lg1, c1 = eng1.prefill(params, {"tokens": toks[:, :16]}, 28)
for t in range(3):
    lg1, c1 = eng1.decode_step(params, c1, toks[:, 16+t:17+t])
engN = KVNANDEngine(cfg, ec, rt, mesh=mesh)
with mesh:
    lgN, cN = jax.jit(lambda p, b: engN.prefill(p, b, 28))(
        params, {"tokens": toks[:, :16]})
    step = jax.jit(lambda p, c, t: engN.decode_step(p, c, t))
    for t in range(3):
        lgN, cN = step(params, cN, toks[:, 16+t:17+t])
np.testing.assert_allclose(np.asarray(lg1), np.asarray(lgN),
                           atol=5e-3, rtol=5e-3)
print("engine sharded quant == single device OK")
""", timeout=900)


@pytest.mark.slow
@pytest.mark.skipif(
    tuple(int(x) for x in jax.__version__.split(".")[:2]) < (0, 5),
    reason="manual-DP shard_map nested around an auto model axis needs "
           "jax>=0.5 (0.4.x rejects inner specs naming manual axes)")
def test_compressed_train_step_close_to_exact():
    run_multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.sharding import make_mesh_compat
mesh = make_mesh_compat((2, 2, 2), ("pod", "data", "model"))
from repro.configs import get_config, EngineConfig
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import (
    make_train_step, make_compressed_train_step, init_train_state)
cfg = get_config("qwen1.5-0.5b").reduced()
rt = Runtime()
m = Model(cfg, rt)
params = m.init(jax.random.PRNGKey(0))
acfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=100,
                   min_lr_ratio=1.0)
batch = {
  "tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                               cfg.vocab_size, jnp.int32),
  "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 32), 0,
                               cfg.vocab_size, jnp.int32)}
with mesh:
    s0 = init_train_state(params, acfg)
    step = jax.jit(make_train_step(cfg, rt, acfg, EngineConfig()))
    s1, m1 = step(s0, batch)
    sc0 = init_train_state(params, acfg, compressed=True)
    cstep = jax.jit(make_compressed_train_step(cfg, rt, acfg,
                                               EngineConfig(), mesh))
    sc1, m2 = cstep(sc0, batch)
# int8-compressed cross-pod grads track the exact step closely
assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
diffs = [float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
         for a, b in zip(jax.tree.leaves(s1.params),
                         jax.tree.leaves(sc1.params))]
scale = max(float(jnp.abs(x).max()) for x in jax.tree.leaves(s1.params))
assert max(diffs) / scale < 0.05, (max(diffs), scale)
print("compressed train OK", float(m1["loss"]), float(m2["loss"]))
""", timeout=900)


@pytest.mark.slow
def test_elastic_checkpoint_restore_different_topology():
    run_multidevice("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint.checkpoint import save_checkpoint, restore_checkpoint
from repro.launch.mesh import mesh_from_devices
mesh8 = mesh_from_devices(jax.devices())            # 4x2 or similar
w = jnp.arange(64.0).reshape(8, 8)
sh8 = NamedSharding(mesh8, P("data", "model"))
state = {"w": jax.device_put(w, sh8)}
d = tempfile.mkdtemp()
save_checkpoint(d, 0, state)
# restart on HALF the fleet (4 devices)
mesh4 = mesh_from_devices(jax.devices()[:4])
sh4 = NamedSharding(mesh4, P("data", "model"))
restored, _ = restore_checkpoint(d, 0, state, shardings={"w": sh4})
assert restored["w"].sharding == sh4
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(w))
print("elastic restore OK", mesh4.shape)
""")


@pytest.mark.slow
def test_sharded_chunk_fill_and_attention_match_reference():
    """Chunked prefill on a mesh: the slot/page-ownership-guarded chunk
    fill and the page-sharded chunk attention (combine over `model`) must
    match the single-device chunk oracle — for bf16 and kv8 pools."""
    run_multidevice(COMMON + """
from repro.core import seqpar, paged_kv
from repro.kernels.paged_attention.ref import paged_chunk_attention_ref

L, B, K, NP, T, dh = 2, 4, 2, 8, 8, 16
S, slot, layer, page0 = 16, 2, 1, 2
kv = jax.random.normal(jax.random.PRNGKey(0), (1, S, K, dh))

# --- fill: intersection of local page range x owned batch row ---------
pool = jnp.zeros((L, B, K, NP, T, dh))
with mesh:
    out = jax.jit(lambda p, kv: seqpar.sharded_chunk_fill(
        p, kv, layer, slot, page0, S, mesh,
        batch_axes=("data",), page_axes=("model",)))(pool, kv)
ref = paged_kv.fill_chunk_global_at(pool, kv, jnp.asarray(layer),
                                    jnp.asarray(slot), jnp.asarray(page0),
                                    jnp.asarray(S))
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)

# quantized variant carries per-page scales
qpool = jnp.zeros((L, B, K, NP, T, dh), jnp.int8)
qscale = jnp.zeros((L, B, K, NP), jnp.float32)
with mesh:
    qo, so = jax.jit(lambda p, s, kv: seqpar.sharded_chunk_fill(
        p, kv, layer, slot, page0, S, mesh, batch_axes=("data",),
        page_axes=("model",), scale=s, kv_quant="kv8"))(qpool, qscale, kv)
qr, sr = paged_kv.fill_chunk_global_at(
    qpool, kv, jnp.asarray(layer), jnp.asarray(slot), jnp.asarray(page0),
    jnp.asarray(S), scale=qscale, kv_quant="kv8")
# sharded vs single-device reduce order can differ by 1 ULP in the page
# amax -> scales to ~1e-7 rtol, codes to at most one rounding tie
assert int(jnp.abs(qo.astype(jnp.int32) - qr.astype(jnp.int32)).max()) <= 1
np.testing.assert_allclose(np.asarray(so), np.asarray(sr), rtol=1e-6)

# --- past-context chunk attention: partials combined over pages -------
H, G = 6, 3
ks = jax.random.split(jax.random.PRNGKey(1), 3)
kp = jax.random.normal(ks[0], (1, K, NP, T, dh))
vp = jax.random.normal(ks[1], (1, K, NP, T, dh))
q = jax.random.normal(ks[2], (1, 12, H, dh))
base = (jnp.arange(NP, dtype=jnp.int32) * T)[None]
start = jnp.asarray(40, jnp.int32)
q_pos = 40 + jnp.arange(12, dtype=jnp.int32)
with mesh:
    o_sh, m_sh, l_sh = jax.jit(lambda *a: seqpar.sharded_chunk_attention(
        *a, mesh, page_axes=("model",)))(q, kp, vp, base, start, q_pos)
o_rf, m_rf, l_rf = paged_chunk_attention_ref(q, kp, vp, base, start, q_pos)
np.testing.assert_allclose(np.asarray(o_sh), np.asarray(o_rf),
                           atol=3e-5, rtol=3e-5)
np.testing.assert_allclose(np.asarray(l_sh), np.asarray(l_rf),
                           atol=3e-5, rtol=3e-5)
print("sharded chunk fill + attention OK")
""")


@pytest.mark.slow
def test_engine_prefill_chunk_sharded_matches_single_device():
    """prefill_chunk on a mesh (global-pool arch): sharded chunk fills +
    sharded past partials reproduce the single-device chunk path."""
    run_multidevice(COMMON + """
from repro.configs import get_config, EngineConfig
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.core.engine import KVNANDEngine
cfg = get_config("qwen2.5-32b").reduced()
rt = Runtime()
m = Model(cfg, rt)
params = m.init(jax.random.PRNGKey(0))
n, C, ctx = 24, 16, 32
toks = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 1,
                          cfg.vocab_size, jnp.int32)
def chunked(engine, cache, use_jit):
    lg = None
    for c0 in range(0, 32, C):
        cl = min(C, n - c0)
        fn = lambda p, c, t, s, st, nn: engine.prefill_chunk(
            p, c, {"tokens": t}, s, st, nn, first=(c0 == 0))
        if use_jit:
            fn = jax.jit(fn)
        lg, cache = fn(params, cache, toks[:, c0:c0 + C], 2,
                       jnp.asarray(c0, jnp.int32), jnp.asarray(cl, jnp.int32))
    return lg, cache
eng1 = KVNANDEngine(cfg, EngineConfig(page_tokens=4, kv_dtype="float32",
                                      uniform_lengths=False), rt)
lg1, _ = chunked(eng1, eng1.init_cache(4, ctx), False)
engN = KVNANDEngine(cfg, EngineConfig(page_tokens=4, kv_dtype="float32",
                                      uniform_lengths=False), rt, mesh=mesh)
with mesh:
    lgN, _ = chunked(engN, engN.init_cache(4, ctx), True)
np.testing.assert_allclose(np.asarray(lg1), np.asarray(lgN),
                           atol=5e-4, rtol=5e-4)
print("sharded prefill_chunk == single device OK")
""", timeout=900)


@pytest.mark.slow
def test_shared_pool_sharded_decode_and_append():
    """Shared-pool P_total sharded over `model`: table-walked partial
    attention + owning-shard appends match the single-device oracle."""
    run_multidevice(COMMON + """
from repro.core import seqpar
from repro.kernels.paged_attention import paged_attention_partial
B, K, G, NP, T, dh, L = 4, 2, 3, 8, 16, 32, 2
P = B * NP
H = K * G
ks = jax.random.split(jax.random.PRNGKey(5), 4)
pool_k = jax.random.normal(ks[0], (K, P, T, dh))
pool_v = jax.random.normal(ks[1], (K, P, T, dh))
q = jax.random.normal(ks[2], (B, H, dh))
table = jnp.asarray(np.random.default_rng(3).permutation(P).reshape(B, NP),
                    jnp.int32)
base = jnp.broadcast_to((jnp.arange(NP) * T)[None], (B, NP)).astype(jnp.int32)
length = jnp.array([7, 33, 64, 128], jnp.int32)
ref, _, _ = paged_attention_partial(q, pool_k, pool_v, base, length,
                                    impl="ref", page_table=table)
with mesh:
    out = jax.jit(lambda q, kp, vp, tbl, b, ln:
                  seqpar.paged_decode_attention_sharded_shared(
                      q, kp, vp, tbl, b, ln, mesh, batch_axes=("data",),
                      page_axes=("model",), impl="ref"))(
        q, pool_k, pool_v, table, base, length)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           atol=3e-5, rtol=3e-5)
pools_k = jnp.zeros((L, K, P, T, dh))
pools_v = jnp.zeros((L, K, P, T, dh))
phys = jnp.array([3, 11, 19, 30], jnp.int32)
slot = jnp.array([0, 5, 15, 2], jnp.int32)
kn = jax.random.normal(ks[3], (B, K, dh))
with mesh:
    ok, ov = jax.jit(lambda kp, vp, kn, vn, ph, sl:
                     seqpar.sharded_append_shared(
                         kp, vp, 1, kn, vn, ph, sl, mesh,
                         batch_axes=("data",), page_axes=("model",)))(
        pools_k, pools_v, kn, -kn, phys, slot)
for b_ in range(B):
    np.testing.assert_allclose(np.asarray(ok[1, :, phys[b_], slot[b_]]),
                               np.asarray(kn[b_]), atol=1e-6)
assert float(jnp.abs(ok[0]).max()) == 0.0
print("shared-pool sharded OK")
""")
