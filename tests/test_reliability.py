"""Reliability model (§V-D, Fig 5a): wear accounting + allocator."""
import numpy as np

from repro.configs import get_config
from repro.core import reliability as rel
from repro.core.flashsim import FlashDie, SystemConfig


def test_lifetime_pe_matches_paper():
    """§V-D: 65B-class model @3 tok/s, 5 years ≈ 143 TB ≈ 1K P/E."""
    out = rel.lifetime_pe_cycles(get_config("llama3.1-70b"))
    assert 100 < out["total_tb"] < 200
    assert 500 < out["pe_cycles"] < 2_000
    assert out["margin_ok"]


def test_early_blocks_accumulate_more_reads():
    """Fig 5a shape: early-context blocks see the most reads."""
    br = rel.simulate_request_reads(get_config("opt-30b"), 25_000, 25_000,
                                    16, FlashDie())
    assert len(br) > 2
    assert br[0] >= br[-1]
    assert np.all(np.diff(br) <= 1e-9)


def test_pgrd_reduction_factors():
    """§V-D: ≈128× (KVNAND-C) and ≈2560× (KVNAND-D) at k=8, 256B units."""
    f = rel.pgrd_reduction_factors(
        get_config("llama3.1-8b"),
        SystemConfig("x", "kvnand-d", 8, 8), abits=16)
    assert abs(f["kvnand_c"] - 128) < 1
    assert abs(f["kvnand_d"] - 2560) < 30


def test_block_allocator_invariants():
    alloc = rel.BlockAllocator(64, seed=1)
    seen = set()
    for _ in range(200):
        blocks = alloc.allocate(4)
        assert len(set(blocks.tolist())) == 4
        seen.update(blocks.tolist())
        alloc.record_request(blocks, np.full(4, 1e5))
    assert len(seen) > 32                        # wear-leveled spread
    assert alloc.utilization() > 0.9
    assert float(alloc.state.page_reads.max()) <= rel.READ_DISTURB_LIMIT
