"""Golden decode tests: prefill+decode through the KVNAND engine must
reproduce the full-forward logits exactly (f32 cache), for every assigned
arch × both variants.  This exercises paged pools (global + window ring),
the head-group pipeline, RWKV/SSM state carry, and whisper cross-attention.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, EngineConfig, get_config
from repro.core.engine import KVNANDEngine
from repro.models.registry import Model
from repro.models.transformer import Runtime


def run_golden(arch, variant, n_decode=3, S_prompt=21, page_tokens=8):
    cfg = get_config(arch).reduced()
    cap = (cfg.n_experts / cfg.top_k) if cfg.is_moe else 1.25  # no-drop MoE
    rt = Runtime(moe_capacity=cap)
    m = Model(cfg, rt)
    params = m.init(jax.random.PRNGKey(0))
    eng = KVNANDEngine(
        cfg, EngineConfig(variant=variant, page_tokens=page_tokens,
                          kv_dtype="float32"), rt)
    B = 2
    toks = jax.random.randint(jax.random.PRNGKey(42),
                              (B, S_prompt + n_decode), 0, cfg.vocab_size,
                              jnp.int32)
    extra, prefix = {}, 0
    if cfg.family == "vlm":
        extra["patches"] = jax.random.normal(jax.random.PRNGKey(3),
                                             (B, 8, cfg.d_model))
        prefix += 8
    if cfg.is_encoder_decoder:
        extra["frames"] = jax.random.normal(jax.random.PRNGKey(4),
                                            (B, 8, cfg.d_model))
    prefix += cfg.n_meta_tokens

    logits_full, _ = m.forward(params, {"tokens": toks, **extra})
    lg, cache = eng.prefill(params, {"tokens": toks[:, :S_prompt], **extra},
                            max_context=S_prompt + n_decode + prefix + 2)
    errs = [float(jnp.abs(lg - logits_full[:, S_prompt - 1]).max())]
    for t in range(n_decode):
        lg, cache = eng.decode_step(
            params, cache, toks[:, S_prompt + t:S_prompt + t + 1])
        errs.append(float(jnp.abs(lg - logits_full[:, S_prompt + t]).max()))
    scale = float(jnp.abs(logits_full).max())
    return max(errs) / scale


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_matches_forward_compact(arch):
    assert run_golden(arch, "compact") < 2e-4


@pytest.mark.parametrize("arch", ["qwen2.5-32b", "gemma3-12b", "hymba-1.5b",
                                  "dbrx-132b", "whisper-base"])
def test_decode_matches_forward_discrete(arch):
    assert run_golden(arch, "discrete") < 2e-4


def test_window_ring_recycling():
    """Decode past the window: ring pages recycle, logits stay faithful."""
    assert run_golden("gemma3-12b", "compact", n_decode=8, S_prompt=70,
                      page_tokens=8) < 2e-4


def test_ragged_lengths_path():
    """Non-uniform appends (continuous batching) use the scatter path."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    rt = Runtime()
    m = Model(cfg, rt)
    params = m.init(jax.random.PRNGKey(0))
    eng = KVNANDEngine(cfg, EngineConfig(page_tokens=8, kv_dtype="float32",
                                         uniform_lengths=False), rt)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                              cfg.vocab_size, jnp.int32)
    logits_full, _ = m.forward(params, {"tokens": toks})
    lg, cache = eng.prefill(params, {"tokens": toks[:, :20]}, 30)
    for t in range(3):
        lg, cache = eng.decode_step(params, cache, toks[:, 20 + t:21 + t])
    err = float(jnp.abs(lg - logits_full[:, 22]).max())
    assert err / float(jnp.abs(logits_full).max()) < 2e-4
