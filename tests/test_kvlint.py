"""kvlint analyzer tests: fire/no-fire fixtures per rule, suppression
comments, baseline round-trip, and a meta-test that the live repo is
clean (zero non-baselined findings).

Pure stdlib — these tests never import jax, so they double as the CI
lint-job smoke test for the analyzer itself.
"""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import kvlint
from repro.analysis.core import RULES, run_paths

REPO_ROOT = Path(__file__).resolve().parents[1]


def lint(tmp_path, files, rules=None):
    """Write fixture files under tmp_path and run the analyzer."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return run_paths(sorted(files), tmp_path, rules)


def rules_of(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# KV001 — jit purity
# ---------------------------------------------------------------------------

KV001_FIRE = """\
    import jax

    def step(x, n):
        if x > 0:
            x = x + 1
        y = x.item()
        print(x)
        return y

    jitted = jax.jit(step, static_argnames=("n",))
"""

KV001_CLEAN = """\
    import jax

    def step(x, n, batch):
        if n > 0:
            x = x + 1
        if x.shape[0] > 2:
            x = x * 2
        if batch is None:
            return x
        if "patches" in batch:
            x = x + len(batch)
        return x

    jitted = jax.jit(step, static_argnames=("n",))
"""


def test_kv001_fires_on_traced_branch_item_and_print(tmp_path):
    findings = lint(tmp_path, {"mod.py": KV001_FIRE}, ["KV001"])
    msgs = [f.message for f in findings]
    assert rules_of(findings) == ["KV001", "KV001", "KV001"]
    assert any("`if`" in m or "Python `if`" in m for m in msgs)
    assert any(".item()" in m for m in msgs)
    assert any("print" in m for m in msgs)


def test_kv001_static_contexts_do_not_fire(tmp_path):
    assert lint(tmp_path, {"mod.py": KV001_CLEAN}, ["KV001"]) == []


def test_kv001_propagates_through_call_graph(tmp_path):
    src = """\
        import jax

        def helper(v):
            if v > 0:
                return v + 1
            return v

        def step(x):
            return helper(x)

        jitted = jax.jit(step)
    """
    findings = lint(tmp_path, {"mod.py": src}, ["KV001"])
    assert rules_of(findings) == ["KV001"]
    assert findings[0].qualname == "helper"


def test_kv001_lambda_default_capture_is_static(tmp_path):
    src = """\
        import jax

        def op(x, quant):
            if quant != "none":
                x = x * 2
            return x

        jitted = jax.jit(lambda x_, quant="none": op(x_, quant))
    """
    assert lint(tmp_path, {"mod.py": src}, ["KV001"]) == []


# ---------------------------------------------------------------------------
# KV002 — donation safety
# ---------------------------------------------------------------------------

KV002_FIRE = """\
    import jax

    def _step(buf, t):
        return buf + t

    step = jax.jit(_step, donate_argnums=(0,))

    def drive(buf, t):
        out = step(buf, t)
        extra = buf + 1
        return out, extra
"""

KV002_CLEAN = """\
    import jax

    def _step(buf, t):
        return buf + t

    step = jax.jit(_step, donate_argnums=(0,))

    def drive(buf, t):
        buf = step(buf, t)
        return buf + 1
"""


def test_kv002_fires_on_read_after_donation(tmp_path):
    findings = lint(tmp_path, {"mod.py": KV002_FIRE}, ["KV002"])
    assert rules_of(findings) == ["KV002"]
    assert "`buf`" in findings[0].message


def test_kv002_rebinding_the_donated_symbol_is_safe(tmp_path):
    assert lint(tmp_path, {"mod.py": KV002_CLEAN}, ["KV002"]) == []


# ---------------------------------------------------------------------------
# KV003 — recompile hazards
# ---------------------------------------------------------------------------

KV003_LOOP_FIRE = """\
    import jax

    def g(x):
        return x * 2

    def drive(xs):
        outs = []
        for x in xs:
            f = jax.jit(g)
            outs.append(f(x))
        return outs
"""

KV003_MIXED_FIRE = """\
    import jax

    def h(x, t):
        return x * t

    step = jax.jit(h)

    def a(x):
        return step(x, 0.5)

    def b(x, t):
        return step(x, t)
"""

KV003_CLEAN = """\
    import jax

    def h(x, t):
        return x * t

    step = jax.jit(h)

    def a(x, t):
        return step(x, t)

    def b(x, t):
        return step(x, t)
"""


def test_kv003_fires_on_jit_in_loop(tmp_path):
    findings = lint(tmp_path, {"mod.py": KV003_LOOP_FIRE}, ["KV003"])
    assert "KV003" in rules_of(findings)
    assert any("inside a loop" in f.message for f in findings)


def test_kv003_fires_on_mixed_literal_and_array_call_sites(tmp_path):
    findings = lint(tmp_path, {"mod.py": KV003_MIXED_FIRE}, ["KV003"])
    assert "KV003" in rules_of(findings)
    assert any("second compiled signature" in f.message for f in findings)


def test_kv003_uniform_call_sites_are_clean(tmp_path):
    assert lint(tmp_path, {"mod.py": KV003_CLEAN}, ["KV003"]) == []


# ---------------------------------------------------------------------------
# KV004 — pool-write discipline
# ---------------------------------------------------------------------------

KV004_FIRE = """\
    import jax

    def bad_set(cache, val):
        pages = cache.k_pages_g
        return pages.at[0, 1].set(val)

    def bad_dus(pool, upd):
        return jax.lax.dynamic_update_slice(pool, upd, (0, 0, 0))
"""

KV004_CLEAN = """\
    def fine(x, val):
        return x.at[0].set(val)
"""


def test_kv004_fires_outside_paged_kv(tmp_path):
    findings = lint(tmp_path, {"core/engine2.py": KV004_FIRE}, ["KV004"])
    assert rules_of(findings) == ["KV004", "KV004"]


def test_kv004_allows_writes_inside_paged_kv(tmp_path):
    assert lint(tmp_path, {"core/paged_kv.py": KV004_FIRE},
                ["KV004"]) == []


def test_kv004_ignores_non_pool_arrays(tmp_path):
    assert lint(tmp_path, {"core/engine2.py": KV004_CLEAN},
                ["KV004"]) == []


# ---------------------------------------------------------------------------
# KV005 — Pallas kernel hygiene
# ---------------------------------------------------------------------------

KV005_FIRE = """\
    from jax.experimental import pallas as pl

    def _body(x_ref, o_ref):
        print("trace me")
        o_ref[...] = x_ref[...]

    def op(x, offs):
        grid = (4, 4)
        return pl.pallas_call(
            _body,
            grid=grid,
            in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i + offs, j))],
            out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
            out_shape=x,
        )(x)
"""

KV005_CLEAN = """\
    from jax.experimental import pallas as pl

    def _body(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def op(x):
        return pl.pallas_call(
            _body,
            grid=(4, 4),
            in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j))],
            out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
            out_shape=x,
            compiler_params=pl.CompilerParams(
                dimension_semantics=("parallel", "arbitrary")),
        )(x)
"""


def test_kv005_fires_on_impure_map_missing_semantics_and_print(tmp_path):
    findings = lint(tmp_path, {"kernels/badkern.py": KV005_FIRE},
                    ["KV005"])
    msgs = [f.message for f in findings]
    assert rules_of(findings) == ["KV005"] * 3
    assert any("closes over" in m for m in msgs)
    assert any("dimension_semantics" in m for m in msgs)
    assert any("side-effect free" in m for m in msgs)


def test_kv005_clean_kernel_passes(tmp_path):
    assert lint(tmp_path, {"kernels/goodkern.py": KV005_CLEAN},
                ["KV005"]) == []


def test_kv005_only_scans_kernel_files(tmp_path):
    # same impure source outside kernels/ is out of scope for KV005
    assert lint(tmp_path, {"serving/notakern.py": KV005_FIRE},
                ["KV005"]) == []


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_inline_suppression_silences_one_line(tmp_path):
    src = """\
        import jax

        def step(x):
            y = x.item()  # kvlint: disable=KV001
            print(x)
            return y

        jitted = jax.jit(step)
    """
    findings = lint(tmp_path, {"mod.py": src}, ["KV001"])
    assert len(findings) == 1
    assert "print" in findings[0].message


def test_standalone_suppression_covers_next_code_line(tmp_path):
    src = """\
        import jax

        def step(x):
            # kvlint: disable=KV001
            y = x.item()
            return y

        jitted = jax.jit(step)
    """
    assert lint(tmp_path, {"mod.py": src}, ["KV001"]) == []


def test_suppression_is_rule_specific(tmp_path):
    src = """\
        import jax

        def step(x):
            y = x.item()  # kvlint: disable=KV004
            return y

        jitted = jax.jit(step)
    """
    findings = lint(tmp_path, {"mod.py": src}, ["KV001"])
    assert rules_of(findings) == ["KV001"]


# ---------------------------------------------------------------------------
# CLI + baseline round-trip
# ---------------------------------------------------------------------------

def write_fixture(tmp_path):
    p = tmp_path / "mod.py"
    p.write_text(textwrap.dedent(KV001_FIRE))
    return p


def test_cli_exit_codes_and_baseline_roundtrip(tmp_path, capsys):
    write_fixture(tmp_path)
    argv = ["mod.py", "--root", str(tmp_path), "--baseline", "bl.txt"]

    assert kvlint.main(argv) == 1            # live findings, no baseline
    assert kvlint.main(argv + ["--update-baseline"]) == 0
    text = (tmp_path / "bl.txt").read_text()
    assert text.count("KV001") == 3
    capsys.readouterr()

    assert kvlint.main(argv) == 0            # everything grandfathered
    assert "baselined" in capsys.readouterr().out

    # a NEW violation is not covered by the stale baseline
    p = tmp_path / "mod.py"
    p.write_text(p.read_text() + "\n\ndef extra(z):\n"
                 "    return z.item()\n\n\n"
                 "jitted2 = jax.jit(extra)\n")
    assert kvlint.main(argv) == 1


def test_cli_unknown_rule_is_usage_error(tmp_path, capsys):
    write_fixture(tmp_path)
    rc = kvlint.main(["mod.py", "--root", str(tmp_path),
                      "--rules", "KV999", "--baseline", "none"])
    assert rc == 2


def test_cli_json_format(tmp_path, capsys):
    write_fixture(tmp_path)
    rc = kvlint.main(["mod.py", "--root", str(tmp_path),
                      "--baseline", "none", "--format", "json"])
    assert rc == 1
    payload = json.loads(capsys.readouterr().out)
    assert len(payload) == 3
    assert {f["rule"] for f in payload} == {"KV001"}
    assert all(not f["baselined"] for f in payload)


def test_baseline_key_survives_line_renumbering(tmp_path, capsys):
    write_fixture(tmp_path)
    argv = ["mod.py", "--root", str(tmp_path), "--baseline", "bl.txt"]
    assert kvlint.main(argv + ["--update-baseline"]) == 0
    # prepend an import: every finding moves down a line, keys hold
    p = tmp_path / "mod.py"
    p.write_text("import math\n" + p.read_text())
    assert kvlint.main(argv) == 0


# ---------------------------------------------------------------------------
# meta: the live repo is clean
# ---------------------------------------------------------------------------

def test_live_repo_has_zero_nonbaselined_findings():
    rc = kvlint.main(["src", "tests", "benchmarks",
                      "--root", str(REPO_ROOT)])
    assert rc == 0, "kvlint found non-baselined findings in the repo"


@pytest.mark.parametrize("rule", RULES)
def test_every_rule_registered(rule):
    assert rule in RULES
