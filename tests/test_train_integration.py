"""Training integration: learning, microbatch equivalence, quantized
forward, serving scheduler round-trip."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import EngineConfig, get_config
from repro.data.pipeline import DataConfig, DataIterator, make_source
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import (
    grads_and_metrics, init_train_state, make_train_step,
)


def test_loss_decreases():
    cfg = get_config("qwen1.5-0.5b").reduced()
    rt = Runtime()
    m = Model(cfg, rt)
    acfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=80)
    state = init_train_state(m.init(jax.random.PRNGKey(0)), acfg)
    step = jax.jit(make_train_step(cfg, rt, acfg, EngineConfig()))
    it = DataIterator(make_source(DataConfig(
        seq_len=64, global_batch=16, vocab_size=cfg.vocab_size)))
    first = last = None
    for _ in range(80):
        state, metrics = step(state, {k: jnp.asarray(v)
                                      for k, v in next(it).items()})
        if first is None:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first - 1.5, (first, last)


def test_microbatch_grads_match_full_batch():
    cfg = get_config("qwen1.5-0.5b").reduced()
    rt = Runtime()
    m = Model(cfg, rt)
    params = m.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                     cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0,
                                     cfg.vocab_size, jnp.int32)}
    g1, m1 = jax.jit(lambda p, b: grads_and_metrics(p, b, cfg, rt, "none",
                                                    1))(params, batch)
    g2, m2 = jax.jit(lambda p, b: grads_and_metrics(p, b, cfg, rt, "none",
                                                    2))(params, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-4, rtol=1e-3)


def test_continuous_batching_matches_sequential():
    """Scheduler outputs == one-at-a-time greedy decoding per request."""
    from repro.serving.scheduler import ContinuousBatcher, Request
    from repro.core.engine import KVNANDEngine
    from repro.serving.sampler import sample

    cfg = get_config("qwen1.5-0.5b").reduced()
    rt = Runtime()
    m = Model(cfg, rt)
    params = m.init(jax.random.PRNGKey(0))
    prompts = [[3, 1, 4, 1, 5], [9, 2, 6], [5, 3, 5, 8, 9, 7, 9]]

    # sequential reference (greedy)
    eng = EngineConfig(page_tokens=8, kv_dtype="float32")
    ref_engine = KVNANDEngine(cfg, eng, rt)
    ref_out = []
    for p in prompts:
        toks = jnp.asarray(p, jnp.int32)[None]
        lg, cache = ref_engine.prefill(params, {"tokens": toks}, 64)
        outs = []
        tok = sample(lg, jax.random.PRNGKey(0), true_vocab=cfg.vocab_size)
        for _ in range(6):
            outs.append(int(tok[0]))
            lg, cache = ref_engine.decode_step(params, cache, tok[:, None])
            tok = sample(lg, jax.random.PRNGKey(0),
                         true_vocab=cfg.vocab_size)
        ref_out.append(outs)

    batcher = ContinuousBatcher(
        cfg, params, batch_slots=2, max_context=64,
        eng=EngineConfig(page_tokens=8, kv_dtype="float32",
                         uniform_lengths=False))
    for uid, p in enumerate(prompts):
        batcher.submit(Request(uid=uid, prompt=list(p), max_new=6))
    done = batcher.run_to_completion()
    for uid, outs in enumerate(ref_out):
        assert done[uid].output[:6] == outs, (uid, done[uid].output, outs)


def test_quantized_decode_close_to_fp():
    from repro.core.engine import KVNANDEngine
    from repro.core.quant import quantize_params
    cfg = get_config("qwen1.5-0.5b").reduced()
    rt = Runtime()
    m = Model(cfg, rt)
    params = m.init(jax.random.PRNGKey(0))
    qparams = quantize_params(params, "w8a8")
    eng = KVNANDEngine(cfg, EngineConfig(page_tokens=8), rt)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size, jnp.int32)
    lg_fp, _ = eng.prefill(params, {"tokens": toks}, 20)
    lg_q, _ = eng.prefill(qparams, {"tokens": toks}, 20)
    scale = float(jnp.abs(lg_fp).max())
    assert float(jnp.abs(lg_fp - lg_q).max()) / scale < 0.15
