"""Fig 16: per-token decode energy (LLaMA2-7B, LLaMA3.1-70B) vs context."""
from benchmarks.common import emit
from repro.configs import get_config
from repro.core import flashsim as fs


def run():
    for m in ("llama2-7b", "llama3.1-70b"):
        cfg = get_config(m)
        for seq in (1_000, 10_000, 30_000, 100_000):
            e_b1 = fs.decode_token_energy(fs.base1(16, 16), cfg, seq)
            e_b2 = fs.decode_token_energy(fs.base2(16, 16), cfg, seq)
            e_kc = fs.decode_token_energy(fs.kvnand_c(16, 16, 16), cfg, seq)
            e_kd = fs.decode_token_energy(fs.kvnand_d(8, 8, 16, 16), cfg,
                                          seq)
            best = min(e_kc["total"], e_kd["total"])
            for name, e in (("base1", e_b1), ("base2", e_b2),
                            ("kvnand_c16", e_kc), ("kvnand_d8+8", e_kd)):
                emit(f"fig16/{m}/{seq}/{name}", 0.0,
                     f"{e['total'] * 1e3:.2f} mJ/token")
            if not fs.is_oom(fs.base1(16, 16), cfg, seq):
                emit(f"fig16/{m}/{seq}/ratio_vs_base1", 0.0,
                     f"{best / e_b1['total']:.2f}x (paper 0.75x@10K 7B)")


if __name__ == "__main__":
    run()
