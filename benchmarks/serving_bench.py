"""Serving-scheduler benchmark: interleaved chunked prefill vs the splice
baseline, plus the shared-pool allocator (FTL-mapped paged KV, §IV-D),
all driven through the request-centric `KVNANDServer` facade.

Runs the same request trace through three schedulers on the reduced
config and emits, per scheduler:

  serving/<mode>/wall                 end-to-end µs (derived: tok/s)
  serving/<mode>/steps_to_drain       scheduler steps to drain the trace
  serving/<mode>/compiles             distinct jit signatures compiled
  serving/<mode>/decode_stall_per_admit
        decode tokens NOT generated while an admit monopolized the engine
        (0 by construction for the interleaved schedulers).
  serving/<mode>/ttft_p50, ttft_p95   time to first token (µs), from
  serving/<mode>/tpot_p50, tpot_p95   RequestOutput timing; TPOT = mean
        per-token time after the first.  p95 TTFT lands on the requests
        that pay the jit compiles (fresh server per drain).

Shared-pool trajectory metrics (the allocator's capacity win):

  serving/shared/pool_util            peak live pages / pool pages
  serving/shared_prefix/prefix_hit_rate
        prompt pages served from the radix prefix cache on a
        shared-system-prompt trace (> 0 == prefix sharing works)
  serving/shared_capacity/stripe_overcommit
        summed per-slot stripe pages of the admitted mix / pool pages —
        > 1 means the mix could NOT have been admitted under the old
        per-slot stripe layout, yet the pooled allocator drains it.

Speculative decoding (DESIGN.md §11) gets its own trace: a repetitive
prompt set where prompt-lookup drafts actually hit, drained through the
verify path and cross-checked token-identical against sequential decode:

  serving/spec/accepted_per_step      tokens emitted per verify step
        (accepted drafts + the correction token); > 1.0 == speculation
        genuinely amortizes weight loads on this trace
  serving/spec/wall                   end-to-end µs for the spec drain
  serving/spec/seq_wall               the same trace decoded sequentially

Tiered flash KV hierarchy (DESIGN.md §13) gets a two-wave trace whose
working set exceeds the hot tier (wave 2 re-admits wave 1's prompts
after their cache pages were demoted to the capacity store), drained
with prefetch on and off and cross-checked token-identical against the
single-tier pool:

  serving/tiered/wall                 end-to-end µs (prefetch on)
  serving/tiered/hit_rate             cached map-ins served hot (< 100%
        by construction — the first re-admission wave demand-faults)
  serving/tiered/stall_tokens         demand promotions with prefetch ON
        (must beat stall_tokens_noprefetch; derived column carries the
        flashsim-modeled stall seconds)
  serving/tiered/stall_tokens_noprefetch   the ablation
  serving/tiered/pool_util_hot        peak hot-resident / hot slots
  serving/tiered/pool_util_capacity   peak live flash pages / flash pool

Overlapped host/device pipeline (DESIGN.md §14) gets a Poisson-arrival
open-loop trace — requests arrive on their own clock, not when a slot
frees — drained twice through the SAME load generator, overlap on and
off, hard-failing on token divergence OR on the overlapped drain
losing to the synchronous one.  The drain is a modeled-device replay:
the real scheduler decodes real tokens on CPU-XLA, and every dispatched
decode step additionally occupies a MODELED kvnand-d device window
(flashsim.serving_step_time with host_s=0 — the flash-read latency a
CPU cannot emulate; the XLA compute rides inside it).  `collect()`
blocks until the oldest step's modeled completion, steps serialize on
the modeled device, and the two disciplines differ only in WHEN the
host half runs: the synchronous loop pays window + host per step, the
pipelined loop does step N+1's host half inside step N's window —
dev + host vs max(dev, host), the exact comparison the flashsim model
makes, here executed by the real scheduler under real load.  (This
container is single-core: without the modeled window, JAX's own async
dispatch plus CPU timesharing make the two disciplines statistically
indistinguishable — there is no second core for "overlap" to use.)

  serving/async/wall                  end-to-end µs, overlap ON (the
        derived column carries the overlap-off wall and the speedup)
  serving/async/wall_overlap_off      the synchronous ablation
  serving/async/device_idle_frac      % of the overlapped drain's wall
        with NO step in flight (sync fraction in derived — the host
        time the pipeline hides; feeds flashsim.overlap_speedup)
Multi-replica router + disaggregated prefill/decode (DESIGN.md §16):
the routed trace drains over 1 and 2 replicas — every replica's decode
steps occupy its OWN modeled kvnand-d device window, so aggregate
throughput scales with fleet slot capacity while staying token-identical
to the single-server drain; the disaggregated drain measures what a
migration actually ships over the wire:

  serving/replicas/tok_s_1, tok_s_2   aggregate modeled tok/s at 1 and
        2 replicas (hard-fails unless 2 replicas drain in fewer router
        steps than 1 — the scaling the fleet exists for)
  serving/replicas/ttft_p95_1, ttft_p95_2   modeled p95 time to first
        token at each replica count
  serving/replicas/migration_bytes_per_req  KVEnvelope wire bytes per
        migrated request (prefill replica -> decode replica, all
        requests migrating)

  serving/async/goodput_under_sla     req/s finishing within the SLA
        (TTFT + max_new x TPOT budget) under overlap

`wall`, `steps_to_drain`, and the ttft/tpot p50 rows are gated by
check_regression.py (p95 rows are informational — compile-dominated;
the serving/spec/*, serving/tiered/* and serving/async/* rows ride the
ungated-prefix mechanism while those features land); counter rows
carry the count in `us_per_call` (the harness's one numeric column)
with the unit spelled out in `derived`.
"""
import time
from collections import deque

import jax
import numpy as np

from benchmarks.common import emit

ARCH = "qwen1.5-0.5b"
SLOTS = 3
MAX_CONTEXT = 128
CHUNK = 32
MAX_NEW = 8
N_REQUESTS = 8
PAGE_TOKENS = 16


def _trace(vocab):
    rng = np.random.default_rng(7)
    return [rng.integers(1, vocab, int(n)).tolist()
            for n in rng.integers(5, 45, N_REQUESTS)]


def _prefix_trace(vocab):
    """Shared 32-token system prompt + unique tails, incl. one repeat."""
    rng = np.random.default_rng(11)
    sysp = rng.integers(1, vocab, 32).tolist()
    tails = [rng.integers(1, vocab, 9).tolist() for _ in range(5)]
    return [sysp + t for t in tails] + [sysp + tails[0]]


def _spec_trace(vocab):
    """Repetitive prompts (a 6-token motif repeated) so prompt-lookup
    drafting has something to hit."""
    rng = np.random.default_rng(17)
    return [(rng.integers(1, vocab, 6).tolist() * 5) for _ in range(4)]


N_TIER_UNIQ = 10
TIER_TOTAL_PAGES = 96
TIER_HOT_PAGES = 12


def _tier_trace(vocab):
    """Shared 32-token system prompt + 10 unique 9-token tails.  Ten
    41-token prompts page out to far more flash pages than the 12-slot
    hot tier holds, so draining them twice forces wave 1's prefix-cache
    pages through demotion and back."""
    rng = np.random.default_rng(23)
    sysp = rng.integers(1, vocab, 32).tolist()
    return [sysp + rng.integers(1, vocab, 9).tolist()
            for _ in range(N_TIER_UNIQ)]


def _drain_tiered(cfg, params, eng, uniq, *, prefetch=True):
    """Two-wave drain on ONE server: wave 1 admits the uniques, wave 2
    re-submits the same prompts after their pages were demoted.  The
    first re-admissions demand-fault in both modes (no queue to peek
    before they map), the staggered rest give prefetch its window."""
    from repro.serving.api import (KVNANDServer, SamplingParams,
                                   ServerConfig)

    server = KVNANDServer(
        ServerConfig(scheduler="interleaved", engine=eng,
                     batch_slots=SLOTS, max_context=64,
                     prefill_chunk_tokens=PAGE_TOKENS,
                     tier_prefetch=prefetch),
        cfg=cfg, params=params)
    sp = SamplingParams(max_new_tokens=MAX_NEW)
    outs = {}
    t0 = time.perf_counter()
    for wave in range(2):
        uids = [server.submit(p, sp) for p in uniq]
        server.run()
        for u in uids:
            outs[(wave, u)] = server.output(u).token_ids
            server.release(u)
    dt = time.perf_counter() - t0
    return dt, outs, server.stats


N_ASYNC = 12
ASYNC_RATE_HZ = 120.0           # open-loop arrivals fast enough to keep
                                # a backlog — overlap has work to hide
ASYNC_SLA_S = 2.0               # e2e budget per request (reduced model)


def _poisson_arrivals(n, rate_hz):
    rng = np.random.default_rng(29)
    return np.cumsum(rng.exponential(1.0 / rate_hz, n)).tolist()


def _drain_poisson(cfg, params, eng, prompts, arrivals, warmup, *,
                   overlap, device_s):
    """Open-loop drain: submit each prompt at its arrival offset while
    stepping the scheduler — the serving shape the overlapped pipeline
    exists for.  Both modes run the SAME generator; only the stepping
    discipline (dispatch N+1 before collect N vs dispatch; collect)
    differs.

    Every dispatched decode step occupies the modeled kvnand-d device
    for `device_s` (the flash-read window CPU-XLA cannot emulate; the
    real XLA compute of the step rides inside it).  Modeled steps
    serialize — step N+1's window opens when step N's closes — and
    `collect` blocks until the oldest step's modeled completion.  The
    synchronous discipline therefore pays window + host per step; the
    pipelined one runs the next step's host half inside the current
    window.  Prefill chunks execute host-side inside `dispatch` in both
    disciplines and are deliberately NOT charged a window (symmetric,
    so the A/B isolates the decode pipeline).

    `warmup` prompts have the SAME lengths as `prompts` but different
    content: chunk jit signatures key on (first-chunk, length) only, so
    the warmup drain compiles every signature the timed window will hit
    WITHOUT seeding the prefix cache with the timed prompts — cache
    hits would both skew the measurement and re-prefill evicted entries
    from mid-page offsets, compiling fresh chunk lengths mid-window."""
    from repro.serving.api import (KVNANDServer, SamplingParams,
                                   ServerConfig)

    server = KVNANDServer(
        ServerConfig(scheduler="interleaved", engine=eng,
                     batch_slots=SLOTS, max_context=MAX_CONTEXT,
                     prefill_chunk_tokens=CHUNK, overlap=overlap),
        cfg=cfg, params=params)
    sp = SamplingParams(max_new_tokens=MAX_NEW)
    server.generate(warmup, sp)             # warmup: pay ALL the compiles
    uids = {}
    nxt = 0
    deadlines = deque()                     # modeled completion, oldest 1st
    last_dl = 0.0

    def _dispatch():
        nonlocal last_dl
        before = server.pending_steps()
        server.dispatch()
        if server.pending_steps() > before:
            last_dl = max(time.perf_counter(), last_dl) + device_s
            deadlines.append(last_dl)

    def _collect():
        if deadlines:
            time.sleep(max(0.0, deadlines[0] - time.perf_counter()))
        server.collect()
        while len(deadlines) > server.pending_steps():
            deadlines.popleft()

    t0 = time.perf_counter()
    # device-idle accounting starts at t0, not at the warmup's end
    server._batcher._idle_since = time.monotonic()
    idle0 = server.stats["device_idle_s"]
    steps0 = server.stats["steps"]
    while nxt < len(prompts) or server._busy() or server.pending_steps():
        now = time.perf_counter() - t0
        while nxt < len(prompts) and arrivals[nxt] <= now:
            uids[nxt] = server.submit(prompts[nxt], sp)
            nxt += 1
        if not server._busy() and not server.pending_steps():
            if nxt < len(prompts):          # idle until the next arrival
                time.sleep(max(0.0, arrivals[nxt]
                               - (time.perf_counter() - t0)))
            continue
        if overlap:
            if server.pending_steps() == 0 and server._busy():
                _dispatch()                 # prime the pipeline
            if server._busy():
                _dispatch()                 # step N+1 onto the device
            _collect()                      # step N's tokens
        else:
            _dispatch()
            _collect()
    wall = time.perf_counter() - t0
    outs = {i: server.output(u) for i, u in uids.items()}
    st = dict(server.stats)
    st["idle_s"] = st["device_idle_s"] - idle0
    st["steps"] = st["steps"] - steps0
    return wall, outs, st


def _drain(scheduler, cfg, params, eng, prompts, *, slots=SLOTS,
           max_context=MAX_CONTEXT, spec_k=0, max_new=MAX_NEW):
    from repro.serving.api import (KVNANDServer, SamplingParams,
                                   ServerConfig)

    server = KVNANDServer(
        ServerConfig(scheduler=scheduler, engine=eng, batch_slots=slots,
                     max_context=max_context,
                     prefill_chunk_tokens=CHUNK, speculation_k=spec_k),
        cfg=cfg, params=params)
    sp = SamplingParams(max_new_tokens=max_new)
    t0 = time.perf_counter()
    outs = server.generate(prompts, sp)
    dt = time.perf_counter() - t0
    total = sum(len(o.token_ids) for o in outs)
    return dt, total, server.stats, {o.uid: o.token_ids for o in outs}, \
        outs


def _drain_router(cfg, params, eng, prompts, n, *, disaggregate=False):
    """Drain `prompts` through a ReplicaRouter over `n` serving replicas
    (+ a dedicated prefill replica when disaggregated).  Returns router
    steps to drain (the fleet's modeled wall — replicas step their own
    modeled devices in parallel), the router step at which each uid's
    first token appeared, the per-uid token streams, and the router."""
    from repro.serving.api import (KVNANDServer, SamplingParams,
                                   ServerConfig)
    from repro.serving.router import ReplicaRouter

    servers = [
        KVNANDServer(
            ServerConfig(scheduler="interleaved", engine=eng,
                         batch_slots=SLOTS, max_context=MAX_CONTEXT,
                         prefill_chunk_tokens=CHUNK),
            cfg=cfg, params=params)
        for _ in range(n + (1 if disaggregate else 0))]
    router = ReplicaRouter(servers, disaggregate=disaggregate)
    sp = SamplingParams(max_new_tokens=MAX_NEW)
    uids = [router.submit(p, sp, uid=i) for i, p in enumerate(prompts)]
    first_step = {}
    steps = 0
    while router._busy():
        for e in router.step():
            if e.index == 0 and e.token is not None:
                first_step.setdefault(e.uid, steps + 1)
        steps += 1
        if steps >= 10_000:
            raise AssertionError("router drain did not converge")
    outs = {u: router.output(u).token_ids for u in uids}
    return steps, first_step, outs, router


def _emit_latency(mode, outs):
    from repro.serving.api import latency_percentile
    for name, sel in (("ttft", lambda o: o.ttft),
                      ("tpot", lambda o: o.tpot)):
        vals = [sel(o) for o in outs if sel(o) is not None]
        for q in (50, 95):
            emit(f"serving/{mode}/{name}_p{q}",
                 latency_percentile(vals, q) * 1e6,
                 f"us {name} p{q} over {len(vals)} requests")


def run():
    from repro.configs import EngineConfig, get_config
    from repro.models.registry import Model
    from repro.models.transformer import Runtime

    cfg = get_config(ARCH).reduced()
    params = Model(cfg, Runtime()).init(jax.random.PRNGKey(0))
    stripe = EngineConfig(page_tokens=PAGE_TOKENS, uniform_lengths=False)
    shared = EngineConfig(page_tokens=PAGE_TOKENS, uniform_lengths=False,
                          shared_pool=True)
    prompts = _trace(cfg.vocab_size)

    outs = {}
    for mode, sched, eng in (("splice", "splice", stripe),
                             ("interleaved", "interleaved", stripe),
                             ("shared", "interleaved", shared)):
        dt, total, st, outs[mode], ro = _drain(sched, cfg, params, eng,
                                               prompts)
        stall = st["decode_stall_tokens"] / max(st["admits"], 1)
        emit(f"serving/{mode}/wall", dt * 1e6,
             f"{total / dt:.1f} tok/s cpu ({total} tokens)")
        emit(f"serving/{mode}/steps_to_drain", float(st["steps"]),
             f"steps; {st['prefill_chunks']} prefill chunks")
        emit(f"serving/{mode}/compiles", float(st["compiles"]),
             "distinct jit signatures")
        emit(f"serving/{mode}/decode_stall_per_admit", stall,
             f"decode tokens stalled per admit "
             f"({st['decode_stall_tokens']} over {st['admits']} admits)")
        _emit_latency(mode, ro)
        if mode == "shared":
            util = st["pool_peak_pages"] / max(st["pool_total_pages"], 1)
            emit("serving/shared/pool_util", util * 100.0,
                 f"% peak: {st['pool_peak_pages']} of "
                 f"{st['pool_total_pages']} pool pages live")
    for mode in ("interleaved", "shared"):
        if outs[mode] != outs["splice"]:
            raise AssertionError(
                f"{mode} scheduler diverged from the splice baseline")

    # prefix sharing: shared system prompt -> cached pages served
    pprompts = _prefix_trace(cfg.vocab_size)
    _, _, st_ref, o_ref, _ = _drain("interleaved", cfg, params, stripe,
                                    pprompts)
    dt, total, st, o_shared, _ = _drain("interleaved", cfg, params,
                                        shared, pprompts)
    if o_shared != o_ref:
        raise AssertionError("prefix-cache outputs diverged from stripe")
    hit_rate = st["prefix_hit_pages"] / max(st["prompt_pages"], 1)
    emit("serving/shared_prefix/prefix_hit_rate", hit_rate * 100.0,
         f"% of prompt pages served from cache "
         f"({st['prefix_hit_pages']}/{st['prompt_pages']}; "
         f"{st['cow_copies']} COW copies)")

    # capacity-proportional admission: 6 slots whose per-slot stripes
    # (6 × NPg pages) cannot fit the 16-page pool, yet the actual mix can
    cap_eng = EngineConfig(page_tokens=PAGE_TOKENS, uniform_lengths=False,
                           shared_pool=True, total_pages=16)
    rng = np.random.default_rng(13)
    cap_prompts = [rng.integers(1, cfg.vocab_size, 11).tolist()
                   for _ in range(6)]
    dt, total, st, o_cap, _ = _drain("interleaved", cfg, params, cap_eng,
                                     cap_prompts, slots=6)
    if len(o_cap) != len(cap_prompts):
        raise AssertionError("capacity mix did not drain")
    npg = -(-MAX_CONTEXT // PAGE_TOKENS)
    overcommit = 6 * npg / st["pool_total_pages"]
    emit("serving/shared_capacity/stripe_overcommit", overcommit,
         f"x: {6 * npg} stripe pages admitted through a "
         f"{st['pool_total_pages']}-page pool "
         f"(peak {st['pool_peak_pages']} live)")

    # speculative decoding: a repetitive trace where lookup drafts hit;
    # outputs must stay token-identical to sequential decode, and each
    # verify step must amortize > 1 token (the whole point)
    sprompts = _spec_trace(cfg.vocab_size)
    dt_seq, _, _, o_seq, _ = _drain("interleaved", cfg, params, shared,
                                    sprompts, max_new=16)
    emit("serving/spec/seq_wall", dt_seq * 1e6,
         "us: same trace decoded sequentially")
    dt, total, st, o_spec, _ = _drain("interleaved", cfg, params, shared,
                                      sprompts, spec_k=4, max_new=16)
    if o_spec != o_seq:
        raise AssertionError("speculative outputs diverged from "
                             "sequential decode")
    from repro.serving.api import accepted_tokens_per_step
    per_step = accepted_tokens_per_step(st["spec_accepted"],
                                        st["spec_steps"]) or 0.0
    if per_step <= 1.0:
        raise AssertionError(
            f"speculation never accepted a draft on the repetitive "
            f"trace (accepted {st['spec_accepted']} over "
            f"{st['spec_steps']} verify row-steps)")
    emit("serving/spec/accepted_per_step", per_step,
         f"tokens per request-verify-step ({st['spec_accepted']} "
         f"drafts accepted of {st['spec_drafted']} over "
         f"{st['spec_steps']} row-steps)")
    emit("serving/spec/wall", dt * 1e6,
         f"{total / dt:.1f} tok/s cpu ({total} tokens, spec_k=4)")

    # tiered flash KV hierarchy (DESIGN.md §13): two-wave trace whose
    # working set (96 flash pages) exceeds the 12-slot hot tier; outputs
    # must stay token-identical to the single-tier pool, the hot tier
    # must actually miss (< 100% hit rate), and prefetch must absorb
    # demand faults relative to the ablation
    tier_uniq = _tier_trace(cfg.vocab_size)
    flat_eng = EngineConfig(page_tokens=PAGE_TOKENS,
                            uniform_lengths=False, shared_pool=True,
                            total_pages=TIER_TOTAL_PAGES)
    tier_eng = EngineConfig(page_tokens=PAGE_TOKENS,
                            uniform_lengths=False, shared_pool=True,
                            total_pages=TIER_TOTAL_PAGES,
                            hot_pages=TIER_HOT_PAGES)
    _, o_flat, _ = _drain_tiered(cfg, params, flat_eng, tier_uniq)
    dt_off, o_off, st_off = _drain_tiered(cfg, params, tier_eng,
                                          tier_uniq, prefetch=False)
    dt_on, o_on, st_on = _drain_tiered(cfg, params, tier_eng, tier_uniq)
    for name, o in (("prefetch-on", o_on), ("prefetch-off", o_off)):
        if o != o_flat:
            raise AssertionError(
                f"tiered {name} outputs diverged from the single-tier "
                "pool")
    touched = st_on["tier_hit_pages"] + st_on["tier_miss_pages"]
    tier_hr = st_on["tier_hit_pages"] / max(touched, 1)
    if tier_hr >= 1.0:
        raise AssertionError(
            "tiered trace never missed the hot tier — working set does "
            "not exceed it")
    if st_on["tier_stall_tokens"] >= st_off["tier_stall_tokens"]:
        raise AssertionError(
            f"prefetch did not reduce demand faults "
            f"({st_on['tier_stall_tokens']} on vs "
            f"{st_off['tier_stall_tokens']} off)")
    from repro.core import flashsim as fs
    sysm = fs.kvnand_d(8, 8, 4, 16, kv_bits=8)
    stall_s = fs.tier_stall_time(sysm, get_config(ARCH),
                                 st_on["tier_stall_tokens"],
                                 PAGE_TOKENS)
    emit("serving/tiered/wall", dt_on * 1e6,
         f"us two-wave drain, prefetch on ({dt_off * 1e6:.0f} off)")
    emit("serving/tiered/hit_rate", tier_hr * 100.0,
         f"% cached map-ins hot ({st_on['tier_hit_pages']}/{touched}; "
         f"{st_on['tier_prefetch_pages']} prefetched)")
    emit("serving/tiered/stall_tokens",
         float(st_on["tier_stall_tokens"]),
         f"demand promotions, prefetch on; modeled stall "
         f"{stall_s * 1e6:.0f} us on kvnand-d")
    emit("serving/tiered/stall_tokens_noprefetch",
         float(st_off["tier_stall_tokens"]),
         f"demand promotions with prefetch disabled "
         f"({st_off['tier_demotes']} demotes)")
    emit("serving/tiered/pool_util_hot",
         st_on["tier_peak_hot"] / st_on["tier_hot_slots"] * 100.0,
         f"% peak: {st_on['tier_peak_hot']} of "
         f"{st_on['tier_hot_slots']} hot slots resident")
    emit("serving/tiered/pool_util_capacity",
         st_on["pool_peak_pages"] / st_on["pool_total_pages"] * 100.0,
         f"% peak: {st_on['pool_peak_pages']} of "
         f"{st_on['pool_total_pages']} flash pages live")

    # overlapped host/device pipeline (DESIGN.md §14): the SAME
    # Poisson-arrival trace through both stepping disciplines over the
    # modeled kvnand-d decode window; tokens must match exactly and the
    # pipelined drain must win wall-clock (best of 2 per mode — arrival
    # sleeps and modeled windows are identical, so the min isolates the
    # stepping discipline from runner noise)
    dev_s = fs.serving_step_time(sysm, get_config(ARCH), MAX_CONTEXT,
                                 0.0, overlap=False)
    rng = np.random.default_rng(31)
    alens = rng.integers(5, 45, N_ASYNC)
    aprompts = [rng.integers(1, cfg.vocab_size, int(n)).tolist()
                for n in alens]
    wrng = np.random.default_rng(37)        # same lengths, fresh content
    awarmup = [wrng.integers(1, cfg.vocab_size, int(n)).tolist()
               for n in alens]
    arrivals = _poisson_arrivals(N_ASYNC, ASYNC_RATE_HZ)
    runs = {}
    for overlap in (False, True):
        runs[overlap] = min(
            (_drain_poisson(cfg, params, shared, aprompts, arrivals,
                            awarmup, overlap=overlap, device_s=dev_s)
             for _ in range(2)),
            key=lambda r: r[0])
    (wall_off, ao_off, ast_off) = runs[False]
    (wall_on, ao_on, ast_on) = runs[True]
    for i in ao_on:
        if ao_on[i].token_ids != ao_off[i].token_ids:
            raise AssertionError(
                f"overlapped pipeline diverged from the synchronous "
                f"schedule on request {i}")
    if wall_on >= wall_off:
        raise AssertionError(
            f"overlapped drain did not beat the synchronous one "
            f"({wall_on * 1e3:.1f} ms on vs {wall_off * 1e3:.1f} ms off)")
    idle_on = ast_on["idle_s"] / wall_on
    idle_off = ast_off["idle_s"] / wall_off
    # the host time the sync loop serializes, per step: what the DSE's
    # overlap recommendation consumes (flashsim.overlap_speedup)
    host_s = ast_off["idle_s"] / max(ast_off["steps"], 1)
    from repro.core import dse
    rec = dse.recommend_overlap(sysm, get_config(ARCH), MAX_CONTEXT,
                                host_s)
    emit("serving/async/wall", wall_on * 1e6,
         f"us Poisson drain, overlap on; {wall_off * 1e6:.0f} us off "
         f"(speedup {wall_off / wall_on:.2f}x, {N_ASYNC} requests at "
         f"{ASYNC_RATE_HZ:.0f}/s, modeled kvnand-d decode window "
         f"{dev_s * 1e6:.0f} us/step)")
    emit("serving/async/wall_overlap_off", wall_off * 1e6,
         "us: the synchronous-stepping ablation, same trace and "
         "modeled device windows")
    emit("serving/async/device_idle_frac", idle_on * 100.0,
         f"% of wall with no step in flight (sync {idle_off * 100.0:.1f}%"
         f"; host {host_s * 1e6:.0f} us/step, dse.recommend_overlap="
         f"{rec} on kvnand-d)")
    met = sum(1 for o in ao_on.values()
              if o.finish_time - o.submit_time <= ASYNC_SLA_S)
    emit("serving/async/goodput_under_sla", met / wall_on,
         f"req/s within the {ASYNC_SLA_S:.1f}s SLA "
         f"({met}/{len(ao_on)} requests met it)")

    # multi-replica router + disaggregated prefill/decode (DESIGN.md
    # §16): same trace, 1 vs 2 replicas; each replica's decode steps
    # occupy its own modeled kvnand-d window, so router steps-to-drain
    # is the fleet's modeled wall.  Token streams must match the
    # single-server drain exactly at every replica count AND through
    # the disaggregated prefill->migrate->decode path.
    from repro.serving.api import latency_percentile
    rep = {}
    for n in (1, 2):
        rep[n] = _drain_router(cfg, params, shared, prompts, n)
    steps_1, _, _, _ = rep[1]
    steps_2, _, _, _ = rep[2]
    if steps_2 >= steps_1:
        raise AssertionError(
            f"2 replicas did not drain in fewer router steps than 1 "
            f"({steps_2} vs {steps_1})")
    total = sum(len(t) for t in outs["shared"].values())
    for n in (1, 2):
        steps_n, first, router_outs, _ = rep[n]
        if router_outs != outs["shared"]:
            raise AssertionError(
                f"router drain at {n} replicas diverged from the "
                "single-server baseline")
        wall_n = steps_n * dev_s
        emit(f"serving/replicas/tok_s_{n}", total / wall_n,
             f"modeled aggregate tok/s, {n} replica(s) x {SLOTS} slots "
             f"({steps_n} router steps x {dev_s * 1e6:.0f} us window)")
        ttft = [s * dev_s * 1e6 for s in first.values()]
        emit(f"serving/replicas/ttft_p95_{n}",
             latency_percentile(ttft, 95),
             f"us modeled p95 TTFT over {len(ttft)} requests")
    _, _, dis_outs, dis_router = _drain_router(cfg, params, shared,
                                               prompts, 1,
                                               disaggregate=True)
    if dis_outs != outs["shared"]:
        raise AssertionError(
            "disaggregated prefill/decode diverged from the "
            "single-server baseline")
    n_mig = dis_router.stats["migrations"]
    if n_mig != len(prompts):
        raise AssertionError(
            f"only {n_mig} of {len(prompts)} requests migrated")
    emit("serving/replicas/migration_bytes_per_req",
         dis_router.stats["migration_bytes"] / n_mig,
         f"KVEnvelope wire bytes per migrated request "
         f"({n_mig} migrations, retries "
         f"{dis_router.stats['migration_retries']})")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
