"""Serving-scheduler benchmark: interleaved chunked prefill vs the splice
baseline under mixed prefill/decode traffic.

Runs the same request trace through both schedulers on the reduced config
and emits, per scheduler:

  serving/<mode>/wall                 end-to-end µs (derived: tok/s)
  serving/<mode>/steps_to_drain       scheduler steps to drain the trace
  serving/<mode>/compiles             distinct jit signatures compiled
  serving/<mode>/decode_stall_per_admit
        decode tokens NOT generated while an admit monopolized the engine
        (chunk-granular: decoders idle × chunks of prefill work).  The
        interleaved scheduler shares every step between one prefill chunk
        and the whole decode batch, so its stall is 0 by construction —
        the acceptance metric for the chunked-prefill tentpole.

Counter rows carry the count in `us_per_call` (the harness's one numeric
column) with the unit spelled out in `derived`.
"""
import time

import jax
import numpy as np

from benchmarks.common import emit

ARCH = "qwen1.5-0.5b"
SLOTS = 3
MAX_CONTEXT = 128
CHUNK = 32
MAX_NEW = 8
N_REQUESTS = 8


def _trace(vocab):
    rng = np.random.default_rng(7)
    return [rng.integers(1, vocab, int(n)).tolist()
            for n in rng.integers(5, 45, N_REQUESTS)]


def _drain(cls, cfg, params, eng, prompts):
    from repro.serving.scheduler import Request

    b = cls(cfg, params, batch_slots=SLOTS, max_context=MAX_CONTEXT,
            temperature=0.0, eng=eng, prefill_chunk_tokens=CHUNK)
    for uid, p in enumerate(prompts):
        b.submit(Request(uid, list(p), max_new=MAX_NEW))
    t0 = time.perf_counter()
    done = b.run_to_completion()
    dt = time.perf_counter() - t0
    total = sum(len(r.output) for r in done.values())
    return dt, total, b.stats, {u: r.output for u, r in done.items()}


def run():
    from repro.configs import EngineConfig, get_config
    from repro.models.registry import Model
    from repro.models.transformer import Runtime
    from repro.serving.scheduler import ContinuousBatcher, SpliceBatcher

    cfg = get_config(ARCH).reduced()
    params = Model(cfg, Runtime()).init(jax.random.PRNGKey(0))
    eng = EngineConfig(page_tokens=16, uniform_lengths=False)
    prompts = _trace(cfg.vocab_size)

    outs = {}
    for mode, cls in (("splice", SpliceBatcher),
                      ("interleaved", ContinuousBatcher)):
        dt, total, st, outs[mode] = _drain(cls, cfg, params, eng, prompts)
        stall = st["decode_stall_tokens"] / max(st["admits"], 1)
        emit(f"serving/{mode}/wall", dt * 1e6,
             f"{total / dt:.1f} tok/s cpu ({total} tokens)")
        emit(f"serving/{mode}/steps_to_drain", float(st["steps"]),
             f"steps; {st['prefill_chunks']} prefill chunks")
        emit(f"serving/{mode}/compiles", float(st["compiles"]),
             "distinct jit signatures")
        emit(f"serving/{mode}/decode_stall_per_admit", stall,
             f"decode tokens stalled per admit "
             f"({st['decode_stall_tokens']} over {st['admits']} admits)")
    if outs["splice"] != outs["interleaved"]:
        raise AssertionError(
            "interleaved scheduler diverged from the splice baseline")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
