"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract).
"""
import sys
import traceback


def main() -> None:
    from benchmarks import (
        cost_analysis, fig5_reliability, fig12_throughput, fig13_breakdown,
        fig14_ablation, fig15_dse, fig16_energy, kernels_bench,
    )
    print("name,us_per_call,derived")
    modules = [
        ("fig12", fig12_throughput), ("fig13", fig13_breakdown),
        ("fig14", fig14_ablation), ("fig15", fig15_dse),
        ("fig16", fig16_energy), ("fig5", fig5_reliability),
        ("cost", cost_analysis), ("kernels", kernels_bench),
    ]
    failed = []
    for name, mod in modules:
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}",
                  file=sys.stderr)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmark modules failed: {failed}")


if __name__ == "__main__":
    main()
