"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract) and
appends the kernel rows of each run to ``BENCH_kernels.json`` so kernel
perf has a machine-readable trajectory across commits.
"""
import json
import pathlib
import sys
import time
import traceback

BENCH_KERNELS_PATH = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_kernels.json"


def _write_kernels_artifact():
    from benchmarks import common
    rows = [r for r in common.RECORDS if r["name"].startswith("kernels/")]
    if not rows:
        return
    runs = []
    if BENCH_KERNELS_PATH.exists():
        try:
            runs = json.loads(BENCH_KERNELS_PATH.read_text())
        except (json.JSONDecodeError, OSError):
            runs = []
    runs.append({"timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
                 "rows": rows})
    BENCH_KERNELS_PATH.write_text(json.dumps(runs, indent=2) + "\n")


def main() -> None:
    from benchmarks import (
        cost_analysis, fig5_reliability, fig12_throughput, fig13_breakdown,
        fig14_ablation, fig15_dse, fig16_energy, kernels_bench,
    )
    print("name,us_per_call,derived")
    modules = [
        ("fig12", fig12_throughput), ("fig13", fig13_breakdown),
        ("fig14", fig14_ablation), ("fig15", fig15_dse),
        ("fig16", fig16_energy), ("fig5", fig5_reliability),
        ("cost", cost_analysis), ("kernels", kernels_bench),
    ]
    failed = []
    for name, mod in modules:
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}",
                  file=sys.stderr)
            traceback.print_exc()
    _write_kernels_artifact()
    if failed:
        raise SystemExit(f"benchmark modules failed: {failed}")


if __name__ == "__main__":
    main()
