"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (harness contract) and
appends each run's rows to per-prefix trajectory artifacts
(``BENCH_kernels.json``, ``BENCH_serving.json``) so kernel and serving
perf have a machine-readable history across commits — the CI bench job
uploads them and gates on ``benchmarks/check_regression.py``.
"""
import json
import pathlib
import sys
import time
import traceback

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
# row-name prefix -> committed trajectory artifact
ARTIFACTS = {
    "kernels/": REPO_ROOT / "BENCH_kernels.json",
    "serving/": REPO_ROOT / "BENCH_serving.json",
}
BENCH_KERNELS_PATH = ARTIFACTS["kernels/"]


def _write_artifacts():
    from benchmarks import common
    stamp = time.strftime("%Y-%m-%dT%H:%M:%S")
    for prefix, path in ARTIFACTS.items():
        rows = [r for r in common.RECORDS if r["name"].startswith(prefix)]
        if not rows:
            continue
        runs = []
        if path.exists():
            try:
                runs = json.loads(path.read_text())
            except (json.JSONDecodeError, OSError):
                runs = []
        runs.append({"timestamp": stamp, "rows": rows})
        path.write_text(json.dumps(runs, indent=2) + "\n")


def main() -> None:
    from benchmarks import (
        cost_analysis, fig5_reliability, fig12_throughput, fig13_breakdown,
        fig14_ablation, fig15_dse, fig16_energy, kernels_bench,
        serving_bench,
    )
    print("name,us_per_call,derived")
    modules = [
        ("fig12", fig12_throughput), ("fig13", fig13_breakdown),
        ("fig14", fig14_ablation), ("fig15", fig15_dse),
        ("fig16", fig16_energy), ("fig5", fig5_reliability),
        ("cost", cost_analysis), ("kernels", kernels_bench),
        ("serving", serving_bench),
    ]
    failed = []
    for name, mod in modules:
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001
            failed.append(name)
            print(f"{name}/ERROR,0.0,{type(e).__name__}: {e}",
                  file=sys.stderr)
            traceback.print_exc()
    _write_artifacts()
    if failed:
        raise SystemExit(f"benchmark modules failed: {failed}")


if __name__ == "__main__":
    main()
