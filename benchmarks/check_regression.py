"""Bench-regression gate for the CI `bench` job.

`benchmarks/run.py` APPENDS the current run's kernel rows to the committed
``BENCH_kernels.json`` trajectory; this script compares that freshest run
against the per-entry MEDIAN of the committed trajectory and fails
(exit 1) if any kernel entry's ``us_per_call`` regressed by more than
``--threshold`` (default 20%).

  python benchmarks/run.py            # appends the current run
  python benchmarks/check_regression.py

Entries faster than ``--min-us`` in the baseline are skipped (CI-runner
timer noise dominates sub-50µs calls); entries that appear or disappear
between runs are reported but never fail the build (renames land with the
PR that introduces them).

Known limitation: the trajectory mixes machines (dev boxes commit runs,
CI appends its own), and absolute wall times do not transfer across CPU
models.  The median baseline + best-of-iters timing absorb load noise,
not machine skew — when the fleet changes, re-baseline by committing a
few runs from the new machine (the median follows the majority).
"""
import argparse
import json
import pathlib
import statistics
import sys

DEFAULT_PATH = pathlib.Path(__file__).resolve().parent.parent \
    / "BENCH_kernels.json"


def trajectory_baseline(runs):
    """Per-entry MEDIAN over the committed runs: tolerant of one noisy
    committed run, without ratcheting down to an unbeatable best-case."""
    series = {}
    for run in runs:
        for r in run["rows"]:
            series.setdefault(r["name"], []).append(r["us_per_call"])
    return [{"name": n, "us_per_call": statistics.median(v)}
            for n, v in series.items()]


def compare(baseline_rows, current_rows, threshold: float, min_us: float):
    """Returns (regressions, notes): regressions are (name, old, new)."""
    base = {r["name"]: r["us_per_call"] for r in baseline_rows}
    cur = {r["name"]: r["us_per_call"] for r in current_rows}
    regressions, notes = [], []
    for name in sorted(set(base) | set(cur)):
        if name not in cur:
            notes.append(f"entry removed: {name}")
            continue
        if name not in base:
            notes.append(f"new entry (no baseline): {name}")
            continue
        old, new = base[name], cur[name]
        if old < min_us:
            notes.append(f"skipped (baseline {old:.1f}us < {min_us:.0f}us "
                         f"noise floor): {name}")
            continue
        if new > old * (1.0 + threshold):
            regressions.append((name, old, new))
    return regressions, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", type=pathlib.Path, default=DEFAULT_PATH)
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="fractional slowdown that fails the build")
    ap.add_argument("--min-us", type=float, default=50.0,
                    help="skip entries whose baseline is below this")
    args = ap.parse_args(argv)

    if not args.path.exists():
        print(f"[check_regression] {args.path} missing — nothing to gate")
        return 0
    runs = json.loads(args.path.read_text())
    if len(runs) < 2:
        print(f"[check_regression] only {len(runs)} run(s) in trajectory — "
              "need a committed baseline plus the current run; passing")
        return 0
    current = runs[-1]
    baseline_rows = trajectory_baseline(runs[:-1])
    regressions, notes = compare(baseline_rows, current["rows"],
                                 args.threshold, args.min_us)
    for n in notes:
        print(f"[check_regression] note: {n}")
    print(f"[check_regression] trajectory median of {len(runs) - 1} "
          f"committed run(s) vs current {current['timestamp']}: "
          f"{len(regressions)} regression(s) at >{args.threshold:.0%}")
    for name, old, new in regressions:
        print(f"  REGRESSED {name}: {old:.1f}us -> {new:.1f}us "
              f"({new / old - 1.0:+.1%})")
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
