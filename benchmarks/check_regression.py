"""Bench-regression gate for the CI `bench` job.

`benchmarks/run.py` APPENDS the current run's rows to the committed
trajectory artifacts (``BENCH_kernels.json`` and ``BENCH_serving.json``);
this script compares each freshest run against the per-entry MEDIAN of
its committed trajectory and fails (exit 1) on a regression of more than
``--threshold`` (default 20%).

  python benchmarks/run.py            # appends the current run
  python benchmarks/check_regression.py

Kernel entries gate on ``us_per_call`` directly.  Serving entries gate
only the trajectory metrics that measure scheduler QUALITY — end-to-end
``wall`` and ``steps_to_drain`` — so the PR 2 interleaving wins (and the
shared-pool admission wins on top) stay protected; counter rows
(compiles, stall/hit/utilization diagnostics) are informational and
never fail the build.

Entries faster than ``--min-us`` in the baseline are skipped (CI-runner
timer noise dominates sub-50µs calls); entries that appear or disappear
between runs are reported but never fail the build (renames land with the
PR that introduces them).

Known limitation: the trajectory mixes machines (dev boxes commit runs,
CI appends its own), and absolute wall times do not transfer across CPU
models.  The median baseline + best-of-iters timing absorb load noise,
not machine skew — when the fleet changes, re-baseline by committing a
few runs from the new machine (the median follows the majority).
"""
import argparse
import json
import pathlib
import statistics
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
DEFAULT_PATH = REPO_ROOT / "BENCH_kernels.json"
SERVING_PATH = REPO_ROOT / "BENCH_serving.json"

# serving rows gated on their trajectory value; everything else in the
# serving artifact is a diagnostic counter.  ttft/tpot percentiles come
# from RequestOutput timing (serving/api.py) — the per-request latency
# surface the wall-clock rows can't see.  The p50 rows gate; the p95
# rows are emitted but informational: on a fresh server per drain they
# land on the requests that pay the jit compiles, whose wall time swings
# with runner speed far more than steady-state serving does.
SERVING_GATED_SUFFIXES = ("/wall", "/steps_to_drain",
                          "/ttft_p50", "/tpot_p50")
# informational prefixes: serving/spec/* rows (speculative decoding),
# serving/tiered/* rows (tiered flash KV hierarchy, DESIGN.md §13) and
# serving/async/* rows (overlapped pipeline under Poisson load,
# DESIGN.md §14) stay ungated while each feature's trajectory
# accumulates — the bench itself hard-fails on output divergence,
# accepted_per_step <= 1, a hot tier that never misses, prefetch
# failing to beat the ablation, or the overlapped drain losing to the
# synchronous one; serving/replicas/* rows (multi-replica router,
# DESIGN.md §16) likewise hard-fail in-bench on token divergence, a
# 2-replica drain that fails to beat 1 replica, or missing migrations
SERVING_UNGATED_PREFIXES = ("serving/spec/", "serving/tiered/",
                            "serving/async/", "serving/replicas/")
# same mechanism for kernel rows: the 100K split-page partition sweep
# stays informational while its trajectory accumulates (the landing run
# has no committed baseline); the correctness of the split is gated by
# tier-1 parity tests, and its speedup is recorded in the row notes
KERNELS_UNGATED_PREFIXES = ("kernels/paged_attention_100k",)


def _gated_serving_rows(rows):
    return [r for r in rows
            if r["name"].endswith(SERVING_GATED_SUFFIXES)
            and not r["name"].startswith(SERVING_UNGATED_PREFIXES)]


def _gated_kernel_rows(rows):
    return [r for r in rows
            if not r["name"].startswith(KERNELS_UNGATED_PREFIXES)]


def trajectory_baseline(runs):
    """Per-entry MEDIAN over the committed runs: tolerant of one noisy
    committed run, without ratcheting down to an unbeatable best-case."""
    series = {}
    for run in runs:
        for r in run["rows"]:
            series.setdefault(r["name"], []).append(r["us_per_call"])
    return [{"name": n, "us_per_call": statistics.median(v)}
            for n, v in series.items()]


def compare(baseline_rows, current_rows, threshold: float, min_us: float):
    """Returns (regressions, notes): regressions are (name, old, new)."""
    base = {r["name"]: r["us_per_call"] for r in baseline_rows}
    cur = {r["name"]: r["us_per_call"] for r in current_rows}
    regressions, notes = [], []
    for name in sorted(set(base) | set(cur)):
        if name not in cur:
            notes.append(f"entry removed: {name}")
            continue
        if name not in base:
            notes.append(f"new entry (no baseline): {name}")
            continue
        old, new = base[name], cur[name]
        if old < min_us:
            notes.append(f"skipped (baseline {old:.1f}us < {min_us:.0f}us "
                         f"noise floor): {name}")
            continue
        if new > old * (1.0 + threshold):
            regressions.append((name, old, new))
    return regressions, notes


def check_artifact(path: pathlib.Path, threshold: float, min_us: float,
                   row_filter=None) -> int:
    """Gate one trajectory artifact; returns the regression count."""
    tag = f"[check_regression:{path.name}]"
    if not path.exists():
        print(f"{tag} missing — nothing to gate")
        return 0
    runs = json.loads(path.read_text())
    if len(runs) < 2:
        print(f"{tag} only {len(runs)} run(s) in trajectory — need a "
              "committed baseline plus the current run; passing")
        return 0
    current = runs[-1]
    baseline_rows = trajectory_baseline(runs[:-1])
    cur_rows = current["rows"]
    if row_filter is not None:
        baseline_rows = row_filter(baseline_rows)
        cur_rows = row_filter(cur_rows)
    regressions, notes = compare(baseline_rows, cur_rows,
                                 threshold, min_us)
    for n in notes:
        print(f"{tag} note: {n}")
    print(f"{tag} trajectory median of {len(runs) - 1} committed run(s) "
          f"vs current {current['timestamp']}: {len(regressions)} "
          f"regression(s) at >{threshold:.0%}")
    for name, old, new in regressions:
        print(f"  REGRESSED {name}: {old:.1f} -> {new:.1f} "
              f"({new / old - 1.0:+.1%})")
    return len(regressions)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", type=pathlib.Path, default=DEFAULT_PATH)
    ap.add_argument("--serving-path", type=pathlib.Path,
                    default=SERVING_PATH)
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="fractional slowdown that fails the build")
    ap.add_argument("--min-us", type=float, default=50.0,
                    help="skip entries whose baseline is below this")
    args = ap.parse_args(argv)

    n_bad = check_artifact(args.path, args.threshold, args.min_us,
                           row_filter=_gated_kernel_rows)
    # serving rows gate WITHOUT the µs noise floor: steps_to_drain is a
    # deterministic step count, and the wall rows are whole-trace drains
    # (seconds — far above any timer noise a floor would need to absorb)
    n_bad += check_artifact(args.serving_path, args.threshold, 0.0,
                            row_filter=_gated_serving_rows)
    return 1 if n_bad else 0


if __name__ == "__main__":
    sys.exit(main())
