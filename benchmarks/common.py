"""Shared benchmark helpers: CSV rows `name,us_per_call,derived`."""
import math
import time

# every emit() lands here too, so the harness (benchmarks/run.py) can dump
# machine-readable trajectory artifacts (e.g. BENCH_kernels.json)
RECORDS = []


def geomean(xs):
    xs = [x for x in xs if x > 0]
    return math.exp(sum(math.log(x) for x in xs) / len(xs)) if xs else 0.0


def emit(name: str, us_per_call: float, derived: str):
    RECORDS.append({"name": name, "us_per_call": round(us_per_call, 3),
                    "derived": derived})
    print(f"{name},{us_per_call:.3f},{derived}")


def time_fn(fn, *args, warmup=1, iters=5):
    """Best-of-iters wall time in µs.  The MIN is the right statistic for
    a regression-gated trajectory (benchmarks/check_regression.py): timer
    noise on shared CI runners is strictly additive, so the mean flaps
    with machine load while the min tracks the code's actual cost."""
    for _ in range(warmup):
        fn(*args)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best * 1e6, out
