"""Shared benchmark helpers: CSV rows `name,us_per_call,derived`."""
import math
import sys
import time

# every emit() lands here too, so the harness (benchmarks/run.py) can dump
# machine-readable trajectory artifacts (e.g. BENCH_kernels.json)
RECORDS = []


def geomean(xs):
    xs = [x for x in xs if x > 0]
    return math.exp(sum(math.log(x) for x in xs) / len(xs)) if xs else 0.0


def emit(name: str, us_per_call: float, derived: str):
    RECORDS.append({"name": name, "us_per_call": round(us_per_call, 3),
                    "derived": derived})
    print(f"{name},{us_per_call:.3f},{derived}")


def time_fn(fn, *args, warmup=1, iters=5):
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    dt = (time.perf_counter() - t0) / iters
    return dt * 1e6, out
