"""Fig 14: ablations — (a) HG pipelining in KVNAND-D, (b) page-level KV
mapping in KVNAND-C (paper: 82.4% @10K; 1.9% @100K MHA-30B)."""
from benchmarks.common import emit
from repro.configs import get_config
from repro.core import flashsim as fs


def run():
    # (a) HG parallelism, normalized latency vs no-dataflow-opt baseline
    for m in ("llama2-7b", "llama3.1-8b", "opt-30b"):
        cfg = get_config(m)
        for seq in (1_000, 10_000, 100_000):
            on = fs.decode_token_latency(
                fs.kvnand_d(4, 4, 16, 16, hg=True), cfg, seq).total
            off = fs.decode_token_latency(
                fs.kvnand_d(4, 4, 16, 16, hg=False), cfg, seq).total
            emit(f"fig14a/hg_pipeline/{m}/{seq}", on * 1e6,
                 f"normalized={100 * on / off:.1f}% (paper 82.4% @10K)")
    # (b) page mapping: attention time with/without §IV-D mapping
    for m in ("opt-30b", "llama3.1-8b"):
        cfg = get_config(m)
        for seq in (10_000, 100_000):
            t_on, _ = fs._attn_terms(fs.kvnand_c(16, 16, 16, mapping=True),
                                     cfg, seq)
            t_off, _ = fs._attn_terms(
                fs.kvnand_c(16, 16, 16, mapping=False), cfg, seq)
            emit(f"fig14b/page_mapping/{m}/{seq}", t_on * 1e6,
                 f"normalized={100 * t_on / t_off:.2f}% (paper 1.9% "
                 f"@100K MHA-30B)")


if __name__ == "__main__":
    run()
