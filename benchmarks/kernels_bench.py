"""Kernel microbenchmarks: wall-clock of the jnp refs + Pallas-interpret
parity checks on CPU (TPU wall-time is out of scope in this container —
kernel perf is reasoned structurally in EXPERIMENTS.md §Perf)."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.quant import quantize_weight
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention_partial
from repro.kernels.quant_gemv import quant_gemv


def run():
    ks = jax.random.split(jax.random.PRNGKey(0), 4)

    # flash attention ref (prefill-block scale)
    B, S, H, K, dh = 2, 1024, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, dh), jnp.float32)
    f = jax.jit(lambda q, k, v: flash_attention(q, k, v, impl="ref"))
    us, _ = time_fn(lambda: jax.block_until_ready(f(q, k, v)))
    flops = 4 * B * S * S * H * dh * 0.5
    emit("kernels/flash_attention_ref_1k", us,
         f"{flops / us / 1e3:.1f} GFLOP/s cpu")

    # paged decode attention
    NP, T = 64, 64
    kp = jax.random.normal(ks[1], (B, K, NP, T, dh), jnp.bfloat16)
    vp = jax.random.normal(ks[2], (B, K, NP, T, dh), jnp.bfloat16)
    base = jnp.broadcast_to((jnp.arange(NP) * T)[None], (B, NP)
                            ).astype(jnp.int32)
    qd = jax.random.normal(ks[3], (B, H, dh), jnp.bfloat16)
    length = jnp.full((B,), NP * T, jnp.int32)
    g = jax.jit(lambda *a: paged_attention_partial(*a, impl="ref"))
    us, _ = time_fn(lambda: jax.block_until_ready(
        g(qd, kp, vp, base, length)))
    kv_bytes = 2 * B * K * NP * T * dh * 2
    emit("kernels/paged_attention_ref_4k", us,
         f"{kv_bytes / us / 1e3:.1f} GB/s kv stream cpu")

    # quantized paged decode attention (kv8 / kv4 pools, fused dequant):
    # the decode hot loop streams the packed codes + one f32 scale per
    # page×head instead of bf16 pages — the bytes ratio is the paper axis
    from repro.core.quant import quantize_kv_page
    for fmt in ("kv8", "kv4"):
        qk, sk = quantize_kv_page(kp.astype(jnp.float32), fmt)
        qv, sv = quantize_kv_page(vp.astype(jnp.float32), fmt)
        gq = jax.jit(lambda q_, k_, v_, b_, l_, ks_, vs_, fmt=fmt:
                     paged_attention_partial(q_, k_, v_, b_, l_, impl="ref",
                                             kv_quant=fmt, k_scale=ks_,
                                             v_scale=vs_))
        us, _ = time_fn(lambda: jax.block_until_ready(
            gq(qd, qk, qv, base, length, sk, sv)))
        q_bytes = 2 * (qk.size * qk.dtype.itemsize
                       + sk.size * sk.dtype.itemsize)
        emit(f"kernels/paged_attention_{fmt}_4k", us,
             f"{q_bytes / us / 1e3:.1f} GB/s kv stream cpu; "
             f"{kv_bytes / q_bytes:.2f}x fewer kv bytes/step vs bf16")

    # 100K-context paged decode: the split-page `partitions` sweep.
    # 1600 pages × 64 tokens = 102400 resident tokens, one decode query.
    # partitions > 1 bounds each partition's dequant copies and score
    # tensor at 1/P of the monolithic walk — the cache-residency win the
    # auto ladder (resolve_partitions) banks on at long context.
    _bench_100k()

    # quantized GEMV
    D, F = 1024, 4096
    w = jax.random.normal(ks[0], (D, F)) * 0.05
    x = jax.random.normal(ks[1], (4, D))
    for scheme in ("w8a8", "w4a16"):
        qw = quantize_weight(w, scheme)
        h = jax.jit(lambda x: quant_gemv(x, qw, impl="ref"))
        us, _ = time_fn(lambda: jax.block_until_ready(h(x)))
        emit(f"kernels/quant_gemv_{scheme}", us,
             f"{qw.q.size * qw.q.dtype.itemsize / us / 1e3:.1f} GB/s "
             f"weight stream cpu")

    # wkv6 chunked vs recurrent
    from repro.models.rwkv6 import wkv_chunked, wkv_recurrent
    Bw, Sw, Hw, dhw = 2, 512, 4, 64
    kk = jax.random.split(jax.random.PRNGKey(1), 6)
    r = jax.random.normal(kk[0], (Bw, Sw, Hw, dhw))
    kkv = jax.random.normal(kk[1], (Bw, Sw, Hw, dhw))
    vv = jax.random.normal(kk[2], (Bw, Sw, Hw, dhw))
    lw = -0.05 - 4.0 * jax.nn.sigmoid(
        jax.random.normal(kk[3], (Bw, Sw, Hw, dhw)))
    u = jax.random.normal(kk[4], (Hw, dhw)) * 0.5
    s0 = jnp.zeros((Bw, Hw, dhw, dhw))
    for name, fn in (("recurrent", wkv_recurrent), ("chunked", wkv_chunked)):
        jfn = jax.jit(lambda *a, fn=fn: fn(*a)[0])
        us, _ = time_fn(lambda: jax.block_until_ready(
            jfn(r, kkv, vv, lw, u, s0)))
        emit(f"kernels/wkv6_{name}_512", us, f"{Sw} tokens")


def _bench_100k():
    from repro.core.quant import quantize_kv_page
    B, K, H, dh = 1, 2, 8, 64
    NP, T = 1600, 64                       # divisible by 4 and 16
    ks = jax.random.split(jax.random.PRNGKey(2), 4)
    kp = jax.random.normal(ks[0], (B, K, NP, T, dh), jnp.float32) * 0.3
    vp = jax.random.normal(ks[1], (B, K, NP, T, dh), jnp.float32) * 0.3
    qd = jax.random.normal(ks[2], (B, H, dh), jnp.float32)
    base = jnp.broadcast_to((jnp.arange(NP) * T)[None], (B, NP)
                            ).astype(jnp.int32)
    length = jnp.full((B,), NP * T, jnp.int32)
    table = jnp.broadcast_to(jnp.arange(NP, dtype=jnp.int32)[None],
                             (B, NP))

    for fmt in ("f32", "kv8", "kv4"):
        if fmt == "f32":
            kk, vv, sk, sv = kp, vp, None, None
            quant = "none"
        else:
            quant = fmt
            kk, sk = quantize_kv_page(kp, fmt)
            vv, sv = quantize_kv_page(vp, fmt)
        # shared pool: same pages as one global pool behind an identity
        # table ([K, NP, Ts, dh] + [K, NP] scales)
        kk_s, vv_s = kk[0], vv[0]
        sk_s = None if sk is None else sk[0]
        sv_s = None if sv is None else sv[0]
        base_us = {}
        for layout in ("striped", "shared"):
            for parts in (1, 4, 16):
                if layout == "striped":
                    fn = jax.jit(lambda q_, k_, v_, b_, l_, ks_, vs_,
                                 quant=quant, parts=parts:
                                 paged_attention_partial(
                                     q_, k_, v_, b_, l_, impl="ref",
                                     kv_quant=quant, k_scale=ks_,
                                     v_scale=vs_, partitions=parts))
                    args = (qd, kk, vv, base, length, sk, sv)
                else:
                    fn = jax.jit(lambda q_, k_, v_, b_, l_, ks_, vs_, t_,
                                 quant=quant, parts=parts:
                                 paged_attention_partial(
                                     q_, k_, v_, b_, l_, impl="ref",
                                     kv_quant=quant, k_scale=ks_,
                                     v_scale=vs_, page_table=t_,
                                     partitions=parts))
                    args = (qd, kk_s, vv_s, base, length, sk_s, sv_s,
                            table)
                us, _ = time_fn(lambda: jax.block_until_ready(fn(*args)))
                if parts == 1:
                    base_us[layout] = us
                    note = f"{NP * T} tokens, monolithic walk"
                else:
                    note = (f"{NP * T} tokens, {parts}-way split; "
                            f"{base_us[layout] / us:.2f}x vs p1")
                emit(f"kernels/paged_attention_100k/{fmt}/{layout}"
                     f"/p{parts}", us, note)


if __name__ == "__main__":
    run()
