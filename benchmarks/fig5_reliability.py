"""Fig 5(a) + §V-D: PGRD counts, reduction factors, lifetime endurance."""
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import reliability as rel
from repro.core.flashsim import FlashDie, SystemConfig


def run():
    cfg8 = get_config("llama3.1-8b")
    br = rel.simulate_request_reads(cfg8, 25_000, 25_000, 16, FlashDie())
    emit("fig5a/llama3.1-8b/max_block_reads", 0.0,
         f"{br.max():.2e} (limit {rel.READ_DISTURB_LIMIT:.0e})")
    emit("fig5a/llama3.1-8b/early_vs_late", 0.0,
         f"{br[0] / max(br[-1], 1):.1f}x more reads on early blocks")

    f = rel.pgrd_reduction_factors(cfg8, SystemConfig("x", "kvnand-d", 8, 8))
    emit("vD/pgrd_reduction/kvnand_c", 0.0,
         f"{f['kvnand_c']:.0f}x (paper ~128x)")
    emit("vD/pgrd_reduction/kvnand_d", 0.0,
         f"{f['kvnand_d']:.0f}x (paper ~2560x)")

    life = rel.lifetime_pe_cycles(get_config("llama3.1-70b"))
    emit("vD/lifetime/total_kv", 0.0,
         f"{life['total_tb']:.0f} TB over 5y (paper ~143)")
    emit("vD/lifetime/pe_cycles", 0.0,
         f"{life['pe_cycles']:.0f} (budget {life['budget']}, "
         f"ok={life['margin_ok']})")

    alloc = rel.BlockAllocator(1024, seed=0)
    for _ in range(500):
        blocks = alloc.allocate(8)
        alloc.record_request(blocks, np.full(8, 5e4))
    emit("vD/allocator/utilization", 0.0,
         f"{100 * alloc.utilization():.1f}% blocks healthy after 500 reqs")


if __name__ == "__main__":
    run()
