"""Fig 15: DSE latency heatmaps — 8-die configs (G1 = 1..7 + C-8) ×
sequence lengths × quantization (W8A8 / W4A16) × {30B MHA, 70B GQA}.
Blank (OOM) cells print derived=OOM."""
import math

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import dse

SEQS = [1_000, 2_000, 5_000, 10_000, 50_000, 100_000]


def run():
    for model in ("opt-30b", "llama3.1-70b"):
        cfg = get_config(model)
        for wbits, abits, tag in ((8, 8, "w8a8"), (4, 16, "w4a16")):
            grid = dse.heatmap(cfg, SEQS, total_dies=8, wbits=wbits,
                               abits=abits)
            # per-seq best config (the red cells of Fig 15)
            for seq in SEQS:
                best = min(((lat[seq], name) for name, lat in grid.items()
                            if not math.isinf(lat[seq])), default=None)
                if best is None:
                    emit(f"fig15/{model}/{tag}/{seq}/best", 0.0, "OOM")
                else:
                    emit(f"fig15/{model}/{tag}/{seq}/best", best[0] * 1e6,
                         best[1])
            n_oom = sum(math.isinf(v) for lat in grid.values()
                        for v in lat.values())
            emit(f"fig15/{model}/{tag}/oom_cells", 0.0,
                 f"{n_oom}/{len(grid) * len(SEQS)} blank")
        t = dse.takeaways(get_config("opt-30b"), get_config("llama3.1-70b"))
        emit(f"fig15/{model}/takeaways", 0.0,
             ";".join(f"{k}={v}" for k, v in t.items()))


if __name__ == "__main__":
    run()
