"""Fig 12: decode throughput of Base-1/Base-2/KVNAND-C/KVNAND-D across the
five paper models × {1K, 10K, 100K} contexts (+128 for the headline
geomean).  derived column: tokens/s (0 = OOM)."""
from benchmarks.common import emit, geomean
from repro.configs import get_config
from repro.core import flashsim as fs

MODELS = ["opt-30b", "llama2-7b", "llama3.1-8b", "llama3.1-70b",
          "mixtral-8x7b"]
SEQS = [128, 1_000, 10_000, 100_000]
W, A = 16, 16   # paper evaluates full-precision models


def best_kvnand_d(cfg, seq):
    cands = [fs.kvnand_d(g1, 8 - g1, W, A) for g1 in range(1, 8)]
    return max(fs.decode_throughput(s, cfg, seq) for s in cands)


def run():
    speedups = {s: [] for s in SEQS}
    for m in MODELS:
        cfg = get_config(m)
        for seq in SEQS:
            rows = {
                "base1": fs.decode_throughput(fs.base1(W, A), cfg, seq),
                "base2": fs.decode_throughput(fs.base2(W, A), cfg, seq),
                "kvnand_c16": fs.decode_throughput(fs.kvnand_c(16, W, A),
                                                   cfg, seq),
                "kvnand_d": best_kvnand_d(cfg, seq),
            }
            for sysname, tp in rows.items():
                lat_us = 1e6 / tp if tp > 0 else 0.0
                emit(f"fig12/{m}/{seq}/{sysname}", lat_us,
                     f"{tp:.2f} tok/s")
            best = max(rows["kvnand_c16"], rows["kvnand_d"])
            if rows["base1"] > 0 and best > 0:
                speedups[seq].append(best / rows["base1"])
    for seq, target in zip(SEQS, (1.98, 1.94, 2.05, None)):
        g = geomean(speedups[seq])
        note = f"geomean_vs_base1={g:.2f}" + \
            (f" (paper {target})" if target else " (base1 OOM @100K)")
        emit(f"fig12/geomean/{seq}", 0.0, note)


if __name__ == "__main__":
    run()
