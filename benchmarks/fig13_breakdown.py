"""Fig 13: decode-latency breakdown (LLaMA3.1-8B @ 1K and 10K)."""
from benchmarks.common import emit
from repro.configs import get_config
from repro.core import flashsim as fs


def run():
    cfg = get_config("llama3.1-8b")
    for seq in (1_000, 10_000):
        for sysf in (fs.base1(16, 16), fs.base2(16, 16),
                     fs.kvnand_c(16, 16, 16), fs.kvnand_d(8, 8, 16, 16)):
            b = fs.decode_token_latency(sysf, cfg, seq)
            total = b.total
            for part in ("qkv", "attention", "o_proj", "ffn", "lm_head",
                         "kv_write", "transfer"):
                v = getattr(b, part)
                emit(f"fig13/{sysf.name}/{seq}/{part}", v * 1e6,
                     f"{100 * v / total:.1f}% of {total * 1e3:.2f}ms")
            emit(f"fig13/{sysf.name}/{seq}/overlap_saved",
                 b.overlap_saved * 1e6,
                 f"hg pipeline recovers {100 * b.overlap_saved / total:.1f}%")


if __name__ == "__main__":
    run()
