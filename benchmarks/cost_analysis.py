"""§V-C cost analysis: SLC-mode flash vs LPDDR5 for weights + KV storage."""
from benchmarks.common import emit
from repro.configs import get_config
from repro.core import flashsim as fs

TLC_PER_GB = 0.11          # YTMC 128-layer TLC [69]
SLC_DENSITY_RATIO = 8.5 / 1.8
AREA_OVERHEAD = 1.22       # page buffers
YIELD = 0.58 / 0.80        # conservative vs 80% base
LPDDR5_PER_GB = 4.62       # [56]


def run():
    slc_per_gb = TLC_PER_GB * SLC_DENSITY_RATIO
    emit("vC/slc_per_gb", 0.0, f"${slc_per_gb:.2f}/GB (paper $0.52)")
    effective = slc_per_gb * AREA_OVERHEAD / YIELD
    emit("vC/effective_per_gb", 0.0, f"${effective:.2f}/GB (paper $0.72)")

    die_gb = fs.FlashDie().capacity / 1e9
    n_dies = 8
    flash_cost = effective * die_gb * n_dies
    emit("vC/kvnand_d_4+4_flash_cost", 0.0,
         f"${flash_cost:.2f} for {n_dies} dies (paper ~$92.16)")

    # same weight+KV capacity in LPDDR5
    cfg = get_config("llama3.1-70b")
    cap_gb = die_gb * n_dies
    dram_cost = LPDDR5_PER_GB * cap_gb
    emit("vC/equivalent_lpddr5_cost", 0.0,
         f"${dram_cost:.2f} ({dram_cost / flash_cost:.1f}x flash; "
         f"paper >2x / $295.68)")


if __name__ == "__main__":
    run()
