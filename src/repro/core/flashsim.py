"""Analytical flash-system simulator — the paper's evaluation methodology.

Models per-token single-batch decode latency + energy for the four systems
of §V-A, parameterized exactly by Table I:

  Base-1     weight-only IFC (8 dies) + KV in LPDDR5X DRAM (8 ch × 8 GB/s),
             Logit/Attend on the NPU (Lincoln-scaled).
  Base-2     Base-1 with DRAM naively replaced by plain NAND (KV over the
             ONFI 4.8 GB/s external interface).
  KVNAND-D-(G1+G2)  weights on G1 IFC dies, KV on G2 IFC dies; head-group
             pipelining overlaps QKV-gen (G1) with Logit/Attend (G2).
  KVNAND-C-n weights + KV co-located on n IFC dies; phases serialize
             (internal-bandwidth contention) but use all dies.

Removing DRAM lets each channel host a second flash die at cost parity, so
the default KVNAND configs have 16 dies vs Base-1's 8 (paper §V-A).

Validation anchors (asserted in tests/test_flashsim.py):
  * Mixtral-8×7B KV/token = 128 KB (§III-B)
  * naive KV read at 1K ctx ≈ 6.9 ms; FFN read ≈ 44 ms (§III-B)
  * OOM: Base-1 at 100K ctx for all models; GQA models exhaust DRAM ≈ 50K
  * HG-pipelining ablation ≈ 82% latency at 10K (Fig 14a)
  * page-mapping ablation: attention-read time collapses at 100K (Fig 14b)
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ModelConfig

GB = 1e9
NPU_ROUNDTRIP = 4e-6   # IFC↔NPU softmax exchange latency per head group


# ---------------------------------------------------------------------------
# Hardware (Table I)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FlashDie:
    page_bytes: int = 4096
    ecc_bytes: int = 448
    pages_per_block: int = 768
    blocks_per_plane: int = 177
    planes: int = 32
    tR: float = 4e-6
    tP: float = 75e-6
    fmacs_per_plane: int = 16        # KVNAND dies (2 suffices for W-GEMV)
    clock: float = 400e6
    ext_bw: float = 4.8e9            # ONFI 6.0
    e_read: float = 3e-12            # J/bit internal read
    e_prog: float = 7.5e-12
    e_io: float = 4.9e-12            # J/bit interface

    @property
    def int_bw(self) -> float:       # 32 planes × 4KB / 4µs = 32 GB/s
        return self.planes * self.page_bytes / self.tR

    @property
    def prog_bw(self) -> float:      # 32 planes × 4KB / 75µs ≈ 1.75 GB/s
        return self.planes * self.page_bytes / self.tP

    @property
    def mac_rate(self) -> float:     # MAC/s per die
        return self.planes * self.fmacs_per_plane * self.clock

    capacity_bits: float = 132.75e9  # Table I: 132.75 Gb per die

    @property
    def capacity(self) -> float:     # ≈ 16.6 GB
        return self.capacity_bits / 8


@dataclass(frozen=True)
class NPU:
    tops: float = 32e12              # BF16
    power: float = 4.60              # W
    sram_kv_buffer: int = 5 << 20    # KVNAND-D SoC buffer
    sram_power: float = 0.36


@dataclass(frozen=True)
class DRAM:
    bw_per_channel: float = 8e9      # LPDDR5X
    channels: int = 8
    capacity: float = 16 * GB        # 8 × 16 Gb
    # §VI: DRAM also hosts system software + embeddings; 0.4 usable for KV
    # reproduces BOTH textual OOM claims (GQA models exhaust ≈50K; all
    # models OOM at 100K)
    usable_fraction: float = 0.4
    e_bit: float = 7e-12

    @property
    def bw(self) -> float:
        return self.bw_per_channel * self.channels

    @property
    def usable(self) -> float:
        return self.capacity * self.usable_fraction


@dataclass(frozen=True)
class SystemConfig:
    name: str
    kind: str                        # "base1" | "base2" | "kvnand-d" | "kvnand-c"
    weight_dies: int = 8
    kv_dies: int = 8                 # G2 (kvnand-d) / plain NAND (base2)
    wbits: int = 4                   # W4A16 default
    abits: int = 16
    hg_pipeline: bool = True         # kvnand-d dataflow optimization
    page_mapping: bool = True        # §IV-D scheme
    die: FlashDie = FlashDie()
    npu: NPU = NPU()
    dram: DRAM = DRAM()
    kv_bits: int = 0                 # KV page format; 0 -> abits (bf16-ish)

    @property
    def kv_bits_eff(self) -> int:
        """Stored KV bits: the Track-B kv8/kv4 page formats, else abits."""
        return self.kv_bits or self.abits

    @property
    def total_ifc_dies(self) -> int:
        if self.kind == "kvnand-c":
            return self.weight_dies           # co-located
        if self.kind == "kvnand-d":
            return self.weight_dies + self.kv_dies
        return self.weight_dies


def base1(wbits=4, abits=16) -> SystemConfig:
    return SystemConfig("Base-1", "base1", 8, 8, wbits, abits)


def base2(wbits=4, abits=16) -> SystemConfig:
    return SystemConfig("Base-2", "base2", 8, 8, wbits, abits)


def kvnand_d(g1=8, g2=8, wbits=4, abits=16, hg=True, mapping=True,
             kv_bits=0):
    name = f"KVNAND-D-({g1}+{g2})"
    if kv_bits:
        name += f"-kv{kv_bits}"
    return SystemConfig(name, "kvnand-d", g1, g2,
                        wbits, abits, hg, mapping, kv_bits=kv_bits)


def kvnand_c(n=16, wbits=4, abits=16, mapping=True, kv_bits=0):
    name = f"KVNAND-C-{n}" + (f"-kv{kv_bits}" if kv_bits else "")
    return SystemConfig(name, "kvnand-c", n, n, wbits, abits,
                        True, mapping, kv_bits=kv_bits)


# ---------------------------------------------------------------------------
# Workload terms
# ---------------------------------------------------------------------------

def weight_bytes(cfg: ModelConfig, wbits: int) -> Dict[str, float]:
    d = cfg.d_model
    qkv = d * (cfg.q_dim + 2 * cfg.kv_dim)
    o = cfg.q_dim * d
    ffn_mult = 3 if cfg.gated_mlp else 2
    ffn_active = (cfg.top_k if cfg.is_moe else 1) * ffn_mult * d * cfg.d_ff
    ffn_total = ((cfg.n_experts if cfg.is_moe else 1)
                 * ffn_mult * d * cfg.d_ff)
    head = cfg.padded_vocab * d
    b = wbits / 8
    return {
        "qkv": qkv * b, "o": o * b,
        "ffn_active": ffn_active * b, "ffn_total": ffn_total * b,
        "lm_head": head * b,
        "total": (qkv + o + ffn_total) * cfg.n_layers * b + head * b * 2,
    }


def kv_bytes_per_token(cfg: ModelConfig, abits: int) -> float:
    return 2 * cfg.n_layers * cfg.kv_dim * abits / 8


def kv_bytes_layer(cfg: ModelConfig, seq: int, abits: int) -> float:
    return 2 * seq * cfg.kv_dim * abits / 8


# ---------------------------------------------------------------------------
# Latency model
# ---------------------------------------------------------------------------

def _gemv_time(die: FlashDie, n_dies: int, wb: float, wbits: int,
               span: int = 1) -> float:
    """Bandwidth/compute max for a weight GEMV spread over n_dies.

    span > 1 (speculative verification) turns the GEMV into a thin GEMM:
    the weight READ is unchanged — the amortization speculation buys —
    while the MAC count scales with the span.
    """
    if n_dies <= 0:
        return math.inf
    t_read = wb / (n_dies * die.int_bw)
    macs = span * wb * 8 / wbits
    t_mac = macs / (n_dies * die.mac_rate)
    return max(t_read, t_mac)


def _attn_terms(sys: SystemConfig, cfg: ModelConfig, seq: int,
                span: int = 1, partitions: int = 1):
    """Per-layer Logit+Attend (time, transfer_bytes) on the KV medium.

    span > 1: one KV walk serves all span queries (read bytes
    unchanged); Logit/Attend MACs and softmax traffic scale with span.

    partitions > 1 (split-page attention, IFC kinds only): the walk
    emits a locally-normalized partial per partition, so the NPU's
    softmax/exchange stream for partition i overlaps the dies' walk of
    partition i+1 instead of serializing after the full walk — all but
    the last partition's softmax traffic hides under the walk (to the
    extent the walk is long enough to hide it), at the cost of one
    extra NPU merge round trip per partial (`merge_partials`).  Long
    contexts (walk-bound) win; short contexts pay the merge trips for
    nothing, which is what drives `recommend_attn_partitions` to 1.
    """
    die, npu = sys.die, sys.npu
    kvb = kv_bytes_layer(cfg, seq, sys.kv_bits_eff)   # K+V bytes
    macs = span * 2 * cfg.n_heads * seq * cfg.d_head  # logit + attend
    # softmax traffic: logits to NPU and probs back (KVNAND), h×seq each
    sm_bytes = span * 2 * cfg.n_heads * seq * sys.abits / 8

    if sys.kind == "base1":
        t = kvb / sys.dram.bw + 2 * macs / npu.tops
        return t, kvb                               # KV crosses to the NPU
    if sys.kind == "base2":
        t = kvb / (sys.kv_dies * die.ext_bw) + 2 * macs / npu.tops
        return t, kvb
    # IFC attention (kvnand-c/d)
    n = sys.kv_dies if sys.kind == "kvnand-d" else sys.weight_dies
    read_amp = 1.0 if sys.page_mapping else _no_mapping_amplification(
        sys, cfg)
    t_read = kvb * read_amp / (n * die.int_bw)
    t_mac = macs / (n * die.mac_rate)
    # per-head-group NPU softmax round trip (logits out, probs back):
    # k serialized Logit→softmax→Attend exchanges per layer (Fig 10)
    t_sm = (sm_bytes / (n * die.ext_bw)
            + cfg.n_kv_heads * NPU_ROUNDTRIP
            + (span * cfg.n_heads * seq) / npu.tops)
    t_walk = max(t_read, t_mac)
    if partitions > 1:
        # first partition's softmax cannot start before its walk ends
        # and the last partition's cannot overlap anything, so at most
        # (P-1)/P of either stream hides under the other.
        hidden = (partitions - 1) / partitions * min(t_sm, t_walk)
        return (t_walk + t_sm - hidden
                + (partitions - 1) * NPU_ROUNDTRIP), sm_bytes
    return t_walk + t_sm, sm_bytes


def _no_mapping_amplification(sys: SystemConfig, cfg: ModelConfig) -> float:
    """Without §IV-D mapping each 256 B KV unit costs a whole page read
    (+ECC) and random plane conflicts break the multi-plane pipeline
    (calibrated queueing factor 3×, cf. Fig 14b)."""
    unit = cfg.d_head * sys.kv_bits_eff / 8
    page = sys.die.page_bytes + sys.die.ecc_bytes
    return (page / unit) * 3.0


def _kv_write_time(sys: SystemConfig, cfg: ModelConfig) -> float:
    """Per-token KV append, amortized over buffered page-sized flushes."""
    b = kv_bytes_per_token(cfg, sys.kv_bits_eff)
    if sys.kind == "base1":
        return b / sys.dram.bw
    n = sys.kv_dies if sys.kind != "kvnand-c" else sys.weight_dies
    return b / (n * sys.die.prog_bw)


@dataclass
class Breakdown:
    qkv: float = 0.0
    attention: float = 0.0
    o_proj: float = 0.0
    ffn: float = 0.0
    lm_head: float = 0.0
    kv_write: float = 0.0
    transfer: float = 0.0
    overlap_saved: float = 0.0

    @property
    def total(self) -> float:
        return (self.qkv + self.attention + self.o_proj + self.ffn
                + self.lm_head + self.kv_write + self.transfer
                - self.overlap_saved)


def _step_breakdown(sys: SystemConfig, cfg: ModelConfig, seq: int,
                    span: int, kv_writes: float,
                    partitions: int = 1) -> Breakdown:
    """One decode/verify step over `span` tokens writing `kv_writes`
    tokens' KV (sequential decode: span = kv_writes = 1)."""
    die = sys.die
    wb = weight_bytes(cfg, sys.wbits)
    L = cfg.n_layers
    n_w = sys.weight_dies

    b = Breakdown()
    b.qkv = L * _gemv_time(die, n_w, wb["qkv"], sys.wbits, span)
    b.o_proj = L * _gemv_time(die, n_w, wb["o"], sys.wbits, span)
    b.ffn = L * _gemv_time(die, n_w, wb["ffn_active"], sys.wbits, span)
    b.lm_head = _gemv_time(die, n_w, wb["lm_head"], sys.wbits, span)
    t_attn, xfer = _attn_terms(sys, cfg, seq, span, partitions)
    b.attention = L * t_attn
    b.kv_write = kv_writes * _kv_write_time(sys, cfg)
    # activation vectors NPU<->IFC each layer (q, o, ffn in/out)
    act = span * 4 * cfg.d_model * sys.abits / 8
    io_bw = sys.total_ifc_dies * die.ext_bw
    b.transfer = L * (act / io_bw) + L * xfer / max(
        (sys.kv_dies if sys.kind in ("base1", "base2") else
         sys.total_ifc_dies) * die.ext_bw, sys.dram.bw
        if sys.kind == "base1" else 1e-9) * 0.0  # folded into terms above
    if sys.kind == "kvnand-d" and sys.hg_pipeline:
        # Fig 10a: QKV-gen of HG i+1 (G1) overlaps attention of HG i (G2)
        b.overlap_saved = min(b.qkv, b.attention) * (1 - 1 / max(
            cfg.n_kv_heads, 1))
    return b


def decode_token_latency(sys: SystemConfig, cfg: ModelConfig,
                         seq: int, partitions: int = 1) -> Breakdown:
    return _step_breakdown(sys, cfg, seq, span=1, kv_writes=1.0,
                           partitions=partitions)


def decode_throughput(sys: SystemConfig, cfg: ModelConfig,
                      seq: int) -> float:
    if is_oom(sys, cfg, seq):
        return 0.0
    return 1.0 / decode_token_latency(sys, cfg, seq).total


# ---------------------------------------------------------------------------
# Speculative decoding (draft-and-verify) — the speculation_k DSE axis
# ---------------------------------------------------------------------------
#
# A verify step scores k drafted tokens + 1 in one pass: the weight load
# and the KV walk are paid ONCE for up to k+1 emitted tokens — the same
# per-token-traffic lever the paper pulls with in-flash compute, applied
# along the time axis.  The draft overhead is the span-scaled MAC and
# softmax-traffic terms (and the accepted-token KV writes); on a
# bandwidth-bound system those are the cheap side of the max(), which is
# why `recommend_engine_config` trades them off explicitly.

def spec_tokens_per_step(k: int, accept_rate: float) -> float:
    """Expected tokens emitted per verify step with k drafts whose
    per-token acceptance probability is `accept_rate` (geometric prefix
    acceptance + the guaranteed correction/bonus token):
    E = 1 + a + ... + a^k."""
    if k <= 0:
        return 1.0
    a = min(max(accept_rate, 0.0), 1.0)
    if a >= 1.0:
        return float(k + 1)
    return (1.0 - a ** (k + 1)) / (1.0 - a)


def spec_decode_step_latency(sys: SystemConfig, cfg: ModelConfig,
                             seq: int, k: int,
                             accept_rate: float) -> Breakdown:
    """One draft-and-verify step: span = k+1 queries, one weight load,
    one KV walk, E[accepted+1] KV writes."""
    return _step_breakdown(sys, cfg, seq, span=k + 1,
                           kv_writes=spec_tokens_per_step(k, accept_rate))


def spec_decode_token_latency(sys: SystemConfig, cfg: ModelConfig,
                              seq: int, k: int,
                              accept_rate: float) -> float:
    """Expected per-EMITTED-token latency under k-token speculation;
    k = 0 is exactly `decode_token_latency`."""
    if k <= 0:
        return decode_token_latency(sys, cfg, seq).total
    step = spec_decode_step_latency(sys, cfg, seq, k, accept_rate)
    return step.total / spec_tokens_per_step(k, accept_rate)


# ---------------------------------------------------------------------------
# Capacity / OOM — pooled page allocation (§IV-D FTL mapping)
# ---------------------------------------------------------------------------
#
# Track-B's shared page pool admits by ACTUAL footprint: a request holds
# ceil(seq / page_tokens) pages, not a max_context stripe.  The capacity
# model mirrors that: `is_oom` with a request mix charges the page-rounded
# sum, and `pooled_capacity` answers "how many concurrent seq-length
# contexts fit this flash budget" — the admission number serving_bench
# tracks.

def kv_budget(sys: SystemConfig, cfg: ModelConfig) -> float:
    """Bytes of the KV medium available for cache pages."""
    die_cap = sys.die.capacity
    if sys.kind == "base1":
        return sys.dram.usable
    if sys.kind in ("base2", "kvnand-d"):
        return sys.kv_dies * die_cap
    # compact: weights + KV share all dies
    return sys.weight_dies * die_cap - weight_bytes(
        cfg, sys.wbits)["total"]


def kv_pool_bytes(cfg: ModelConfig, seqs, kv_bits: int,
                  page_tokens: int = 64) -> float:
    """Pooled KV footprint of a request mix: page-rounded per sequence,
    summed — versus the stripe model's len(seqs) × max_context charge."""
    per_tok = kv_bytes_per_token(cfg, kv_bits)
    return sum(-(-int(s) // page_tokens) * page_tokens
               for s in seqs) * per_tok


def is_oom(sys: SystemConfig, cfg: ModelConfig, seq: int,
           seqs=None, page_tokens: int = 64) -> bool:
    """Single-context check by default; with `seqs`, a concurrent request
    mix is charged its POOLED page-rounded footprint instead of the
    per-slot worst case."""
    wb = weight_bytes(cfg, sys.wbits)["total"]
    if wb > sys.weight_dies * sys.die.capacity:
        return True
    if seqs is not None:
        kv = kv_pool_bytes(cfg, seqs, sys.kv_bits_eff, page_tokens)
    else:
        kv = kv_bytes_per_token(cfg, sys.kv_bits_eff) * seq
    return kv > kv_budget(sys, cfg)


def pooled_capacity(sys: SystemConfig, cfg: ModelConfig, seq: int,
                    page_tokens: int = 64) -> int:
    """Concurrent seq-length contexts that fit the KV budget under pooled
    allocation (0 when even one does not)."""
    if is_oom(sys, cfg, seq):
        return 0
    per = kv_pool_bytes(cfg, [seq], sys.kv_bits_eff, page_tokens)
    if per <= 0:
        return 10 ** 9        # attention-free: no KV bound
    return int(kv_budget(sys, cfg) // per)


# ---------------------------------------------------------------------------
# Tiered KV hierarchy (DESIGN.md §13): hot-tier staging cost model
# ---------------------------------------------------------------------------
# The serving scheduler's tiered pool keeps `EngineConfig.hot_pages`
# pages staged NPU-side (the SoC SRAM KV buffer of Table I) and leaves
# the rest flash-resident.  These helpers price the tier boundary: what
# one page promotion costs (a flash page-granular read plus the KV bytes
# over the external interface), how many pages the staging buffer holds,
# and the total stall a drain's demand faults charge.  PREFETCHED
# promotions are issued at the end of a step and overlap the next step's
# compute, so only DEMAND faults (`tier_stall_tokens`) are charged.

def kv_page_bytes(cfg: ModelConfig, kv_bits: int,
                  page_tokens: int = 64) -> float:
    """Bytes of one KV page (all layers, K+V) at the stored precision."""
    return kv_bytes_per_token(cfg, kv_bits) * page_tokens


def page_promote_time(sys: SystemConfig, cfg: ModelConfig,
                      page_tokens: int = 64) -> float:
    """Seconds to stage ONE capacity-tier page into the hot tier: a
    page-granular flash read (tR) plus the page's KV bytes over the KV
    medium's external interface, striped over its dies."""
    b = kv_page_bytes(cfg, sys.kv_bits_eff, page_tokens)
    if sys.kind == "base1":
        return b / sys.dram.bw
    n = sys.kv_dies if sys.kind != "kvnand-c" else sys.weight_dies
    return sys.die.tR + b / (n * sys.die.ext_bw)


def hot_tier_pages(sys: SystemConfig, cfg: ModelConfig,
                   page_tokens: int = 64) -> int:
    """Pages of KV the NPU-side SRAM staging buffer holds — the natural
    hot-tier size for this (system, model) pair; 0 when even one page
    overflows the buffer (tiering then needs a device-DRAM-class hot
    tier, which the DRAM-free configs do not have)."""
    b = kv_page_bytes(cfg, sys.kv_bits_eff, page_tokens)
    if b <= 0:
        return 10 ** 9        # attention-free: everything is "hot"
    return int(sys.npu.sram_kv_buffer // b)


def tier_stall_time(sys: SystemConfig, cfg: ModelConfig,
                    demand_faults: int, page_tokens: int = 64) -> float:
    """Modeled wall-clock charged to DEMAND promotions over a drain
    (`stats["tier_stall_tokens"]` × the per-page staging cost);
    prefetched pages are free — their reads hid under compute."""
    return demand_faults * page_promote_time(sys, cfg, page_tokens)


# ---------------------------------------------------------------------------
# Serving step model (DESIGN.md §14): host overhead and overlap
# ---------------------------------------------------------------------------
# A SERVING decode step is device compute plus per-step host work the
# device model cannot see: token emission, finish sweeps, admission and
# page-table bookkeeping.  The synchronous scheduler serializes the two
# (the device idles for the host share every step); the overlapped
# scheduler dispatches step N+1 before collecting step N, so each
# steady-state step costs max(device, host) — classic one-deep software
# pipelining.  `host_s` is measured, not modeled: the serving bench
# derives it from the synchronous loop's host-observed device-idle
# fraction (`stats["device_idle_s"] / steps`).

def serving_step_time(sys: SystemConfig, cfg: ModelConfig, seq: int,
                      host_s: float, *, overlap: bool,
                      span: int = 1, partitions: int = 1) -> float:
    """Seconds per steady-state serving step: device compute for a
    span-wide decode/verify step at context `seq`, serialized with
    (synchronous) or hidden behind (overlapped) `host_s` of host-side
    scheduling work."""
    if host_s < 0:
        raise ValueError(f"host_s must be >= 0, got {host_s}")
    dev = _step_breakdown(sys, cfg, seq, span=span, kv_writes=float(span),
                          partitions=partitions).total
    if overlap:
        return max(dev, host_s)
    return dev + host_s


def overlap_speedup(sys: SystemConfig, cfg: ModelConfig, seq: int,
                    host_s: float, *, span: int = 1,
                    partitions: int = 1) -> float:
    """Synchronous / overlapped steady-state step time: the wall-clock
    factor the pipelined scheduler buys.  Bounded by 2.0 (host and
    device perfectly balanced) and ~1.0 when either side dominates."""
    sync = serving_step_time(sys, cfg, seq, host_s, overlap=False,
                             span=span, partitions=partitions)
    piped = serving_step_time(sys, cfg, seq, host_s, overlap=True,
                              span=span, partitions=partitions)
    return sync / max(piped, 1e-30)


# ---------------------------------------------------------------------------
# Energy model (per decoded token, J)
# ---------------------------------------------------------------------------

def decode_token_energy(sys: SystemConfig, cfg: ModelConfig,
                        seq: int) -> Dict[str, float]:
    die = sys.die
    wb = weight_bytes(cfg, sys.wbits)
    L = cfg.n_layers
    w_read_bits = 8 * (L * (wb["qkv"] + wb["o"] + wb["ffn_active"])
                       + wb["lm_head"])
    kv_bits = 8 * kv_bytes_layer(cfg, seq, sys.kv_bits_eff) * L
    kv_write_bits = 8 * kv_bytes_per_token(cfg, sys.kv_bits_eff)
    act_bits = 8 * 4 * cfg.d_model * sys.abits / 8 * L

    e: Dict[str, float] = {}
    e["weights_read"] = w_read_bits * die.e_read
    if sys.kind == "base1":
        e["kv"] = kv_bits * (sys.dram.e_bit + sys.dram.e_bit)  # read + io
        e["kv_write"] = kv_write_bits * sys.dram.e_bit
    elif sys.kind == "base2":
        e["kv"] = kv_bits * (die.e_read + die.e_io)     # read + ONFI out
        e["kv_write"] = kv_write_bits * (die.e_prog + die.e_io)
    else:
        amp = 1.0 if sys.page_mapping else _no_mapping_amplification(
            sys, cfg)
        e["kv"] = kv_bits * amp * die.e_read            # stays in-die
        sm_bits = 8 * 2 * cfg.n_heads * seq * sys.abits / 8 * L
        e["kv"] += sm_bits * die.e_io                   # softmax traffic
        e["kv_write"] = kv_write_bits * die.e_prog
    e["io"] = act_bits * die.e_io
    lat = decode_token_latency(sys, cfg, seq).total
    e["npu"] = sys.npu.power * 0.15 * lat + sys.npu.sram_power * lat
    n_dies = sys.total_ifc_dies
    logic_w = 6.98e-3 * die.planes                      # per die logic
    e["ifc_logic"] = logic_w * n_dies * lat
    e["total"] = sum(v for k, v in e.items() if k != "total")
    return e
