"""Reliability model (paper §III-D, §V-D, Fig 5a).

Tracks per-block cumulative page reads (read disturb) and P/E cycles for
flash-resident KV under a decode workload, and quantifies how KVNAND's
mapping/parallelization reduce PGRD stress:

  * KVNAND-C head-parallel generation: per-block reads drop by
    ≈ k·page/KVbuf  (~128× in the paper's config)
  * KVNAND-D weight/KV die separation: ≈ 2560× total reduction
  * §V-D endurance: 65B @ 3 tok/s for 5 years ≈ 143 TB KV ≈ 1K P/E cycles
    (SLC budget 100K)

Also reproduces Fig 5(a)'s shape: blocks holding EARLY context accumulate
reads ∝ remaining output length; late blocks stay far below the disturb
limit.  Access-aware allocation (§IV-D) randomizes blocks across requests
and retires blocks at the read-disturb limit (trading spare capacity).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.flashsim import FlashDie, SystemConfig

READ_DISTURB_LIMIT = 1e6          # intrinsic page-read limit per block [83]
SLC_PE_BUDGET = 100_000           # P/E endurance [2]


@dataclasses.dataclass
class WearState:
    page_reads: np.ndarray        # [blocks]
    pe_cycles: np.ndarray         # [blocks]
    retired: np.ndarray           # [blocks] bool

    @property
    def max_reads(self) -> float:
        return float(self.page_reads[~self.retired].max(initial=0.0))


def kv_pages_per_request(cfg: ModelConfig, ctx: int, abits: int,
                         die: FlashDie) -> int:
    kv_bytes = 2 * cfg.n_layers * cfg.kv_dim * abits / 8 * ctx
    return int(np.ceil(kv_bytes / die.page_bytes))


def simulate_request_reads(cfg: ModelConfig, n_input: int, n_output: int,
                           abits: int, die: FlashDie,
                           pages_per_block: int = 768) -> np.ndarray:
    """Per-block page-read counts for ONE request (Fig 5a).

    Token t's KV pages are read once per subsequent generated token, so a
    block holding tokens [a, b) accumulates Σ_{t∈[a,b)} (n_total - max(t,
    n_input)) reads across its pages.
    """
    n_total = n_input + n_output
    unit = cfg.d_head * abits / 8
    units_per_page = max(int(die.page_bytes // unit), 1)
    # head-major mapping: pages hold contiguous tokens of one (layer, head)
    tokens = np.arange(n_total)
    reads_per_token = (n_total - np.maximum(tokens, n_input)).clip(min=0)
    n_pages_tok = int(np.ceil(n_total / units_per_page))
    page_reads = np.add.reduceat(
        reads_per_token,
        np.arange(0, n_total, units_per_page))[:n_pages_tok]
    # blocks of consecutive pages
    n_blocks = int(np.ceil(n_pages_tok / pages_per_block))
    block_reads = np.zeros(n_blocks)
    for b in range(n_blocks):
        block_reads[b] = page_reads[b * pages_per_block:
                                    (b + 1) * pages_per_block].max(initial=0)
    return block_reads


def pgrd_reduction_factors(cfg: ModelConfig, sys: SystemConfig,
                           abits: int = 16) -> Dict[str, float]:
    """§V-D: mapping + parallelization PGRD reduction factors.

    KVNAND-C: head-parallel generation spreads one (layer, head)'s stream
    across planes — per-block reads drop ≈ k·page_size/KV_size_unit
    (paper: ≈128× at k=8, 256 B units).  KVNAND-D additionally removes
    weight-read interference from KV blocks and stripes KV over dedicated
    G2 dies — paper reports ≈2560× (=128×20); the ×20 die-separation
    factor is adopted from §V-D (weight reads dominate block accesses
    ~20:1 at the 50K-context workload)."""
    die = sys.die
    unit = cfg.d_head * abits / 8
    c_factor = cfg.n_kv_heads * die.page_bytes / unit
    d_factor = c_factor * 20.0
    return {"kvnand_c": c_factor, "kvnand_d": d_factor}


def lifetime_pe_cycles(cfg: ModelConfig, *, tok_per_s: float = 3.0,
                       years: float = 5.0, abits: int = 16,
                       n_dies: int = 8, die: Optional[FlashDie] = None
                       ) -> Dict[str, float]:
    """§V-D endurance check: total KV written over the device lifetime."""
    if die is None:
        die = FlashDie()
    seconds = years * 365 * 24 * 3600
    kv_per_tok = 2 * cfg.n_layers * cfg.kv_dim * abits / 8
    total_bytes = kv_per_tok * tok_per_s * seconds
    capacity = n_dies * die.capacity
    pe = total_bytes / capacity
    return {"total_tb": total_bytes / 1e12, "pe_cycles": pe,
            "budget": SLC_PE_BUDGET,
            "margin_ok": pe < SLC_PE_BUDGET * 0.05}


class BlockAllocator:
    """Access-aware block allocation (§IV-D): randomized across requests,
    read/PE counters per block, migration at limits (trade space for
    reliability)."""

    def __init__(self, n_blocks: int, seed: int = 0):
        self.rng = np.random.default_rng(seed)
        self.state = WearState(np.zeros(n_blocks), np.zeros(n_blocks),
                               np.zeros(n_blocks, bool))

    def allocate(self, n: int) -> np.ndarray:
        free = np.flatnonzero(~self.state.retired)
        order = free[np.argsort(self.state.pe_cycles[free],
                                kind="stable")]
        take = order[:n]
        self.rng.shuffle(take)
        return take

    def record_request(self, blocks: np.ndarray, reads: np.ndarray):
        self.state.page_reads[blocks] += reads[:len(blocks)]
        self.state.pe_cycles[blocks] += 1
        over = self.state.page_reads > READ_DISTURB_LIMIT
        # migrate: reclaim resets reads, costs one P/E
        self.state.pe_cycles[over] += 1
        self.state.page_reads[over] = 0.0
        self.state.retired |= self.state.pe_cycles > SLC_PE_BUDGET

    def utilization(self) -> float:
        return 1.0 - self.state.retired.mean()
