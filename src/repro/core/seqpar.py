"""Sequence-parallel attention: the paper's G2 dataflow on a TPU mesh.

KVNAND §IV-B: G2 dies each hold a slice of the KV cache, compute partial
K·q products, the NPU aggregates them for the softmax, and the dies apply
Attend to their local V slice.  That is precisely *flash-decoding* with a
log-sum-exp combine:

  decode : KV pages sharded over `model` (± `data`/`pod` for batch-1 long
           context); each device computes partial (ō, m, ℓ) over local pages;
           `combine_partials` (pmax/psum) plays the NPU-aggregation role.
  train / prefill : ring attention — Q/K/V sequence-sharded, KV blocks
           rotate via ppermute with online-softmax accumulation (SP).

Neither path ever constrains on head-count divisibility (20/25-head archs
shard fine on a 16-wide axis) and the KV bytes never cross the interconnect
— only q vectors and [B, H] statistics do, the paper's core bandwidth
insight.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
try:  # jax >= 0.5 re-exports shard_map at top level
    from jax import shard_map as _shard_map_impl
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_impl

import inspect as _inspect

if "check_vma" in _inspect.signature(_shard_map_impl).parameters:
    shard_map = _shard_map_impl
else:  # jax 0.4.x: replication check is `check_rep`, manual axes via `auto`
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True,
                  axis_names=None, **kw):
        if axis_names is not None:
            kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
        return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_rep=check_vma,
                               **kw)

from repro.kernels.flash_attention.ref import NEG_INF


def _axis_size(name):
    """jax.lax.axis_size where available; psum(1, axis) on jax 0.4.x
    (constant-folds to the same static int inside shard_map)."""
    try:
        return jax.lax.axis_size(name)
    except AttributeError:
        return jax.lax.psum(1, name)


# ---------------------------------------------------------------------------
# Partial-attention merge (the "NPU softmax aggregation")
# ---------------------------------------------------------------------------

def merge_two(o1, m1, l1, o2, m2, l2):
    """Merge two locally-normalized partial attentions (log-sum-exp).

    Two-ary convenience over the N-partial merge core
    (`kernels.paged_attention.merge_partials`) — same math, same
    empty-partition identity."""
    from repro.kernels.paged_attention.merge import merge_partials
    return merge_partials(jnp.stack([o1, o2]), jnp.stack([m1, m2]),
                          jnp.stack([l1, l2]), axis=0)


def combine_partials(o, m, l, axis_names: Sequence[str]):
    """Cross-device merge over mesh axes (inside shard_map).

    The collective twin of `merge_partials`: the same one-max/one-sum
    reduction, with pmax/psum standing in for the stacked-axis reduce.

    o: [..., dh] locally-normalized partial outputs; m/l: [...] stats.
    """
    o, _, _ = combine_partials_stats(o, m, l, axis_names)
    return o


def combine_partials_stats(o, m, l, axis_names: Sequence[str]):
    """`combine_partials` that also returns the combined (m, ℓ) stats, for
    callers that merge the cross-device result with FURTHER partials (the
    chunked-prefill path merges the sharded past-context partial with the
    in-chunk causal partial via `merge_two`)."""
    ax = tuple(axis_names)
    M = jax.lax.pmax(m, ax)
    w = l * jnp.exp(m - M)
    L = jax.lax.psum(w, ax)
    denom = jnp.maximum(L, 1e-30)
    o = jax.lax.psum(o * w[..., None], ax) / denom[..., None]
    return o, M, L


# ---------------------------------------------------------------------------
# Ring attention (train / prefill sequence parallelism)
# ---------------------------------------------------------------------------

def _attn_block_partial(q, k, v, q_pos, k_pos0, *, causal, window, is_global,
                        scale):
    """One (q-chunk × kv-chunk) partial: returns (o_normed, m, l).

    q: [B, Sq, H, dh]; k/v: [B, Sk, K, dh]; q_pos: [Sq] absolute positions;
    k_pos0: scalar absolute position of k[0].
    """
    B, Sq, H, dh = q.shape
    K = k.shape[2]
    G = H // K
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(B, Sq, K, G, dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kf)               # [B,K,G,Sq,Sk]
    k_pos = k_pos0 + jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        in_w = k_pos[None, :] > q_pos[:, None] - window
        if is_global is not None:
            in_w = in_w | is_global
        mask &= in_w
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)                                   # [B,K,G,Sq]
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    o = o / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return (o.reshape(B, Sq, H, dh),
            m.transpose(0, 3, 1, 2).reshape(B, Sq, H),
            l.transpose(0, 3, 1, 2).reshape(B, Sq, H))


def ring_attention_local(q, k, v, *, axis_name: str, causal: bool,
                         window: Optional[int], is_global, scale: float):
    """Per-device body (inside shard_map): rotate KV chunks around the ring.

    q/k/v: LOCAL chunks [B, Sl, H/K, dh]; device i owns positions
    [i·Sl, (i+1)·Sl).  n_dev-1 ppermutes stream every KV chunk past every
    q chunk; online softmax merges partials.
    """
    n_dev = _axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    B, Sl, H, dh = q.shape
    q_pos = idx * Sl + jnp.arange(Sl)

    def step(carry, r):
        kc, vc, o, m, l = carry
        src = (idx - r) % n_dev                                # owner of kc
        o2, m2, l2 = _attn_block_partial(
            q, kc, vc, q_pos, src * Sl, causal=causal, window=window,
            is_global=is_global, scale=scale)
        o, m, l = merge_two(o, m, l, o2, m2, l2)
        perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]
        kc = jax.lax.ppermute(kc, axis_name, perm)
        vc = jax.lax.ppermute(vc, axis_name, perm)
        return (kc, vc, o, m, l), None

    o0 = jnp.zeros((B, Sl, H, dh), jnp.float32)
    m0 = jnp.full((B, Sl, H), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sl, H), jnp.float32)
    (_, _, o, m, l), _ = jax.lax.scan(
        step, (k, v, o0, m0, l0), jnp.arange(n_dev))
    return o.astype(q.dtype)


def ring_attention(q, k, v, mesh: Mesh, *, causal: bool = True,
                   window: Optional[int] = None, is_global=None,
                   batch_axes=("data",), seq_axis: str = "model"):
    """shard_map wrapper: q/k/v [B, S, H/K, dh] seq-sharded over `seq_axis`."""
    scale = q.shape[-1] ** -0.5
    bspec = P(_axes_spec(batch_axes), seq_axis, None, None)
    fn = functools.partial(ring_attention_local, axis_name=seq_axis,
                           causal=causal, window=window, is_global=is_global,
                           scale=scale)
    if is_global is not None:
        # traced flag rides along as an argument, replicated
        fn2 = lambda qq, kk, vv, gg: functools.partial(  # noqa: E731
            ring_attention_local, axis_name=seq_axis, causal=causal,
            window=window, scale=scale)(qq, kk, vv, is_global=gg)
        return shard_map(fn2, mesh=mesh,
                         in_specs=(bspec, bspec, bspec, P()),
                         out_specs=bspec, check_vma=False)(q, k, v, is_global)
    return shard_map(fn, mesh=mesh, in_specs=(bspec, bspec, bspec),
                     out_specs=bspec, check_vma=False)(q, k, v)


# ---------------------------------------------------------------------------
# Sequence-parallel paged decode attention (the G2 dataflow proper)
# ---------------------------------------------------------------------------

def _shard_page_offset(page_axes: Sequence[str], np_local: int):
    """Linearized first-local-page index of this shard."""
    idx = 0
    for a in page_axes:
        idx = idx * _axis_size(a) + jax.lax.axis_index(a)
    return idx * np_local


def local_append_uniform(pool_local, phys, slot, val, page_axes):
    """Append one token's K or V inside the owning shard (no cross-shard
    select): read-modify-write of a single [B, K, 1, 1, dh] slice.  phys and
    slot are uniform across the batch (lockstep decode).

    pool_local: [B, K, NP_local, T, dh]; val: [B, K, dh].
    """
    B, K, NPl, T, dh = pool_local.shape
    p_loc = phys[0] - _shard_page_offset(page_axes, NPl)
    owned = (p_loc >= 0) & (p_loc < NPl)
    p_c = jnp.clip(p_loc, 0, NPl - 1)
    zero = jnp.zeros((), jnp.int32)
    cur = jax.lax.dynamic_slice(pool_local, (zero, zero, p_c, slot[0], zero),
                                (B, K, 1, 1, dh))
    upd = jnp.where(owned, val[:, :, None, None, :].astype(pool_local.dtype),
                    cur)
    return jax.lax.dynamic_update_slice(pool_local, upd,
                                        (zero, zero, p_c, slot[0], zero))


def sharded_append_uniform(pool_k, pool_v, layer, k_new, v_new, phys, slot,
                           mesh: Mesh, *,
                           batch_axes: Sequence[str] = ("data",),
                           page_axes: Sequence[str] = ("model",),
                           k_scale=None, v_scale=None,
                           kv_quant: str = "none"):
    """In-place append of one token's K/V into FULL stacked pools
    [L, B, K, NP, T, dh] at a traced layer index, inside the owning shard
    (the paper's direct G2-die write).  Uniform lockstep positions.

    Quantized pools (kv8/kv4) carry per-page×head scales [L, B, K, NP]:
    the owning shard dequantizes ONLY the touched page, inserts the token,
    requantizes, and writes page + scale back — still O(page) per layer.
    Returns (k, v) or (k, v, k_scale, v_scale) when quantized.
    """
    from repro.core import quant

    bspec = _axes_spec(batch_axes)
    pspec = P(None, bspec, None, _axes_spec(page_axes), None, None)
    sspec = P(None, bspec, None, _axes_spec(page_axes))
    nspec = P(bspec, None, None)
    lspec = P(bspec)

    def local(kp, vp, kn, vn, ph, sl, layer):
        L, B, K, NPl, T, dh = kp.shape
        p_loc = ph[0] - _shard_page_offset(page_axes, NPl)
        owned = (p_loc >= 0) & (p_loc < NPl)
        p_c = jnp.clip(p_loc, 0, NPl - 1)
        zero = jnp.zeros((), jnp.int32)
        idx = (layer, zero, zero, p_c, sl[0], zero)

        def put(pool, val):
            cur = jax.lax.dynamic_slice(pool, idx, (1, B, K, 1, 1, dh))
            upd = jnp.where(owned,
                            val[None, :, :, None, None, :].astype(pool.dtype),
                            cur)
            return jax.lax.dynamic_update_slice(pool, upd, idx)

        return put(kp, kn), put(vp, vn)

    def local_quant(kp, vp, ks, vs, kn, vn, ph, sl, layer):
        L, B, K, NPl, Ts, dh = kp.shape
        p_loc = ph[0] - _shard_page_offset(page_axes, NPl)
        owned = (p_loc >= 0) & (p_loc < NPl)
        p_c = jnp.clip(p_loc, 0, NPl - 1)
        zero = jnp.zeros((), jnp.int32)
        pidx = (layer, zero, zero, p_c, zero, zero)
        sidx = (layer, zero, zero, p_c)

        def put(pool, scl, val):
            from repro.core.paged_kv import _zero_dead_slots
            cur_q = jax.lax.dynamic_slice(pool, pidx, (1, B, K, 1, Ts, dh))
            cur_s = jax.lax.dynamic_slice(scl, sidx, (1, B, K, 1))
            page = quant.dequantize_kv_page(cur_q[0, :, :, 0],
                                            cur_s[0, :, :, 0], kv_quant)
            page = jax.lax.dynamic_update_slice(
                page, val[:, :, None, :].astype(page.dtype),
                (zero, zero, sl[0], zero))
            page = _zero_dead_slots(page, sl[0])
            q2, s2 = quant.quantize_kv_page(page, kv_quant)
            q2 = jnp.where(owned, q2[:, :, None][None], cur_q)
            s2 = jnp.where(owned, s2[:, :, None][None], cur_s)
            return (jax.lax.dynamic_update_slice(pool, q2, pidx),
                    jax.lax.dynamic_update_slice(scl, s2, sidx))

        kp, ks = put(kp, ks, kn)
        vp, vs = put(vp, vs, vn)
        return kp, vp, ks, vs

    if kv_quant != "none":
        return shard_map(local_quant, mesh=mesh,
                         in_specs=(pspec, pspec, sspec, sspec, nspec, nspec,
                                   lspec, lspec, P()),
                         out_specs=(pspec, pspec, sspec, sspec),
                         check_vma=False)(
            pool_k, pool_v, k_scale, v_scale, k_new, v_new, phys, slot,
            jnp.asarray(layer, jnp.int32))

    return shard_map(local, mesh=mesh,
                     in_specs=(pspec, pspec, nspec, nspec, lspec, lspec,
                               P()),
                     out_specs=(pspec, pspec), check_vma=False)(
        pool_k, pool_v, k_new, v_new, phys, slot,
        jnp.asarray(layer, jnp.int32))


def sharded_prefill_fill(pool, kv_seq, layer, mesh: Mesh, *,
                         batch_axes: Sequence[str] = ("data",),
                         page_axes: Sequence[str] = ("model",),
                         scale=None, kv_quant: str = "none"):
    """Write prefill K/V [B, S, K, dh] into ONE layer of the stacked global
    pool [L, B, K, NP, T, dh], each shard packing ONLY its own page range.

    kv is replicated over the page axes already (prefill activations are
    batch-sharded), so the per-shard slice is local — a pjit-level fill
    all-gathers the ENTIRE pool per layer (measured 148 GiB × layers).

    Quantized pools (kv8/kv4): each shard quantizes its own page range and
    writes codes + per-page scales; returns (pool, scale).
    """
    from repro.core import quant

    L, Bt, K, NP, Ts, dh = pool.shape
    T = Ts * (2 if kv_quant == "kv4" else 1)
    B, S, _, _ = kv_seq.shape
    pad = NP * T - S
    kv = jnp.pad(kv_seq, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else \
        kv_seq
    bspec = _axes_spec(batch_axes)
    pspec = P(None, bspec, None, _axes_spec(page_axes), None, None)
    sspec = P(None, bspec, None, _axes_spec(page_axes))
    kvspec = P(bspec, None, None, None)

    def local(pool_l, kvv, lyr):
        _, Bl, _, NPl, _, _ = pool_l.shape
        off = _shard_page_offset(page_axes, NPl)
        zero = jnp.zeros((), jnp.int32)
        chunk = jax.lax.dynamic_slice(
            kvv, (zero, off * T, zero, zero), (Bl, NPl * T, K, dh))
        pages = chunk.reshape(Bl, NPl, T, K, dh).transpose(0, 3, 1, 2, 4)
        return jax.lax.dynamic_update_slice(
            pool_l, pages[None].astype(pool_l.dtype),
            (lyr, zero, zero, zero, zero, zero))

    def local_quant(pool_l, scale_l, kvv, lyr):
        _, Bl, _, NPl, _, _ = pool_l.shape
        off = _shard_page_offset(page_axes, NPl)
        zero = jnp.zeros((), jnp.int32)
        chunk = jax.lax.dynamic_slice(
            kvv, (zero, off * T, zero, zero), (Bl, NPl * T, K, dh))
        pages = chunk.reshape(Bl, NPl, T, K, dh).transpose(0, 3, 1, 2, 4)
        q, s = quant.quantize_kv_page(pages, kv_quant)
        pool_l = jax.lax.dynamic_update_slice(
            pool_l, q[None], (lyr, zero, zero, zero, zero, zero))
        scale_l = jax.lax.dynamic_update_slice(
            scale_l, s[None], (lyr, zero, zero, zero))
        return pool_l, scale_l

    if kv_quant != "none":
        return shard_map(local_quant, mesh=mesh,
                         in_specs=(pspec, sspec, kvspec, P()),
                         out_specs=(pspec, sspec), check_vma=False)(
            pool, scale, kv, jnp.asarray(layer, jnp.int32))

    return shard_map(local, mesh=mesh, in_specs=(pspec, kvspec, P()),
                     out_specs=pspec, check_vma=False)(
        pool, kv, jnp.asarray(layer, jnp.int32))


def sharded_window_fill(pool, kv_seq, layer, mesh: Mesh, *,
                        batch_axes: Sequence[str] = ("data",),
                        page_axes: Sequence[str] = ("model",),
                        scale=None, kv_quant: str = "none"):
    """Ring-fill the newest window pages of ONE layer, shard-locally.

    Quantized pools: shard-local page quantization; returns (pool, scale).
    """
    from repro.core import paged_kv as pk
    from repro.core import quant

    L, Bt, K, NP, Ts, dh = pool.shape
    T = Ts * (2 if kv_quant == "kv4" else 1)
    B, S, _, _ = kv_seq.shape
    n_src = pk.ceil_div(S, T)
    pad = n_src * T - S
    kv = jnp.pad(kv_seq, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else \
        kv_seq
    bspec = _axes_spec(batch_axes)
    pspec = P(None, bspec, None, _axes_spec(page_axes), None, None)
    sspec = P(None, bspec, None, _axes_spec(page_axes))
    kvspec = P(bspec, None, None, None)

    def local(pool_l, kvv, lyr, scale_l=None):
        _, Bl, _, NPl, _, _ = pool_l.shape
        off = _shard_page_offset(page_axes, NPl)
        zero = jnp.zeros((), jnp.int32)
        x = kvv.reshape(Bl, n_src, T, K, dh).transpose(0, 3, 1, 2, 4)
        if kv_quant != "none":
            x, s_all = quant.quantize_kv_page(x, kv_quant)
        for sp in range(max(0, n_src - NP), n_src):   # static, ≤ NP pages
            slot = sp % NP
            loc = slot - off
            owned = (loc >= 0) & (loc < NPl)
            loc_c = jnp.clip(loc, 0, NPl - 1)
            idx = (lyr, zero, zero, loc_c, zero, zero)
            cur = jax.lax.dynamic_slice(pool_l, idx, (1, Bl, K, 1, Ts, dh))
            upd = jnp.where(owned,
                            x[:, :, sp][None, :, :, None].astype(
                                pool_l.dtype), cur)
            pool_l = jax.lax.dynamic_update_slice(pool_l, upd, idx)
            if kv_quant != "none":
                sidx = (lyr, zero, zero, loc_c)
                cur_s = jax.lax.dynamic_slice(scale_l, sidx, (1, Bl, K, 1))
                upd_s = jnp.where(owned, s_all[:, :, sp][None, :, :, None],
                                  cur_s)
                scale_l = jax.lax.dynamic_update_slice(scale_l, upd_s, sidx)
        if kv_quant != "none":
            return pool_l, scale_l
        return pool_l

    if kv_quant != "none":
        def local_q(pool_l, scale_l, kvv, lyr):
            return local(pool_l, kvv, lyr, scale_l)
        return shard_map(local_q, mesh=mesh,
                         in_specs=(pspec, sspec, kvspec, P()),
                         out_specs=(pspec, sspec), check_vma=False)(
            pool, scale, kv, jnp.asarray(layer, jnp.int32))

    return shard_map(local, mesh=mesh, in_specs=(pspec, kvspec, P()),
                     out_specs=pspec, check_vma=False)(
        pool, kv, jnp.asarray(layer, jnp.int32))


def sharded_chunk_fill(pool, kv_chunk, layer, slot, page0, valid_len,
                       mesh: Mesh, *,
                       batch_axes: Sequence[str] = ("data",),
                       page_axes: Sequence[str] = ("model",),
                       scale=None, kv_quant: str = "none"):
    """Chunked-prefill fill of ONE slot's stripe in the sharded stacked
    global pool [L, B, K, NP, Ts, dh]: each shard writes only the
    intersection of its local page range with the chunk's pages, and only
    when it owns the slot's batch row — the direct G2-die write of the
    paper, at chunk granularity.  kv_chunk [1, C, K, dh] is replicated
    (chunk bytes are tiny against the pool).  Pages holding none of the
    `valid_len` real tokens are skipped; quantized pools (kv8/kv4) get
    whole-page codes + per-page scales.  Returns pool or (pool, scale).
    """
    from repro.core.paged_kv import _fill_chunk_pages

    L, Bt, K, NP, Ts, dh = pool.shape
    T = Ts * (2 if kv_quant == "kv4" else 1)
    bspec = _axes_spec(batch_axes)
    pspec = P(None, bspec, None, _axes_spec(page_axes), None, None)
    sspec = P(None, bspec, None, _axes_spec(page_axes))
    kvspec = P(None, None, None, None)

    def local(pool_l, kvv, lyr, sl, p0, n_valid, scale_l=None):
        # same body as the single-device fills — only the page/slot
        # coordinates shift into shard-local space, and writes outside
        # this shard's (batch row × page range) drop via valid_of
        _, Bl, _, NPl, _, _ = pool_l.shape
        b_off = _shard_page_offset(batch_axes, Bl)   # generic linear offset
        p_off = _shard_page_offset(page_axes, NPl)
        sl_loc = sl - b_off
        own_b = (sl_loc >= 0) & (sl_loc < Bl)
        return _fill_chunk_pages(
            pool_l, kvv, lyr, jnp.clip(sl_loc, 0, Bl - 1),
            lambda sp: jnp.clip(p0 + sp - p_off, 0, NPl - 1),
            lambda sp: (own_b & (p0 + sp - p_off >= 0)
                        & (p0 + sp - p_off < NPl) & (sp * T < n_valid)),
            scale=scale_l, kv_quant=kv_quant)

    args = (jnp.asarray(layer, jnp.int32), jnp.asarray(slot, jnp.int32),
            jnp.asarray(page0, jnp.int32), jnp.asarray(valid_len, jnp.int32))
    if kv_quant != "none":
        def local_q(pool_l, scale_l, kvv, lyr, sl, p0, n_valid):
            return local(pool_l, kvv, lyr, sl, p0, n_valid, scale_l)
        return shard_map(local_q, mesh=mesh,
                         in_specs=(pspec, sspec, kvspec, P(), P(), P(), P()),
                         out_specs=(pspec, sspec), check_vma=False)(
            pool, scale, kv_chunk, *args)

    return shard_map(local, mesh=mesh,
                     in_specs=(pspec, kvspec, P(), P(), P(), P()),
                     out_specs=pspec, check_vma=False)(pool, kv_chunk, *args)


def sharded_chunk_attention(q, k_pages, v_pages, page_base, start, q_pos,
                            mesh: Mesh, *,
                            window: Optional[int] = None,
                            page_axes: Sequence[str] = ("model",),
                            impl: str = "auto",
                            kv_quant: str = "none",
                            k_scale=None, v_scale=None,
                            partitions: int = 0):
    """Past-context partial attention of one slot's chunk queries against
    its page-sharded stripe (chunked prefill on a mesh).

    q: [1, S, H, dh] replicated chunk queries; pages: [1, K, NP, Ts, dh]
    the slot's stripe (batch row already sliced out), NP sharded over
    `page_axes`; page_base: [1, NP] absolute positions.  Each shard runs
    the chunk-attention oracle over its local pages and the partials merge
    via the log-sum-exp combine (the NPU softmax aggregation, at chunk
    granularity).  Returns REPLICATED combined (o, m, ℓ) so the caller can
    merge with the in-chunk causal partial.
    """
    from repro.kernels.paged_attention.ops import paged_chunk_attention

    n_page_shards = 1
    for a in page_axes:
        n_page_shards *= mesh.shape[a]

    qspec = P(None, None, None, None)
    pspec = P(None, None, _axes_spec(page_axes), None, None)
    sspec = P(None, None, _axes_spec(page_axes))
    basespec = P(None, _axes_spec(page_axes))

    def run(qq, kp, vp, base, st, qp, ks=None, vs=None):
        # `partitions` splits each shard's LOCAL page walk (resolved
        # against the local page count inside the op)
        o, m, l = paged_chunk_attention(
            qq, kp, vp, base, st, qp, window=window, impl=impl,
            kv_quant=kv_quant, k_scale=ks, v_scale=vs,
            partitions=partitions)
        if n_page_shards > 1:
            o, m, l = combine_partials_stats(o, m, l, tuple(page_axes))
        return o, m, l

    out_specs = (qspec, P(None, None, None), P(None, None, None))
    if kv_quant != "none":
        return shard_map(run, mesh=mesh,
                         in_specs=(qspec, pspec, pspec, basespec, P(), P(None),
                                   sspec, sspec),
                         out_specs=out_specs, check_vma=False)(
            q, k_pages, v_pages, page_base, jnp.asarray(start, jnp.int32),
            q_pos, k_scale, v_scale)
    return shard_map(run, mesh=mesh,
                     in_specs=(qspec, pspec, pspec, basespec, P(), P(None)),
                     out_specs=out_specs, check_vma=False)(
        q, k_pages, v_pages, page_base, jnp.asarray(start, jnp.int32), q_pos)


def paged_decode_attention_sharded(
    q, k_pages, v_pages, page_base, length, mesh: Mesh, *,
    window: Optional[int] = None, is_global=None,
    batch_axes: Sequence[str] = ("data",),
    page_axes: Sequence[str] = ("model",),
    impl: str = "auto",
    append: Optional[Tuple] = None,   # (k_new [B,K,dh], v_new, phys, slot)
    kv_quant: str = "none",
    k_scale=None, v_scale=None,       # [B, K, NP] per-page×head scales
    partitions: int = 0,              # split of each shard's local walk
):
    """q: [B, H, dh]; pages: [B, K, NP, T, dh]; page_base: [B, NP] absolute
    position of each physical page's slot 0 (<0 = unwritten);
    length: [B] context length INCLUDING the token being decoded.

    Pages sharded over `page_axes`; batch over `batch_axes`; combine via
    psum over `page_axes` (the paper's NPU aggregation step).  When `append`
    is given, the new token's K/V land in the owning shard *inside* the
    shard_map (the paper's direct G2 write) — a pjit-level update on the
    sharded page dim would lower to a full-pool ownership select per layer
    (measured: the dominant decode HLO traffic).

    Returns o, or (o, new_k_pages, new_v_pages) when appending.
    """
    from repro.kernels.paged_attention.ops import paged_attention_partial

    if append is not None and kv_quant != "none":
        raise NotImplementedError(
            "fused append+attention does not support quantized pools; "
            "the engine appends via sharded_append_uniform instead")

    n_page_shards = 1
    for a in page_axes:
        n_page_shards *= mesh.shape[a]

    bspec = _axes_spec(batch_axes)
    qspec = P(bspec, None, None)
    pspec = P(bspec, None, _axes_spec(page_axes), None, None)
    sspec = P(bspec, None, _axes_spec(page_axes))
    basespec = P(bspec, _axes_spec(page_axes))
    lenspec = P(bspec)
    nspec = P(bspec, None, None)

    def run(qq, kp, vp, base, ln, ks=None, vs=None):
        o, m, l = paged_attention_partial(qq, kp, vp, base, ln,
                                          window=window, is_global=is_global,
                                          impl=impl, kv_quant=kv_quant,
                                          k_scale=ks, v_scale=vs,
                                          partitions=partitions)
        if n_page_shards > 1:
            o = combine_partials(o, m, l, tuple(page_axes))
        return o.astype(qq.dtype)

    if append is None and kv_quant != "none":
        return shard_map(run, mesh=mesh,
                         in_specs=(qspec, pspec, pspec, basespec, lenspec,
                                   sspec, sspec),
                         out_specs=qspec, check_vma=False)(
            q, k_pages, v_pages, page_base, length, k_scale, v_scale)

    if append is None:
        return shard_map(run, mesh=mesh,
                         in_specs=(qspec, pspec, pspec, basespec, lenspec),
                         out_specs=qspec, check_vma=False)(
            q, k_pages, v_pages, page_base, length)

    def run_append(qq, kp, vp, base, ln, kn, vn, phys, slot):
        kp = local_append_uniform(kp, phys, slot, kn, page_axes)
        vp = local_append_uniform(vp, phys, slot, vn, page_axes)
        return run(qq, kp, vp, base, ln), kp, vp

    k_new, v_new, phys, slot = append
    return shard_map(run_append, mesh=mesh,
                     in_specs=(qspec, pspec, pspec, basespec, lenspec,
                               nspec, nspec, lenspec, lenspec),
                     out_specs=(qspec, pspec, pspec), check_vma=False)(
        q, k_pages, v_pages, page_base, length, k_new, v_new, phys, slot)


# ---------------------------------------------------------------------------
# Shared-pool (FTL-mapped) variants: P_total sharded over the mesh
# ---------------------------------------------------------------------------
#
# The shared pool [K, P_total, T, dh] shards its PHYSICAL page axis over
# `page_axes` (the paper's G2 dies); page tables hold GLOBAL physical
# indices, so each shard subtracts its page offset and masks entries
# outside its local range — a table walk is shard-local arithmetic, and
# the KV bytes still never cross the interconnect.

def paged_decode_attention_sharded_shared(
    q, k_pages, v_pages, page_table, page_base, length, mesh: Mesh, *,
    window: Optional[int] = None, is_global=None,
    batch_axes: Sequence[str] = ("data",),
    page_axes: Sequence[str] = ("model",),
    impl: str = "auto",
    kv_quant: str = "none",
    k_scale=None, v_scale=None,       # [K, P_total] per-page×head scales
    partitions: int = 0,              # split of each shard's local walk
):
    """q: [B, H, dh]; pages: [K, P_total, T, dh] sharded on P_total;
    page_table: [B, NP] GLOBAL physical indices; page_base: [B, NP] base
    position of LOGICAL page j (<0 = unwritten); length: [B].

    Each shard translates the table into its local page range (entries it
    does not own become data-invalid via page_base = -1e9), runs the
    shared-pool partial over its local pages, and the partials merge via
    the log-sum-exp combine (the paper's NPU aggregation).
    """
    from repro.kernels.paged_attention.ops import paged_attention_partial

    n_page_shards = 1
    for a in page_axes:
        n_page_shards *= mesh.shape[a]

    bspec = _axes_spec(batch_axes)
    qspec = P(bspec, None, None)
    pspec = P(None, _axes_spec(page_axes), None, None)
    sspec = P(None, _axes_spec(page_axes))
    tspec = P(bspec, None)
    lenspec = P(bspec)

    def run(qq, kp, vp, tbl, base, ln, ks=None, vs=None):
        P_local = kp.shape[1]
        off = _shard_page_offset(page_axes, P_local)
        tl = tbl - off
        owned = (tl >= 0) & (tl < P_local)
        base_l = jnp.where(owned, base, -(10 ** 9))
        tl = jnp.clip(tl, 0, P_local - 1)
        o, m, l = paged_attention_partial(
            qq, kp, vp, base_l, ln, window=window, is_global=is_global,
            impl=impl, kv_quant=kv_quant, k_scale=ks, v_scale=vs,
            page_table=tl, partitions=partitions)
        if n_page_shards > 1:
            o = combine_partials(o, m, l, tuple(page_axes))
        return o.astype(qq.dtype)

    if kv_quant != "none":
        return shard_map(run, mesh=mesh,
                         in_specs=(qspec, pspec, pspec, tspec, tspec,
                                   lenspec, sspec, sspec),
                         out_specs=qspec, check_vma=False)(
            q, k_pages, v_pages, page_table, page_base, length,
            k_scale, v_scale)
    return shard_map(run, mesh=mesh,
                     in_specs=(qspec, pspec, pspec, tspec, tspec, lenspec),
                     out_specs=qspec, check_vma=False)(
        q, k_pages, v_pages, page_table, page_base, length)


def sharded_append_shared(pool_k, pool_v, layer, k_new, v_new, phys, slot,
                          mesh: Mesh, *,
                          batch_axes: Sequence[str] = ("data",),
                          page_axes: Sequence[str] = ("model",),
                          k_scale=None, v_scale=None,
                          kv_quant: str = "none"):
    """One-token append into FULL stacked shared pools [L, K, P, T, dh]
    at a traced layer index: the shard owning each sequence's physical
    page scatters locally; everyone else's write drops (ragged positions,
    so this is the continuous-batching path on a mesh).

    Returns (k, v) or (k, v, k_scale, v_scale) when quantized.

    NB: the shared pool has no batch dim, so over any BATCH mesh axes the
    pool is replicated — every replica must apply the SAME full-batch
    append or the copies diverge.  The new-token values/positions are
    therefore replicated into the shard_map (a [B, K, dh] vector against
    a pool measured in GB), and only the PAGE axes select which shard's
    local range actually lands the write.
    """
    from repro.core import paged_kv as pk

    del batch_axes                       # see NB above — values replicate
    pspec = P(None, None, _axes_spec(page_axes), None, None)
    sspec = P(None, None, _axes_spec(page_axes))
    nspec = P(None, None, None)
    lspec = P(None)

    def local(kp, vp, kn, vn, ph, sl, lyr):
        P_local = kp.shape[2]
        off = _shard_page_offset(page_axes, P_local)
        ph_loc = ph - off
        ph_drop = jnp.where((ph_loc >= 0) & (ph_loc < P_local), ph_loc,
                            P_local)
        kp = pk.append_global_shared(kp, lyr, ph_drop, sl, kn)
        vp = pk.append_global_shared(vp, lyr, ph_drop, sl, vn)
        return kp, vp

    def local_quant(kp, vp, ks, vs, kn, vn, ph, sl, lyr):
        P_local = kp.shape[2]
        off = _shard_page_offset(page_axes, P_local)
        ph_loc = ph - off
        ph_drop = jnp.where((ph_loc >= 0) & (ph_loc < P_local), ph_loc,
                            P_local)
        kp, ks = pk.append_token_quant_shared(kp, ks, lyr, ph_drop, sl, kn,
                                              kv_quant)
        vp, vs = pk.append_token_quant_shared(vp, vs, lyr, ph_drop, sl, vn,
                                              kv_quant)
        return kp, vp, ks, vs

    lyr = jnp.asarray(layer, jnp.int32)
    if kv_quant != "none":
        return shard_map(local_quant, mesh=mesh,
                         in_specs=(pspec, pspec, sspec, sspec, nspec, nspec,
                                   lspec, lspec, P()),
                         out_specs=(pspec, pspec, sspec, sspec),
                         check_vma=False)(
            pool_k, pool_v, k_scale, v_scale, k_new, v_new, phys, slot, lyr)
    return shard_map(local, mesh=mesh,
                     in_specs=(pspec, pspec, nspec, nspec, lspec, lspec,
                               P()),
                     out_specs=(pspec, pspec), check_vma=False)(
        pool_k, pool_v, k_new, v_new, phys, slot, lyr)


def _axes_spec(axes: Sequence[str]):
    axes = tuple(axes)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes
