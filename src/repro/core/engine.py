"""KVNAND engine — prefill + decode with paged KV, compact/discrete plans.

The decode step realizes the paper's Figure 7(b) on a TPU mesh:

  * every memory-bound GEMV (QKV gen, Logit, Attend, O-proj, FFN) runs where
    its bytes live — weights TP-sharded over `model`, KV pages sequence-
    striped over `model` (± spare batch axes for batch-1 long context);
  * Logit/Attend are per-shard partials over local pages, merged by a
    log-sum-exp combine (the paper's NPU softmax-aggregation, Fig 8 ❺–❼);
  * `variant="discrete"` pipelines head groups (Fig 9(c)/10(a)): the q-GEMV
    of head-group i+1 is issued in the same scan step as the attention of
    head-group i with no data dependence between them — XLA's latency-hiding
    scheduler overlaps them exactly as the G1/G2 dies do.  On a TPU the
    paper's *spatial* G1/G2 split would idle half the MXUs (flash PEs are
    fixed-function; TPUs are not), so the split is temporal — see DESIGN.md.
  * `variant="compact"` fuses all heads into single larger GEMVs (max TP,
    Fig 10(b)).

Memory discipline (§Perf iteration 1): KV pools and recurrent states are
scan CARRIES updated in place at a traced layer index — never scan xs/ys.
Threading pools through xs/ys made XLA rewrite the full per-layer pool
through the ys-stacking buffer every step (~70 MB of copy traffic per layer
against 4 KB of appended KV at qwen1.5-0.5b/decode_32k scale).

Layer heterogeneity (gemma3 5:1 local:global, hymba sparse-global) scans
over repeating layer *groups*; global/window pools are indexed by per-group
base offsets carried as scanned index arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.configs.base import EngineConfig, ModelConfig
from repro.core import paged_kv, seqpar
from repro.core.paged_kv import DecodeCache
from repro.kernels.paged_attention import paged_attention_partial
from repro.models import attention as attn_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import dense, embed_lookup, mlp, moe, rms_norm
from repro.models.transformer import Runtime, embed_inputs, lm_head_logits

STATE_LEAVES = ("rwkv_state", "rwkv_shift", "rwkv_shift2", "ssm_state",
                "conv_tail")
POOL_G = ("k_pages_g", "v_pages_g", "k_scale_g", "v_scale_g")
POOL_W = ("k_pages_w", "v_pages_w", "k_scale_w", "v_scale_w")


# ---------------------------------------------------------------------------
# Mesh planning
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardPlan:
    batch_axes: Tuple[str, ...] = ()
    page_axes_g: Tuple[str, ...] = ()
    page_axes_w: Tuple[str, ...] = ()


def _axes_size(mesh: Optional[Mesh], axes) -> int:
    if mesh is None:
        return 1
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return max(n, 1)


def plan_sharding(mesh: Optional[Mesh], batch: int,
                  np_g_raw: int) -> ShardPlan:
    """Pick batch vs page mesh axes.  Batch-1 long context pushes spare
    data/pod axes onto the global page dimension (up to 512-way striping)."""
    if mesh is None or mesh.size == 1:
        return ShardPlan()
    batch_axes: List[str] = []
    spare: List[str] = []
    rem = batch
    for a in ("pod", "data"):
        if a not in mesh.shape:
            continue
        if rem % mesh.shape[a] == 0 and rem >= mesh.shape[a]:
            batch_axes.append(a)
            rem //= mesh.shape[a]
        else:
            spare.append(a)
    page_axes_g: List[str] = []
    n = mesh.shape["model"]
    for a in spare:
        if np_g_raw >= n * mesh.shape[a]:
            page_axes_g.append(a)
            n *= mesh.shape[a]
    page_axes_g.append("model")
    return ShardPlan(tuple(batch_axes), tuple(page_axes_g), ("model",))


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class KVNANDEngine:
    def __init__(self, cfg: ModelConfig, eng: Optional[EngineConfig] = None,
                 rt: Optional[Runtime] = None, mesh: Optional[Mesh] = None):
        self.cfg = cfg
        self.eng = eng or EngineConfig()
        self.rt = rt or Runtime()
        self.mesh = mesh
        self.period, self.pattern = paged_kv.layer_pattern(cfg)
        # per-period static offsets into the global/window pools
        self._g_off = []
        self._w_off = []
        g = w = 0
        for is_glob in self.pattern:
            use_window = (cfg.window is not None) and not is_glob
            self._g_off.append(g)
            self._w_off.append(w)
            if cfg.family != "ssm":
                if use_window:
                    w += 1
                else:
                    g += 1
        self.g_per_group, self.w_per_group = g, w

    # ------------------------------------------------------------------
    # cache construction
    # ------------------------------------------------------------------
    def plan(self, batch: int, max_context: int) -> ShardPlan:
        return plan_sharding(
            self.mesh, batch,
            paged_kv.ceil_div(max_context, self.eng.page_tokens))

    def _cache_kw(self, batch: int, max_context: int, enc_len: int):
        plan = self.plan(batch, max_context)
        return dict(dtype=jnp.dtype(self.eng.kv_dtype), enc_len=enc_len,
                    page_shards_g=_axes_size(self.mesh, plan.page_axes_g),
                    page_shards_w=_axes_size(self.mesh, plan.page_axes_w))

    def init_cache(self, batch: int, max_context: int,
                   enc_len: int = 0) -> DecodeCache:
        return paged_kv.init_cache(self.cfg, self.eng, batch, max_context,
                                   **self._cache_kw(batch, max_context,
                                                    enc_len))

    def abstract_cache(self, batch: int, max_context: int,
                       enc_len: int = 0) -> DecodeCache:
        return paged_kv.abstract_cache(self.cfg, self.eng, batch, max_context,
                                       **self._cache_kw(batch, max_context,
                                                        enc_len))

    # ------------------------------------------------------------------
    # paged attention dispatch (single device vs sharded combine)
    # ------------------------------------------------------------------
    def _paged_attn(self, q, kp, vp, base, length, plan: ShardPlan,
                    pool: str, window, ks=None, vs=None, table=None):
        """ks/vs: per-page×head dequant scales (None -> bf16 pool).

        kp/vp with a batch dim ([B, K, NP, T, dh]) read the slot's private
        stripe; 4-D pools ([K, P_total, T, dh]) are the SHARED pool and
        `table` [B, NP] supplies the logical→physical walk.
        """
        kv_quant = self.eng.kv_quant if ks is not None else "none"
        page_axes = plan.page_axes_g if pool == "g" else plan.page_axes_w
        shared = kp.ndim == 4
        if self.mesh is None or self.mesh.size == 1 or not page_axes:
            o, _, _ = paged_attention_partial(
                q, kp, vp, base, length, window=window,
                impl=self.eng.attn_impl, kv_quant=kv_quant,
                k_scale=ks, v_scale=vs,
                page_table=table if shared else None,
                partitions=self.eng.attn_partitions)
            return o
        if shared:
            return seqpar.paged_decode_attention_sharded_shared(
                q, kp, vp, table, base, length, self.mesh, window=window,
                batch_axes=plan.batch_axes, page_axes=page_axes,
                impl=self.eng.attn_impl, kv_quant=kv_quant,
                k_scale=ks, v_scale=vs,
                partitions=self.eng.attn_partitions)
        return seqpar.paged_decode_attention_sharded(
            q, kp, vp, base, length, self.mesh, window=window,
            batch_axes=plan.batch_axes, page_axes=page_axes,
            impl=self.eng.attn_impl, kv_quant=kv_quant,
            k_scale=ks, v_scale=vs,
            partitions=self.eng.attn_partitions)

    # ------------------------------------------------------------------
    # in-place pool ops (pools carried through the layer scan)
    # ------------------------------------------------------------------
    def _append_token(self, pool, layer, phys, slot, val):
        """pool: [L, B, K, NP, T, dh]; write one token's K or V in place
        through the `paged_kv` writer family (KV004: pool-leaf writes live
        in core/paged_kv.py; see its docstring for the uniform-lengths
        fast-path rationale)."""
        return paged_kv.append_token_inplace(
            pool, layer, phys, slot, val,
            uniform_lengths=self.eng.uniform_lengths)

    @staticmethod
    def _layer_slice(pool, layer):
        return jax.lax.dynamic_index_in_dim(pool, layer, 0, keepdims=False)

    def _global_bases(self, table) -> jax.Array:
        """Per-page base positions [B, NP] for attention over the global
        pool (decode and verify share this).  Shared pools walk LOGICAL
        pages through the table, so logical page j's base is simply j·T
        and pages past `lengths` (unallocated table entries) are
        data-invalid already; stripe tables are permutations within the
        stripe, inverted here into physical-page-indexed bases."""
        B, NP = table.shape
        T = self.eng.page_tokens
        if self.eng.shared_pool:
            return jnp.broadcast_to(
                (jnp.arange(NP, dtype=jnp.int32) * T)[None], (B, NP))
        return jnp.zeros((B, NP), jnp.int32).at[
            jnp.arange(B)[:, None], table].set(
            jnp.arange(NP, dtype=jnp.int32)[None] * T)

    # ------------------------------------------------------------------
    # per-layer attention (compact vs discrete)
    # ------------------------------------------------------------------
    def _attend_compact(self, pl_, x_norm, kp, vp, ks, vs, base, lengths,
                        plan, pool, window, table=None):
        """Fused QKV gen + attention (KVNAND-C, Fig 10b).  kp/vp are the
        already-appended layer slices (+scales when the pool is quantized)."""
        q, _, _ = attn_mod.project_qkv(pl_["attn"], self.cfg, x_norm,
                                       lengths[:, None])
        return self._paged_attn(q[:, 0], kp, vp, base, lengths + 1, plan,
                                pool, window, ks, vs, table)

    def _attend_discrete(self, pl_, x_norm, kp, vp, ks, vs, base, lengths,
                         plan, pool, window, table=None):
        """Head-group pipelined attention (KVNAND-D, Fig 10a): q-GEMV of
        group i+1 is independent of group i's attention -> overlapped."""
        cfg = self.cfg
        B = x_norm.shape[0]
        K = cfg.n_kv_heads
        x_tok = x_norm[:, 0]
        k_axis = 0 if kp.ndim == 4 else 1   # shared pools are [K, P, T, dh]

        def body(q_cur, i):
            q_next = attn_mod.project_q_group(
                pl_["attn"], cfg, x_tok, jnp.minimum(i + 1, K - 1), lengths)
            # slice head group i on the K dim directly (no pool transpose)
            kp_i = jax.lax.dynamic_slice_in_dim(kp, i, 1, k_axis)
            vp_i = jax.lax.dynamic_slice_in_dim(vp, i, 1, k_axis)
            ks_i = vs_i = None
            if ks is not None:
                ks_i = jax.lax.dynamic_slice_in_dim(ks, i, 1, k_axis)
                vs_i = jax.lax.dynamic_slice_in_dim(vs, i, 1, k_axis)
            o = self._paged_attn(q_cur, kp_i, vp_i, base, lengths + 1,
                                 plan, pool, window, ks_i, vs_i,
                                 table)  # [B, G, dh]
            return q_next, o

        q0 = attn_mod.project_q_group(pl_["attn"], cfg, x_tok,
                                      jnp.zeros((), jnp.int32), lengths)
        _, outs = jax.lax.scan(body, q0, jnp.arange(K))
        return outs.transpose(1, 0, 2, 3).reshape(B, cfg.n_heads,
                                                  cfg.d_head)

    # ------------------------------------------------------------------
    # decode blocks
    # ------------------------------------------------------------------
    def _decode_attn_layer(self, pl_, x, pools, g_idx, w_idx, lengths,
                           plan, is_glob):
        cfg = self.cfg
        shared = self.eng.shared_pool
        h = rms_norm(x, pl_["ln1"], cfg.norm_eps)
        use_window = (cfg.window is not None) and not is_glob
        # K/V for the new token (the paper's ❸→❹ write into G2/own pages)
        _, k_new, v_new = attn_mod.project_qkv(pl_["attn"], cfg, h,
                                               lengths[:, None])
        k1, v1 = k_new[:, 0], v_new[:, 0]
        T = self.eng.page_tokens
        slot = lengths % T
        if use_window:
            kname, vname, idx = "k_pages_w", "v_pages_w", w_idx
            if shared:
                NPw = self._table_w.shape[1]
                ring = (lengths // T) % NPw
                phys = jnp.take_along_axis(self._table_w, ring[:, None],
                                           axis=1)[:, 0]
                table, drop = self._table_w, pools[kname].shape[2]
            else:
                NP = pools[kname].shape[3]
                phys = (lengths // T) % NP
                table, drop = None, NP
            base, window = self._page_pos_w_new, cfg.window
        else:
            kname, vname, idx = "k_pages_g", "v_pages_g", g_idx
            logical = lengths // T
            phys = jnp.take_along_axis(self._table, logical[:, None],
                                       axis=1)[:, 0]
            table = self._table if shared else None
            drop = pools[kname].shape[2 if shared else 3]
            base, window = self._base_g, None
        if self._active is not None:
            # interleaved scheduler: slots mid-prefill (or empty) must not
            # append — redirect their page index out of range so the
            # mode="drop" scatter discards the write
            phys = jnp.where(self._active, phys, drop)
        page_axes = (plan.page_axes_w if use_window else plan.page_axes_g)
        sharded = (self.mesh is not None and self.mesh.size > 1
                   and bool(page_axes))
        fmt = self.eng.kv_quant
        ksname = "k_scale_w" if use_window else "k_scale_g"
        vsname = "v_scale_w" if use_window else "v_scale_g"
        if sharded and shared:
            # shared pool sharded over P_total: the owning shard translates
            # the global physical index to its local range and scatters
            out = seqpar.sharded_append_shared(
                pools[kname], pools[vname], idx, k1, v1, phys, slot,
                self.mesh, batch_axes=plan.batch_axes, page_axes=page_axes,
                k_scale=pools.get(ksname), v_scale=pools.get(vsname),
                kv_quant=fmt)
            if fmt != "none":
                (pools[kname], pools[vname], pools[ksname],
                 pools[vsname]) = out
            else:
                pools[kname], pools[vname] = out
        elif sharded and self.eng.uniform_lengths:
            # append INSIDE the owning shard (paper: direct G2-die write);
            # a pjit-level update on the sharded page dim lowers to a
            # full-pool ownership select per layer (§Perf iteration 2)
            if fmt != "none":
                (pools[kname], pools[vname], pools[ksname],
                 pools[vsname]) = seqpar.sharded_append_uniform(
                    pools[kname], pools[vname], idx, k1, v1, phys, slot,
                    self.mesh, batch_axes=plan.batch_axes,
                    page_axes=page_axes, k_scale=pools[ksname],
                    v_scale=pools[vsname], kv_quant=fmt)
            else:
                pools[kname], pools[vname] = seqpar.sharded_append_uniform(
                    pools[kname], pools[vname], idx, k1, v1, phys, slot,
                    self.mesh, batch_axes=plan.batch_axes,
                    page_axes=page_axes)
        elif fmt != "none":
            # page-granular requantizing append (tentpole write path)
            if shared:
                append = paged_kv.append_token_quant_shared
            else:
                append = (paged_kv.append_token_quant_uniform
                          if self.eng.uniform_lengths
                          else paged_kv.append_token_quant)
            pools[kname], pools[ksname] = append(
                pools[kname], pools[ksname], idx, phys, slot, k1, fmt)
            pools[vname], pools[vsname] = append(
                pools[vname], pools[vsname], idx, phys, slot, v1, fmt)
        elif shared:
            pools[kname] = paged_kv.append_global_shared(
                pools[kname], idx, phys, slot, k1)
            pools[vname] = paged_kv.append_global_shared(
                pools[vname], idx, phys, slot, v1)
        else:
            pools[kname] = self._append_token(pools[kname], idx, phys, slot,
                                              k1)
            pools[vname] = self._append_token(pools[vname], idx, phys, slot,
                                              v1)
        kp = self._layer_slice(pools[kname], idx)
        vp = self._layer_slice(pools[vname], idx)
        ks = vs = None
        if fmt != "none":
            ks = self._layer_slice(pools[ksname], idx)
            vs = self._layer_slice(pools[vsname], idx)

        attend = (self._attend_discrete
                  if self.eng.variant == "discrete" or self.eng.hg_pipeline
                  else self._attend_compact)
        o = attend(pl_, h, kp, vp, ks, vs, base, lengths, plan,
                   "w" if use_window else "g", window, table)
        aout = attn_mod.project_out(pl_["attn"], cfg, o[:, None])
        return h, aout, pools

    def _decode_block(self, pl_, x, pools, states, cross, l_idx, g_idx,
                      w_idx, lengths, plan, is_glob):
        cfg = self.cfg

        if cfg.family == "ssm":
            return self._rwkv_decode_block(pl_, x, states, l_idx), pools

        h, aout, pools = self._decode_attn_layer(
            pl_, x, pools, g_idx, w_idx, lengths, plan, is_glob)

        if cfg.family == "hybrid":
            st = {k: self._layer_slice(states[k], l_idx)
                  for k in ("ssm_state", "conv_tail")}
            sout, s_new, tail_new = ssm_mod.ssm_decode_step(
                pl_["ssm"], cfg, h, st["ssm_state"], st["conv_tail"])
            aout = (aout + sout) * 0.5
            s_new, tail_new = self._mask_state(
                (s_new, st["ssm_state"]), (tail_new, st["conv_tail"]))
            states["ssm_state"] = states["ssm_state"].at[l_idx].set(s_new)
            states["conv_tail"] = states["conv_tail"].at[l_idx].set(
                tail_new.astype(states["conv_tail"].dtype))
        x = x + aout

        if cross is not None:
            h = rms_norm(x, pl_["ln_cross"], cfg.norm_eps)
            ck = self._layer_slice(cross["cross_k"], l_idx)
            cv = self._layer_slice(cross["cross_v"], l_idx)
            x = x + self._cross_attention(pl_["cross"], h, ck, cv, plan)

        h = rms_norm(x, pl_["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            ff = moe(pl_["moe"], h, top_k=cfg.top_k,
                     capacity_factor=self.rt.moe_capacity)
        else:
            ff = mlp(pl_["mlp"], h, cfg.gated_mlp)
        return ((x + ff, states), pools)

    def _mask_state(self, *pairs):
        """Freeze recurrent-state updates for inactive slots: each pair is
        (new, old) with a leading batch dim; returns the masked news."""
        if self._active is None:
            return [new for new, _ in pairs] if len(pairs) > 1 else pairs[0][0]
        out = []
        for new, old in pairs:
            act = self._active.reshape((-1,) + (1,) * (new.ndim - 1))
            out.append(jnp.where(act, new, old.astype(new.dtype)))
        return out if len(pairs) > 1 else out[0]

    def _rwkv_decode_block(self, pl_, x, states, l_idx):
        cfg = self.cfg
        h = rms_norm(x, pl_["ln1"], cfg.norm_eps)
        st = self._layer_slice(states["rwkv_state"], l_idx)
        sh = self._layer_slice(states["rwkv_shift"], l_idx)
        tout, s_new, shift_new = rwkv_mod.rwkv_timemix(
            pl_["tmix"], cfg, h, st, sh.astype(h.dtype), chunked=False)
        x = x + tout
        h = rms_norm(x, pl_["ln2"], cfg.norm_eps)
        cm = pl_["cmix"]
        h_prev = self._layer_slice(states["rwkv_shift2"],
                                   l_idx).astype(h.dtype)[:, None]
        xk = h + (h_prev - h) * cm["mu_k"].astype(h.dtype)
        xr = h + (h_prev - h) * cm["mu_r"].astype(h.dtype)
        k = jnp.square(jax.nn.relu(dense(cm, "ck", xk)))
        v = dense(cm, "cv", k)
        r = jax.nn.sigmoid(dense(cm, "cr", xr))
        x = x + r * v
        s_new, shift_new, shift2_new = self._mask_state(
            (s_new, st), (shift_new, sh),
            (h[:, -1], self._layer_slice(states["rwkv_shift2"], l_idx)))
        states["rwkv_state"] = states["rwkv_state"].at[l_idx].set(s_new)
        states["rwkv_shift"] = states["rwkv_shift"].at[l_idx].set(
            shift_new.astype(states["rwkv_shift"].dtype))
        states["rwkv_shift2"] = states["rwkv_shift2"].at[l_idx].set(
            shift2_new.astype(states["rwkv_shift2"].dtype))
        return x, states

    def _cross_attention(self, pcross, h, ck, cv, plan: ShardPlan):
        """Whisper decode cross-attention via the paged partial-attention op
        (encoder KV viewed as pages: Senc = NP·T)."""
        cfg = self.cfg
        B = h.shape[0]
        Senc = ck.shape[1]
        T = self.eng.page_tokens
        NP = paged_kv.ceil_div(Senc, T)
        q = attn_mod._proj(pcross, "wq", h).reshape(
            B, cfg.n_heads, cfg.d_head)
        kp = ck.reshape(B, NP, T, cfg.n_kv_heads, cfg.d_head
                        ).transpose(0, 3, 1, 2, 4)
        vp = cv.reshape(B, NP, T, cfg.n_kv_heads, cfg.d_head
                        ).transpose(0, 3, 1, 2, 4)
        base = jnp.broadcast_to(
            (jnp.arange(NP, dtype=jnp.int32) * T)[None], (B, NP))
        length = jnp.full((B,), Senc, jnp.int32)
        o = self._paged_attn(q, kp, vp, base, length, plan, "w", None)
        return attn_mod.project_out(pcross, cfg, o[:, None])

    # ------------------------------------------------------------------
    # decode step
    # ------------------------------------------------------------------
    def _collect(self, cache: DecodeCache, names) -> Dict[str, jax.Array]:
        return {n: getattr(cache, n) for n in names
                if getattr(cache, n) is not None}

    def decode_step(self, params, cache: DecodeCache, tokens: jax.Array,
                    active: Optional[jax.Array] = None):
        """tokens: [B, 1] -> (logits [B, V], updated cache).

        active: optional [B] bool mask (interleaved continuous batching):
        inactive slots — empty, or mid-way through a chunked prefill — get
        no KV append, no length advance, and frozen recurrent state, so a
        decode step never perturbs a stripe another path is filling.  Their
        logits are computed (the batch is dense) and ignored by the host.
        """
        cfg, rt = self.cfg, self.rt
        if active is not None and self.eng.uniform_lengths:
            raise ValueError("active-mask decode requires the ragged "
                             "(uniform_lengths=False) append path")
        self._active = active
        B = tokens.shape[0]
        lengths = cache.lengths
        shared = self.eng.shared_pool
        plan = plan_sharding(
            self.mesh, B, paged_kv.pool_page_count(cache.k_pages_g, shared))

        # shared per-step page bookkeeping (identical for every layer)
        self._table = cache.page_table_g
        self._table_w = cache.page_table_w
        self._base_g = (self._global_bases(cache.page_table_g)
                        if cache.page_table_g is not None else None)
        if cache.page_pos_w is not None:
            T = self.eng.page_tokens
            NPw = cache.page_pos_w.shape[1]
            phys = (lengths // T) % NPw
            slot = lengths % T
            newp = cache.page_pos_w.at[jnp.arange(B), phys].set(
                lengths - slot)
            fresh = (slot == 0)
            if active is not None:
                fresh = fresh & active
            self._page_pos_w_new = jnp.where(
                fresh[:, None], newp, cache.page_pos_w)
        else:
            self._page_pos_w_new = None

        x = embed_lookup(params["embedding"], tokens, rt.activ_dtype)

        n_groups = cfg.n_layers // self.period
        grouped_params = jax.tree.map(
            lambda a: a.reshape((n_groups, self.period) + a.shape[1:]),
            params["layers"])
        pools = self._collect(cache, POOL_G + POOL_W)
        states = self._collect(cache, STATE_LEAVES)
        cross = self._collect(cache, ("cross_k", "cross_v")) or None

        idx = {
            "p": grouped_params,
            "l0": jnp.arange(n_groups, dtype=jnp.int32) * self.period,
            "g0": jnp.arange(n_groups, dtype=jnp.int32) * self.g_per_group,
            "w0": jnp.arange(n_groups, dtype=jnp.int32) * self.w_per_group,
        }

        def group_body(carry, xs):
            xc, pools, states = carry
            for j, is_glob in enumerate(self.pattern):
                pl_ = jax.tree.map(lambda a, j=j: a[j], xs["p"])
                out, pools = self._decode_block(
                    pl_, xc, pools, states, cross,
                    xs["l0"] + j, xs["g0"] + self._g_off[j],
                    xs["w0"] + self._w_off[j], lengths, plan, is_glob)
                xc, states = out
            return (xc, pools, states), None

        (x, pools, states), _ = jax.lax.scan(
            group_body, (x, pools, states), idx)

        updates: Dict[str, Any] = dict(pools)
        updates.update(states)
        if self._page_pos_w_new is not None:
            updates["page_pos_w"] = self._page_pos_w_new
        updates["lengths"] = (lengths + 1 if active is None
                              else lengths + active.astype(lengths.dtype))
        new_cache = dataclasses.replace(cache, **updates)
        logits = lm_head_logits(params, cfg, x)[:, 0]
        return logits, new_cache

    # ------------------------------------------------------------------
    # speculative decode: draft-and-verify over a k+1-token span
    # ------------------------------------------------------------------
    def verify_step(self, params, cache: DecodeCache, tokens: jax.Array,
                    *, accept, active: Optional[jax.Array] = None):
        """Score a drafted span in ONE forward pass and append only the
        accepted prefix (DESIGN.md §11).

        tokens: [B, S] — per slot, the last emitted token followed by
        S-1 drafted tokens (prompt lookup, `serving/draft.py`); logits
        at span position j are the target distribution of the token
        AFTER tokens[:, j].  The span attends via the two-partial merge
        of chunked prefill (§8): a causal in-span partial over the
        span's fresh K/V (`seqpar._attn_block_partial` — the mask is
        position-relative, so one call serves every slot whatever its
        length) and a past-pages partial (`paged_chunk_attention`,
        batched per-row start/q_pos), merged by log-sum-exp.

        accept: traced callback ``logits [B, S, V] -> (n_acc [B], aux)``
        — the scheduler's sampler closure (`speculative_accept`), kept
        outside the engine so it stays sampling-free.  After it returns,
        ``n_acc[b] + 1`` span tokens (the last emitted token's K/V plus
        the accepted drafts) are appended per active slot through the
        span writers (`paged_kv.append_span*`): rejected positions are
        gated to the drop sentinel, so rollback is "never written" on
        every layout — f32, requantizing kv8/kv4 chains, window rings,
        and shared-pool tables alike.  `lengths` advance by the emitted
        count; the correction/bonus token's K/V lands on the NEXT step,
        exactly like sequential decode.

        active: optional [B] bool mask (continuous batching): inactive
        slots get no append and no length advance.

        Returns (aux, updated cache).  Recurrent families (ssm/hybrid)
        and encoder-decoder archs are unsupported (carried state cannot
        roll back); sharded meshes take the sequential decode path.
        """
        cfg, rt = self.cfg, self.rt
        if cfg.family in ("ssm", "hybrid"):
            raise ValueError(
                f"{cfg.family}: speculative verification cannot roll back "
                "carried recurrent state; decode sequentially")
        if cfg.is_encoder_decoder:
            raise ValueError("verify_step does not support encoder-decoder "
                             "archs")
        if self.mesh is not None and self.mesh.size > 1:
            raise NotImplementedError(
                "sharded verify_step is not wired; run speculation "
                "single-host (the mesh path covers sequential decode)")
        if self.eng.uniform_lengths:
            raise ValueError("verify_step requires the ragged "
                             "(uniform_lengths=False) append path: slots "
                             "accept different span lengths")
        B, S = tokens.shape
        lengths = cache.lengths
        shared = self.eng.shared_pool
        T = self.eng.page_tokens
        scale = cfg.d_head ** -0.5

        self._table = cache.page_table_g
        self._table_w = cache.page_table_w
        base_g = (self._global_bases(cache.page_table_g)
                  if cache.page_table_g is not None else None)

        positions = lengths[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
        x = embed_lookup(params["embedding"], tokens, rt.activ_dtype)

        n_groups = cfg.n_layers // self.period
        grouped_params = jax.tree.map(
            lambda a: a.reshape((n_groups, self.period) + a.shape[1:]),
            params["layers"])
        pools = self._collect(cache, POOL_G + POOL_W)
        fmt = self.eng.kv_quant

        idx = {
            "p": grouped_params,
            "g0": jnp.arange(n_groups, dtype=jnp.int32) * self.g_per_group,
            "w0": jnp.arange(n_groups, dtype=jnp.int32) * self.w_per_group,
        }

        def attn_layer(pl_, xc, g_idx, w_idx, is_glob):
            """One attention layer of the span forward; returns the layer
            output and the span's fresh (k, v) for the append phase."""
            use_window = (cfg.window is not None) and not is_glob
            window = cfg.window if use_window else None
            h = rms_norm(xc, pl_["ln1"], cfg.norm_eps)
            q, k, v = attn_mod.project_qkv(pl_["attn"], cfg, h, positions)
            # in-span causal partial: the mask is position-RELATIVE
            # (span token i sees span tokens <= i, window likewise), so
            # relative coordinates serve every slot at once.  The span's
            # K/V are rounded through the pool dtype first — sequential
            # decode would read these tokens back from the pool, and the
            # greedy-parity guarantee needs the same values on both
            # paths (quantized pools keep full-precision span K/V: the
            # sequential requant chain is unknowable mid-span, and the
            # residual is bounded by the format's own quant noise).
            if fmt == "none":
                kv_dt = jnp.dtype(self.eng.kv_dtype)
                q_in = (q.astype(jnp.float32) * scale).astype(kv_dt)
                k_in, v_in, sc = k.astype(kv_dt), v.astype(kv_dt), 1.0
            else:
                q_in, k_in, v_in, sc = q, k, v, scale
            o, m, l = seqpar._attn_block_partial(
                q_in, k_in, v_in, jnp.arange(S), jnp.zeros((), jnp.int32),
                causal=True, window=window, is_global=None, scale=sc)
            # past partial vs the slot's already-written pages
            if use_window:
                kname, vname, idx_l = "k_pages_w", "v_pages_w", w_idx
                base, table = cache.page_pos_w, self._table_w
            else:
                kname, vname, idx_l = "k_pages_g", "v_pages_g", g_idx
                base, table = base_g, self._table
            kp = self._layer_slice(pools[kname], idx_l)
            vp = self._layer_slice(pools[vname], idx_l)
            ks = vs = None
            if fmt != "none":
                sfx = "w" if use_window else "g"
                ks = self._layer_slice(pools[f"k_scale_{sfx}"], idx_l)
                vs = self._layer_slice(pools[f"v_scale_{sfx}"], idx_l)
            from repro.kernels.paged_attention import paged_chunk_attention
            o2, m2, l2 = paged_chunk_attention(
                q, kp, vp, base, lengths, positions, window=window,
                impl=self.eng.attn_impl, kv_quant=fmt, k_scale=ks,
                v_scale=vs, page_table=table if shared else None,
                partitions=self.eng.attn_partitions)
            o, m, l = seqpar.merge_two(o, m, l, o2, m2, l2)
            aout = attn_mod.project_out(pl_["attn"], cfg,
                                        o.astype(h.dtype))
            xc = xc + aout
            h = rms_norm(xc, pl_["ln2"], cfg.norm_eps)
            if cfg.is_moe:
                ff = moe(pl_["moe"], h, top_k=cfg.top_k,
                         capacity_factor=rt.moe_capacity)
            else:
                ff = mlp(pl_["mlp"], h, cfg.gated_mlp)
            return xc + ff, k, v

        def fwd_body(xc, xs):
            kv_k, kv_v = [], []
            for j, is_glob in enumerate(self.pattern):
                pl_ = jax.tree.map(lambda a, j=j: a[j], xs["p"])
                xc, k, v = attn_layer(pl_, xc, xs["g0"] + self._g_off[j],
                                      xs["w0"] + self._w_off[j], is_glob)
                kv_k.append(k)
                kv_v.append(v)
            # span K/V ride the ys stack — tiny ([period, B, S, K, dh])
            # next to the pool carries the memory discipline protects
            return xc, {"k": jnp.stack(kv_k), "v": jnp.stack(kv_v)}

        x, span_kv = jax.lax.scan(fwd_body, x, idx)
        logits = lm_head_logits(params, cfg, x)            # [B, S, V]

        n_acc, aux = accept(logits)
        n_write = jnp.clip(jnp.asarray(n_acc, jnp.int32) + 1, 0, S)
        if active is not None:
            n_write = jnp.where(active, n_write, 0)

        # span page coordinates, shared by every layer of a pool: the
        # write gate redirects rejected/inactive positions to the drop
        # sentinel — rejected drafts never touch a page (the rollback)
        pos_s = lengths[None, :] + jnp.arange(S, dtype=jnp.int32)[:, None]
        slot_s = pos_s % T                                  # [S, B]
        write = jnp.arange(S, dtype=jnp.int32)[:, None] < n_write[None, :]
        phys_g = phys_w = None
        if cache.page_table_g is not None:
            drop_g = paged_kv.pool_page_count(cache.k_pages_g, shared)
            pg = jnp.take_along_axis(cache.page_table_g,
                                     (pos_s // T).T, axis=1).T
            phys_g = jnp.where(write, pg, drop_g)
        if cache.page_pos_w is not None:
            NPw = cache.page_pos_w.shape[1]
            ring = (pos_s // T) % NPw
            if shared:
                drop_w = paged_kv.pool_page_count(cache.k_pages_w, shared)
                pw = jnp.take_along_axis(cache.page_table_w, ring.T,
                                         axis=1).T
            else:
                drop_w, pw = NPw, ring
            phys_w = jnp.where(write, pw, drop_w)

        def append_body(pools, xs):
            for j, is_glob in enumerate(self.pattern):
                use_window = (cfg.window is not None) and not is_glob
                k_span = xs["kv"]["k"][j]                  # [B, S, K, dh]
                v_span = xs["kv"]["v"][j]
                if use_window:
                    idx_l, phys = xs["w0"] + self._w_off[j], phys_w
                    names = ("k_pages_w", "v_pages_w", "k_scale_w",
                             "v_scale_w")
                else:
                    idx_l, phys = xs["g0"] + self._g_off[j], phys_g
                    names = ("k_pages_g", "v_pages_g", "k_scale_g",
                             "v_scale_g")
                kname, vname, ksname, vsname = names
                if fmt != "none":
                    append = (paged_kv.append_span_quant_shared if shared
                              else paged_kv.append_span_quant)
                    pools[kname], pools[ksname] = append(
                        pools[kname], pools[ksname], idx_l, phys, slot_s,
                        k_span, fmt)
                    pools[vname], pools[vsname] = append(
                        pools[vname], pools[vsname], idx_l, phys, slot_s,
                        v_span, fmt)
                elif shared:
                    pools[kname] = paged_kv.append_span_shared(
                        pools[kname], idx_l, phys, slot_s, k_span)
                    pools[vname] = paged_kv.append_span_shared(
                        pools[vname], idx_l, phys, slot_s, v_span)
                else:
                    pools[kname] = paged_kv.append_span(
                        pools[kname], idx_l, phys, slot_s, k_span)
                    pools[vname] = paged_kv.append_span(
                        pools[vname], idx_l, phys, slot_s, v_span)
            return pools, None

        pools, _ = jax.lax.scan(append_body, pools,
                                {"kv": span_kv, "g0": idx["g0"],
                                 "w0": idx["w0"]})

        updates: Dict[str, Any] = dict(pools)
        if cache.page_pos_w is not None:
            # ring bases advance only for pages that received an
            # ACCEPTED token, replaying sequential decode's fresh-page
            # rule position by position
            NPw = cache.page_pos_w.shape[1]
            pos_w = cache.page_pos_w
            b_idx = jnp.arange(B)
            for s in range(S):
                ring = (pos_s[s] // T) % NPw
                fresh = (slot_s[s] == 0) & write[s]
                newp = pos_w.at[b_idx, ring].set(pos_s[s])
                pos_w = jnp.where(fresh[:, None], newp, pos_w)
            updates["page_pos_w"] = pos_w
        updates["lengths"] = lengths + n_write
        return aux, dataclasses.replace(cache, **updates)

    # ------------------------------------------------------------------
    # prefill
    # ------------------------------------------------------------------
    def prefill(self, params, batch: Dict[str, jax.Array], max_context: int,
                prompt_len: Optional[jax.Array] = None):
        """Full-prompt prefill.  Returns (last-token logits, primed cache).

        Attention runs compute-bound (ring/flash — the paper's NPU prefill);
        the K/V stream is page-packed into the pools (Fig 7a).

        prompt_len: traced scalar count of VALID tokens in batch["tokens"]
        (uniform across the batch).  When given, the trailing tokens are
        bucket padding (scheduler recompile avoidance): logits are gathered
        at the true last token, `lengths` reflect the true length, and the
        window-ring fill walks only real source pages so padding never
        evicts live KV.  Unsupported for recurrent state (ssm/hybrid),
        where padded tokens would pollute the carried state.
        """
        cfg, rt = self.cfg, self.rt
        if prompt_len is not None and cfg.family in ("ssm", "hybrid"):
            raise ValueError(
                f"{cfg.family}: bucketed prefill would fold padding into "
                "recurrent state; pass exact-length prompts instead")
        if prompt_len is not None and self.mesh is not None \
                and self.mesh.size > 1:
            raise ValueError("bucketed prefill is a single-host scheduler "
                             "feature; sharded fills take exact lengths")
        x, positions = embed_inputs(params, cfg, batch, rt)
        B, S = x.shape[:2]
        if prompt_len is None:
            self._true_S = None
        else:
            # prefix = frontend tokens (patches/meta) prepended by embed
            prefix = S - batch["tokens"].shape[1]
            self._true_S = jnp.asarray(prompt_len, jnp.int32) + prefix
        enc_out = None
        enc_len = 0
        if cfg.is_encoder_decoder:
            from repro.models.transformer import run_layers
            enc = batch["frames"].astype(rt.activ_dtype)
            enc_pos = jnp.broadcast_to(jnp.arange(enc.shape[1])[None],
                                       enc.shape[:2])
            enc_out, _ = run_layers(params, cfg, enc, rt, enc_pos,
                                    stack="encoder")
            enc_out = rms_norm(enc_out, params["encoder_norm"], cfg.norm_eps)
            enc_len = enc_out.shape[1]

        cache = self.init_cache(B, max(max_context, S + 1), enc_len=enc_len)
        shared = self.eng.shared_pool
        if shared and self.mesh is not None and self.mesh.size > 1:
            raise NotImplementedError(
                "sharded one-shot prefill into a shared pool is not wired; "
                "shared-pool serving prefills via prefill_chunk (the mesh "
                "path covers decode and chunk attention)")
        if shared and self.eng.hot_pages:
            raise ValueError(
                "one-shot prefill cannot run against a TIERED pool: the "
                "identity-striped init tables would alias slots inside the "
                "hot tier's few device pages; tiered pools are managed by "
                "the serving scheduler's residency machinery (DESIGN.md "
                "§13) — run hot_pages=0 here, or serve via KVNANDServer")
        # prefill writes through the (identity-striped) tables; they are
        # read-only during the layer scan so they ride as closure constants
        self._prefill_tables = {"g": cache.page_table_g,
                                "w": cache.page_table_w}
        self._prefill_plan = plan_sharding(
            self.mesh, B, paged_kv.pool_page_count(cache.k_pages_g, shared))
        n_groups = cfg.n_layers // self.period
        grouped_params = jax.tree.map(
            lambda a: a.reshape((n_groups, self.period) + a.shape[1:]),
            params["layers"])
        pools = self._collect(cache, POOL_G + POOL_W)
        states = self._collect(cache, STATE_LEAVES)
        cross = self._collect(cache, ("cross_k", "cross_v"))

        idx = {
            "p": grouped_params,
            "l0": jnp.arange(n_groups, dtype=jnp.int32) * self.period,
            "g0": jnp.arange(n_groups, dtype=jnp.int32) * self.g_per_group,
            "w0": jnp.arange(n_groups, dtype=jnp.int32) * self.w_per_group,
        }

        def group_body(carry, xs):
            xc, pools, states, cross_c = carry
            for j, is_glob in enumerate(self.pattern):
                pl_ = jax.tree.map(lambda a, j=j: a[j], xs["p"])
                xc, pools, states, cross_c = self._prefill_block(
                    pl_, xc, positions, enc_out, is_glob, pools, states,
                    cross_c, xs["l0"] + j, xs["g0"] + self._g_off[j],
                    xs["w0"] + self._w_off[j])
            return (xc, pools, states, cross_c), None

        (x, pools, states, cross), _ = jax.lax.scan(
            group_body, (x, pools, states, cross), idx)

        updates: Dict[str, Any] = dict(pools)
        updates.update(states)
        updates.update(cross)
        if self._true_S is None:
            updates["lengths"] = jnp.full((B,), S, jnp.int32)
            x_last = x[:, -1:]
        else:
            updates["lengths"] = jnp.broadcast_to(self._true_S, (B,)
                                                  ).astype(jnp.int32)
            x_last = jax.lax.dynamic_slice_in_dim(x, self._true_S - 1, 1, 1)
        if cache.page_pos_w is not None:
            NPw = cache.page_pos_w.shape[1]
            if self._true_S is None:
                updates["page_pos_w"] = self._prefill_window_pos(S, NPw, B)
            else:
                vals = paged_kv.window_page_positions_dyn(
                    self._true_S, NPw, self.eng.page_tokens)
                updates["page_pos_w"] = jnp.broadcast_to(vals[None],
                                                         (B, NPw))
        cache = dataclasses.replace(cache, **updates)
        logits = lm_head_logits(params, cfg, x_last)[:, 0]
        return logits, cache

    def _prefill_window_pos(self, S: int, NPw: int, B: int):
        vals = paged_kv.window_page_positions(S, NPw, self.eng.page_tokens)
        return jnp.broadcast_to(jnp.asarray(vals)[None], (B, NPw))

    def _prefill_block(self, pl_, x, positions, enc_out, is_glob, pools,
                       states, cross, l_idx, g_idx, w_idx):
        cfg, rt = self.cfg, self.rt
        B, S = x.shape[:2]

        if cfg.family == "ssm":
            x, states = self._rwkv_prefill_block(pl_, x, states, l_idx)
            return x, pools, states, cross

        h = rms_norm(x, pl_["ln1"], cfg.norm_eps)
        q, k, v = attn_mod.project_qkv(pl_["attn"], cfg, h, positions)
        window = cfg.window if (cfg.window and not is_glob) else None
        o = attn_mod.sharded_flash_attention(
            q, k, v, causal=True, window=window, impl=rt.attn_impl)
        aout = attn_mod.project_out(pl_["attn"], cfg, o)

        use_window = (cfg.window is not None) and not is_glob
        plan = self._prefill_plan
        sharded = self.mesh is not None and self.mesh.size > 1
        fmt = self.eng.kv_quant

        # ONE fill path for every arch/format/layout: the one-shot fill is
        # `prefill_chunk`'s whole-prompt chunk write (`paged_kv.fill_layer`
        # — bit-identical pages, see the chunk parity tests); only the
        # mesh-sharded stripe fills keep their shard-local writers.
        # Global-pool bucket padding needs no valid-length guard — padded
        # pages land after the true length and stay masked by `lengths`.
        suffix = "w" if use_window else "g"
        idx = w_idx if use_window else g_idx
        page_axes = plan.page_axes_w if use_window else plan.page_axes_g
        for prefix, kv_seq in (("k", k), ("v", v)):
            name = f"{prefix}_pages_{suffix}"
            sname = f"{prefix}_scale_{suffix}"
            if sharded and page_axes:
                sfill = (seqpar.sharded_window_fill if use_window
                         else seqpar.sharded_prefill_fill)
                out = sfill(pools[name], kv_seq, idx, mesh=self.mesh,
                            batch_axes=plan.batch_axes, page_axes=page_axes,
                            scale=pools.get(sname), kv_quant=fmt)
            else:
                out = paged_kv.fill_layer(
                    pools[name], kv_seq, idx, ring=use_window,
                    true_len=self._true_S if use_window else None,
                    table=self._prefill_tables[suffix]
                    if self.eng.shared_pool else None,
                    scale=pools.get(sname), kv_quant=fmt)
            if fmt != "none":
                pools[name], pools[sname] = out
            else:
                pools[name] = out

        if cfg.family == "hybrid":
            state0 = jnp.zeros(states["ssm_state"].shape[1:], jnp.float32)
            tail0 = jnp.zeros(states["conv_tail"].shape[1:],
                              states["conv_tail"].dtype)
            sout, s_new, tail_new = ssm_mod.ssm_mixer(
                pl_["ssm"], cfg, h, state0, tail0)
            aout = (aout + sout) * 0.5
            states["ssm_state"] = states["ssm_state"].at[l_idx].set(s_new)
            states["conv_tail"] = states["conv_tail"].at[l_idx].set(
                tail_new.astype(states["conv_tail"].dtype))
        x = x + aout

        if cfg.is_encoder_decoder and enc_out is not None:
            h = rms_norm(x, pl_["ln_cross"], cfg.norm_eps)
            x = x + attn_mod.attention_train(pl_["cross"], cfg, h,
                                             kv_x=enc_out, impl=rt.attn_impl)
            kv_dt = jnp.dtype(self.eng.kv_dtype)
            ck = attn_mod._proj(pl_["cross"], "wk", enc_out).astype(kv_dt)
            cv = attn_mod._proj(pl_["cross"], "wv", enc_out).astype(kv_dt)
            cross["cross_k"] = cross["cross_k"].at[l_idx].set(ck)
            cross["cross_v"] = cross["cross_v"].at[l_idx].set(cv)

        h = rms_norm(x, pl_["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            ff = moe(pl_["moe"], h, top_k=cfg.top_k,
                     capacity_factor=rt.moe_capacity)
        else:
            ff = mlp(pl_["mlp"], h, cfg.gated_mlp)
        return x + ff, pools, states, cross

    def _rwkv_prefill_block(self, pl_, x, states, l_idx):
        cfg = self.cfg
        B = x.shape[0]
        h = rms_norm(x, pl_["ln1"], cfg.norm_eps)
        state0 = jnp.zeros(states["rwkv_state"].shape[1:], jnp.float32)
        shift0 = jnp.zeros((B, cfg.d_model), h.dtype)
        tout, s_new, shift_new = rwkv_mod.rwkv_timemix(
            pl_["tmix"], cfg, h, state0, shift0)
        x = x + tout
        h = rms_norm(x, pl_["ln2"], cfg.norm_eps)
        cm = pl_["cmix"]
        h_prev = jnp.concatenate([jnp.zeros_like(h[:, :1]), h[:, :-1]],
                                 axis=1)
        xk = h + (h_prev - h) * cm["mu_k"].astype(h.dtype)
        xr = h + (h_prev - h) * cm["mu_r"].astype(h.dtype)
        kk = jnp.square(jax.nn.relu(dense(cm, "ck", xk)))
        vv = dense(cm, "cv", kk)
        rr = jax.nn.sigmoid(dense(cm, "cr", xr))
        x = x + rr * vv
        states["rwkv_state"] = states["rwkv_state"].at[l_idx].set(s_new)
        states["rwkv_shift"] = states["rwkv_shift"].at[l_idx].set(
            shift_new.astype(states["rwkv_shift"].dtype))
        states["rwkv_shift2"] = states["rwkv_shift2"].at[l_idx].set(
            h[:, -1].astype(states["rwkv_shift2"].dtype))
        return x, states

    # ------------------------------------------------------------------
    # chunked prefill (interleaved continuous batching)
    # ------------------------------------------------------------------
    def prefill_chunk(self, params, cache: DecodeCache,
                      batch: Dict[str, jax.Array], slot, start, chunk_len,
                      *, first: bool = False):
        """Process one page-aligned chunk of ONE slot's prompt directly
        into that slot's stripe of the SHARED paged pool.

        This replaces the admit-time "prefill into a one-sequence cache,
        then splice" dance: each chunk's K/V lands exactly once, in place,
        so admission costs O(chunk) instead of O(prompt) + O(pool-splice),
        and a chunk can share a scheduler step with the decode batch.

        batch["tokens"]: [1, C] chunk tokens (C static — the scheduler's
        chunk bucket); slot/start/chunk_len: traced scalars — the batch
        row, the absolute cache position of the chunk's first token
        (page-aligned: ``start % page_tokens == 0``), and the number of
        valid tokens in the chunk (the rest is bucket padding).
        first=True (static) routes through `embed_inputs` so frontend
        prefixes (hymba meta tokens) are prepended, and skips the
        past-context partial; it is required for ssm/hybrid continuations
        to start from zero state, and for any arch whose prefix would
        break page alignment of later chunks (those use one whole-prompt
        chunk).

        Per attention layer the chunk runs two partial attentions merged
        by log-sum-exp (the NPU softmax-aggregation of Fig 8, applied at
        chunk granularity): a causal in-chunk partial over the chunk's own
        fresh K/V, and a past-context partial read from the slot's already
        written pages (dequantized page-wise for kv8/kv4 pools) — then the
        chunk's K/V are filled into the stripe as whole pages (quantized
        pools get bit-identical codes to the one-shot prefill fill).
        Recurrent families carry (state, shift) per slot instead.

        Returns (logits [1, V] at the chunk's last valid token, cache).
        The scheduler samples from the logits only on the final chunk.
        """
        cfg, rt = self.cfg, self.rt
        if cfg.is_encoder_decoder:
            raise ValueError("chunked prefill does not support "
                             "encoder-decoder archs (cross-KV is built by "
                             "full prefill)")
        mesh_on = self.mesh is not None and self.mesh.size > 1
        if mesh_on and (cfg.window is not None
                        or cfg.family in ("ssm", "hybrid")):
            raise NotImplementedError(
                "sharded chunked prefill covers global-pool attention "
                "archs; window-ring / recurrent archs are single-host")
        shared = self.eng.shared_pool
        if mesh_on and shared:
            raise NotImplementedError(
                "sharded chunked prefill into a shared pool is not wired "
                "(the mesh path covers shared-pool decode); run the "
                "scheduler single-host or use the stripe layout on a mesh")
        slot = jnp.asarray(slot, jnp.int32)
        start = jnp.asarray(start, jnp.int32)
        chunk_len = jnp.asarray(chunk_len, jnp.int32)

        if first:
            x, _ = embed_inputs(params, cfg, batch, rt)
        else:
            x = embed_lookup(params["embedding"], batch["tokens"],
                             rt.activ_dtype)
        B1, S = x.shape[:2]
        prefix = S - batch["tokens"].shape[1]
        q_pos = start + jnp.arange(S, dtype=jnp.int32)
        positions = q_pos[None]
        v_len = chunk_len + prefix                 # valid extent incl prefix
        end = start + v_len
        T = self.eng.page_tokens
        page0 = start // T

        B = cache.lengths.shape[0]
        plan = plan_sharding(
            self.mesh, B, paged_kv.pool_page_count(cache.k_pages_g, shared))
        zero = jnp.zeros((), jnp.int32)

        # per-call temporaries shared by every layer of the scan
        self._ck = dict(slot=slot, start=start, page0=page0, v_len=v_len,
                        q_pos=q_pos, first=first, plan=plan, mesh_on=mesh_on,
                        shared=shared)
        if cache.page_table_g is not None:
            NPg = cache.page_table_g.shape[1]
            trow = jax.lax.dynamic_slice(cache.page_table_g, (slot, zero),
                                         (1, NPg))
            if shared:
                # attention/fills walk LOGICAL pages through the row, so
                # logical page j's base is j·T; stale/unallocated entries
                # are masked by `pos < start` in the past partial
                self._ck["trow_g"] = trow[0]
                self._ck["base_g"] = jnp.broadcast_to(
                    (jnp.arange(NPg, dtype=jnp.int32) * T)[None], (1, NPg))
            else:
                self._ck["base_g"] = jnp.zeros((1, NPg), jnp.int32).at[
                    0, trow[0]].set(jnp.arange(NPg, dtype=jnp.int32) * T)
        if cache.page_pos_w is not None:
            NPw = cache.page_pos_w.shape[1]
            # ring state BEFORE this chunk; chunk 0 rewrote the row, so a
            # recycled occupant's stale bases are already gone
            self._ck["pos_w"] = jax.lax.dynamic_slice(
                cache.page_pos_w, (slot, zero), (1, NPw))
            if shared:
                self._ck["trow_w"] = jax.lax.dynamic_slice(
                    cache.page_table_w, (slot, zero), (1, NPw))[0]

        n_groups = cfg.n_layers // self.period
        grouped_params = jax.tree.map(
            lambda a: a.reshape((n_groups, self.period) + a.shape[1:]),
            params["layers"])
        pools = self._collect(cache, POOL_G + POOL_W)
        states = self._collect(cache, STATE_LEAVES)

        idx = {
            "p": grouped_params,
            "l0": jnp.arange(n_groups, dtype=jnp.int32) * self.period,
            "g0": jnp.arange(n_groups, dtype=jnp.int32) * self.g_per_group,
            "w0": jnp.arange(n_groups, dtype=jnp.int32) * self.w_per_group,
        }

        def group_body(carry, xs):
            xc, pools, states = carry
            for j, is_glob in enumerate(self.pattern):
                pl_ = jax.tree.map(lambda a, j=j: a[j], xs["p"])
                xc, pools, states = self._chunk_block(
                    pl_, xc, positions, is_glob, pools, states,
                    xs["l0"] + j, xs["g0"] + self._g_off[j],
                    xs["w0"] + self._w_off[j])
            return (xc, pools, states), None

        (x, pools, states), _ = jax.lax.scan(
            group_body, (x, pools, states), idx)

        updates: Dict[str, Any] = dict(pools)
        updates.update(states)
        updates["lengths"] = jax.lax.dynamic_update_slice(
            cache.lengths, jnp.reshape(end, (1,)).astype(cache.lengths.dtype),
            (slot,))
        if cache.page_pos_w is not None:
            NPw = cache.page_pos_w.shape[1]
            vals = paged_kv.window_page_positions_dyn(end, NPw, T)
            updates["page_pos_w"] = jax.lax.dynamic_update_slice(
                cache.page_pos_w, vals[None], (slot, zero))
        cache = dataclasses.replace(cache, **updates)
        x_last = jax.lax.dynamic_slice_in_dim(x, v_len - 1, 1, 1)
        logits = lm_head_logits(params, cfg, x_last)[:, 0]
        return logits, cache

    def _chunk_past_partial(self, pools, kname, vname, ksname, vsname, idx,
                            q, base, window, trow=None):
        """Past-context partial of the chunk queries vs the slot's pages.

        Stripe layout slices the slot's private stripe; shared pools pass
        the layer's GLOBAL pool plus the slot's table row (`trow`)."""
        ck = self._ck
        fmt = self.eng.kv_quant
        from repro.kernels.paged_attention import paged_chunk_attention
        if ck["shared"]:
            kp = self._layer_slice(pools[kname], idx)     # [K, P, Ts, dh]
            vp = self._layer_slice(pools[vname], idx)
            ks = vs = None
            if fmt != "none":
                ks = self._layer_slice(pools[ksname], idx)
                vs = self._layer_slice(pools[vsname], idx)
            return paged_chunk_attention(
                q, kp, vp, base, ck["start"], ck["q_pos"], window=window,
                impl=self.eng.attn_impl, kv_quant=fmt, k_scale=ks,
                v_scale=vs, page_table=trow[None],
                partitions=self.eng.attn_partitions)
        Lp, B, K, NP, Ts, dh = pools[kname].shape
        zero = jnp.zeros((), jnp.int32)
        pidx = (idx, ck["slot"], zero, zero, zero, zero)
        kp = jax.lax.dynamic_slice(pools[kname], pidx,
                                   (1, 1, K, NP, Ts, dh))[0]
        vp = jax.lax.dynamic_slice(pools[vname], pidx,
                                   (1, 1, K, NP, Ts, dh))[0]
        ks = vs = None
        if fmt != "none":
            sidx = pidx[:4]
            ks = jax.lax.dynamic_slice(pools[ksname], sidx, (1, 1, K, NP))[0]
            vs = jax.lax.dynamic_slice(pools[vsname], sidx, (1, 1, K, NP))[0]
        if ck["mesh_on"] and ck["plan"].page_axes_g:
            return seqpar.sharded_chunk_attention(
                q, kp, vp, base, ck["start"], ck["q_pos"], self.mesh,
                window=window, page_axes=ck["plan"].page_axes_g,
                impl=self.eng.attn_impl, kv_quant=fmt,
                k_scale=ks, v_scale=vs,
                partitions=self.eng.attn_partitions)
        return paged_chunk_attention(
            q, kp, vp, base, ck["start"], ck["q_pos"], window=window,
            impl=self.eng.attn_impl, kv_quant=fmt, k_scale=ks, v_scale=vs,
            partitions=self.eng.attn_partitions)

    def _chunk_block(self, pl_, x, positions, is_glob, pools, states,
                     l_idx, g_idx, w_idx):
        cfg, rt = self.cfg, self.rt
        ck = self._ck

        if cfg.family == "ssm":
            return self._rwkv_chunk_block(pl_, x, pools, states, l_idx)

        h = rms_norm(x, pl_["ln1"], cfg.norm_eps)
        q, k, v = attn_mod.project_qkv(pl_["attn"], cfg, h, positions)
        use_window = (cfg.window is not None) and not is_glob
        window = cfg.window if use_window else None
        scale = cfg.d_head ** -0.5

        # in-chunk causal partial over the chunk's own (full-precision) K/V
        o, m, l = seqpar._attn_block_partial(
            q, k, v, ck["q_pos"], ck["start"], causal=True, window=window,
            is_global=None, scale=scale)
        if not ck["first"]:
            # past-context partial from the already-written pages
            if use_window:
                o2, m2, l2 = self._chunk_past_partial(
                    pools, "k_pages_w", "v_pages_w", "k_scale_w",
                    "v_scale_w", w_idx, q, ck["pos_w"], window,
                    trow=ck.get("trow_w"))
            else:
                o2, m2, l2 = self._chunk_past_partial(
                    pools, "k_pages_g", "v_pages_g", "k_scale_g",
                    "v_scale_g", g_idx, q, ck["base_g"], None,
                    trow=ck.get("trow_g"))
            o, m, l = seqpar.merge_two(o, m, l, o2, m2, l2)
        aout = attn_mod.project_out(pl_["attn"], cfg, o.astype(h.dtype))

        # fill the chunk's K/V into the slot's pages (whole pages, in place)
        fmt = self.eng.kv_quant
        if use_window:
            names = ("k_pages_w", "v_pages_w", "k_scale_w", "v_scale_w")
            fill_idx, fill = w_idx, paged_kv.fill_chunk_window_at
            fill_sh, trow = paged_kv.fill_chunk_window_at_shared, \
                ck.get("trow_w")
        else:
            names = ("k_pages_g", "v_pages_g", "k_scale_g", "v_scale_g")
            fill_idx, fill = g_idx, paged_kv.fill_chunk_global_at
            fill_sh, trow = paged_kv.fill_chunk_global_at_shared, \
                ck.get("trow_g")
        for prefix_, kv_seq in (("k", k), ("v", v)):
            name = names[0] if prefix_ == "k" else names[1]
            sname = names[2] if prefix_ == "k" else names[3]
            if ck["mesh_on"] and ck["plan"].page_axes_g and not use_window:
                out = seqpar.sharded_chunk_fill(
                    pools[name], kv_seq, fill_idx, ck["slot"], ck["page0"],
                    ck["v_len"], self.mesh,
                    batch_axes=ck["plan"].batch_axes,
                    page_axes=ck["plan"].page_axes_g,
                    scale=pools.get(sname), kv_quant=fmt)
            elif ck["shared"]:
                out = fill_sh(pools[name], kv_seq, fill_idx, trow,
                              ck["page0"], ck["v_len"],
                              scale=pools.get(sname), kv_quant=fmt)
            else:
                out = fill(pools[name], kv_seq, fill_idx, ck["slot"],
                           ck["page0"], ck["v_len"],
                           scale=pools.get(sname), kv_quant=fmt)
            if fmt != "none":
                pools[name], pools[sname] = out
            else:
                pools[name] = out

        if cfg.family == "hybrid":
            Hs = states["ssm_state"].shape
            Ts_ = states["conv_tail"].shape
            if ck["first"]:
                s0 = jnp.zeros((1,) + Hs[2:], jnp.float32)
                t0 = jnp.zeros((1,) + Ts_[2:], states["conv_tail"].dtype)
            else:
                s0 = jax.lax.dynamic_slice(
                    states["ssm_state"], (l_idx, ck["slot"], 0, 0),
                    (1, 1) + Hs[2:])[0]
                t0 = jax.lax.dynamic_slice(
                    states["conv_tail"], (l_idx, ck["slot"], 0, 0),
                    (1, 1) + Ts_[2:])[0]
            sout, s_new, tail_new = ssm_mod.ssm_mixer(
                pl_["ssm"], cfg, h, s0, t0)
            aout = (aout + sout) * 0.5
            states["ssm_state"] = jax.lax.dynamic_update_slice(
                states["ssm_state"], s_new[None].astype(jnp.float32),
                (l_idx, ck["slot"], 0, 0))
            states["conv_tail"] = jax.lax.dynamic_update_slice(
                states["conv_tail"],
                tail_new[None].astype(states["conv_tail"].dtype),
                (l_idx, ck["slot"], 0, 0))
        x = x + aout

        h = rms_norm(x, pl_["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            ff = moe(pl_["moe"], h, top_k=cfg.top_k,
                     capacity_factor=rt.moe_capacity)
        else:
            ff = mlp(pl_["mlp"], h, cfg.gated_mlp)
        return x + ff, pools, states

    def _rwkv_chunk_block(self, pl_, x, pools, states, l_idx):
        cfg = self.cfg
        ck = self._ck
        h = rms_norm(x, pl_["ln1"], cfg.norm_eps)
        Hs = states["rwkv_state"].shape
        if ck["first"]:
            st0 = jnp.zeros((1,) + Hs[2:], jnp.float32)
            sh0 = jnp.zeros((1, cfg.d_model), h.dtype)
            sh2 = jnp.zeros((1, cfg.d_model), h.dtype)
        else:
            st0 = jax.lax.dynamic_slice(
                states["rwkv_state"], (l_idx, ck["slot"], 0, 0, 0),
                (1, 1) + Hs[2:])[0]
            sh0 = jax.lax.dynamic_slice(
                states["rwkv_shift"], (l_idx, ck["slot"], 0),
                (1, 1, cfg.d_model))[0].astype(h.dtype)
            sh2 = jax.lax.dynamic_slice(
                states["rwkv_shift2"], (l_idx, ck["slot"], 0),
                (1, 1, cfg.d_model))[0].astype(h.dtype)
        tout, s_new, shift_new = rwkv_mod.rwkv_timemix(
            pl_["tmix"], cfg, h, st0, sh0)
        x = x + tout
        h = rms_norm(x, pl_["ln2"], cfg.norm_eps)
        cm = pl_["cmix"]
        h_prev = jnp.concatenate([sh2[:, None], h[:, :-1]], axis=1)
        xk = h + (h_prev - h) * cm["mu_k"].astype(h.dtype)
        xr = h + (h_prev - h) * cm["mu_r"].astype(h.dtype)
        kk = jnp.square(jax.nn.relu(dense(cm, "ck", xk)))
        vv = dense(cm, "cv", kk)
        rr = jax.nn.sigmoid(dense(cm, "cr", xr))
        x = x + rr * vv
        states["rwkv_state"] = jax.lax.dynamic_update_slice(
            states["rwkv_state"], s_new[None].astype(jnp.float32),
            (l_idx, ck["slot"], 0, 0, 0))
        states["rwkv_shift"] = jax.lax.dynamic_update_slice(
            states["rwkv_shift"],
            shift_new[None].astype(states["rwkv_shift"].dtype),
            (l_idx, ck["slot"], 0))
        states["rwkv_shift2"] = jax.lax.dynamic_update_slice(
            states["rwkv_shift2"],
            h[:, -1][None].astype(states["rwkv_shift2"].dtype),
            (l_idx, ck["slot"], 0))
        return x, pools, states
