"""Page-level KV cache (paper §IV-D) adapted to TPU sharding.

Layout is (layer, head)-major exactly as Fig 11(b): pages never mix layers or
heads, so the paged-attention kernel streams whole pages HBM→VMEM with full
spatial locality — the TPU analogue of eliminating flash page-read
amplification.

  k_pages / v_pages : [L, B, K, NP, T, dh]
      L  stacked layers (scanned)        B  sequences (sharded over `data`)
      K  kv heads                        NP pages per sequence (sharded over
      T  page_tokens                        `model` — the paper's G2 dies)

Two page pools per model when the arch mixes attention spans:
  * global pool — NP covers the full context;
  * window pool — NP covers only the sliding window, recycled as a ring
    (the paper's "access-aware block allocation": stale pages are retired
    and their slots reused, bounding both capacity and — in flash terms —
    read-disturb accumulation).

`page_table` gives the logical→physical indirection inside each sequence's
stripe (the FTL analogue); `page_pos` records each physical page's base
token position so window validity is derived from data, not control flow.

Recurrent families store O(1) state instead (rwkv/ssm fields); hybrids carry
both; encoder-decoder carries precomputed cross-attention K/V.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EngineConfig, ModelConfig
from repro.models import rwkv6 as rwkv_mod
from repro.models import ssm as ssm_mod


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# Layer grouping: smallest repeating local/global period (scan-friendly)
# ---------------------------------------------------------------------------

def layer_pattern(cfg: ModelConfig) -> Tuple[int, Tuple[bool, ...]]:
    """Returns (period, pattern) with pattern[i] == layer i is global."""
    flags = tuple(cfg.is_global_layer(i) for i in range(cfg.n_layers))
    for p in range(1, cfg.n_layers + 1):
        if cfg.n_layers % p:
            continue
        if all(flags[i] == flags[i % p] for i in range(cfg.n_layers)):
            return p, flags[:p]
    return cfg.n_layers, flags


# ---------------------------------------------------------------------------
# Cache container
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass
class DecodeCache:
    """Pytree of per-request decode state (all leaves optional)."""
    # paged attention KV — global-span layers
    k_pages_g: Optional[jax.Array] = None   # [Lg, B, K, NPg, T, dh]
    v_pages_g: Optional[jax.Array] = None
    page_table_g: Optional[jax.Array] = None  # [B, NPg] logical -> physical
    # paged attention KV — sliding-window layers (ring-recycled)
    k_pages_w: Optional[jax.Array] = None   # [Lw, B, K, NPw, T, dh]
    v_pages_w: Optional[jax.Array] = None
    page_pos_w: Optional[jax.Array] = None  # [B, NPw] base token position
    # recurrent state
    rwkv_state: Optional[jax.Array] = None  # [L, B, H, dh, dh]
    rwkv_shift: Optional[jax.Array] = None  # [L, B, D] time-mix token shift
    rwkv_shift2: Optional[jax.Array] = None  # [L, B, D] channel-mix shift
    ssm_state: Optional[jax.Array] = None   # [L, B, D, N]
    conv_tail: Optional[jax.Array] = None   # [L, B, CONV_K-1, D]
    # encoder-decoder cross attention (read-only after prefill)
    cross_k: Optional[jax.Array] = None     # [L, B, Senc, K, dh]
    cross_v: Optional[jax.Array] = None
    # bookkeeping
    lengths: Optional[jax.Array] = None     # [B] tokens written so far


def _n_layers_split(cfg: ModelConfig) -> Tuple[int, int]:
    n_global = sum(cfg.is_global_layer(i) for i in range(cfg.n_layers))
    return n_global, cfg.n_layers - n_global


def cache_spec(cfg: ModelConfig, eng: EngineConfig, batch: int,
               max_context: int, *, dtype=jnp.bfloat16,
               enc_len: int = 0, page_shards_g: int = 1,
               page_shards_w: int = 1) -> Dict[str, Any]:
    """Abstract shapes for every cache leaf of this (arch, context).

    page_shards_*: round each pool's page count up to a multiple of the
    number of mesh shards holding the page axis.
    """
    T = eng.page_tokens
    K, dh, D = cfg.n_kv_heads, cfg.d_head, cfg.d_model
    Lg, Lw = _n_layers_split(cfg)
    spec: Dict[str, Any] = {}

    def round_np(np_raw: int, shards: int) -> int:
        return max(ceil_div(np_raw, shards), 1) * shards

    has_attn = cfg.family != "ssm"
    if has_attn:
        if Lg:
            NPg = eng.max_pages_per_seq or ceil_div(max_context, T)
            NPg = round_np(NPg, page_shards_g)
            spec["k_pages_g"] = ((Lg, batch, K, NPg, T, dh), dtype)
            spec["v_pages_g"] = ((Lg, batch, K, NPg, T, dh), dtype)
            spec["page_table_g"] = ((batch, NPg), jnp.int32)
        if Lw:
            NPw = round_np(ceil_div(cfg.window, T) + 1, page_shards_w)
            spec["k_pages_w"] = ((Lw, batch, K, NPw, T, dh), dtype)
            spec["v_pages_w"] = ((Lw, batch, K, NPw, T, dh), dtype)
            spec["page_pos_w"] = ((batch, NPw), jnp.int32)
    if cfg.family == "ssm":
        H = cfg.n_heads
        spec["rwkv_state"] = ((cfg.n_layers, batch, H, dh, dh), jnp.float32)
        spec["rwkv_shift"] = ((cfg.n_layers, batch, D), dtype)
        spec["rwkv_shift2"] = ((cfg.n_layers, batch, D), dtype)
    if cfg.family == "hybrid":
        spec["ssm_state"] = ((cfg.n_layers, batch, D, cfg.ssm_state),
                             jnp.float32)
        spec["conv_tail"] = ((cfg.n_layers, batch, ssm_mod.CONV_K - 1, D),
                             dtype)
    if cfg.is_encoder_decoder and enc_len:
        spec["cross_k"] = ((cfg.n_layers, batch, enc_len, K, dh), dtype)
        spec["cross_v"] = ((cfg.n_layers, batch, enc_len, K, dh), dtype)
    spec["lengths"] = ((batch,), jnp.int32)
    return spec


CACHE_AXES: Dict[str, Tuple] = {
    # logical axes per leaf (mapped by distributed.sharding rules)
    "k_pages_g": ("layer", "batch", None, "kv_pages", None, None),
    "v_pages_g": ("layer", "batch", None, "kv_pages", None, None),
    "page_table_g": ("batch", None),
    "k_pages_w": ("layer", "batch", None, "kv_pages", None, None),
    "v_pages_w": ("layer", "batch", None, "kv_pages", None, None),
    "page_pos_w": ("batch", None),
    "rwkv_state": ("layer", "batch", None, None, None),
    "rwkv_shift": ("layer", "batch", "embed"),
    "rwkv_shift2": ("layer", "batch", "embed"),
    "ssm_state": ("layer", "batch", None, None),
    "conv_tail": ("layer", "batch", None, "embed"),
    "cross_k": ("layer", "batch", "act_seq", None, None),
    "cross_v": ("layer", "batch", "act_seq", None, None),
    "lengths": ("batch",),
}


def abstract_cache(cfg: ModelConfig, eng: EngineConfig, batch: int,
                   max_context: int, *, dtype=jnp.bfloat16,
                   enc_len: int = 0, page_shards_g: int = 1,
                   page_shards_w: int = 1) -> DecodeCache:
    spec = cache_spec(cfg, eng, batch, max_context, dtype=dtype,
                      enc_len=enc_len, page_shards_g=page_shards_g,
                      page_shards_w=page_shards_w)
    return DecodeCache(**{k: jax.ShapeDtypeStruct(s, d)
                          for k, (s, d) in spec.items()})


def init_cache(cfg: ModelConfig, eng: EngineConfig, batch: int,
               max_context: int, *, dtype=jnp.bfloat16,
               enc_len: int = 0, page_shards_g: int = 1,
               page_shards_w: int = 1) -> DecodeCache:
    spec = cache_spec(cfg, eng, batch, max_context, dtype=dtype,
                      enc_len=enc_len, page_shards_g=page_shards_g,
                      page_shards_w=page_shards_w)
    leaves = {}
    for k, (shape, dt) in spec.items():
        if k == "page_table_g":
            leaves[k] = jnp.broadcast_to(
                jnp.arange(shape[1], dtype=jnp.int32)[None], shape)
        elif k == "page_pos_w":
            leaves[k] = jnp.full(shape, -(10 ** 9), jnp.int32)
        else:
            leaves[k] = jnp.zeros(shape, dt)
    return DecodeCache(**leaves)


def cache_logical_axes(cache: DecodeCache) -> DecodeCache:
    """Mirror of the cache with logical-axis tuples (None leaves preserved)."""
    return DecodeCache(**{
        f.name: (CACHE_AXES[f.name]
                 if getattr(cache, f.name) is not None else None)
        for f in dataclasses.fields(cache)})


# ---------------------------------------------------------------------------
# Page write paths (token append / bulk prefill fill)
# ---------------------------------------------------------------------------

def append_global(k_pages, v_pages, page_table, lengths, k_new, v_new):
    """Append one token's K/V into the global page pool of ONE layer.

    k_pages/v_pages: [B, K, NP, T, dh]; k_new/v_new: [B, K, dh];
    lengths: [B] (current position).  Returns updated pages.
    """
    T = k_pages.shape[3]
    logical = lengths // T                                    # [B]
    slot = lengths % T
    phys = jnp.take_along_axis(page_table, logical[:, None], axis=1)[:, 0]
    b_idx = jnp.arange(k_pages.shape[0])
    k_pages = k_pages.at[b_idx, :, phys, slot].set(
        k_new.astype(k_pages.dtype), mode="drop")
    v_pages = v_pages.at[b_idx, :, phys, slot].set(
        v_new.astype(v_pages.dtype), mode="drop")
    return k_pages, v_pages


def append_window(k_pages, v_pages, page_pos, lengths, k_new, v_new):
    """Ring append for window layers; also refreshes page base positions.

    Page recycling: physical page = (t // T) mod NP (the retired page's
    slot is reused — the paper's block-reclaim analogue).
    """
    B, K, NP, T, dh = k_pages.shape
    phys = (lengths // T) % NP                                # [B]
    slot = lengths % T
    b_idx = jnp.arange(B)
    k_pages = k_pages.at[b_idx, :, phys, slot].set(
        k_new.astype(k_pages.dtype), mode="drop")
    v_pages = v_pages.at[b_idx, :, phys, slot].set(
        v_new.astype(v_pages.dtype), mode="drop")
    base = lengths - slot
    new_pos = page_pos.at[b_idx, phys].set(base, mode="drop")
    page_pos = jnp.where((slot == 0)[:, None],
                         new_pos, page_pos)
    return k_pages, v_pages, page_pos


def fill_prefill_at(pool, kv_seq, layer):
    """Bulk-write prefill K/V into ONE layer of a stacked global pool.

    pool: [L, B, K, NP, T, dh] (in-place carry); kv_seq: [B, S, K, dh];
    layer: traced index.  S tokens land in the first ceil(S/T) pages.
    """
    B, S, K, dh = kv_seq.shape
    T, NP = pool.shape[4], pool.shape[3]
    n_pages = ceil_div(S, T)
    pad = n_pages * T - S
    x = jnp.pad(kv_seq, ((0, 0), (0, pad), (0, 0), (0, 0)))
    x = x.reshape(B, n_pages, T, K, dh).transpose(0, 3, 1, 2, 4)
    zero = jnp.zeros((), jnp.int32)
    return jax.lax.dynamic_update_slice(
        pool, x[None].astype(pool.dtype),
        (layer, zero, zero, zero, zero, zero))


def fill_window_at(pool, kv_seq, layer):
    """Bulk-write the newest ring pages into ONE layer of a window pool."""
    B, S, K, dh = kv_seq.shape
    NP, T = pool.shape[3], pool.shape[4]
    n_src = ceil_div(S, T)
    pad = n_src * T - S
    x = jnp.pad(kv_seq, ((0, 0), (0, pad), (0, 0), (0, 0)))
    x = x.reshape(B, n_src, T, K, dh).transpose(0, 3, 1, 2, 4)
    for sp in range(max(0, n_src - NP), n_src):               # static loop
        pool = pool.at[layer, :, :, sp % NP].set(
            x[:, :, sp].astype(pool.dtype))
    return pool


def fill_from_prefill(k_pages, kv_seq, page_table=None):
    """Bulk-write prefill K/V [B, S, K, dh] into pages [B, K, NP, T, dh].

    S tokens land in the first ceil(S/T) logical pages in order (page_table
    is identity at prefill time).
    """
    B, S, K, dh = kv_seq.shape
    T = k_pages.shape[3]
    NP = k_pages.shape[2]
    n_pages = ceil_div(S, T)
    pad = n_pages * T - S
    x = jnp.pad(kv_seq, ((0, 0), (0, pad), (0, 0), (0, 0)))
    x = x.reshape(B, n_pages, T, K, dh).transpose(0, 3, 1, 2, 4)
    return jax.lax.dynamic_update_slice(
        k_pages, x.astype(k_pages.dtype), (0, 0, 0, 0, 0))


def fill_window(k_pages, kv_seq):
    """Bulk-write the newest ring pages from prefill K/V.

    k_pages: [B, K, NP, T, dh] ring pool; kv_seq: [B, S, K, dh].  Only the
    newest NP source pages land (older ones are already outside any window);
    ring slot = source_page mod NP.  Returns updated pages (base positions
    are computed statically by the engine).
    """
    B, S, K, dh = kv_seq.shape
    _, _, NP, T, _ = k_pages.shape
    n_src = ceil_div(S, T)
    pad = n_src * T - S
    x = jnp.pad(kv_seq, ((0, 0), (0, pad), (0, 0), (0, 0)))
    x = x.reshape(B, n_src, T, K, dh).transpose(0, 3, 1, 2, 4)
    kp = k_pages
    for sp in range(max(0, n_src - NP), n_src):               # static loop
        kp = kp.at[:, :, sp % NP].set(x[:, :, sp].astype(kp.dtype))
    return kp


def window_page_positions(S: int, NP: int, T: int) -> np.ndarray:
    """Static ring base positions after prefilling S tokens (-1e9 = empty)."""
    vals = np.full((NP,), -(10 ** 9), np.int64)
    n_src = ceil_div(S, T)
    for sp in range(max(0, n_src - NP), n_src):
        vals[sp % NP] = sp * T
    return vals.astype(np.int32)
