"""Page-level KV cache (paper §IV-D) adapted to TPU sharding.

Layout is (layer, head)-major exactly as Fig 11(b): pages never mix layers or
heads, so the paged-attention kernel streams whole pages HBM→VMEM with full
spatial locality — the TPU analogue of eliminating flash page-read
amplification.

Two physical layouts share every read/write path:

  stripe (default)            shared pool (EngineConfig.shared_pool)
  k/v_pages: [L, B, K, NP, T, dh]   k/v_pages: [L, K, P_total, T, dh]
      L  stacked layers (scanned)        B  sequences (sharded over `data`)
      K  kv heads                        NP logical pages per sequence
      T  page_tokens                     P_total pool pages (sharded over
                                           `model` — the paper's G2 dies)

In the stripe layout each slot owns a private run of NP physical pages
sized to max_context; `page_table` permutes only within the stripe.  In
the SHARED layout (the paper's §IV-D FTL mapping proper) all slots draw
pages from one pool per layer-group: `page_table_g/_w: [B, NP] -> phys`
hold global physical indices handed out by the host-side free-page
allocator (`core/page_alloc.py`), so a 128-token request holds 2 pages
while a 100K-token one holds thousands — admission is bounded by actual
KV footprint, prefixes can be shared copy-on-write, and unallocated
logical pages stay data-invalid (their token positions lie beyond
`lengths`).

Two page pools per model when the arch mixes attention spans:
  * global pool — NP covers the full context;
  * window pool — NP covers only the sliding window, recycled as a ring
    (the paper's "access-aware block allocation": stale pages are retired
    and their slots reused, bounding both capacity and — in flash terms —
    read-disturb accumulation).

`page_pos` records each physical page's base token position so window
validity is derived from data, not control flow.

The writer family, layout by layout: one-shot/chunk fills
(`fill_layer`, `fill_chunk_*`), single-token appends (`append_*`,
`append_token_quant*`), and the accept-gated multi-token span appends
(`append_span*`) that speculative verification uses — every write path
shares the same drop-sentinel convention, so an out-of-range physical
index discards the write instead of corrupting a live page.

Recurrent families store O(1) state instead (rwkv/ssm fields); hybrids carry
both; encoder-decoder carries precomputed cross-attention K/V.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EngineConfig, ModelConfig
from repro.core import quant
from repro.models import ssm as ssm_mod


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def pool_page_count(pool_leaf, shared: bool) -> int:
    """Physical pages of a k/v pool leaf: the page axis sits at index 2
    in the shared layout [L, K, P, T, dh], index 3 in the stripe layout
    [L, B, K, NP, T, dh]; 1 when the arch has no such pool."""
    if pool_leaf is None:
        return 1
    return pool_leaf.shape[2 if shared else 3]


# ---------------------------------------------------------------------------
# Layer grouping: smallest repeating local/global period (scan-friendly)
# ---------------------------------------------------------------------------

def layer_pattern(cfg: ModelConfig) -> Tuple[int, Tuple[bool, ...]]:
    """Returns (period, pattern) with pattern[i] == layer i is global."""
    flags = tuple(cfg.is_global_layer(i) for i in range(cfg.n_layers))
    for p in range(1, cfg.n_layers + 1):
        if cfg.n_layers % p:
            continue
        if all(flags[i] == flags[i % p] for i in range(cfg.n_layers)):
            return p, flags[:p]
    return cfg.n_layers, flags


# ---------------------------------------------------------------------------
# Cache container
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclass
class DecodeCache:
    """Pytree of per-request decode state (all leaves optional)."""
    # paged attention KV — global-span layers
    k_pages_g: Optional[jax.Array] = None   # [Lg, B, K, NPg, T, dh]
    v_pages_g: Optional[jax.Array] = None   # (shared: [Lg, K, Pg, T, dh])
    page_table_g: Optional[jax.Array] = None  # [B, NPg] logical -> physical
    # paged attention KV — sliding-window layers (ring-recycled)
    k_pages_w: Optional[jax.Array] = None   # [Lw, B, K, NPw, T, dh]
    v_pages_w: Optional[jax.Array] = None   # (shared: [Lw, K, Pw, T, dh])
    page_table_w: Optional[jax.Array] = None  # [B, NPw] ring slot -> physical
    page_pos_w: Optional[jax.Array] = None  # [B, NPw] base token position
    # per-page × per-kv-head dequant scales (kv8/kv4 pools only)
    # (shared: [Lg, K, Pg] — one scale vector per physical pool page)
    k_scale_g: Optional[jax.Array] = None   # [Lg, B, K, NPg] f32
    v_scale_g: Optional[jax.Array] = None
    k_scale_w: Optional[jax.Array] = None   # [Lw, B, K, NPw] f32
    v_scale_w: Optional[jax.Array] = None
    # recurrent state
    rwkv_state: Optional[jax.Array] = None  # [L, B, H, dh, dh]
    rwkv_shift: Optional[jax.Array] = None  # [L, B, D] time-mix token shift
    rwkv_shift2: Optional[jax.Array] = None  # [L, B, D] channel-mix shift
    ssm_state: Optional[jax.Array] = None   # [L, B, D, N]
    conv_tail: Optional[jax.Array] = None   # [L, B, CONV_K-1, D]
    # encoder-decoder cross attention (read-only after prefill)
    cross_k: Optional[jax.Array] = None     # [L, B, Senc, K, dh]
    cross_v: Optional[jax.Array] = None
    # bookkeeping
    lengths: Optional[jax.Array] = None     # [B] tokens written so far


def _n_layers_split(cfg: ModelConfig) -> Tuple[int, int]:
    n_global = sum(cfg.is_global_layer(i) for i in range(cfg.n_layers))
    return n_global, cfg.n_layers - n_global


def cache_spec(cfg: ModelConfig, eng: EngineConfig, batch: int,
               max_context: int, *, dtype=jnp.bfloat16,
               enc_len: int = 0, page_shards_g: int = 1,
               page_shards_w: int = 1) -> Dict[str, Any]:
    """Abstract shapes for every cache leaf of this (arch, context).

    page_shards_*: round each pool's page count up to a multiple of the
    number of mesh shards holding the page axis.
    """
    T = eng.page_tokens
    K, dh, D = cfg.n_kv_heads, cfg.d_head, cfg.d_model
    Lg, Lw = _n_layers_split(cfg)
    spec: Dict[str, Any] = {}

    def round_np(np_raw: int, shards: int) -> int:
        return max(ceil_div(np_raw, shards), 1) * shards

    # quantized pools store packed int codes + per-page×head f32 scales
    fmt = eng.kv_quant
    if fmt != "none":
        Ts = quant.kv_page_tokens_stored(T, fmt)
        pool_dt = quant.kv_storage_dtype(fmt)
    else:
        Ts, pool_dt = T, dtype

    has_attn = cfg.family != "ssm"
    if has_attn:
        if Lg:
            NPg = eng.max_pages_per_seq or ceil_div(max_context, T)
            NPg = round_np(NPg, page_shards_g)
            if eng.shared_pool:
                # tiered hierarchy (DESIGN.md §13): only the HOT tier is
                # device-resident — the flash-total page count lives in
                # the allocator, not in this pool.
                Pg_flash = eng.total_pages or batch * NPg
                Pg = round_np(eng.hot_pages or Pg_flash, page_shards_g)
                spec["k_pages_g"] = ((Lg, K, Pg, Ts, dh), pool_dt)
                spec["v_pages_g"] = ((Lg, K, Pg, Ts, dh), pool_dt)
                if fmt != "none":
                    spec["k_scale_g"] = ((Lg, K, Pg), jnp.float32)
                    spec["v_scale_g"] = ((Lg, K, Pg), jnp.float32)
            else:
                spec["k_pages_g"] = ((Lg, batch, K, NPg, Ts, dh), pool_dt)
                spec["v_pages_g"] = ((Lg, batch, K, NPg, Ts, dh), pool_dt)
                if fmt != "none":
                    spec["k_scale_g"] = ((Lg, batch, K, NPg), jnp.float32)
                    spec["v_scale_g"] = ((Lg, batch, K, NPg), jnp.float32)
            spec["page_table_g"] = ((batch, NPg), jnp.int32)
        if Lw:
            NPw = round_np(ceil_div(cfg.window, T) + 1, page_shards_w)
            if eng.shared_pool:
                Pw = round_np(eng.total_pages_w or batch * NPw,
                              page_shards_w)
                spec["k_pages_w"] = ((Lw, K, Pw, Ts, dh), pool_dt)
                spec["v_pages_w"] = ((Lw, K, Pw, Ts, dh), pool_dt)
                spec["page_table_w"] = ((batch, NPw), jnp.int32)
                if fmt != "none":
                    spec["k_scale_w"] = ((Lw, K, Pw), jnp.float32)
                    spec["v_scale_w"] = ((Lw, K, Pw), jnp.float32)
            else:
                spec["k_pages_w"] = ((Lw, batch, K, NPw, Ts, dh), pool_dt)
                spec["v_pages_w"] = ((Lw, batch, K, NPw, Ts, dh), pool_dt)
                if fmt != "none":
                    spec["k_scale_w"] = ((Lw, batch, K, NPw), jnp.float32)
                    spec["v_scale_w"] = ((Lw, batch, K, NPw), jnp.float32)
            spec["page_pos_w"] = ((batch, NPw), jnp.int32)
    if cfg.family == "ssm":
        H = cfg.n_heads
        spec["rwkv_state"] = ((cfg.n_layers, batch, H, dh, dh), jnp.float32)
        spec["rwkv_shift"] = ((cfg.n_layers, batch, D), dtype)
        spec["rwkv_shift2"] = ((cfg.n_layers, batch, D), dtype)
    if cfg.family == "hybrid":
        spec["ssm_state"] = ((cfg.n_layers, batch, D, cfg.ssm_state),
                             jnp.float32)
        spec["conv_tail"] = ((cfg.n_layers, batch, ssm_mod.CONV_K - 1, D),
                             dtype)
    if cfg.is_encoder_decoder and enc_len:
        spec["cross_k"] = ((cfg.n_layers, batch, enc_len, K, dh), dtype)
        spec["cross_v"] = ((cfg.n_layers, batch, enc_len, K, dh), dtype)
    spec["lengths"] = ((batch,), jnp.int32)
    return spec


CACHE_AXES: Dict[str, Tuple] = {
    # logical axes per leaf (mapped by distributed.sharding rules)
    "k_pages_g": ("layer", "batch", None, "kv_pages", None, None),
    "v_pages_g": ("layer", "batch", None, "kv_pages", None, None),
    "page_table_g": ("batch", None),
    "k_pages_w": ("layer", "batch", None, "kv_pages", None, None),
    "v_pages_w": ("layer", "batch", None, "kv_pages", None, None),
    "page_table_w": ("batch", None),
    "page_pos_w": ("batch", None),
    "k_scale_g": ("layer", "batch", None, "kv_pages"),
    "v_scale_g": ("layer", "batch", None, "kv_pages"),
    "k_scale_w": ("layer", "batch", None, "kv_pages"),
    "v_scale_w": ("layer", "batch", None, "kv_pages"),
    "rwkv_state": ("layer", "batch", None, None, None),
    "rwkv_shift": ("layer", "batch", "embed"),
    "rwkv_shift2": ("layer", "batch", "embed"),
    "ssm_state": ("layer", "batch", None, None),
    "conv_tail": ("layer", "batch", None, "embed"),
    "cross_k": ("layer", "batch", "act_seq", None, None),
    "cross_v": ("layer", "batch", "act_seq", None, None),
    "lengths": ("batch",),
}

# shared-pool leaves drop the batch dim: the physical page axis carries the
# `kv_pages` (model) sharding instead of a per-slot stripe
SHARED_CACHE_AXES: Dict[str, Tuple] = {
    "k_pages_g": ("layer", None, "kv_pages", None, None),
    "v_pages_g": ("layer", None, "kv_pages", None, None),
    "k_pages_w": ("layer", None, "kv_pages", None, None),
    "v_pages_w": ("layer", None, "kv_pages", None, None),
    "k_scale_g": ("layer", None, "kv_pages"),
    "v_scale_g": ("layer", None, "kv_pages"),
    "k_scale_w": ("layer", None, "kv_pages"),
    "v_scale_w": ("layer", None, "kv_pages"),
}


def abstract_cache(cfg: ModelConfig, eng: EngineConfig, batch: int,
                   max_context: int, *, dtype=jnp.bfloat16,
                   enc_len: int = 0, page_shards_g: int = 1,
                   page_shards_w: int = 1) -> DecodeCache:
    spec = cache_spec(cfg, eng, batch, max_context, dtype=dtype,
                      enc_len=enc_len, page_shards_g=page_shards_g,
                      page_shards_w=page_shards_w)
    return DecodeCache(**{k: jax.ShapeDtypeStruct(s, d)
                          for k, (s, d) in spec.items()})


def init_cache(cfg: ModelConfig, eng: EngineConfig, batch: int,
               max_context: int, *, dtype=jnp.bfloat16,
               enc_len: int = 0, page_shards_g: int = 1,
               page_shards_w: int = 1) -> DecodeCache:
    spec = cache_spec(cfg, eng, batch, max_context, dtype=dtype,
                      enc_len=enc_len, page_shards_g=page_shards_g,
                      page_shards_w=page_shards_w)
    leaves = {}
    shared = eng.shared_pool
    for k, (shape, dt) in spec.items():
        if k in ("page_table_g", "page_table_w"):
            B, NP = shape
            if shared:
                # identity stripes mod pool size: slot b's logical page j
                # starts on physical page b·NP + j (the allocator-free
                # default used by one-shot prefill and parity tests; the
                # scheduler overwrites tables from its allocator)
                pool_key = "k_pages_g" if k == "page_table_g" else \
                    "k_pages_w"
                P = spec[pool_key][0][2]
                rows = (jnp.arange(B, dtype=jnp.int32)[:, None] * NP
                        + jnp.arange(NP, dtype=jnp.int32)[None]) % P
                leaves[k] = rows
            else:
                leaves[k] = jnp.broadcast_to(
                    jnp.arange(NP, dtype=jnp.int32)[None], shape)
        elif k == "page_pos_w":
            leaves[k] = jnp.full(shape, -(10 ** 9), jnp.int32)
        else:
            leaves[k] = jnp.zeros(shape, dt)
    return DecodeCache(**leaves)


def cache_logical_axes(cache: DecodeCache) -> DecodeCache:
    """Mirror of the cache with logical-axis tuples (None leaves preserved).

    Shared-pool caches (pool leaves without the batch dim) pick the
    matching-rank axes from SHARED_CACHE_AXES.
    """
    out = {}
    for f in dataclasses.fields(cache):
        leaf = getattr(cache, f.name)
        if leaf is None:
            out[f.name] = None
            continue
        axes = CACHE_AXES[f.name]
        if len(axes) != leaf.ndim:
            axes = SHARED_CACHE_AXES[f.name]
        out[f.name] = axes
    return DecodeCache(**out)


# ---------------------------------------------------------------------------
# Page write paths (token append / bulk prefill fill)
# ---------------------------------------------------------------------------

def append_global(k_pages, v_pages, page_table, lengths, k_new, v_new):
    """Append one token's K/V into the global page pool of ONE layer.

    k_pages/v_pages: [B, K, NP, T, dh]; k_new/v_new: [B, K, dh];
    lengths: [B] (current position).  Returns updated pages.
    """
    T = k_pages.shape[3]
    logical = lengths // T                                    # [B]
    slot = lengths % T
    phys = jnp.take_along_axis(page_table, logical[:, None], axis=1)[:, 0]
    b_idx = jnp.arange(k_pages.shape[0])
    k_pages = k_pages.at[b_idx, :, phys, slot].set(
        k_new.astype(k_pages.dtype), mode="drop")
    v_pages = v_pages.at[b_idx, :, phys, slot].set(
        v_new.astype(v_pages.dtype), mode="drop")
    return k_pages, v_pages


def append_window(k_pages, v_pages, page_pos, lengths, k_new, v_new):
    """Ring append for window layers; also refreshes page base positions.

    Page recycling: physical page = (t // T) mod NP (the retired page's
    slot is reused — the paper's block-reclaim analogue).
    """
    B, K, NP, T, dh = k_pages.shape
    phys = (lengths // T) % NP                                # [B]
    slot = lengths % T
    b_idx = jnp.arange(B)
    k_pages = k_pages.at[b_idx, :, phys, slot].set(
        k_new.astype(k_pages.dtype), mode="drop")
    v_pages = v_pages.at[b_idx, :, phys, slot].set(
        v_new.astype(v_pages.dtype), mode="drop")
    base = lengths - slot
    new_pos = page_pos.at[b_idx, phys].set(base, mode="drop")
    page_pos = jnp.where((slot == 0)[:, None],
                         new_pos, page_pos)
    return k_pages, v_pages, page_pos


def fill_prefill_at(pool, kv_seq, layer):
    """Bulk-write prefill K/V into ONE layer of a stacked global pool.

    pool: [L, B, K, NP, T, dh] (in-place carry); kv_seq: [B, S, K, dh];
    layer: traced index.  S tokens land in the first ceil(S/T) pages.
    (Thin wrapper over `fill_layer`, the unified one-shot/chunk writer.)
    """
    return fill_layer(pool, kv_seq, layer, ring=False)


def fill_window_at(pool, kv_seq, layer):
    """Bulk-write the newest ring pages into ONE layer of a window pool."""
    return fill_layer(pool, kv_seq, layer, ring=True)


def fill_prefill_at_quant(pool, scale, kv_seq, layer, fmt: str):
    """Quantizing variant of `fill_prefill_at` (global pool, one layer)."""
    return fill_layer(pool, kv_seq, layer, ring=False, scale=scale,
                      kv_quant=fmt)


def window_page_positions(S: int, NP: int, T: int) -> np.ndarray:
    """Static ring base positions after prefilling S tokens (-1e9 = empty)."""
    vals = np.full((NP,), -(10 ** 9), np.int64)
    n_src = ceil_div(S, T)
    for sp in range(max(0, n_src - NP), n_src):
        vals[sp % NP] = sp * T
    return vals.astype(np.int32)


def window_page_positions_dyn(true_len, NP: int, T: int) -> jax.Array:
    """`window_page_positions` for a TRACED length (bucketed prefill).

    For ring slot j the newest source page mapping there is
    ``m - ((m - j) mod NP)`` with m = n_src-1; negative -> never written.
    """
    true_len = jnp.asarray(true_len, jnp.int32)
    n_src = (true_len + T - 1) // T
    m = n_src - 1
    j = jnp.arange(NP, dtype=jnp.int32)
    sp = m - ((m - j) % NP)
    return jnp.where((sp >= 0) & (n_src > 0), sp * T,
                     -(10 ** 9)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Quantized page write paths (kv8 / kv4 pools carry per-page scales)
# ---------------------------------------------------------------------------
#
# Token appends re-quantize ONLY the touched page: read the [T, dh] page,
# dequantize with its current scale, insert the new token, recompute the
# scale, write the packed page + scale back.  Everything else in the pool
# is untouched — the append stays O(page), not O(pool).
#
# Tokens land in page order, so slots > slot of the touched page are never
# live — they hold a recycled occupant's stale K/V or bucket padding.
# Those slots are masked at read time, but they MUST NOT enter the new
# amax: a 10×-larger stale value would inflate the scale and crush the
# real tokens' precision.  The appends therefore zero the dead tail
# before requantizing.

def _zero_dead_slots(page, slot):
    """page: [..., T, dh]; keep slots 0..slot, zero the rest."""
    T = page.shape[-2]
    live = jax.lax.broadcasted_iota(jnp.int32, (T, 1), 0) <= \
        jnp.reshape(slot, (1, 1))
    return jnp.where(live, page, 0.0)


def append_token_quant_uniform(pool, scale, layer, phys, slot, val,
                               fmt: str):
    """Lockstep append into a quantized stacked pool.

    pool: [L, B, K, NP, Ts, dh] int codes; scale: [L, B, K, NP] f32;
    phys/slot: [B] uniform positions; val: [B, K, dh].
    """
    L, B, K, NP, Ts, dh = pool.shape
    zero = jnp.zeros((), jnp.int32)
    pidx = (layer, zero, zero, phys[0], zero, zero)
    qpage = jax.lax.dynamic_slice(pool, pidx,
                                  (1, B, K, 1, Ts, dh))[0, :, :, 0]
    s = jax.lax.dynamic_slice(scale, (layer, zero, zero, phys[0]),
                              (1, B, K, 1))[0, :, :, 0]        # [B, K]
    page = quant.dequantize_kv_page(qpage, s, fmt)             # [B, K, T, dh]
    page = jax.lax.dynamic_update_slice(
        page, val[:, :, None, :].astype(page.dtype),
        (zero, zero, slot[0], zero))
    page = _zero_dead_slots(page, slot[0])
    q2, s2 = quant.quantize_kv_page(page, fmt)
    pool = jax.lax.dynamic_update_slice(pool, q2[:, :, None][None], pidx)
    scale = jax.lax.dynamic_update_slice(scale, s2[:, :, None][None],
                                         (layer, zero, zero, phys[0]))
    return pool, scale


def append_token_quant(pool, scale, layer, phys, slot, val, fmt: str):
    """Ragged (per-sequence position) append into a quantized pool.

    Gathers each sequence's touched page, requantizes it with the new
    token, scatters page + scale back (continuous-batching path).
    """
    L, B, K, NP, Ts, dh = pool.shape
    b_idx = jnp.arange(B)
    qpage = pool[layer, b_idx, :, phys]                        # [B, K, Ts, dh]
    s = scale[layer, b_idx, :, phys]                           # [B, K]
    page = quant.dequantize_kv_page(qpage, s, fmt)
    page = page.at[b_idx, :, slot].set(val.astype(page.dtype))
    T = page.shape[-2]
    live = jnp.arange(T)[None, :] <= slot[:, None]             # [B, T]
    page = jnp.where(live[:, None, :, None], page, 0.0)
    q2, s2 = quant.quantize_kv_page(page, fmt)
    pool = pool.at[layer, b_idx, :, phys].set(q2, mode="drop")
    scale = scale.at[layer, b_idx, :, phys].set(s2, mode="drop")
    return pool, scale


def _paged_from_seq(kv_seq, T: int):
    """[B, S, K, dh] -> page-major [B, K, n_pages, T, dh] (zero-padded)."""
    B, S, K, dh = kv_seq.shape
    n_pages = ceil_div(S, T)
    pad = n_pages * T - S
    x = jnp.pad(kv_seq, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return x.reshape(B, n_pages, T, K, dh).transpose(0, 3, 1, 2, 4)


# ---------------------------------------------------------------------------
# Unified one-shot fill: the whole-prompt chunk fill (satellite: the old
# per-arch fill_prefill_at/fill_window_at(+quant, +dyn) bodies collapsed
# onto the chunk-fill writer — bit-identical pages, one code path)
# ---------------------------------------------------------------------------

def fill_layer(pool, kv_seq, layer, *, ring: bool, true_len=None,
               table=None, scale=None, kv_quant: str = "none"):
    """One-shot prefill fill of ONE layer for every batch row.

    Semantically this IS `prefill_chunk`'s fill applied to one whole-prompt
    chunk at page0 = 0 (the chunk-fill parity tests pin the page contents
    bit-identical), generalized over:

      ring      False -> global pool (logical page sp), True -> window ring
                (ring slot sp % NP, ascending so each slot keeps its
                NEWEST valid occupant);
      true_len  traced count of real tokens when kv_seq carries bucket
                padding (padding pages are never written); None -> all S
                tokens are real;
      table     shared-pool page table [B, NP] (physical ids); None ->
                stripe layout;
      kv_quant  kv8/kv4 pools quantize whole pages and return
                (pool, scale).

    The exact-length stripe global fill keeps the original fused
    single-slice write (identity mapping, every page valid — bit-identical
    to the page walk, and O(1) ops for a 500-page prompt).
    """
    Ts = pool.shape[-2]
    T = Ts * (2 if kv_quant == "kv4" else 1)
    B, S = kv_seq.shape[:2]
    if table is not None:
        return _fill_layer_shared(pool, kv_seq, layer, table, ring=ring,
                                  true_len=true_len, scale=scale,
                                  kv_quant=kv_quant)
    NP = pool.shape[3]
    if not ring and true_len is None:
        x = _paged_from_seq(kv_seq, T)             # [B, K, n_pages, Ts, dh]
        zero = jnp.zeros((), jnp.int32)
        if kv_quant != "none":
            q, s = quant.quantize_kv_page(x, kv_quant)
            pool = jax.lax.dynamic_update_slice(
                pool, q[None], (layer, zero, zero, zero, zero, zero))
            scale = jax.lax.dynamic_update_slice(scale, s[None],
                                                 (layer, zero, zero, zero))
            return pool, scale
        return jax.lax.dynamic_update_slice(
            pool, x[None].astype(pool.dtype),
            (layer, zero, zero, zero, zero, zero))
    if ring and true_len is not None:
        # bucketed ring: the newest real page is traced, so a static trim
        # cannot find it — walk the newest ≤ NP REAL source pages via
        # traced indices (min(NP, n_pad) writes, not one per bucket page)
        return _fill_ring_dyn(pool, kv_seq, layer, true_len, scale=scale,
                              kv_quant=kv_quant)
    page0 = 0
    if ring:
        # statically drop source pages that can only be overwritten: the
        # ring keeps the newest NP pages, so start the "chunk" there
        page0 = max(0, ceil_div(S, T) - NP)
        kv_seq = kv_seq[:, page0 * T:]
    valid_len = jnp.asarray(kv_seq.shape[1], jnp.int32)
    fill = fill_chunk_window_at if ring else fill_chunk_global_at
    return fill(pool, kv_seq, layer, None,
                jnp.asarray(page0, jnp.int32), valid_len,
                scale=scale, kv_quant=kv_quant)


def _fill_ring_dyn(pool, kv_seq, layer, true_len, *, scale=None,
                   kv_quant: str = "none"):
    """Ring-fill ONE layer when only `true_len` of kv_seq's S tokens are
    real (bucket padding beyond).  Walks the NEWEST ≤ NP real source
    pages via traced indices so padding pages never evict live ones and
    the write count stays min(NP, n_pad)."""
    B, S, K, dh = kv_seq.shape
    NP, Ts = pool.shape[3], pool.shape[4]
    T = Ts * (2 if kv_quant == "kv4" else 1)
    x = _paged_from_seq(kv_seq, T)                 # [B, K, n_pad, T, dh]
    n_pad = x.shape[2]
    if kv_quant != "none":
        x, s_all = quant.quantize_kv_page(x, kv_quant)
    true_len = jnp.asarray(true_len, jnp.int32)
    n_src = (true_len + T - 1) // T
    zero = jnp.zeros((), jnp.int32)
    for r in range(min(NP, n_pad)):                # static trip count
        sp = n_src - 1 - r                         # traced source page
        ok = sp >= 0
        spc = jnp.clip(sp, 0, n_pad - 1)
        page = jax.lax.dynamic_slice_in_dim(x, spc, 1, axis=2)  # [B,K,1,*]
        phys = spc % NP
        pidx = (layer, zero, zero, phys, zero, zero)
        cur = jax.lax.dynamic_slice(pool, pidx, (1, B, K, 1, Ts, dh))
        upd = jnp.where(ok, page[None].astype(pool.dtype), cur)
        pool = jax.lax.dynamic_update_slice(pool, upd, pidx)
        if kv_quant != "none":
            sidx = (layer, zero, zero, phys)
            s_pg = jax.lax.dynamic_slice_in_dim(s_all, spc, 1, axis=2)
            cur_s = jax.lax.dynamic_slice(scale, sidx, (1, B, K, 1))
            scale = jax.lax.dynamic_update_slice(
                scale, jnp.where(ok, s_pg[None], cur_s), sidx)
    if kv_quant != "none":
        return pool, scale
    return pool


def _fill_layer_shared(pool, kv_seq, layer, table, *, ring: bool,
                       true_len=None, scale=None, kv_quant: str = "none"):
    """`fill_layer` for the shared pool: pages scatter through the table.

    pool: [L, K, P, Ts, dh]; table: [B, NP] physical ids; writes whose
    logical page holds no real token are redirected past P and dropped.
    """
    L, K, P, Ts, dh = pool.shape
    T = Ts * (2 if kv_quant == "kv4" else 1)
    B, S = kv_seq.shape[:2]
    NP = table.shape[1]
    x = _paged_from_seq(kv_seq, T)                 # [B, K, n_src, Ts, dh]
    if kv_quant != "none":
        x, s_all = quant.quantize_kv_page(x, kv_quant)
    n_src = x.shape[2]
    valid_len = jnp.asarray(S if true_len is None else true_len, jnp.int32)
    # NB: `layer` (traced scalar) and `phys` are NON-adjacent advanced
    # indices, so the scatter result dims are [*phys.shape, K, ...]
    if not ring:
        n_w = min(n_src, NP)
        ok = (jnp.arange(n_w, dtype=jnp.int32) * T) < valid_len   # [n_w]
        phys = jnp.where(ok[None], table[:, :n_w], P)             # [B, n_w]
        pool = pool.at[layer, :, phys].set(
            x[:, :, :n_w].transpose(0, 2, 1, 3, 4).astype(pool.dtype),
            mode="drop")
        if kv_quant != "none":
            scale = scale.at[layer, :, phys].set(
                s_all[:, :, :n_w].transpose(0, 2, 1), mode="drop")
            return pool, scale
        return pool
    # ring: ascending source pages so each ring slot keeps its newest
    # valid occupant (exactly the chunk-fill ordering); with an exact
    # length the oldest n_src - NP pages can only be overwritten — skip
    # them statically
    sp0 = max(0, n_src - NP) if true_len is None else 0
    for sp in range(sp0, n_src):                   # static trip count
        ok = (sp * T) < valid_len
        phys = jnp.where(ok, table[:, sp % NP], P)                # [B]
        pool = pool.at[layer, :, phys].set(
            x[:, :, sp].astype(pool.dtype), mode="drop")
        if kv_quant != "none":
            scale = scale.at[layer, :, phys].set(
                s_all[:, :, sp], mode="drop")
    if kv_quant != "none":
        return pool, scale
    return pool


# ---------------------------------------------------------------------------
# Chunked-prefill fills: one slot's page-aligned chunk into the SHARED pool
# ---------------------------------------------------------------------------
#
# The interleaved scheduler prefills each admitted prompt chunk-by-chunk
# straight into its slot's stripe of the batch pool (no one-sequence
# side cache, no splice copy).  Chunk starts are page-aligned, so every
# write lands on whole pages; the chunk's first token occupies physical
# page `page0` (the prefill page table is identity, logical == physical).
# Only pages holding at least one of the chunk's `valid_len` real tokens
# are written — bucket-padding pages are skipped, and a page index past
# the stripe is dropped rather than clamped into a live page.

def _fill_chunk_pages(pool, kv_chunk, layer, slot, page_of, valid_of, *,
                      scale, kv_quant: str):
    """Shared chunk-fill body: paginate (+quantize), then one guarded
    `dynamic_update_slice` of page (+scale) per chunk page.

    page_of(sp) -> traced physical page index (already in range);
    valid_of(sp) -> traced bool, False drops the write (keeps `cur`).
    slot=None writes EVERY batch row at the same page coordinates (the
    one-shot `fill_layer` path: a prefill is one whole-prompt chunk).
    A 5-D pool ([L, K, P, Ts, dh]) is the SHARED layout: page_of must
    then return table-translated GLOBAL physical indices, and `slot` is
    meaningless (the table row already names the slot's pages).
    """
    shared = pool.ndim == 5
    Bc, C, K, dh = kv_chunk.shape
    Ts = pool.shape[-2]
    T = Ts * (2 if kv_quant == "kv4" else 1)
    x = _paged_from_seq(kv_chunk, T)               # [Bc, K, n_pages, Ts, dh]
    n_pages = x.shape[2]
    if kv_quant != "none":
        x, s_all = quant.quantize_kv_page(x, kv_quant)
    zero = jnp.zeros((), jnp.int32)
    if slot is None and not shared:
        assert Bc == pool.shape[1], (Bc, pool.shape)
        slot = zero
    for sp in range(n_pages):                      # static trip count
        gp = page_of(sp)
        ok = valid_of(sp)
        page = jax.lax.dynamic_slice_in_dim(x, sp, 1, axis=2)  # [Bc,K,1,*]
        if shared:
            pidx = (layer, zero, gp, zero, zero)
            blk = (1, K, 1, Ts, dh)
            upd = page[0][None]                    # [1, K, 1, Ts, dh]
        else:
            pidx = (layer, slot, zero, gp, zero, zero)
            blk = (1, Bc, K, 1, Ts, dh)
            upd = page[None]
        cur = jax.lax.dynamic_slice(pool, pidx, blk)
        pool = jax.lax.dynamic_update_slice(
            pool, jnp.where(ok, upd.astype(pool.dtype), cur), pidx)
        if kv_quant != "none":
            s_pg = jax.lax.dynamic_slice_in_dim(s_all, sp, 1, axis=2)
            if shared:
                sidx = (layer, zero, gp)
                sblk, s_upd = (1, K, 1), s_pg[0][None]
            else:
                sidx = (layer, slot, zero, gp)
                sblk, s_upd = (1, Bc, K, 1), s_pg[None]
            cur_s = jax.lax.dynamic_slice(scale, sidx, sblk)
            scale = jax.lax.dynamic_update_slice(
                scale, jnp.where(ok, s_upd, cur_s), sidx)
    if kv_quant != "none":
        return pool, scale
    return pool


def fill_chunk_global_at(pool, kv_chunk, layer, slot, page0, valid_len, *,
                         scale=None, kv_quant: str = "none"):
    """Write one slot's prompt chunk into its stripe of the global pool.

    pool: [L, B, K, NP, Ts, dh] (in-place carry); kv_chunk: [1, C, K, dh];
    layer/slot/page0/valid_len: traced scalars.  A write past the stripe
    is dropped, never clamped into a live page.  Quantized pools (kv8/kv4)
    quantize whole pages exactly as `fill_prefill_at_quant`, so a page
    produced chunk-by-chunk is bit-identical to the one-shot fill's page.
    Returns pool, or (pool, scale) when quantized.
    """
    NP, T = pool.shape[3], pool.shape[4] * (2 if kv_quant == "kv4" else 1)
    return _fill_chunk_pages(
        pool, kv_chunk, layer, slot,
        lambda sp: jnp.clip(page0 + sp, 0, NP - 1),
        lambda sp: (sp * T < valid_len) & (page0 + sp < NP),
        scale=scale, kv_quant=kv_quant)


def fill_chunk_window_at(pool, kv_chunk, layer, slot, page0, valid_len, *,
                         scale=None, kv_quant: str = "none"):
    """Ring variant of `fill_chunk_global_at` for the window pool.

    Chunk page `page0 + sp` lands in ring slot `(page0 + sp) % NP`.
    Page-aligned chunk starts mean every global page is written exactly
    once across the whole prefill; when the chunk spans more pages than
    the ring, ascending order + the valid-page guard leave each ring slot
    holding its NEWEST valid occupant (a trailing padding page must not
    shadow the valid page `NP` positions older).  Base positions are
    derived by the engine (`window_page_positions_dyn`), not here.
    """
    NP, T = pool.shape[3], pool.shape[4] * (2 if kv_quant == "kv4" else 1)
    return _fill_chunk_pages(
        pool, kv_chunk, layer, slot,
        lambda sp: (page0 + sp) % NP,
        lambda sp: sp * T < valid_len,
        scale=scale, kv_quant=kv_quant)


# ---------------------------------------------------------------------------
# Shared-pool write paths: all coordinates go through the page table
# ---------------------------------------------------------------------------
#
# Pools are [L, K, P, Ts, dh] (+ scales [L, K, P]); the per-slot page
# tables hold GLOBAL physical indices handed out by the host allocator
# (`core/page_alloc.py`).  A table entry equal to P (one past the pool) is
# the engine's drop sentinel: scatters with mode="drop" discard the write,
# so inactive slots and unallocated logical pages can never corrupt a
# page another sequence owns.

def append_global_shared(pool, layer, phys, slot, val):
    """Ragged one-token append into a shared stacked pool.

    pool: [L, K, P, Ts, dh]; phys/slot: [B] per-sequence physical page and
    in-page slot; val: [B, K, dh].  phys >= P drops the write.
    """
    # layer (traced scalar) + phys/slot are non-adjacent advanced indices:
    # scatter result dims are [B, K, dh]
    return pool.at[layer, :, phys, slot].set(
        val.astype(pool.dtype), mode="drop")


def append_token_quant_shared(pool, scale, layer, phys, slot, val,
                              fmt: str):
    """Ragged requantizing append into a shared quantized pool.

    Gathers each sequence's touched page [K, Ts, dh] from the pool,
    dequantizes with its scale, inserts the token, zeros dead slots,
    requantizes, scatters page + scale back (O(page) per layer, exactly
    the stripe-layout `append_token_quant` through one indirection).
    """
    L, K, P, Ts, dh = pool.shape
    B = phys.shape[0]
    qpage = pool[layer, :, phys]                   # [B, K, Ts, dh] (clipped
    s = scale[layer, :, phys]                      # [B, K]  gather for the
    page = quant.dequantize_kv_page(qpage, s, fmt)  # dropped sentinel rows)
    b_idx = jnp.arange(B)
    page = page.at[b_idx, :, slot].set(val.astype(page.dtype))
    T = page.shape[-2]
    live = jnp.arange(T)[None, :] <= slot[:, None]             # [B, T]
    page = jnp.where(live[:, None, :, None], page, 0.0)
    q2, s2 = quant.quantize_kv_page(page, fmt)
    pool = pool.at[layer, :, phys].set(q2, mode="drop")
    scale = scale.at[layer, :, phys].set(s2, mode="drop")
    return pool, scale


def fill_chunk_global_at_shared(pool, kv_chunk, layer, table_row, page0,
                                valid_len, *, scale=None,
                                kv_quant: str = "none"):
    """Shared-pool `fill_chunk_global_at`: logical chunk page page0+sp
    resolves through ``table_row`` [NP] to its pool page (same writer
    body — `_fill_chunk_pages` detects the 5-D shared layout)."""
    NP = table_row.shape[0]
    T = pool.shape[3] * (2 if kv_quant == "kv4" else 1)
    return _fill_chunk_pages(
        pool, kv_chunk, layer, None,
        lambda sp: table_row[jnp.clip(page0 + sp, 0, NP - 1)],
        lambda sp: (sp * T < valid_len) & (page0 + sp < NP),
        scale=scale, kv_quant=kv_quant)


def fill_chunk_window_at_shared(pool, kv_chunk, layer, table_row, page0,
                                valid_len, *, scale=None,
                                kv_quant: str = "none"):
    """Shared-pool ring chunk fill: ring slot (page0+sp) % NP resolves
    through ``table_row`` [NPw]."""
    NP = table_row.shape[0]
    T = pool.shape[3] * (2 if kv_quant == "kv4" else 1)
    return _fill_chunk_pages(
        pool, kv_chunk, layer, None,
        lambda sp: table_row[(page0 + sp) % NP],
        lambda sp: sp * T < valid_len,
        scale=scale, kv_quant=kv_quant)


# ---------------------------------------------------------------------------
# Speculative-decode span appends (multi-token, accept-gated)
# ---------------------------------------------------------------------------
#
# `KVNANDEngine.verify_step` scores a k+1-token span in one forward pass
# and only then learns how many drafts were accepted.  The span writers
# below append UP TO S tokens per sequence in page order, but every write
# is gated per (sequence, span-position): the engine redirects the
# physical page index of a rejected (or inactive-slot) position to the
# pool's drop sentinel, so rejected drafts never reach a page.  That IS
# the rollback for every layout — nothing stale to undo:
#
#   * f32 pools: no write happened, so no stale bytes sit beyond `lengths`
#     waiting to inflate anything;
#   * kv8/kv4 pools: each accepted token replays `append_token_quant`'s
#     exact page chain (dequant → insert → zero dead slots → requant), so
#     the page codes and scales match what sequential decode would have
#     produced — a rejected draft never enters a page's amax;
#   * window rings: ring base positions advance only for pages that
#     received an accepted token (the engine derives them from the same
#     gate);
#   * shared pools: writes go through the slot's table row; the HOST half
#     of the rollback (returning speculatively allocated pages to
#     `core.page_alloc.PageAllocator` with refcounts and reservations
#     intact) lives in `serving/scheduler.py`.
#
# phys/slot: [S, B] per-span-position page coordinates (already gated —
# out-of-range phys drops); vals: [B, S, K, dh] span K or V.

def append_span(pool, layer, phys, slot, vals):
    """Ragged multi-token append into a stacked stripe pool.

    pool: [L, B, K, NP, T, dh]; the S span positions land in sequence
    order, so the page chain equals S sequential `decode_step` appends.
    """
    B = vals.shape[0]
    b_idx = jnp.arange(B)
    for s in range(vals.shape[1]):
        pool = pool.at[layer, b_idx, :, phys[s], slot[s]].set(
            vals[:, s].astype(pool.dtype), mode="drop")
    return pool


def append_span_shared(pool, layer, phys, slot, vals):
    """`append_span` for a shared pool [L, K, P, T, dh] (table-translated
    physical indices; the drop sentinel is P)."""
    for s in range(vals.shape[1]):
        pool = append_global_shared(pool, layer, phys[s], slot[s],
                                    vals[:, s])
    return pool


def append_span_quant(pool, scale, layer, phys, slot, vals, fmt: str):
    """Requantizing span append: one `append_token_quant` per span
    position, reproducing sequential decode's page chain bit-for-bit
    for the accepted prefix."""
    for s in range(vals.shape[1]):
        pool, scale = append_token_quant(pool, scale, layer, phys[s],
                                         slot[s], vals[:, s], fmt)
    return pool, scale


def append_span_quant_shared(pool, scale, layer, phys, slot, vals,
                             fmt: str):
    """Shared-pool requantizing span append (see `append_span_quant`)."""
    for s in range(vals.shape[1]):
        pool, scale = append_token_quant_shared(pool, scale, layer,
                                                phys[s], slot[s],
                                                vals[:, s], fmt)
    return pool, scale


def copy_page_shared(pool, src, dst):
    """Copy one physical page src -> dst across ALL layers of a shared
    pool [L, K, P, ...] (COW: the new exclusive owner starts from the
    shared page's bytes; works for code pools and scale leaves alike)."""
    L, K = pool.shape[:2]
    tail = pool.shape[3:]
    zeros = (0,) * len(tail)
    page = jax.lax.dynamic_slice(
        pool, (0, 0, jnp.asarray(src, jnp.int32)) + zeros, (L, K, 1) + tail)
    return jax.lax.dynamic_update_slice(
        pool, page, (0, 0, jnp.asarray(dst, jnp.int32)) + zeros)


# ---------------------------------------------------------------------------
# Host-staging / slot-splice writers (every pool-leaf write lives here:
# kvlint rule KV004 rejects direct .at[].set / dynamic_update_slice on
# cache pool leaves anywhere outside this module — DESIGN.md §15)
# ---------------------------------------------------------------------------

def append_token_inplace(pool, layer, phys, slot, val, *,
                         uniform_lengths: bool = False):
    """pool: [L, B, K, NP, T, dh]; write one token's K or V in place.

    Uniform-length fast path: all sequences advance in lockstep (static
    decode batching — every dry-run cell), so the append is ONE
    dynamic_update_slice.  The general per-sequence path lowers to a
    scatter, which XLA implements with whole-pool layout transposes
    (measured 3× pool traffic per layer) — only the ragged continuous-
    batching scheduler pays it.
    """
    if uniform_lengths:
        upd = val[None, :, :, None, None, :].astype(pool.dtype)
        zero = jnp.zeros((), jnp.int32)
        return jax.lax.dynamic_update_slice(
            pool, upd, (layer, zero, zero, phys[0], slot[0], zero))
    B = val.shape[0]
    b_idx = jnp.arange(B)
    return pool.at[layer, b_idx, :, phys, slot].set(
        val.astype(pool.dtype), mode="drop")


def stage_hot_slot(cache: "DecodeCache", slot, vals) -> "DecodeCache":
    """Tiered staging (DESIGN.md §13): write a promoted page's bytes into
    its freshly bound hot slot — one dynamic_update_slice per pool leaf
    named in `vals` ({leaf name: [L, K, T, dh] host bytes}).  Jit with a
    donated `cache` so the upload lands in place.

    Migration import (DESIGN.md §16) reuses this writer with `slot` as a
    flat-pool PHYSICAL page index (same page axis 2 on every shared-pool
    leaf, global and window alike), so a KVEnvelope's page bytes splice
    into a decode replica's pool through the one staging path."""
    upd = {}
    for name, val in vals.items():
        leaf = getattr(cache, name)
        v = jnp.expand_dims(val, 2).astype(leaf.dtype)
        start = tuple(slot if d == 2 else 0 for d in range(leaf.ndim))
        upd[name] = jax.lax.dynamic_update_slice(leaf, v, start)
    return dataclasses.replace(cache, **upd)


# leaves whose batch axis is axis 0 (tables / ring positions / lengths);
# pool data leaves carry the stacked-layer axis first
_BATCH_AXIS0 = ("page_table_g", "page_table_w", "page_pos_w", "lengths")


def import_slot_rows(cache: "DecodeCache", i, rows) -> "DecodeCache":
    """Migration import (DESIGN.md §16): write one slot's per-sequence
    rows into slot i of the batch cache — the `lengths` scalar, the
    `page_pos_w` ring-base row, and recurrent-state stacks ([L, ...]
    per-layer rows) named in `rows`.  The page-byte half of a KVEnvelope
    import goes through `stage_hot_slot`; together they keep every
    migration splice inside this module (KV004).  Jit with a donated
    `cache` so the rows land in place."""
    upd = {}
    for name, val in rows.items():
        leaf = getattr(cache, name)
        v = jnp.asarray(val).astype(leaf.dtype)
        if name in _BATCH_AXIS0:
            upd[name] = leaf.at[i].set(v)
        else:
            upd[name] = leaf.at[:, i].set(v)
    return dataclasses.replace(cache, **upd)


def splice_slot(cache: "DecodeCache", one: "DecodeCache",
                i) -> "DecodeCache":
    """Copy sequence 0 of a B=1 cache into slot i of the batch cache.

    One `dynamic_update_slice` per leaf: `one` already has a size-1 batch
    dim, so the update writes exactly the slot's stripe.  Jit this with a
    donated `cache` so XLA updates the pools in place instead of copying
    the whole pool per admit.
    """
    updates = {}
    for f in dataclasses.fields(cache):
        cur, new = getattr(cache, f.name), getattr(one, f.name)
        if cur is None:
            continue
        # batch axis position: leaf layouts are [L, B, ...] or [B, ...]
        ax = 0 if f.name in _BATCH_AXIS0 else 1
        start = tuple(jnp.asarray(i if d == ax else 0, jnp.int32)
                      for d in range(cur.ndim))
        updates[f.name] = jax.lax.dynamic_update_slice(
            cur, new.astype(cur.dtype), start)
    return dataclasses.replace(cache, **updates)


def splice_slot_ref(cache: "DecodeCache", one: "DecodeCache",
                    i: int) -> "DecodeCache":
    """Eager reference splice (the old O(pool) path) — kept for tests."""
    updates = {}
    for f in dataclasses.fields(cache):
        cur, new = getattr(cache, f.name), getattr(one, f.name)
        if cur is None:
            continue
        if f.name in _BATCH_AXIS0:
            updates[f.name] = cur.at[i].set(new[0])
        else:
            updates[f.name] = cur.at[:, i].set(new[:, 0])
    return dataclasses.replace(cache, **updates)
