"""Host-side free-page allocator + radix-style prefix cache (paper §IV-D).

The paper describes page-level KV mapping as an FTL analogy: a
logical→physical page table with access-aware block allocation.  This
module is the FTL's host half for the SHARED page pool
(``EngineConfig.shared_pool``): pure-numpy bookkeeping that decides which
physical page of the pool backs each (slot, logical page) mapping.  The
device half (the tables the kernels consume, the page copies for COW)
lives in ``core/paged_kv.py``; the serving policy that drives both lives
in ``serving/scheduler.py``.

Invariants (property-tested in tests/test_page_alloc.py):

  * conservation — every physical page is either on the free list
    (refcount 0) or mapped with refcount ≥ 1; free + live == total;
  * single writer — a page with refcount > 1 is never written: writers
    must `cow()` first (the allocator hands out a fresh page and drops
    one reference from the shared page);
  * fork safety — `share()`-ing a table row only bumps refcounts, so a
    forked sequence's decode can never mutate pages it shares until it
    owns them exclusively;
  * speculative rollback — pages backed for a draft span that acceptance
    never reaches are returned through plain `free()` with the caller's
    reservation ledger restored (`serving/scheduler._rollback_pages`,
    DESIGN.md §11): the allocator needs no special mode because
    rejected drafts are never written.

Shard awareness: when the physical page axis is sharded over the mesh
(``seqpar``'s G2 dies), logical page j of a sequence should land on shard
``j % n_shards`` so a sequence's pages stripe across dies exactly like
the private-stripe layout did.  The allocator keeps one free list per
shard and honours a preferred shard per allocation, falling back to any
shard only when the preferred one is dry.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class OutOfPages(RuntimeError):
    """The pool has no free page left (caller should evict / back off)."""


class PageAllocator:
    """Free-page allocator with refcounts over ``total`` physical pages."""

    def __init__(self, total: int, n_shards: int = 1):
        if total <= 0:
            raise ValueError(f"pool needs at least one page, got {total}")
        if n_shards <= 0 or total % n_shards:
            raise ValueError(
                f"total={total} pages must split evenly over "
                f"n_shards={n_shards}")
        self.total = total
        self.n_shards = n_shards
        self.pages_per_shard = total // n_shards
        self.refcount = np.zeros(total, np.int32)
        # LIFO free lists (hot pages get reused first — the access-aware
        # block-reclaim analogue); shard s owns [s*pps, (s+1)*pps)
        self._free: List[List[int]] = [
            list(range((s + 1) * self.pages_per_shard - 1,
                       s * self.pages_per_shard - 1, -1))
            for s in range(n_shards)]

    # ------------------------------------------------------------------
    @property
    def free_count(self) -> int:
        return sum(len(f) for f in self._free)

    @property
    def live_count(self) -> int:
        return self.total - self.free_count

    def shard_of(self, page: int) -> int:
        return page // self.pages_per_shard

    # ------------------------------------------------------------------
    def alloc(self, prefer_shard: int = 0) -> int:
        """Pop one free page, preferring ``prefer_shard``'s list."""
        order = [prefer_shard % self.n_shards] + [
            s for s in range(self.n_shards)
            if s != prefer_shard % self.n_shards]
        for s in order:
            if self._free[s]:
                p = self._free[s].pop()
                assert self.refcount[p] == 0, (p, self.refcount[p])
                self.refcount[p] = 1
                return p
        raise OutOfPages(f"all {self.total} pages live")

    def alloc_for_logical(self, logical: int) -> int:
        """Allocate the backing page for logical page ``logical`` of some
        sequence — striped over shards like the old private layout."""
        return self.alloc(prefer_shard=logical % self.n_shards)

    def share(self, pages) -> None:
        """Add one reference to each page (prefix-cache map-in / fork)."""
        for p in np.atleast_1d(np.asarray(pages, np.int64)):
            if self.refcount[p] <= 0:
                raise ValueError(f"share of dead page {int(p)}")
            self.refcount[p] += 1

    def free(self, pages) -> int:
        """Drop one reference per page; pages reaching refcount 0 return
        to their shard's free list.  Returns the number actually freed."""
        n = 0
        for p in np.atleast_1d(np.asarray(pages, np.int64)):
            p = int(p)
            if self.refcount[p] <= 0:
                raise ValueError(f"double free of page {p}")
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self._free[self.shard_of(p)].append(p)
                n += 1
        return n

    def cow(self, page: int, prefer_shard: Optional[int] = None) -> int:
        """Copy-on-write: give the caller exclusive ownership of ``page``.

        refcount == 1 -> the caller already owns it, returned unchanged.
        refcount > 1  -> allocate a fresh page (same shard by default so
        the stripe stays aligned), move one reference over, and return
        the fresh page.  The CALLER copies the device bytes.
        """
        if self.refcount[page] <= 0:
            raise ValueError(f"cow of dead page {int(page)}")
        if self.refcount[page] == 1:
            return int(page)
        fresh = self.alloc(self.shard_of(int(page))
                           if prefer_shard is None else prefer_shard)
        self.refcount[page] -= 1
        return fresh

    def is_shared(self, page: int) -> bool:
        return bool(self.refcount[page] > 1)

    def check(self) -> None:
        """Assert the conservation invariant (tests / debugging)."""
        free = sorted(p for f in self._free for p in f)
        assert len(free) == len(set(free)), "page on free list twice"
        assert all(self.refcount[p] == 0 for p in free)
        live = int((self.refcount > 0).sum())
        assert live + len(free) == self.total, (live, len(free), self.total)
        assert (self.refcount >= 0).all()


# ---------------------------------------------------------------------------
# Radix-style prefix cache (full-page token prefixes + exact prompts)
# ---------------------------------------------------------------------------

@dataclass
class _Exact:
    pages: List[int]            # every page covering the prompt (last may
    n: int                      # be partial); n = prompt length in tokens
    logits: np.ndarray          # last-token logits (to sample the first
                                # output without recomputing the prompt)


@dataclass
class CacheHit:
    full_pages: List[int] = field(default_factory=list)  # read-only map-in
    exact: Optional[_Exact] = None                       # whole-prompt hit


class PrefixCache:
    """Token-prefix → physical-page cache at page granularity.

    ``register`` records, for a freshly prefilled prompt, one entry per
    full-page depth k (key = the first k·T tokens, value = the physical
    page holding tokens [(k-1)T, kT)) plus one EXACT entry for the whole
    prompt (all pages including a trailing partial page, and the
    last-token logits).  Page K/V at any layer depends only on tokens at
    positions ≤ its own (causal attention), so a key match guarantees
    bit-identical page contents regardless of which sequence registered
    it.  Every referenced page carries one cache refcount in the
    allocator; `evict_lru` drops entries (and their references) until
    pages come free.
    """

    def __init__(self, alloc: PageAllocator, page_tokens: int,
                 max_entries: int = 1024):
        self.alloc = alloc
        self.T = page_tokens
        self.max_entries = max_entries
        self._full: "OrderedDict[Tuple[int, ...], int]" = OrderedDict()
        self._exact: "OrderedDict[Tuple[int, ...], _Exact]" = OrderedDict()
        self.hits = 0           # pages served from the cache
        self.lookups = 0        # prompt pages that could have been served

    # ------------------------------------------------------------------
    def lookup(self, prompt: Sequence[int]) -> CacheHit:
        """Longest usable hit for ``prompt``: an exact whole-prompt entry,
        else the deepest contiguous full-page chain with h·T < len(prompt)
        (strict: at least the last token is always computed so the caller
        has logits to sample from)."""
        toks = tuple(int(t) for t in prompt)
        n = len(toks)
        self.lookups += (n + self.T - 1) // self.T
        hit = CacheHit()
        ex = self._exact.get(toks)
        if ex is not None:
            self._exact.move_to_end(toks)
            nf = n // self.T
            hit.full_pages = ex.pages[:nf]
            hit.exact = ex
            self.hits += len(ex.pages)
            for k in range(1, nf + 1):
                if toks[:k * self.T] in self._full:
                    self._full.move_to_end(toks[:k * self.T])
            return hit
        h = 0
        while (h + 1) * self.T < n:
            key = toks[:(h + 1) * self.T]
            page = self._full.get(key)
            if page is None:
                break
            self._full.move_to_end(key)
            hit.full_pages.append(page)
            h += 1
        self.hits += h
        return hit

    # ------------------------------------------------------------------
    def register(self, prompt: Sequence[int], pages: Sequence[int],
                 logits: np.ndarray, include_exact: bool = True) -> bool:
        """Insert a prefilled prompt's pages.  ``pages`` are the physical
        pages of logical pages 0..ceil(n/T)-1 in order.  Each NEW entry
        takes one allocator reference per page it names.

        include_exact=False registers only the full-page chain (callers
        skip the exact entry when the pool lacks slack to fund the
        copy-on-write its shared partial page would later force).
        Returns True when a NEW exact entry was added."""
        toks = tuple(int(t) for t in prompt)
        n = len(toks)
        n_pages = (n + self.T - 1) // self.T
        assert len(pages) >= n_pages, (len(pages), n_pages)
        for k in range(1, n // self.T + 1):
            key = toks[:k * self.T]
            if key not in self._full:
                self._full[key] = int(pages[k - 1])
                self.alloc.share([pages[k - 1]])
        added = False
        if include_exact and toks not in self._exact:
            ps = [int(p) for p in pages[:n_pages]]
            self._exact[toks] = _Exact(ps, n, np.asarray(logits))
            self.alloc.share(ps)
            added = True
        self._trim()
        return added

    # ------------------------------------------------------------------
    @property
    def entry_count(self) -> int:
        return len(self._full) + len(self._exact)

    def evictable_pages(self) -> int:
        """Pages that would come FREE if the whole cache were dropped:
        cache references to pages no live slot maps (refcount equals the
        number of cache references)."""
        refs: Dict[int, int] = {}
        for p in self._full.values():
            refs[p] = refs.get(p, 0) + 1
        for e in self._exact.values():
            for p in e.pages:
                refs[p] = refs.get(p, 0) + 1
        return sum(1 for p, r in refs.items()
                   if self.alloc.refcount[p] == r)

    def evict_lru(self) -> bool:
        """Drop the least-recently-used entry (exact entries first — they
        hold the partial page that full-page chains can't serve anyway).
        Returns False when the cache is empty."""
        if self._exact:
            _, e = self._exact.popitem(last=False)
            self.alloc.free(e.pages)
            return True
        if self._full:
            _, page = self._full.popitem(last=False)
            self.alloc.free([page])
            return True
        return False

    def _trim(self) -> None:
        while self.entry_count > self.max_entries:
            if not self.evict_lru():
                break
