"""Host-side free-page allocator + radix-style prefix cache (paper §IV-D).

The paper describes page-level KV mapping as an FTL analogy: a
logical→physical page table with access-aware block allocation.  This
module is the FTL's host half for the SHARED page pool
(``EngineConfig.shared_pool``): pure-numpy bookkeeping that decides which
physical page of the pool backs each (slot, logical page) mapping.  The
device half (the tables the kernels consume, the page copies for COW)
lives in ``core/paged_kv.py``; the serving policy that drives both lives
in ``serving/scheduler.py``.

Invariants (property-tested in tests/test_page_alloc.py):

  * conservation — every physical page is either on the free list
    (refcount 0) or mapped with refcount ≥ 1; free + live == total;
  * single writer — a page with refcount > 1 is never written: writers
    must `cow()` first (the allocator hands out a fresh page and drops
    one reference from the shared page);
  * fork safety — `share()`-ing a table row only bumps refcounts, so a
    forked sequence's decode can never mutate pages it shares until it
    owns them exclusively;
  * speculative rollback — pages backed for a draft span that acceptance
    never reaches are returned through plain `free()` with the caller's
    reservation ledger restored (`serving/scheduler._rollback_pages`,
    DESIGN.md §11): the allocator needs no special mode because
    rejected drafts are never written.

Shard awareness: when the physical page axis is sharded over the mesh
(``seqpar``'s G2 dies), logical page j of a sequence should land on shard
``j % n_shards`` so a sequence's pages stripe across dies exactly like
the private-stripe layout did.  The allocator keeps one free list per
shard and honours a preferred shard per allocation, falling back to any
shard only when the preferred one is dry.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class OutOfPages(RuntimeError):
    """The pool has no free page left (caller should evict / back off)."""


class PageAllocator:
    """Free-page allocator with refcounts over ``total`` physical pages.

    In a TIERED pool (DESIGN.md §13) ``total`` counts FLASH pages — the
    stable ids every table/cache structure holds — while device
    residency is tracked separately by :class:`HotTier`.  Release hooks
    (``add_release_hook``) let the residency layer observe every page
    whose refcount reaches 0, whatever path freed it (slot teardown,
    prefix-cache eviction, speculative rollback).
    """

    def __init__(self, total: int, n_shards: int = 1):
        if total <= 0:
            raise ValueError(f"pool needs at least one page, got {total}")
        if n_shards <= 0 or total % n_shards:
            raise ValueError(
                f"total={total} pages must split evenly over "
                f"n_shards={n_shards}")
        self.total = total
        self.n_shards = n_shards
        self.pages_per_shard = total // n_shards
        self.refcount = np.zeros(total, np.int32)
        # LIFO free lists (hot pages get reused first — the access-aware
        # block-reclaim analogue); shard s owns [s*pps, (s+1)*pps)
        self._free: List[List[int]] = [
            list(range((s + 1) * self.pages_per_shard - 1,
                       s * self.pages_per_shard - 1, -1))
            for s in range(n_shards)]
        self._release_hooks: List = []

    def add_release_hook(self, fn) -> None:
        """Call ``fn(page)`` whenever a page's refcount reaches 0 (just
        before it rejoins the free list).  The tiered scheduler uses this
        to retire the page's hot-tier slot / capacity-store bytes on ALL
        free paths without wrapping each one."""
        self._release_hooks.append(fn)

    # ------------------------------------------------------------------
    @property
    def free_count(self) -> int:
        return sum(len(f) for f in self._free)

    @property
    def live_count(self) -> int:
        return self.total - self.free_count

    def shard_of(self, page: int) -> int:
        return page // self.pages_per_shard

    # ------------------------------------------------------------------
    def alloc(self, prefer_shard: int = 0) -> int:
        """Pop one free page, preferring ``prefer_shard``'s list."""
        order = [prefer_shard % self.n_shards] + [
            s for s in range(self.n_shards)
            if s != prefer_shard % self.n_shards]
        for s in order:
            if self._free[s]:
                p = self._free[s].pop()
                assert self.refcount[p] == 0, (p, self.refcount[p])
                self.refcount[p] = 1
                return p
        raise OutOfPages(f"all {self.total} pages live")

    def alloc_for_logical(self, logical: int) -> int:
        """Allocate the backing page for logical page ``logical`` of some
        sequence — striped over shards like the old private layout."""
        return self.alloc(prefer_shard=logical % self.n_shards)

    def share(self, pages) -> None:
        """Add one reference to each page (prefix-cache map-in / fork)."""
        for p in np.atleast_1d(np.asarray(pages, np.int64)):
            if self.refcount[p] <= 0:
                raise ValueError(f"share of dead page {int(p)}")
            self.refcount[p] += 1

    def free(self, pages) -> int:
        """Drop one reference per page; pages reaching refcount 0 return
        to their shard's free list.  Returns the number actually freed."""
        n = 0
        for p in np.atleast_1d(np.asarray(pages, np.int64)):
            p = int(p)
            if self.refcount[p] <= 0:
                raise ValueError(f"double free of page {p}")
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                for hook in self._release_hooks:
                    hook(p)
                self._free[self.shard_of(p)].append(p)
                n += 1
        return n

    def cow(self, page: int, prefer_shard: Optional[int] = None) -> int:
        """Copy-on-write: give the caller exclusive ownership of ``page``.

        refcount == 1 -> the caller already owns it, returned unchanged.
        refcount > 1  -> allocate a fresh page (same shard by default so
        the stripe stays aligned), move one reference over, and return
        the fresh page.  The CALLER copies the device bytes.
        """
        if self.refcount[page] <= 0:
            raise ValueError(f"cow of dead page {int(page)}")
        if self.refcount[page] == 1:
            return int(page)
        fresh = self.alloc(self.shard_of(int(page))
                           if prefer_shard is None else prefer_shard)
        self.refcount[page] -= 1
        return fresh

    def is_shared(self, page: int) -> bool:
        return bool(self.refcount[page] > 1)

    def check(self) -> None:
        """Assert the conservation invariant (tests / debugging)."""
        free = sorted(p for f in self._free for p in f)
        assert len(free) == len(set(free)), "page on free list twice"
        assert all(self.refcount[p] == 0 for p in free)
        live = int((self.refcount > 0).sum())
        assert live + len(free) == self.total, (live, len(free), self.total)
        assert (self.refcount >= 0).all()


# ---------------------------------------------------------------------------
# Hot-tier residency (tiered flash KV hierarchy, DESIGN.md §13)
# ---------------------------------------------------------------------------

class OutOfHotSlots(OutOfPages):
    """Every hot slot is pinned or excluded (caller should back off)."""


class HotTier:
    """Residency manager for the DEVICE half of a tiered shared pool.

    A tiered pool keeps ``total_pages`` stable FLASH page ids in the
    :class:`PageAllocator` but only ``hot_slots`` physical slots on the
    device.  This class owns the flash-id → hot-slot map and the
    tier-bit encoding the per-slot page tables use:

      * ``entry(page)`` is the table word for a flash page — its hot
        slot index when resident, else ``HotTier.CAPACITY`` (the tier
        bit: a negative sentinel that must never reach a dispatched
        table, because the scheduler promotes before mapping);
      * ``pin``/``unpin`` count live-slot mappings.  A pinned resident
        is NEVER a demotion victim — this is the "a mapped hot page is
        never evicted" invariant: decode/chunked-prefill/verify walks
        touch only pages their own slot has pinned, so they can never
        fault mid-flight;
      * unpinned residents (prefix-cache-only pages, refcount ≥ 1 in
        the allocator but mapped by no slot) sit on an LRU and demote
        one at a time when ``bind`` needs a slot — the "refcounted
        shared prefix pages demote only at refcount 0 ... or under slot
        pressure, to the capacity store" side of the invariant;
      * ``release(page)`` (driven by the allocator's release hook)
        frees the slot when the flash page itself dies.

    Conservation (``check``, property-tested in test_page_alloc.py):
    free slots + resident pages == hot_slots, always; the LRU holds
    exactly the unpinned residents; no two residents share a slot.

    The class moves no bytes — the scheduler stages page contents on
    ``bind``'s demotion victim / promotion target.
    """

    CAPACITY = -1               # table-word sentinel for a non-resident page

    def __init__(self, hot_slots: int, total_pages: int):
        if hot_slots <= 0:
            raise ValueError(f"hot tier needs at least one slot, "
                             f"got {hot_slots}")
        if hot_slots > total_pages:
            raise ValueError(f"hot_slots={hot_slots} exceeds "
                             f"total_pages={total_pages}")
        self.hot_slots = hot_slots
        self.total_pages = total_pages
        self._slot_of: Dict[int, int] = {}          # flash page -> hot slot
        self._pins = np.zeros(total_pages, np.int32)
        self._lru: "OrderedDict[int, None]" = OrderedDict()  # unpinned res.
        self._free_slots: List[int] = list(range(hot_slots - 1, -1, -1))
        self.promotes = 0       # bind() calls for pages with stored bytes
        self.demotes = 0        # LRU victims pushed to the capacity store

    # ------------------------------------------------------------------
    @property
    def resident_count(self) -> int:
        return len(self._slot_of)

    @property
    def free_slot_count(self) -> int:
        return len(self._free_slots)

    @property
    def demotable_count(self) -> int:
        """Unpinned residents — candidates for demotion."""
        return len(self._lru)

    @property
    def pinned_count(self) -> int:
        return len(self._slot_of) - len(self._lru)

    def is_resident(self, page: int) -> bool:
        return int(page) in self._slot_of

    def slot_of(self, page: int) -> int:
        """Hot slot backing ``page`` (raises KeyError if not resident)."""
        return self._slot_of[int(page)]

    def entry(self, page: int) -> int:
        """Page-table word: hot slot index, or ``CAPACITY`` (tier bit)."""
        return self._slot_of.get(int(page), self.CAPACITY)

    # ------------------------------------------------------------------
    def bind(self, page: int, avoid: frozenset = frozenset()
             ) -> Tuple[int, Optional[int]]:
        """Make ``page`` resident: returns ``(slot, victim)``.

        Takes a free slot when one exists, else demotes the
        least-recently-used UNPINNED resident not in ``avoid`` (the
        prefetcher excludes the working set it is staging so promotion
        N cannot demote promotion N-1).  ``victim`` is the demoted flash
        page (``None`` when a free slot served) — the CALLER must save
        its device bytes to the capacity store BEFORE overwriting the
        slot.  Raises :class:`OutOfHotSlots` when every slot is pinned
        or excluded; pinned residents are never victims.
        """
        page = int(page)
        if page in self._slot_of:
            raise ValueError(f"page {page} already resident")
        victim: Optional[int] = None
        if self._free_slots:
            slot = self._free_slots.pop()
        else:
            victim = next((p for p in self._lru if p not in avoid), None)
            if victim is None:
                raise OutOfHotSlots(
                    f"all {self.hot_slots} hot slots pinned or excluded")
            del self._lru[victim]
            slot = self._slot_of.pop(victim)
            self.demotes += 1
        self._slot_of[page] = slot
        if self._pins[page] == 0:
            self._lru[page] = None
        return slot, victim

    def pin(self, page: int) -> None:
        """One live-slot mapping now points at ``page`` (must be
        resident).  Pinned pages are exempt from demotion."""
        page = int(page)
        assert page in self._slot_of, f"pin of non-resident page {page}"
        self._pins[page] += 1
        self._lru.pop(page, None)

    def unpin(self, page: int) -> None:
        """Drop one live-slot mapping.  At pin count 0 a still-resident
        page joins the LRU (most-recently-used end) as a demotion
        candidate — it stays hot until slot pressure evicts it."""
        page = int(page)
        if self._pins[page] <= 0:
            raise ValueError(f"unpin of unpinned page {page}")
        self._pins[page] -= 1
        if self._pins[page] == 0 and page in self._slot_of:
            self._lru[page] = None

    def touch(self, page: int) -> None:
        """LRU bump for an unpinned resident (prefetch keeps the pages
        it staged warm until admission pins them)."""
        if int(page) in self._lru:
            self._lru.move_to_end(int(page))

    def release(self, page: int) -> None:
        """The flash page died (allocator refcount 0): free its slot.
        Wired as a ``PageAllocator`` release hook so every free path —
        slot teardown, cache eviction, speculative rollback — retires
        residency without knowing about tiers."""
        page = int(page)
        assert self._pins[page] == 0, \
            f"release of pinned page {page} (pins={int(self._pins[page])})"
        slot = self._slot_of.pop(page, None)
        if slot is not None:
            self._lru.pop(page, None)
            self._free_slots.append(slot)

    # ------------------------------------------------------------------
    def check(self) -> None:
        """Assert tier conservation (tests / debugging)."""
        slots = list(self._slot_of.values())
        assert len(slots) == len(set(slots)), "two pages share a hot slot"
        assert len(self._free_slots) == len(set(self._free_slots))
        assert not (set(self._free_slots) & set(slots)), \
            "slot both free and mapped"
        assert len(self._free_slots) + len(slots) == self.hot_slots, \
            (len(self._free_slots), len(slots), self.hot_slots)
        assert all(0 <= s < self.hot_slots for s in slots + self._free_slots)
        for p in self._lru:
            assert p in self._slot_of and self._pins[p] == 0, p
        for p, _ in self._slot_of.items():
            assert (self._pins[p] > 0) != (p in self._lru), p
        assert (self._pins >= 0).all()


# ---------------------------------------------------------------------------
# Radix-style prefix cache (full-page token prefixes + exact prompts)
# ---------------------------------------------------------------------------

@dataclass
class _Exact:
    pages: List[int]            # every page covering the prompt (last may
    n: int                      # be partial); n = prompt length in tokens
    logits: np.ndarray          # last-token logits (to sample the first
                                # output without recomputing the prompt)


@dataclass
class CacheHit:
    full_pages: List[int] = field(default_factory=list)  # read-only map-in
    exact: Optional[_Exact] = None                       # whole-prompt hit


class PrefixCache:
    """Token-prefix → physical-page cache at page granularity.

    ``register`` records, for a freshly prefilled prompt, one entry per
    full-page depth k (key = the first k·T tokens, value = the physical
    page holding tokens [(k-1)T, kT)) plus one EXACT entry for the whole
    prompt (all pages including a trailing partial page, and the
    last-token logits).  Page K/V at any layer depends only on tokens at
    positions ≤ its own (causal attention), so a key match guarantees
    bit-identical page contents regardless of which sequence registered
    it.  Every referenced page carries one cache refcount in the
    allocator; `evict_lru` drops entries (and their references) until
    pages come free.
    """

    def __init__(self, alloc: PageAllocator, page_tokens: int,
                 max_entries: int = 1024):
        self.alloc = alloc
        self.T = page_tokens
        self.max_entries = max_entries
        self._full: "OrderedDict[Tuple[int, ...], int]" = OrderedDict()
        self._exact: "OrderedDict[Tuple[int, ...], _Exact]" = OrderedDict()
        self.hits = 0           # pages served from the cache
        self.lookups = 0        # prompt pages that could have been served

    # ------------------------------------------------------------------
    def lookup(self, prompt: Sequence[int], record: bool = True) -> CacheHit:
        """Longest usable hit for ``prompt``: an exact whole-prompt entry,
        else the deepest contiguous full-page chain with h·T < len(prompt)
        (strict: at least the last token is always computed so the caller
        has logits to sample from).

        record=False is a side-effect-free PEEK — no hit/lookup counter
        bumps, no LRU reordering.  The tiered prefetcher uses it to see
        which pages the next admission will map without perturbing the
        statistics or eviction order of the admission's own lookup."""
        toks = tuple(int(t) for t in prompt)
        n = len(toks)
        if record:
            self.lookups += (n + self.T - 1) // self.T
        hit = CacheHit()
        ex = self._exact.get(toks)
        if ex is not None:
            nf = n // self.T
            hit.full_pages = ex.pages[:nf]
            hit.exact = ex
            if record:
                self._exact.move_to_end(toks)
                self.hits += len(ex.pages)
                for k in range(1, nf + 1):
                    if toks[:k * self.T] in self._full:
                        self._full.move_to_end(toks[:k * self.T])
            return hit
        h = 0
        while (h + 1) * self.T < n:
            key = toks[:(h + 1) * self.T]
            page = self._full.get(key)
            if page is None:
                break
            if record:
                self._full.move_to_end(key)
            hit.full_pages.append(page)
            h += 1
        if record:
            self.hits += h
        return hit

    # ------------------------------------------------------------------
    def register(self, prompt: Sequence[int], pages: Sequence[int],
                 logits: np.ndarray, include_exact: bool = True) -> bool:
        """Insert a prefilled prompt's pages.  ``pages`` are the physical
        pages of logical pages 0..ceil(n/T)-1 in order.  Each NEW entry
        takes one allocator reference per page it names.

        include_exact=False registers only the full-page chain (callers
        skip the exact entry when the pool lacks slack to fund the
        copy-on-write its shared partial page would later force).
        Returns True when a NEW exact entry was added."""
        toks = tuple(int(t) for t in prompt)
        n = len(toks)
        n_pages = (n + self.T - 1) // self.T
        assert len(pages) >= n_pages, (len(pages), n_pages)
        for k in range(1, n // self.T + 1):
            key = toks[:k * self.T]
            if key not in self._full:
                self._full[key] = int(pages[k - 1])
                self.alloc.share([pages[k - 1]])
        added = False
        if include_exact and toks not in self._exact:
            ps = [int(p) for p in pages[:n_pages]]
            self._exact[toks] = _Exact(ps, n, np.asarray(logits))
            self.alloc.share(ps)
            added = True
        self._trim()
        return added

    # ------------------------------------------------------------------
    @property
    def entry_count(self) -> int:
        return len(self._full) + len(self._exact)

    def evictable_pages(self) -> int:
        """Pages that would come FREE if the whole cache were dropped:
        cache references to pages no live slot maps (refcount equals the
        number of cache references)."""
        refs: Dict[int, int] = {}
        for p in self._full.values():
            refs[p] = refs.get(p, 0) + 1
        for e in self._exact.values():
            for p in e.pages:
                refs[p] = refs.get(p, 0) + 1
        return sum(1 for p, r in refs.items()
                   if self.alloc.refcount[p] == r)

    def evict_lru(self) -> bool:
        """Drop the least-recently-used entry (exact entries first — they
        hold the partial page that full-page chains can't serve anyway).
        Returns False when the cache is empty."""
        if self._exact:
            _, e = self._exact.popitem(last=False)
            self.alloc.free(e.pages)
            return True
        if self._full:
            _, page = self._full.popitem(last=False)
            self.alloc.free([page])
            return True
        return False

    def _trim(self) -> None:
        while self.entry_count > self.max_entries:
            if not self.evict_lru():
                break
