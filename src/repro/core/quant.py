"""Weight/activation quantization (paper DSE axes: W8A8, W4A16).

Weights quantize symmetrically per output channel; int4 packs two nibbles
per byte along the input dim.  `QuantizedWeight` is a pytree whose `scheme`
is static metadata, so quantized params flow through jit/eval_shape/dry-run
unchanged — `layers.dense` dispatches on the leaf type.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
class QuantizedWeight:
    """q: int8 storage ([D, F] for w8, packed [D/2, F] for w4); scale: [F]."""

    def __init__(self, q, scale, scheme: str, orig_shape: Tuple[int, ...]):
        self.q = q
        self.scale = scale
        self.scheme = scheme
        self.orig_shape = tuple(orig_shape)

    def tree_flatten(self):
        return (self.q, self.scale), (self.scheme, self.orig_shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1])

    @property
    def shape(self):  # duck-type jnp array enough for spec machinery
        return self.orig_shape

    @property
    def dtype(self):
        return jnp.bfloat16

    def __repr__(self):
        return (f"QuantizedWeight({self.scheme}, {self.orig_shape}, "
                f"q={getattr(self.q, 'shape', None)})")


# ---------------------------------------------------------------------------
# quantize / dequantize
# ---------------------------------------------------------------------------

def quantize_weight(w: jax.Array, scheme: str) -> QuantizedWeight:
    """w: [..., D, F] -> per-(...,F)-channel symmetric int quantization."""
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=-2, keepdims=True)        # [..., 1, F]
    if scheme == "w8a8":
        scale = jnp.maximum(amax, 1e-8) / 127.0
        q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    elif scheme == "w4a16":
        scale = jnp.maximum(amax, 1e-8) / 7.0
        q = jnp.clip(jnp.round(wf / scale), -7, 7).astype(jnp.int8) + 8
        # pack two int4 along the input dim: [..., D/2, F] uint8
        D = q.shape[-2]
        assert D % 2 == 0, "w4a16 needs even input dim"
        hi = q[..., 0::2, :].astype(jnp.uint8)
        lo = q[..., 1::2, :].astype(jnp.uint8)
        q = ((hi << 4) | lo).astype(jnp.uint8)
    else:
        raise ValueError(scheme)
    return QuantizedWeight(q, scale[..., 0, :], scheme, w.shape)


def dequantize(qw: QuantizedWeight, dtype=jnp.bfloat16) -> jax.Array:
    if qw.scheme == "w8a8":
        wf = qw.q.astype(jnp.float32)
    else:  # w4a16: unpack nibbles, undo the +8 offset
        hi = ((qw.q >> 4) & 0xF).astype(jnp.int32) - 8
        lo = (qw.q & 0xF).astype(jnp.int32) - 8
        D2 = qw.q.shape[-2]
        wf = jnp.stack([hi, lo], axis=-2)                      # [..., D/2, 2, F]
        wf = wf.reshape(qw.q.shape[:-2] + (2 * D2,) + qw.q.shape[-1:])
        wf = wf.astype(jnp.float32)
    return (wf * qw.scale[..., None, :]).astype(dtype)


def quantize_activations_int8(x: jax.Array):
    """Per-token symmetric int8 activation quantization (w8a8)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


# ---------------------------------------------------------------------------
# KV-cache page quantization (paper: the KV analogue of the weight DSE axis)
# ---------------------------------------------------------------------------
#
# KV pages quantize symmetrically at page × kv-head granularity: one fp32
# scale per [T, dh] page so the paged-attention kernel folds dequantization
# into its online-softmax inner loop (scale per score column) while the
# page pool itself stores 2×/4× fewer bytes.  kv4 packs two tokens per byte
# along the token dim, mirroring `quant_gemv`'s input-dim nibble packing.

KV_QUANT_FORMATS = ("none", "kv8", "kv4")


def kv_quant_bits(fmt: str) -> int:
    """Stored bits per KV element (none -> 16, the bf16 default)."""
    return {"none": 16, "kv8": 8, "kv4": 4}[fmt]


def kv_storage_dtype(fmt: str):
    return {"kv8": jnp.int8, "kv4": jnp.uint8}[fmt]


def kv_page_tokens_stored(page_tokens: int, fmt: str) -> int:
    """Length of the (possibly packed) token dim in storage."""
    if fmt == "kv4":
        if page_tokens % 2:
            raise ValueError(f"kv4 needs even page_tokens, got {page_tokens}")
        return page_tokens // 2
    return page_tokens


def pack_int4_tokens(q: jax.Array) -> jax.Array:
    """[..., T, dh] offset-binary int (0..15) -> [..., T/2, dh] uint8.

    Token 2i lands in the high nibble, token 2i+1 in the low nibble
    (the `quant_gemv` packing order, applied to the token dim).
    """
    hi = q[..., 0::2, :].astype(jnp.uint8)
    lo = q[..., 1::2, :].astype(jnp.uint8)
    return ((hi << 4) | lo).astype(jnp.uint8)


def unpack_int4_tokens(q: jax.Array) -> jax.Array:
    """[..., T/2, dh] uint8 -> [..., T, dh] int8 centered at 0 (-8 offset)."""
    hi = ((q >> 4) & 0xF).astype(jnp.int8) - 8
    lo = (q & 0xF).astype(jnp.int8) - 8
    T2 = q.shape[-2]
    out = jnp.stack([hi, lo], axis=-2)                  # [..., T/2, 2, dh]
    return out.reshape(q.shape[:-2] + (2 * T2,) + q.shape[-1:])


def quantize_kv_page(x: jax.Array, fmt: str):
    """x: [..., T, dh] float -> (q [..., T(/2), dh] int, scale [...] f32).

    Per-(leading dims) symmetric scale over the whole [T, dh] page — the
    issue's page × kv-head granularity when called on [B, K, NP, T, dh].
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=(-2, -1))
    if fmt == "kv8":
        scale = jnp.maximum(amax, 1e-8) / 127.0
        q = jnp.clip(jnp.round(xf / scale[..., None, None]),
                     -127, 127).astype(jnp.int8)
    elif fmt == "kv4":
        scale = jnp.maximum(amax, 1e-8) / 7.0
        q = jnp.clip(jnp.round(xf / scale[..., None, None]),
                     -7, 7).astype(jnp.int8) + 8
        q = pack_int4_tokens(q)
    else:
        raise ValueError(fmt)
    return q, scale


def dequantize_kv_page(q: jax.Array, scale: jax.Array, fmt: str,
                       dtype=jnp.float32) -> jax.Array:
    """Inverse of `quantize_kv_page`; scale broadcasts over [T, dh]."""
    if fmt == "kv8":
        w = q.astype(jnp.float32)
    elif fmt == "kv4":
        w = unpack_int4_tokens(q).astype(jnp.float32)
    else:
        raise ValueError(fmt)
    return (w * scale[..., None, None]).astype(dtype)


# ---------------------------------------------------------------------------
# tree-level quantization
# ---------------------------------------------------------------------------

_QUANT_SUFFIXES = ("_w",)
_QUANT_KEYS = ("w_gate", "w_up", "w_down")
_SKIP_KEYS = ("embedding", "meta_tokens", "conv_w", "router_w")


def _should_quantize(key: str, leaf) -> bool:
    if not hasattr(leaf, "ndim") or leaf.ndim < 2:
        return False
    if key in _SKIP_KEYS:
        return False
    return key.endswith(_QUANT_SUFFIXES) or key in _QUANT_KEYS


def quantize_params(params: Dict[str, Any], scheme: str) -> Dict[str, Any]:
    """Quantize every matmul weight in the tree (norms/bias/embeds stay fp)."""
    if scheme in (None, "none"):
        return params

    def walk(tree):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = walk(v)
            elif _should_quantize(k, v):
                out[k] = quantize_weight(v, scheme)
            else:
                out[k] = v
        return out

    return walk(params)


def quantize_params_and_specs(params: Dict[str, Any], specs: Dict[str, Any],
                              scheme: str):
    """Quantize params and mirror the logical-axis spec tree: a quantized
    leaf's spec becomes QuantizedWeight(spec_q, spec_scale) so sharding
    construction stays structurally aligned."""
    if scheme in (None, "none"):
        return params, specs

    def walk(ptree, stree):
        pout, sout = {}, {}
        for k, v in ptree.items():
            if isinstance(v, dict):
                pout[k], sout[k] = walk(v, stree[k])
            elif _should_quantize(k, v):
                qw = quantize_weight(v, scheme)
                ax = tuple(stree[k])
                scale_ax = (ax[:-2] + (ax[-1],)) if len(ax) > 2 \
                    else (ax[-1],)
                pout[k] = qw
                sout[k] = QuantizedWeight(ax, scale_ax, scheme, qw.orig_shape)
            else:
                pout[k], sout[k] = v, stree[k]
        return pout, sout

    return walk(params, specs)


def quantized_matmul(x: jax.Array, qw: QuantizedWeight,
                     impl: str = "ref") -> jax.Array:
    """x: [..., D] @ qw -> [..., F].  w8a8 quantizes x per token too."""
    from repro.kernels.quant_gemv.ops import quant_gemv
    return quant_gemv(x, qw, impl=impl)
