"""Design-space exploration (paper §V-B Fig 15 + Takeaways 1–2).

Enumerates KVNAND variants over die grouping, quantization, model and
context length under flash-capacity constraints (OOM → blank cell), and
returns the latency heatmap + the argmin configuration.  The same DSE
output drives Track-B engine configuration (`recommend_engine_config`):
software-defined reconfiguration on workload change, §V-B.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.configs.base import EngineConfig, ModelConfig, get_config
from repro.core import flashsim as fs


@dataclasses.dataclass
class DSEPoint:
    system: str
    g1: int
    g2: int
    wbits: int
    abits: int
    seq: int
    latency: float            # s/token; inf = OOM
    oom: bool


def enumerate_configs(total_dies: int = 8, wbits: int = 4, abits: int = 16
                      ) -> List[fs.SystemConfig]:
    out = []
    for g1 in range(1, total_dies):
        g2 = total_dies - g1
        out.append(fs.kvnand_d(g1, g2, wbits, abits))
    out.append(fs.kvnand_c(total_dies, wbits, abits))
    return out


def sweep(cfg: ModelConfig, seqs, total_dies: int = 8, wbits: int = 4,
          abits: int = 16) -> List[DSEPoint]:
    points = []
    for sys in enumerate_configs(total_dies, wbits, abits):
        for seq in seqs:
            oom = fs.is_oom(sys, cfg, seq)
            lat = math.inf if oom else \
                fs.decode_token_latency(sys, cfg, seq).total
            points.append(DSEPoint(
                sys.name, sys.weight_dies,
                sys.kv_dies if sys.kind == "kvnand-d" else 0,
                wbits, abits, seq, lat, oom))
    return points


def heatmap(cfg: ModelConfig, seqs, total_dies: int = 8, wbits: int = 4,
            abits: int = 16) -> Dict[str, Dict[int, float]]:
    """{config_name: {seq: latency}} — Fig 15 layout (inf = OOM blank)."""
    grid: Dict[str, Dict[int, float]] = {}
    for p in sweep(cfg, seqs, total_dies, wbits, abits):
        grid.setdefault(p.system, {})[p.seq] = p.latency
    return grid


def best_config(cfg: ModelConfig, seq: int, total_dies: int = 8,
                wbits: int = 4, abits: int = 16) -> Optional[DSEPoint]:
    pts = [p for p in sweep(cfg, [seq], total_dies, wbits, abits)
           if not p.oom]
    return min(pts, key=lambda p: p.latency) if pts else None


def recommend_engine_config(arch: str, seq: int, *,
                            total_dies: int = 16) -> EngineConfig:
    """Map the Track-A DSE winner onto Track-B engine knobs:

    KVNAND-D winner  -> discrete plan (HG pipelining on)
    KVNAND-C winner  -> compact plan
    W4A16 vs W8A8    -> whichever quantization wins at this context
    """
    cfg = get_config(arch)
    candidates = []
    for wbits, abits, quant in ((4, 16, "w4a16"), (8, 8, "w8a8")):
        p = best_config(cfg, seq, total_dies, wbits, abits)
        if p is not None:
            candidates.append((p.latency, p, quant))
    if not candidates:
        # nothing fits the flash budget — compact + max quantization
        return EngineConfig(variant="compact", quant="w4a16")
    _, p, quant = min(candidates)
    variant = "discrete" if p.system.startswith("KVNAND-D") else "compact"
    return EngineConfig(variant=variant, quant=quant,
                        hg_pipeline=(variant == "discrete"))


def best_discrete(cfg: ModelConfig, seq: int, total_dies: int = 8,
                  wbits: int = 4, abits: int = 16) -> Optional[DSEPoint]:
    pts = [p for p in sweep(cfg, [seq], total_dies, wbits, abits)
           if not p.oom and p.system.startswith("KVNAND-D")]
    return min(pts, key=lambda p: p.latency) if pts else None


def takeaways(cfg30b: ModelConfig, cfg70b: ModelConfig) -> Dict[str, bool]:
    """Machine-checkable versions of the paper's Takeaways 1-2.

    Note (DESIGN.md): at bandwidth granularity the optimal discrete split
    equals compact — max(t_w/g1, t_kv/g2) minimized over g1+g2=N gives
    (t_w+t_kv)/N.  The paper's D-beyond-2K preference rests on buffer-
    pressure/reliability effects; what the bandwidth model *does* predict
    (and the paper also states: "optimal configuration reaching 4 dies in
    G2 at 100K") is that the optimal G2 allocation grows with context.
    """
    out = {}
    # T1: the optimal G2 (KV) die allocation grows with context length
    d_short = best_discrete(cfg70b, 1_000, 8, 4, 16)
    d_long = best_discrete(cfg70b, 100_000, 8, 4, 16)
    out["t1_g2_allocation_grows_with_context"] = (
        d_short is not None and d_long is not None
        and d_long.g2 > d_short.g2)
    # T1b: short context — compact or G1-heavy discrete wins
    s_best = best_config(cfg70b, 1_000, 8, 4, 16)
    out["t1_short_ctx_prefers_compact_or_g1heavy"] = (
        s_best is not None and (s_best.system.startswith("KVNAND-C")
                                or s_best.g1 >= s_best.g2))
    # T2: W8A8 optimum is more G1-heavy than W4A16 optimum (30B, 50K)
    p8 = best_discrete(cfg30b, 50_000, 8, 8, 8)
    p4 = best_discrete(cfg30b, 50_000, 8, 4, 16)
    if p8 and p4:
        out["t2_w8a8_more_g1_heavy"] = p8.g1 >= p4.g1
    return out
