"""Design-space exploration (paper §V-B Fig 15 + Takeaways 1–2).

Enumerates KVNAND variants over die grouping, quantization, model and
context length under flash-capacity constraints (OOM → blank cell), and
returns the latency heatmap + the argmin configuration.  The same DSE
output drives Track-B engine configuration (`recommend_engine_config`):
software-defined reconfiguration on workload change, §V-B.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.configs.base import EngineConfig, ModelConfig, get_config
from repro.core import flashsim as fs


@dataclasses.dataclass
class DSEPoint:
    system: str
    g1: int
    g2: int
    wbits: int
    abits: int
    seq: int
    latency: float            # s/token; inf = OOM
    oom: bool
    kv_bits: int = 0          # stored KV page format (0 -> abits)
    capacity: int = 0         # concurrent seq-length contexts (pooled
                              # page allocation, §IV-D — Track-B admission)
    spec_k: int = 0           # draft tokens per verify step (0 = seq.)
    tokens_per_step: float = 1.0  # E[emitted] at the assumed accept rate


# Track-B paged-KV formats as a DSE axis (0 = keep abits-wide KV, the
# bf16 pool); mirrors how the paper's DSE already sweeps weight bits.
KV_FORMATS = {0: "none", 8: "kv8", 4: "kv4"}

# speculation depths swept by the speculation_k axis (0 = sequential)
SPEC_KS = (0, 2, 4, 8)

# split-page attention partition counts swept by the attn_partitions
# axis (1 = monolithic walk); mirrors the engine's resolve_partitions
# auto ladder.
ATTN_PARTITIONS = (1, 4, 16)


def enumerate_configs(total_dies: int = 8, wbits: int = 4, abits: int = 16,
                      kv_bits: int = 0) -> List[fs.SystemConfig]:
    out = []
    for g1 in range(1, total_dies):
        g2 = total_dies - g1
        out.append(fs.kvnand_d(g1, g2, wbits, abits, kv_bits=kv_bits))
    out.append(fs.kvnand_c(total_dies, wbits, abits, kv_bits=kv_bits))
    return out


def sweep(cfg: ModelConfig, seqs, total_dies: int = 8, wbits: int = 4,
          abits: int = 16, kv_bits: int = 0) -> List[DSEPoint]:
    points = []
    for sys in enumerate_configs(total_dies, wbits, abits, kv_bits):
        for seq in seqs:
            oom = fs.is_oom(sys, cfg, seq)
            lat = math.inf if oom else \
                fs.decode_token_latency(sys, cfg, seq).total
            points.append(DSEPoint(
                sys.name, sys.weight_dies,
                sys.kv_dies if sys.kind == "kvnand-d" else 0,
                wbits, abits, seq, lat, oom, kv_bits,
                capacity=fs.pooled_capacity(sys, cfg, seq)))
    return points


def sweep_kv_formats(cfg: ModelConfig, seqs, total_dies: int = 8,
                     wbits: int = 4, abits: int = 16) -> List[DSEPoint]:
    """Full sweep with the KV bit-width axis unlocked (none/kv8/kv4)."""
    points = []
    for kv_bits in KV_FORMATS:
        points += sweep(cfg, seqs, total_dies, wbits, abits, kv_bits)
    return points


def sweep_speculation(cfg: ModelConfig, seqs, total_dies: int = 8,
                      wbits: int = 4, abits: int = 16, kv_bits: int = 0,
                      accept_rate: float = 0.6,
                      spec_ks=SPEC_KS) -> List[DSEPoint]:
    """Sweep with the speculation_k axis unlocked: per-token latency of
    k-draft verify steps at the assumed per-token `accept_rate` (draft
    overhead — span-scaled MACs/softmax traffic — against one weight
    load and one KV walk amortized over E[accepted+1] tokens)."""
    points = []
    for sys in enumerate_configs(total_dies, wbits, abits, kv_bits):
        for seq in seqs:
            oom = fs.is_oom(sys, cfg, seq)
            for k in spec_ks:
                lat = math.inf if oom else fs.spec_decode_token_latency(
                    sys, cfg, seq, k, accept_rate)
                points.append(DSEPoint(
                    sys.name, sys.weight_dies,
                    sys.kv_dies if sys.kind == "kvnand-d" else 0,
                    wbits, abits, seq, lat, oom, kv_bits,
                    capacity=fs.pooled_capacity(sys, cfg, seq),
                    spec_k=k,
                    tokens_per_step=fs.spec_tokens_per_step(
                        k, accept_rate)))
    return points


def heatmap(cfg: ModelConfig, seqs, total_dies: int = 8, wbits: int = 4,
            abits: int = 16, kv_bits: int = 0) -> Dict[str, Dict[int, float]]:
    """{config_name: {seq: latency}} — Fig 15 layout (inf = OOM blank)."""
    grid: Dict[str, Dict[int, float]] = {}
    for p in sweep(cfg, seqs, total_dies, wbits, abits, kv_bits):
        grid.setdefault(p.system, {})[p.seq] = p.latency
    return grid


def best_config(cfg: ModelConfig, seq: int, total_dies: int = 8,
                wbits: int = 4, abits: int = 16,
                kv_bits: int = 0) -> Optional[DSEPoint]:
    pts = [p for p in sweep(cfg, [seq], total_dies, wbits, abits, kv_bits)
           if not p.oom]
    return min(pts, key=lambda p: p.latency) if pts else None


def _system_of(p: DSEPoint) -> fs.SystemConfig:
    """Rebuild the swept SystemConfig a DSEPoint was scored on."""
    if p.system.startswith("KVNAND-D"):
        return fs.kvnand_d(p.g1, p.g2, p.wbits, p.abits,
                           kv_bits=p.kv_bits)
    return fs.kvnand_c(p.g1, p.wbits, p.abits, kv_bits=p.kv_bits)


def recommend_speculation_k(sys: fs.SystemConfig, cfg: ModelConfig,
                            seq: int, accept_rate: float,
                            spec_ks=SPEC_KS,
                            min_speedup: float = 1.05) -> int:
    """Pick the verify span that minimizes expected per-token latency on
    `sys` at the assumed acceptance rate.  Speculation must BEAT
    sequential decode by `min_speedup` to be recommended at all — a
    compute-bound short-context point where the span-scaled MACs eat
    the amortization keeps speculation_k = 0."""
    base = fs.decode_token_latency(sys, cfg, seq).total
    best_k, best_lat = 0, base
    for k in spec_ks:
        if k <= 0:
            continue
        lat = fs.spec_decode_token_latency(sys, cfg, seq, k, accept_rate)
        if lat < best_lat:
            best_k, best_lat = k, lat
    return best_k if base / max(best_lat, 1e-30) >= min_speedup else 0


def recommend_attn_partitions(sys: fs.SystemConfig, cfg: ModelConfig,
                              seq: int,
                              partition_counts=ATTN_PARTITIONS,
                              min_speedup: float = 1.02) -> int:
    """Pick the split-page partition count that minimizes decode latency
    on `sys`.  Each extra partition buys plane-level KV-read concurrency
    but costs one more NPU merge round trip, so short contexts (where
    the walk is already cheap) keep partitions = 1; the split must BEAT
    the monolithic walk by `min_speedup` to be recommended."""
    base = fs.decode_token_latency(sys, cfg, seq).total
    best_p, best_lat = 1, base
    for p in partition_counts:
        if p <= 1:
            continue
        lat = fs.decode_token_latency(sys, cfg, seq, partitions=p).total
        if lat < best_lat:
            best_p, best_lat = p, lat
    return best_p if base / max(best_lat, 1e-30) >= min_speedup else 1


def recommend_overlap(sys: fs.SystemConfig, cfg: ModelConfig, seq: int,
                      host_s: float, *, span: int = 1,
                      min_speedup: float = 1.02) -> bool:
    """Should the serving loop run the overlapped (dispatch N+1 before
    collect N) schedule on `sys`?  `host_s` is the measured per-step
    host overhead (the serving bench derives it from the synchronous
    loop's `device_idle_s / steps`).  Overlap must BEAT the synchronous
    schedule by `min_speedup` to be recommended — when device compute
    dwarfs host work the pipeline's phantom-step and staging complexity
    buys nothing (DESIGN.md §14)."""
    return fs.overlap_speedup(sys, cfg, seq, host_s,
                              span=span) >= min_speedup


def recommend_hot_pages(sys: fs.SystemConfig, cfg: ModelConfig, seq: int,
                        *, slots: int = 1, page_tokens: int = 64,
                        total_pages: int = 0) -> int:
    """Pick `EngineConfig.hot_pages` for a tiered shared pool on `sys`
    (DESIGN.md §13): the NPU-side SRAM staging buffer sized in KV pages
    (`flashsim.hot_tier_pages`), floored at the pinned working set of
    `slots` concurrent seq-length requests — a mapped hot page is never
    demoted, so admission needs at least that many slots to make
    progress.  Returns 0 (single tier) when the whole flash pool
    (`total_pages`, when known) already fits the hot tier: tiering a
    pool that never demotes buys nothing."""
    if slots <= 0:
        raise ValueError(f"slots must be >= 1, got {slots}")
    working_set = slots * -(-seq // page_tokens)
    hot = max(fs.hot_tier_pages(sys, cfg, page_tokens), working_set)
    if total_pages and hot >= total_pages:
        return 0
    return hot


def recommend_engine_config(arch: str, seq: int, *,
                            total_dies: int = 16,
                            allow_kv_quant: bool = True,
                            spec_accept_rate: float = 0.0) -> EngineConfig:
    """Map the Track-A DSE winner onto Track-B engine knobs:

    KVNAND-D winner  -> discrete plan (HG pipelining on)
    KVNAND-C winner  -> compact plan
    W4A16 vs W8A8    -> whichever quantization wins at this context
    kv8/kv4 pages    -> cheapest KV format, but fidelity-guarded: the
                        bandwidth model is monotone in kv_bits (fewer
                        bits never slows it down), so among candidates
                        within `kv_fidelity_margin` of the best latency
                        the WIDEST format wins.  Low-bit KV is only
                        recommended where KV traffic actually dominates
                        (long context), not as a blanket downgrade.
    speculation_k    -> with `spec_accept_rate` > 0 (the workload's
                        measured/assumed draft acceptance — serving
                        tracks it on `RequestOutput`), the span that
                        minimizes expected per-token latency on the
                        winning system (`recommend_speculation_k`);
                        0 / default keeps sequential decode.
    attn_partitions  -> the split-page partition count that minimizes
                        decode latency on the winning system
                        (`recommend_attn_partitions`): long contexts
                        pick a plane-parallel split, short contexts
                        keep the monolithic walk.
    """
    cfg = get_config(arch)
    kv_axis = tuple(KV_FORMATS) if allow_kv_quant else (0,)
    kv_fidelity_margin = 1.05
    candidates = []
    for wbits, abits, quant in ((4, 16, "w4a16"), (8, 8, "w8a8")):
        for kv_bits in kv_axis:
            p = best_config(cfg, seq, total_dies, wbits, abits, kv_bits)
            if p is not None:
                candidates.append((p.latency, p, quant))
    if not candidates:
        # nothing fits the flash budget — compact + max quantization
        return EngineConfig(variant="compact", quant="w4a16",
                            kv_quant="kv4" if allow_kv_quant else "none")
    best_lat = min(c[0] for c in candidates)
    near = [c for c in candidates if c[0] <= best_lat * kv_fidelity_margin]
    _, p, quant = max(near, key=lambda c: (c[1].kv_bits == 0, c[1].kv_bits,
                                           -c[0]))
    variant = "discrete" if p.system.startswith("KVNAND-D") else "compact"
    spec_k = 0
    if spec_accept_rate > 0.0:
        spec_k = recommend_speculation_k(_system_of(p), cfg, seq,
                                         spec_accept_rate)
    attn_parts = recommend_attn_partitions(_system_of(p), cfg, seq)
    return EngineConfig(variant=variant, quant=quant,
                        hg_pipeline=(variant == "discrete"),
                        kv_quant=KV_FORMATS[p.kv_bits],
                        speculation_k=spec_k,
                        attn_partitions=attn_parts)


def best_discrete(cfg: ModelConfig, seq: int, total_dies: int = 8,
                  wbits: int = 4, abits: int = 16) -> Optional[DSEPoint]:
    pts = [p for p in sweep(cfg, [seq], total_dies, wbits, abits)
           if not p.oom and p.system.startswith("KVNAND-D")]
    return min(pts, key=lambda p: p.latency) if pts else None


def takeaways(cfg30b: ModelConfig, cfg70b: ModelConfig) -> Dict[str, bool]:
    """Machine-checkable versions of the paper's Takeaways 1-2.

    Note (DESIGN.md): at bandwidth granularity the optimal discrete split
    equals compact — max(t_w/g1, t_kv/g2) minimized over g1+g2=N gives
    (t_w+t_kv)/N.  The paper's D-beyond-2K preference rests on buffer-
    pressure/reliability effects; what the bandwidth model *does* predict
    (and the paper also states: "optimal configuration reaching 4 dies in
    G2 at 100K") is that the optimal G2 allocation grows with context.
    """
    out = {}
    # T1: the optimal G2 (KV) die allocation grows with context length
    d_short = best_discrete(cfg70b, 1_000, 8, 4, 16)
    d_long = best_discrete(cfg70b, 100_000, 8, 4, 16)
    out["t1_g2_allocation_grows_with_context"] = (
        d_short is not None and d_long is not None
        and d_long.g2 > d_short.g2)
    # T1b: short context — compact or G1-heavy discrete wins
    s_best = best_config(cfg70b, 1_000, 8, 4, 16)
    out["t1_short_ctx_prefers_compact_or_g1heavy"] = (
        s_best is not None and (s_best.system.startswith("KVNAND-C")
                                or s_best.g1 >= s_best.g2))
    # T2: W8A8 optimum is more G1-heavy than W4A16 optimum (30B, 50K)
    p8 = best_discrete(cfg30b, 50_000, 8, 8, 8)
    p4 = best_discrete(cfg30b, 50_000, 8, 4, 16)
    if p8 and p4:
        out["t2_w8a8_more_g1_heavy"] = p8.g1 >= p4.g1
    return out
