"""HLO-text cost model with while-loop trip-count multiplication.

XLA's aggregate `compiled.cost_analysis()` counts a `while` body ONCE
(verified: an 8-step scan of 2.1 MFLOP matmuls reports 2.1 MFLOP, the
unrolled equivalent 16.8 MFLOP).  Every model here scans over layers (and
microbatches, ring steps, head groups), so aggregate numbers would be off
by 1–2 orders of magnitude.  This module re-derives costs from the
optimized per-device HLO text:

  flops   — `dot` ops: 2 · |result| · K (K from lhs_contracting_dims),
            counted inside fused computations too, × execution multiplicity
            (product of enclosing while trip counts from
            backend_config known_trip_count).
  bytes   — per *scheduled* op (fusions opaque: their params/results only):
            Σ operands + result, with slicing ops counted by the data they
            actually move (dynamic-slice/gather = |result| read,
            dynamic-update-slice/scatter ≈ 2·|update|); parameters/GTE/
            tuple/bitcast/constant are register/aliasing ops → 0.

            TPU-native discounts (the CPU stand-in backend emulates bf16 by
            f32 convert-wrapping every op and double-buffers while-loop
            carries; a TPU build does neither — verified by re-lowering
            with f32 pools: 347 GiB → 16 GiB for the same program):
              * pure convert/repack fusions (only convert/copy/bitcast/
                reshape/transpose/broadcast inside, result dims == a param's
                dims) → 0;
              * dtype-convert aliasing is followed when detecting
                dynamic-(update-)slice targets inside fusions, and the
                in-place result alias match ignores dtype;
              * same-shape top-level `copy` ops (carry double-buffering) → 0.
  colls   — collective payload bytes by kind (all-reduce, all-gather,
            reduce-scatter, all-to-all, collective-permute), × multiplicity.

All values are per-device (the HLO is the SPMD-partitioned module).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(
    r"([a-z]+[0-9]+[a-z0-9]*|pred|token|opaque)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_TRIP_RE = re.compile(r'known_trip_count[\\"{:\s]+n[\\":\s]+"?(\d+)')

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

ZERO_BYTE_OPS = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "iota", "after-all", "add-dependency", "partition-id", "replica-id",
    # control flow: the called computations' ops are costed directly
    "while", "conditional", "call",
}


def _parse_shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        shape = tuple(int(d) for d in dims.split(",") if d)
        out.append((dt, shape))
    return out


def _bytes_of(shapes) -> float:
    total = 0.0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_shapes: List[Tuple[str, Tuple[int, ...]]]
    operand_names: List[str]
    attrs: str
    trip_count: int = 1            # for while ops
    called: Tuple[str, ...] = ()


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, List[Tuple[str, Tuple[int, ...]]]]
    ops: List[Op]


def _split_operands(args: str) -> List[str]:
    """Operand list of `op(...)` — top-level comma split."""
    out, depth, cur = [], 0, []
    for ch in args:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    # older HLO printers emit typed operands ("f32[4,128]{1,0} %name"),
    # newer ones bare "%name" — keep just the symbol
    return [o.split()[-1].lstrip("%") for o in out if o.strip()]


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            m = _COMP_HEADER_RE.match(stripped)
            if m and stripped.endswith("{"):
                params = {}
                for part in _split_operands(m.group(2)):
                    if ":" in part:
                        pname, ptype = part.split(":", 1)
                        params[pname.strip().lstrip("%")] = \
                            _parse_shapes(ptype)
                cur = Computation(m.group(1), params, [])
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(stripped)
        if not m:
            continue
        name, rhs = m.groups()
        # result type(s) = prefix of rhs up to the opcode word
        om = re.match(r"^((?:\([^)]*\)|[a-z0-9_\[\]{},\s]+?))\s+"
                      r"([a-z][\w\-]*)\(", rhs)
        if not om:
            continue
        rtype, opcode = om.group(1), om.group(2)
        # operands: inside the first balanced paren after opcode
        start = rhs.index(opcode + "(") + len(opcode) + 1
        depth, i = 1, start
        while i < len(rhs) and depth:
            if rhs[i] == "(":
                depth += 1
            elif rhs[i] == ")":
                depth -= 1
            i += 1
        operand_str = rhs[start:i - 1]
        attrs = rhs[i:]
        called = tuple(re.findall(
            r"(?:calls|body|condition|to_apply|branch_computations=\{)"
            r"=?%?([\w.\-]+)", attrs))
        op = Op(name=name, opcode=opcode,
                result_shapes=_parse_shapes(rtype),
                operand_names=_split_operands(operand_str),
                attrs=attrs, called=called)
        if opcode == "while":
            tm = _TRIP_RE.search(attrs)
            op.trip_count = int(tm.group(1)) if tm else 1
        cur.ops.append(op)
    return comps


@dataclasses.dataclass
class CostSummary:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    fusible_bytes: float = 0.0     # attention-intermediate traffic a fused
    #                                (Pallas) kernel keeps in VMEM
    collective_bytes: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(default_factory=dict)
    transcendental: float = 0.0

    def to_dict(self):
        return dataclasses.asdict(self)


def _dot_flops(op: Op, symtab) -> float:
    res_elems = 0.0
    for _dt, shape in op.result_shapes:
        n = 1
        for d in shape:
            n *= d
        res_elems += n
    lhs = symtab.get(op.operand_names[0]) if op.operand_names else None
    k = 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    if lhs and m:
        dims = [int(d) for d in m.group(1).split(",") if d]
        shape = lhs[0][1]
        for d in dims:
            if d < len(shape):
                k *= shape[d]
    return 2.0 * res_elems * k


def _op_bytes(op: Op, symtab, zero_cost=frozenset()) -> float:
    if op.opcode in ZERO_BYTE_OPS:
        return 0.0
    res = _bytes_of(op.result_shapes)
    if op.opcode in ("dynamic-slice", "gather", "slice"):
        return res                      # slice read; consumer fuses on TPU
    if op.opcode in ("dynamic-update-slice", "scatter"):
        upd = (symtab.get(op.operand_names[1])
               if len(op.operand_names) > 1 else None)
        return 2.0 * (_bytes_of(upd) if upd else res)
    if op.opcode == "copy" and op.operand_names:
        src = symtab.get(op.operand_names[0])
        if src and [s[1] for s in src] == [s[1] for s in op.result_shapes]:
            return 0.0                  # carry double-buffer alias
    operands = 0.0
    for nm in op.operand_names:
        if nm in zero_cost:
            continue
        shapes = symtab.get(nm)
        if shapes:
            operands += _bytes_of(shapes)
    return operands + res


def _fusion_bytes(op: Op, symtab, comps, classify_only: bool = False):
    """HBM traffic of a fusion: params read + result written, EXCEPT that
    params consumed only through dynamic-slice (and the in-place target of
    dynamic-update-slice, whose output aliases the input) count by the
    slice actually touched — the pattern every paged-KV append and scan
    layer-slice lowers to."""
    PASSTHROUGH = ("bitcast", "copy", "reshape", "transpose", "convert")
    ELEMENTWISE = PASSTHROUGH + (
        "parameter", "broadcast", "constant", "select", "compare", "add",
        "iota", "multiply", "subtract", "and", "or", "xor",
        "shift-right-logical", "shift-right-arithmetic", "shift-left",
        "concatenate")
    INT_STORAGE = {"s8", "u8", "s4", "u4", "s2", "u2"}
    total_in = 0.0
    big: set = set()
    sliced = 0.0
    big_shapes = []
    pure_repack = True
    elementwise_only = True
    has_int_param = False
    for cname in op.called:
        comp = comps.get(cname)
        if comp is None:
            continue
        # resolve pass-through renames (incl. dtype converts: the CPU
        # backend wraps bf16 ops in f32 converts a TPU wouldn't emit)
        alias = {}
        for o in comp.ops:
            if o.opcode in PASSTHROUGH and o.operand_names:
                src = o.operand_names[0]
                alias[o.name] = alias.get(src, src)
            if o.opcode not in PASSTHROUGH and o.opcode not in (
                    "parameter", "broadcast", "constant", "select",
                    "compare", "add", "iota"):
                pure_repack = False
            if o.opcode not in ELEMENTWISE:
                elementwise_only = False
        def origin(nm):
            return alias.get(nm, nm)
        for o in comp.ops:
            if o.opcode in ("dynamic-slice", "gather"):
                tgt = origin(o.operand_names[0]) if o.operand_names else ""
                if tgt in comp.params:
                    big.add(tgt)
                    big_shapes.append(comp.params[tgt])
                    sliced += _bytes_of(o.result_shapes)
            elif o.opcode in ("dynamic-update-slice", "scatter"):
                tgt = origin(o.operand_names[0]) if o.operand_names else ""
                if tgt in comp.params:
                    big.add(tgt)
                    big_shapes.append(comp.params[tgt])
                upd_nm = (o.operand_names[1]
                          if len(o.operand_names) > 1 else None)
                upd = comp.params.get(upd_nm)
                if upd is None:
                    for oo in comp.ops:
                        if oo.name == upd_nm:
                            upd = oo.result_shapes
                sliced += 2.0 * (_bytes_of(upd) if upd else 0.0)
        for pname, pshape in comp.params.items():
            if pname not in big:
                total_in += _bytes_of(pshape)
            if any(dt in INT_STORAGE for dt, _ in pshape):
                has_int_param = True
    # result: drop leaves that alias an in-place-updated big param
    # (dims-only match: emulation may have changed the dtype)
    res = 0.0
    remaining = list(big_shapes)
    dims_in = [[x[1] for x in comps[c].params[p]]
               for c in op.called if c in comps
               for p in comps[c].params]
    for s in op.result_shapes:
        match = next((i for i, bs in enumerate(remaining)
                      if [x[1] for x in bs] == [s[1]]), None)
        if match is not None:
            remaining.pop(match)
        elif pure_repack and [s[1]] in dims_in:
            pass                         # pure convert/repack of an input
        else:
            res += _bytes_of([s])
    if classify_only:
        # True iff this fusion's RESULT is a no-HBM product on TPU
        return (pure_repack and not sliced) or \
            (elementwise_only and has_int_param and not sliced)
    if pure_repack and not sliced:
        return 0.0                       # whole fusion is emulation repack
    if elementwise_only and has_int_param and not sliced:
        # fused dequantization: on TPU the quant_gemv kernel streams the
        # PACKED int weights straight into the MXU — the dequantized bf16
        # copy this fusion writes never touches HBM.  Count the packed read.
        return total_in
    return total_in + sliced + res


def analyze_text(text: str, fusible_last2=frozenset()) -> CostSummary:
    """fusible_last2: set of (d_penultimate, d_last) dim pairs marking
    attention-intermediate tensors (score/probability blocks and KV layout
    copies).  HLO written by the jnp reference path materializes these to
    HBM; the Pallas kernels (the TPU execution path) keep them in VMEM, so
    their traffic is accumulated separately in `fusible_bytes` and the
    roofline reports both raw and kernel-fused memory terms."""
    comps = parse_module(text)
    entry = None
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HEADER_RE.match(line.strip())
            if m:
                entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: the computation named main-ish or the last one
        entry = next((n for n in comps if n.startswith("main")),
                     list(comps)[-1] if comps else None)
    summary = CostSummary()
    if entry is None:
        return summary

    # which computations are fusion bodies (opaque for bytes)
    fused = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.opcode == "fusion":
                fused.update(op.called)

    def walk(comp_name: str, mult: float, count_bytes: bool, seen):
        comp = comps.get(comp_name)
        if comp is None or mult == 0:
            return
        symtab = dict(comp.params)
        for op in comp.ops:
            symtab[op.name] = op.result_shapes
        # results of dequant/repack fusions never hit HBM on TPU (the
        # Pallas quant_gemv / fused consumers read the packed form), so
        # downstream ops must not re-count them as operands
        zero_cost: set = set()
        for op in comp.ops:
            if op.opcode == "fusion" and _fusion_bytes(
                    op, symtab, comps, classify_only=True):
                zero_cost.add(op.name)
        for op in comp.ops:
            if op.opcode == "dot":
                summary.flops += mult * _dot_flops(op, symtab)
            is_coll = next((c for c in COLLECTIVES
                            if op.opcode.startswith(c)), None)
            if is_coll and not op.opcode.endswith("-done"):
                payload = max((_bytes_of([s]) for s in op.result_shapes),
                              default=0.0)
                # -start ops carry (operand, result, ...) tuples
                summary.collectives[is_coll] = \
                    summary.collectives.get(is_coll, 0.0) + mult * payload
                summary.collective_bytes += mult * payload
            if count_bytes and comp_name not in fused:
                if op.opcode == "fusion":
                    b = mult * _fusion_bytes(op, symtab, comps)
                else:
                    b = mult * _op_bytes(op, symtab, zero_cost)
                if b and _is_fusible(op, fusible_last2):
                    summary.fusible_bytes += b
                else:
                    summary.bytes_accessed += b
            if op.opcode == "while":
                for c in op.called:
                    walk(c, mult * op.trip_count, True, seen)
            elif op.opcode == "fusion":
                for c in op.called:
                    walk(c, mult, False, seen)      # flops only
            elif op.opcode in ("call", "conditional", "map", "reduce",
                               "reduce-window", "sort", "custom-call"):
                for c in op.called:
                    walk(c, mult, False, seen)

    walk(entry, 1.0, True, set())
    return summary


def _is_fusible(op: Op, fusible_last2) -> bool:
    if not fusible_last2:
        return False
    for _, shape in op.result_shapes:
        if len(shape) >= 2 and tuple(shape[-2:]) in fusible_last2:
            return True
    return False


def analyze_compiled(compiled, fusible_last2=frozenset()) -> CostSummary:
    return analyze_text(compiled.as_text(), fusible_last2)
