"""Per-cell step builders: (arch × shape × mesh) → jit-able fn + abstract
inputs + sharding trees.  Used by the dry-run, the drivers, and benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (
    SHAPES, EngineConfig, ModelConfig, ShapeConfig, get_config,
    shape_applicable,
)
from repro.core.engine import KVNANDEngine, ShardPlan, plan_sharding
from repro.core.quant import quantize_params_and_specs
from repro.distributed import sharding as shd
from repro.models import transformer
from repro.models.registry import batch_sharding_axes, input_specs
from repro.models.transformer import Runtime
from repro.training import optimizer as opt_mod
from repro.training.train_step import TrainState, make_train_step


FSDP_THRESHOLD = 8e9          # params above this shard over `data` too
BF16_MOMENTS_THRESHOLD = 3e11  # kimi-scale: bf16 AdamW moments


def runtime_for(cfg: ModelConfig, kind: str = "serve") -> Runtime:
    # train: sequence-chunked CE — full [B, S, V] logits are the dominant
    # temp allocation at 150K–260K vocabs (§Perf iteration T1)
    return Runtime(activ_dtype=jnp.bfloat16, attn_impl="ref",
                   loss_chunk=1024 if kind == "train" else 0)


def engine_config_for(cfg: ModelConfig, shape: ShapeConfig,
                      overrides: Optional[Dict[str, Any]] = None
                      ) -> EngineConfig:
    kw: Dict[str, Any] = dict(remat="block")
    if shape.kind == "decode":
        # Measured (EXPERIMENTS.md §Perf Q3/Q4): on TPU the compact plan
        # beats the paper's discrete/HG plan at every context — page
        # validity/masks are computed once instead of per head-group, and
        # the HG overlap is a fixed-function-flash property that doesn't
        # translate to fungible MXUs.  The paper-faithful discrete plan
        # remains selectable (--variant discrete).
        kw.update(variant="compact")
    if overrides:
        kw.update(overrides)
    return EngineConfig(**kw)


def _axes_or_none(axes: Tuple[str, ...]):
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def _sh(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


# ---------------------------------------------------------------------------
# sharding trees
# ---------------------------------------------------------------------------

def batch_shardings(cfg, shape, mesh, rules):
    specs = input_specs(cfg, shape, runtime_for(cfg))
    axes = batch_sharding_axes(cfg, shape)
    out = {}
    for name, sds in specs.items():
        out[name] = NamedSharding(
            mesh, shd.spec_for_shape(sds.shape, axes[name], rules, mesh))
    return out


def cache_shardings(cache, mesh: Mesh, plan: ShardPlan):
    b = _axes_or_none(plan.batch_axes)
    pg = _axes_or_none(plan.page_axes_g)
    pw = _axes_or_none(plan.page_axes_w)
    field_specs = {
        "k_pages_g": P(None, b, None, pg, None, None),
        "v_pages_g": P(None, b, None, pg, None, None),
        "page_table_g": P(b, None),
        "k_pages_w": P(None, b, None, pw, None, None),
        "v_pages_w": P(None, b, None, pw, None, None),
        "page_table_w": P(b, None),
        "page_pos_w": P(b, None),
        "k_scale_g": P(None, b, None, pg),
        "v_scale_g": P(None, b, None, pg),
        "k_scale_w": P(None, b, None, pw),
        "v_scale_w": P(None, b, None, pw),
        "rwkv_state": P(None, b, None, None, None),
        "rwkv_shift": P(None, b, None),
        "rwkv_shift2": P(None, b, None),
        "ssm_state": P(None, b, None, None),
        "conv_tail": P(None, b, None, None),
        "cross_k": P(None, b, "model", None, None),
        "cross_v": P(None, b, "model", None, None),
        "lengths": P(b),
    }
    # shared-pool leaves drop the batch dim (EngineConfig.shared_pool):
    # the physical page axis carries the page sharding instead
    shared_specs = {
        "k_pages_g": P(None, None, pg, None, None),
        "v_pages_g": P(None, None, pg, None, None),
        "k_pages_w": P(None, None, pw, None, None),
        "v_pages_w": P(None, None, pw, None, None),
        "k_scale_g": P(None, None, pg),
        "v_scale_g": P(None, None, pg),
        "k_scale_w": P(None, None, pw),
        "v_scale_w": P(None, None, pw),
    }
    kw = {}
    for f in dataclasses.fields(cache):
        leaf = getattr(cache, f.name)
        if leaf is None:
            kw[f.name] = None
            continue
        spec = field_specs[f.name]
        if len(spec) != leaf.ndim:
            spec = shared_specs[f.name]
        kw[f.name] = NamedSharding(mesh, spec)
    return type(cache)(**kw)


# ---------------------------------------------------------------------------
# cell builders
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    kind: str
    jitted: Any                 # jit-wrapped step fn
    abstract_args: Tuple        # ShapeDtypeStructs to .lower(*args)
    chips: int
    note: str = ""
    fusible_last2: frozenset = frozenset()   # attention-intermediate dims


def _train_fusible_hints(cfg: ModelConfig, total_seq: int,
                         mesh: Mesh) -> frozenset:
    """Score/probability block dims for ring attention + dense paths."""
    m = mesh.shape["model"]
    hints = set()
    for s in {total_seq, total_seq // m}:
        for s2 in {total_seq, total_seq // m}:
            hints.add((s, s2))
    if cfg.is_encoder_decoder:
        enc = total_seq // 4
        for a in {enc, enc // m, total_seq, total_seq // m}:
            for b in {enc, enc // m, total_seq, total_seq // m}:
                hints.add((a, b))
    return frozenset(hints)


def _decode_fusible_hints(cfg: ModelConfig, acache, eng: EngineConfig,
                          mesh: Mesh, plan) -> frozenset:
    hints = set()
    T = eng.page_tokens
    dh, G = cfg.d_head, cfg.group_size
    pools = (("k_pages_g", plan.page_axes_g), ("k_pages_w",
                                               plan.page_axes_w))
    for name, axes in pools:
        pool = getattr(acache, name, None)
        if pool is None:
            continue
        shards = 1
        for a in axes:
            shards *= mesh.shape.get(a, 1)
        npl = pool.shape[3] // max(shards, 1)
        for np_ in {npl, pool.shape[3]}:
            hints |= {(np_, T), (np_ * T, dh), (dh, np_ * T),
                      (G, np_ * T), (np_ * T, G), (np_ * T, T)}
    if getattr(acache, "cross_k", None) is not None:
        senc = acache.cross_k.shape[2]
        npc = senc // T
        hints |= {(npc, T), (npc * T, dh), (dh, npc * T)}
    return frozenset(hints)


def build_train_cell(arch: str, mesh: Mesh, *, multi_pod: bool,
                     eng_overrides=None) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES["train_4k"]
    rt = runtime_for(cfg, kind="train")
    fsdp = cfg.param_count() * 2 > FSDP_THRESHOLD * 2  # bf16 bytes
    overrides = None
    if fsdp and cfg.is_moe:
        # §Perf K3: for MoE, ZeRO-shard the per-expert FFN dim over `data`
        # instead of d_model — the expert einsums then contract over an
        # UNSHARDED dim and XLA reshards the (much smaller) activations
        # rather than gathering 2 TB of expert weights per pass
        overrides = {"embed": None, "moe_mlp": "data"}
    rules = shd.make_rules(fsdp=fsdp, multi_pod=multi_pod,
                           overrides=overrides)
    eng = engine_config_for(cfg, shape, eng_overrides)

    aparams, specs = transformer.abstract_params(cfg, jnp.bfloat16)
    params_sh = shd.tree_shardings(aparams, specs, rules, mesh)

    mdt = (jnp.bfloat16 if cfg.param_count() > BF16_MOMENTS_THRESHOLD
           else jnp.float32)
    acfg = opt_mod.AdamWConfig(moment_dtype=mdt)
    astate = TrainState(
        params=aparams,
        opt=opt_mod.AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            m=jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, mdt),
                           aparams),
            v=jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, mdt),
                           aparams)),
        ef=None)
    state_sh = TrainState(
        params=params_sh,
        opt=opt_mod.AdamWState(step=_sh(mesh), m=params_sh, v=params_sh),
        ef=None)

    abatch = input_specs(cfg, shape, rt)
    batch_sh = batch_shardings(cfg, shape, mesh, rules)

    layer_constrain = None
    if False and fsdp:  # §Perf K1/K2: REFUTED — constraint-steered ZeRO-3
        # gathers repartition the layer einsums replicated over `data`
        # (compute ×12–14 on dbrx/kimi); kept for the record/real-TPU retry
        # ZeRO-3: gather each layer's fsdp shards INSIDE the scan body
        # (per-slice all-gather fwd, per-slice reduce-scatter of grads in
        # bwd) — without this XLA moves full-stack collectives into the
        # loop (EXPERIMENTS.md §Perf, kimi-k2 iteration 1)
        tp_rules = shd.make_rules(fsdp=False, multi_pod=multi_pod)
        layer_specs = specs["layers"]
        batch_axes = tp_rules["batch"]
        x_sh = NamedSharding(mesh, P(
            batch_axes if len(batch_axes) > 1 else batch_axes[0],
            None, None))

        def layer_constrain(pl_, xc):
            def one(leaf, ax):
                if ax is None or not hasattr(leaf, "shape"):
                    return leaf
                ax = tuple(ax)[1:]  # strip the scanned "layer" axis
                return jax.lax.with_sharding_constraint(
                    leaf, NamedSharding(mesh, shd.spec_for_shape(
                        leaf.shape, ax, tp_rules, mesh)))
            is_leaf = lambda x: x is None or isinstance(x, tuple)  # noqa
            pl_ = jax.tree.map(one, pl_, layer_specs, is_leaf=is_leaf)
            # keep activations batch-sharded: gathered (data-replicated)
            # weights otherwise make XLA replicate the layer compute over
            # `data` (measured 14× flops on dbrx — §Perf iteration 2)
            xc = jax.lax.with_sharding_constraint(xc, x_sh)
            return pl_, xc

    step_fn = make_train_step(cfg, rt, acfg, eng,
                              layer_constrain=layer_constrain)
    jitted = jax.jit(step_fn, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
    total_seq = shape.seq_len
    return Cell(arch, shape, "train", jitted, (astate, abatch), mesh.size,
                note=f"fsdp={fsdp} moments={mdt.__name__} remat={eng.remat}",
                fusible_last2=_train_fusible_hints(cfg, total_seq, mesh))


def build_prefill_cell(arch: str, mesh: Mesh, *, multi_pod: bool,
                       eng_overrides=None) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES["prefill_32k"]
    rt = runtime_for(cfg)
    rules = shd.make_rules(fsdp=False, multi_pod=multi_pod)
    eng = engine_config_for(cfg, shape, eng_overrides)
    engine = KVNANDEngine(cfg, eng, rt, mesh)

    aparams, specs = transformer.abstract_params(cfg, jnp.bfloat16)
    if eng.quant != "none":
        aparams, specs = _abstract_quant(cfg, eng.quant)
    params_sh = shd.tree_shardings(aparams, specs, rules, mesh)

    abatch = input_specs(cfg, shape, rt)
    batch_sh = batch_shardings(cfg, shape, mesh, rules)
    max_ctx = shape.seq_len + 1

    def prefill_step(params, batch):
        return engine.prefill(params, batch, max_ctx)

    acache = engine.abstract_cache(
        shape.global_batch, max_ctx,
        enc_len=(shape.seq_len // rt.enc_frames_ratio
                 if cfg.is_encoder_decoder else 0))
    plan = engine.plan(shape.global_batch, max_ctx)
    cache_sh = cache_shardings(acache, mesh, plan)

    jitted = jax.jit(prefill_step, in_shardings=(params_sh, batch_sh),
                     out_shardings=(None, cache_sh))
    return Cell(arch, shape, "prefill", jitted, (aparams, abatch), mesh.size,
                note=f"variant={eng.variant}",
                fusible_last2=_train_fusible_hints(cfg, shape.seq_len, mesh))


def build_decode_cell(arch: str, shape_name: str, mesh: Mesh, *,
                      multi_pod: bool, eng_overrides=None) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rt = runtime_for(cfg)
    rules = shd.make_rules(fsdp=False, multi_pod=multi_pod)
    eng = engine_config_for(cfg, shape, eng_overrides)
    engine = KVNANDEngine(cfg, eng, rt, mesh)

    aparams, specs = transformer.abstract_params(cfg, jnp.bfloat16)
    if eng.quant != "none":
        aparams, specs = _abstract_quant(cfg, eng.quant)
    params_sh = shd.tree_shardings(aparams, specs, rules, mesh)

    B, S_ctx = shape.global_batch, shape.seq_len
    enc_len = (S_ctx // rt.enc_frames_ratio if cfg.is_encoder_decoder else 0)
    acache = engine.abstract_cache(B, S_ctx + 8, enc_len=enc_len)
    NPg = (acache.k_pages_g.shape[3] if acache.k_pages_g is not None else 1)
    plan = plan_sharding(mesh, B, NPg)
    cache_sh = cache_shardings(acache, mesh, plan)
    tok_sh = NamedSharding(mesh, P(_axes_or_none(plan.batch_axes), None))
    atoks = jax.ShapeDtypeStruct((B, 1), jnp.int32)

    def serve_step(params, cache, tokens):
        return engine.decode_step(params, cache, tokens)

    jitted = jax.jit(serve_step,
                     in_shardings=(params_sh, cache_sh, tok_sh),
                     out_shardings=(None, cache_sh), donate_argnums=(1,))
    return Cell(arch, shape, "decode", jitted, (aparams, acache, atoks),
                mesh.size,
                note=f"variant={eng.variant} quant={eng.quant} "
                     f"pages={plan.page_axes_g}",
                fusible_last2=_decode_fusible_hints(cfg, acache, eng, mesh,
                                                    plan))


def _abstract_quant(cfg: ModelConfig, scheme: str):
    holder = {}

    def f(k):
        params, specs = transformer.init_model(cfg, k, jnp.bfloat16)
        qp, qs = quantize_params_and_specs(params, specs, scheme)
        holder["specs"] = qs
        return qp

    aparams = jax.eval_shape(f, jax.random.PRNGKey(0))
    return aparams, holder["specs"]


def build_cell(arch: str, shape_name: str, mesh: Mesh, *, multi_pod: bool,
               eng_overrides=None) -> Optional[Cell]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None
    if shape.kind == "train":
        return build_train_cell(arch, mesh, multi_pod=multi_pod,
                                eng_overrides=eng_overrides)
    if shape.kind == "prefill":
        return build_prefill_cell(arch, mesh, multi_pod=multi_pod,
                                  eng_overrides=eng_overrides)
    return build_decode_cell(arch, shape_name, mesh, multi_pod=multi_pod,
                             eng_overrides=eng_overrides)
