"""Render EXPERIMENTS.md tables from artifacts/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os
import sys

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "artifacts", "dryrun")


def load(multi_pod: bool):
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("multi_pod") == multi_pod:
            rows.append(r)
    return rows


def fmt_ms(s):
    return f"{s * 1e3:.1f}"


def roofline_table(multi_pod: bool = False) -> str:
    rows = load(multi_pod)
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
             "long_500k": 3}
    rows.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    out = ["| arch | shape | GiB/dev | compute ms | memory ms (raw) | "
           "collective ms | bottleneck | useful |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                       f"skipped: sub-quadratic-only shape | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                       f"ERROR {r.get('error','')[:40]} | — |")
            continue
        roof = r["roofline"]
        mem = r.get("memory", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{mem.get('total_bytes', 0) / 2**30:.1f} | "
            f"{fmt_ms(roof['compute_s'])} | {fmt_ms(roof['memory_s'])} "
            f"({fmt_ms(roof['memory_raw_s'])}) | "
            f"{fmt_ms(roof['collective_s'])} | {roof['bottleneck']} | "
            f"{roof['useful_ratio']:.2f} |")
    return "\n".join(out)


def summary_stats():
    single = [r for r in load(False) if r["status"] == "ok"]
    multi = [r for r in load(True) if r["status"] == "ok"]
    sk = [r for r in load(False) if r["status"] == "skipped"]
    print(f"single-pod ok: {len(single)}  multi-pod ok: {len(multi)}  "
          f"skipped/mesh: {len(sk)}")
    worst = sorted(
        single, key=lambda r: -(r["roofline"]["memory_s"]
                                + r["roofline"]["collective_s"])
        / max(r["roofline"]["compute_s"], 1e-9))[:5]
    print("\nworst roofline fraction (dominant/compute):")
    for r in worst:
        roof = r["roofline"]
        print(f"  {r['arch']} × {r['shape']}: compute "
              f"{fmt_ms(roof['compute_s'])} vs mem "
              f"{fmt_ms(roof['memory_s'])} coll "
              f"{fmt_ms(roof['collective_s'])}")
    collb = sorted(single,
                   key=lambda r: -r["roofline"]["collective_s"])[:5]
    print("\nmost collective-bound:")
    for r in collb:
        roof = r["roofline"]
        print(f"  {r['arch']} × {r['shape']}: coll "
              f"{fmt_ms(roof['collective_s'])} "
              f"({ {k: round(v/2**30, 1) for k, v in roof['collectives'].items()} } GiB)")


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "table":
        print(roofline_table(multi_pod=len(sys.argv) > 2))
    else:
        summary_stats()
