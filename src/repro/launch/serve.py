"""Serving driver: batched requests through the KVNAND engine with
continuous batching (see serving/scheduler.py).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --reduced --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import EngineConfig, get_config
from repro.core.dse import recommend_engine_config
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.serving.scheduler import (ContinuousBatcher, Request,
                                     SpliceBatcher)


def serve(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-context", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--scheduler", choices=("interleaved", "splice"),
                    default="interleaved",
                    help="interleaved: chunked prefill shares each step "
                    "with the decode batch; splice: legacy admit-time "
                    "full prefill (baseline)")
    ap.add_argument("--chunk-tokens", type=int, default=64,
                    help="prefill chunk size (multiple of page_tokens)")
    ap.add_argument("--use-dse", action="store_true",
                    help="pick variant/quant from the Track-A DSE")
    ap.add_argument("--shared-pool", action="store_true",
                    help="shared-pool paged KV (§IV-D FTL mapping): one "
                    "physical page pool, admission by free pages, "
                    "prefix-cache sharing with COW")
    ap.add_argument("--total-pages", type=int, default=0,
                    help="shared-pool size in pages (0: slots × pages "
                    "per max_context — byte parity with the stripes)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    pool_kw = dict(shared_pool=args.shared_pool,
                   total_pages=args.total_pages)
    if args.use_dse:
        eng = recommend_engine_config(args.arch, args.max_context)
        eng = EngineConfig(**{**eng.__dict__, "page_tokens": 16,
                              "uniform_lengths": False, "quant": "none",
                              **pool_kw})
        print(f"[serve] DSE picked variant={eng.variant} "
              f"kv_quant={eng.kv_quant}")
    else:
        eng = EngineConfig(page_tokens=16, uniform_lengths=False,
                           **pool_kw)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg, Runtime())
    params = model.init(jax.random.PRNGKey(0))

    cls = ContinuousBatcher if args.scheduler == "interleaved" \
        else SpliceBatcher
    batcher = cls(cfg, params, batch_slots=args.slots,
                  max_context=args.max_context, eng=eng,
                  temperature=args.temperature,
                  prefill_chunk_tokens=args.chunk_tokens)
    rng = np.random.default_rng(0)
    for uid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              int(rng.integers(4, 24))).tolist()
        batcher.submit(Request(uid=uid, prompt=prompt,
                               max_new=args.max_new))
    t0 = time.time()
    done = batcher.run_to_completion()
    dt = time.time() - t0
    total_tokens = sum(len(r.output) for r in done.values())
    st = batcher.stats
    print(f"[serve] {len(done)} requests, {total_tokens} tokens in "
          f"{dt:.1f}s ({total_tokens / dt:.1f} tok/s on CPU)")
    print(f"[serve] scheduler={args.scheduler}: {st['steps']} steps, "
          f"{st['prefill_chunks']} prefill chunks, {st['compiles']} "
          f"compiles, {st['decode_stall_tokens']} decode-stall tokens "
          f"over {st['admits']} admits")
    if args.shared_pool and st["pool_total_pages"]:
        hit_rate = st["prefix_hit_pages"] / max(st["prompt_pages"], 1)
        print(f"[serve] shared pool: peak {st['pool_peak_pages']}/"
              f"{st['pool_total_pages']} pages live, "
              f"{hit_rate:.0%} prompt pages from prefix cache, "
              f"{st['cow_copies']} COW copies")
    for uid in sorted(done)[:3]:
        print(f"  req {uid}: {len(done[uid].output)} tokens -> "
              f"{done[uid].output[:8]}...")
    return done


if __name__ == "__main__":
    serve()
