"""Serving driver: batched requests through the request-centric
`KVNANDServer` facade (serving/api.py) — per-request SamplingParams,
streaming outputs, TTFT/TPOT reporting.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
      --reduced --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import EngineConfig
from repro.core.dse import recommend_engine_config
from repro.serving.api import (KVNANDServer, SamplingParams, ServerConfig,
                               accepted_tokens_per_step,
                               latency_percentile)


def serve(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-context", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=None,
                    help="per-request sampling seed (bit-reproducible "
                    "output regardless of batch composition)")
    ap.add_argument("--scheduler", choices=("interleaved", "splice"),
                    default="interleaved",
                    help="interleaved: chunked prefill shares each step "
                    "with the decode batch; splice: legacy admit-time "
                    "full prefill (baseline)")
    ap.add_argument("--chunk-tokens", type=int, default=64,
                    help="prefill chunk size (multiple of page_tokens)")
    ap.add_argument("--use-dse", action="store_true",
                    help="pick variant/quant from the Track-A DSE")
    ap.add_argument("--shared-pool", action="store_true",
                    help="shared-pool paged KV (§IV-D FTL mapping): one "
                    "physical page pool, admission by free pages, "
                    "prefix-cache sharing with COW")
    ap.add_argument("--total-pages", type=int, default=0,
                    help="shared-pool size in pages (0: slots × pages "
                    "per max_context — byte parity with the stripes)")
    ap.add_argument("--hot-pages", type=int, default=0,
                    help="tiered flash KV hierarchy (DESIGN.md §13): "
                    "keep only this many pages device-resident (the hot "
                    "tier) and stage the rest from the capacity tier; "
                    "0 = single tier.  Requires --shared-pool; "
                    "repro.core.dse.recommend_hot_pages derives a value "
                    "from the flash model")
    ap.add_argument("--no-tier-prefetch", action="store_true",
                    help="disable the queue-ahead hot-tier prefetch "
                    "stage (every capacity-tier map-in demand-faults — "
                    "the ablation serving_bench measures)")
    ap.add_argument("--speculation-k", type=int, default=None,
                    help="draft tokens verified per decode step "
                    "(prompt-lookup self-drafting, DESIGN.md §11); "
                    "0 forces sequential decode, unset defers to the "
                    "EngineConfig (e.g. a --use-dse pick)")
    ap.add_argument("--overlap", action="store_true",
                    help="pipelined stepping (DESIGN.md §14): dispatch "
                    "step N+1 before collecting step N so host "
                    "bookkeeping hides behind device compute; outputs "
                    "stay token-identical to the synchronous loop")
    ap.add_argument("--http", action="store_true",
                    help="serve over HTTP instead of running the batch "
                    "trace: POST /v1/completions (one-shot + SSE), "
                    "GET /metrics — the asyncio front door "
                    "(repro.serving.async_server)")
    ap.add_argument("--port", type=int, default=8777,
                    help="HTTP port for --http (0 = ephemeral)")
    args = ap.parse_args(argv)

    if args.http:
        from repro.serving.async_server import main as http_main
        http_argv = ["--arch", args.arch, "--port", str(args.port),
                     "--slots", str(args.slots),
                     "--max-context", str(args.max_context)]
        if args.reduced:
            http_argv.append("--reduced")
        if not args.overlap:
            http_argv.append("--no-overlap")
        return http_main(http_argv)

    pool_kw = dict(shared_pool=args.shared_pool,
                   total_pages=args.total_pages,
                   hot_pages=args.hot_pages)
    if args.use_dse:
        eng = recommend_engine_config(args.arch, args.max_context)
        eng = EngineConfig(**{**eng.__dict__, "page_tokens": 16,
                              "uniform_lengths": False, "quant": "none",
                              **pool_kw})
        print(f"[serve] DSE picked variant={eng.variant} "
              f"kv_quant={eng.kv_quant}")
    else:
        eng = EngineConfig(page_tokens=16, uniform_lengths=False,
                           **pool_kw)

    spec_k = (args.speculation_k if args.speculation_k is not None
              else eng.speculation_k)
    server = KVNANDServer(ServerConfig(
        arch=args.arch, reduced=args.reduced, engine=eng,
        scheduler=args.scheduler, batch_slots=args.slots,
        max_context=args.max_context,
        prefill_chunk_tokens=args.chunk_tokens,
        speculation_k=args.speculation_k,
        tier_prefetch=not args.no_tier_prefetch,
        overlap=args.overlap))
    cfg = server.cfg
    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                        top_p=args.top_p, seed=args.seed,
                        max_new_tokens=args.max_new)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size,
                            int(rng.integers(4, 24))).tolist()
               for _ in range(args.requests)]
    t0 = time.time()
    outs = server.generate(prompts, sp)
    dt = time.time() - t0
    total_tokens = sum(len(o.token_ids) for o in outs)
    st = server.stats
    print(f"[serve] {len(outs)} requests, {total_tokens} tokens in "
          f"{dt:.1f}s ({total_tokens / dt:.1f} tok/s on CPU)")
    print(f"[serve] scheduler={args.scheduler}: {st['steps']} steps, "
          f"{st['prefill_chunks']} prefill chunks, {st['compiles']} "
          f"compiles, {st['decode_stall_tokens']} decode-stall tokens "
          f"over {st['admits']} admits")
    ttfts = [o.ttft for o in outs]
    tpots = [o.tpot for o in outs]
    print(f"[serve] TTFT p50/p95 {latency_percentile(ttfts, 50) * 1e3:.0f}/"
          f"{latency_percentile(ttfts, 95) * 1e3:.0f} ms, "
          f"TPOT p50/p95 {latency_percentile(tpots, 50) * 1e3:.0f}/"
          f"{latency_percentile(tpots, 95) * 1e3:.0f} ms "
          "(CPU; first requests carry jit compiles)")
    if spec_k > 0 and st["spec_steps"]:
        per_step = accepted_tokens_per_step(st["spec_accepted"],
                                            st["spec_steps"])
        print(f"[serve] speculation k={spec_k}: "
              f"{per_step:.2f} tokens/verify-step "
              f"({st['spec_accepted']}/{st['spec_drafted']} drafts "
              "accepted)")
    if args.shared_pool and st["pool_total_pages"]:
        hit_rate = st["prefix_hit_pages"] / max(st["prompt_pages"], 1)
        print(f"[serve] shared pool: peak {st['pool_peak_pages']}/"
              f"{st['pool_total_pages']} pages live, "
              f"{hit_rate:.0%} prompt pages from prefix cache, "
              f"{st['cow_copies']} COW copies")
    if st["tier_hot_slots"]:
        touched = st["tier_hit_pages"] + st["tier_miss_pages"]
        tier_hr = st["tier_hit_pages"] / max(touched, 1)
        print(f"[serve] tiered pool: {st['tier_hot_slots']} hot slots "
              f"(peak {st['tier_peak_hot']} resident), "
              f"{tier_hr:.0%} cached map-ins hot, "
              f"{st['tier_stall_tokens']} stall tokens, "
              f"{st['tier_promotes']} promotes / {st['tier_demotes']} "
              f"demotes ({st['tier_prefetch_pages']} prefetched)")
    for o in outs[:3]:
        print(f"  req {o.uid}: {len(o.token_ids)} tokens "
              f"({o.finish_reason}) -> {o.token_ids[:8]}...")
    return {o.uid: o for o in outs}


if __name__ == "__main__":
    serve()
