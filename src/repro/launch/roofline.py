"""Roofline-term extraction from compiled dry-run artifacts.

All XLA metrics on an SPMD-partitioned program are PER-DEVICE (verified
empirically: a (16×256)·(256×512) matmul on a 2×4 mesh reports 0.56 MFLOP
= the per-shard work), so:

    compute term    = flops_per_device            / peak_FLOP/s
    memory term     = bytes_accessed_per_device   / HBM_bw
    collective term = Σ collective operand bytes  / link_bw
                      (operand sizes parsed from the optimized per-device
                       HLO — equivalent to the assignment's global-bytes /
                       (chips·link_bw) formulation)

v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
LINK_BW = 50e9             # B/s / ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "s2": 0.25, "u2": 0.25,
}

_COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b")
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9a-z]+)?|pred|token)"
                       r"\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> Dict[str, float]:
    """Sum per-device operand bytes per collective kind from optimized HLO.

    Uses the op RESULT type on the lhs of each collective instruction —
    for -start ops the result is a tuple (operand, result, ...); we take
    the max leaf as the payload proxy.
    """
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        # match only instruction definitions: "%name = type op-name(...)"
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+)$", line)
        if not m:
            continue
        rhs = m.group(1)
        cm = _COLLECTIVE_RE.search(rhs.split("(")[0])
        if not cm:
            continue
        kind = cm.group(1)
        shapes = _SHAPE_RE.findall(rhs.split(")")[0].split("(")[0])
        if not shapes:
            continue
        payload = max(_shape_bytes(dt, dims) for dt, dims in shapes)
        out[kind] = out.get(kind, 0.0) + payload
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per device
    bytes_accessed: float        # per device (excl. kernel-fusible)
    fusible_bytes: float         # attention intermediates (VMEM on TPU)
    collective_bytes: float      # per device (summed operands)
    collectives: Dict[str, float]
    compute_s: float = 0.0
    memory_s: float = 0.0        # fused-kernel memory term (the roofline)
    memory_raw_s: float = 0.0    # jnp-path memory term (incl. fusible)
    collective_s: float = 0.0
    bottleneck: str = ""
    model_flops: float = 0.0     # 6·N·D (or 2·N·D decode), global
    useful_ratio: float = 0.0    # model_flops / (flops × chips)

    def finalize(self, chips: int, model_flops: float = 0.0):
        self.compute_s = self.flops / PEAK_FLOPS
        self.memory_s = self.bytes_accessed / HBM_BW
        self.memory_raw_s = (self.bytes_accessed
                             + self.fusible_bytes) / HBM_BW
        self.collective_s = self.collective_bytes / LINK_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        self.model_flops = model_flops
        total_hlo = self.flops * chips
        self.useful_ratio = (model_flops / total_hlo) if total_hlo else 0.0
        return self

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, chips: int, model_flops: float = 0.0,
            fusible_last2=frozenset()) -> Roofline:
    """Derive per-device costs from the optimized HLO text via
    launch/hlo_cost.py (XLA's aggregate cost_analysis counts while bodies
    once — useless for scan-over-layers programs; verified empirically)."""
    from repro.launch import hlo_cost
    s = hlo_cost.analyze_compiled(compiled, fusible_last2)
    return Roofline(
        flops=s.flops, bytes_accessed=s.bytes_accessed,
        fusible_bytes=s.fusible_bytes,
        collective_bytes=s.collective_bytes, collectives=dict(s.collectives),
    ).finalize(chips, model_flops)


def memory_summary(compiled) -> Dict[str, float]:
    try:
        ms = compiled.memory_analysis()
        return {
            "argument_bytes": float(ms.argument_size_in_bytes),
            "output_bytes": float(ms.output_size_in_bytes),
            "temp_bytes": float(ms.temp_size_in_bytes),
            "alias_bytes": float(ms.alias_size_in_bytes),
            "total_bytes": float(ms.argument_size_in_bytes
                                 + ms.output_size_in_bytes
                                 + ms.temp_size_in_bytes
                                 - ms.alias_size_in_bytes),
        }
    except Exception:
        return {}


def model_flops_estimate(cfg, shape) -> float:
    """6·N_active·D for train; 2·N_active per generated token (+KV reads
    folded into memory, not FLOPs) for decode; 2·N_active·D prefill."""
    n_act = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_act * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        flops = 2.0 * n_act * tokens
        # attention score/attend FLOPs (quadratic part)
        if cfg.n_heads:
            flops += (4.0 * cfg.n_layers * cfg.n_heads * cfg.d_head
                      * shape.seq_len ** 2 * shape.global_batch * 0.5)
        return flops
    # decode: one token per sequence + attention over the KV cache
    flops = 2.0 * n_act * shape.global_batch
    if cfg.n_heads and cfg.family != "ssm":
        flops += (4.0 * cfg.n_layers * cfg.n_heads * cfg.d_head
                  * shape.seq_len * shape.global_batch)
    return flops
