"""Fault-tolerant training driver.

Checkpoint/restart, async saves, straggler detection, deterministic resume
(index-based data cursor), elastic restart (mesh re-derived from the live
device fleet), optional failure injection for testing the recovery path.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
      --reduced --steps 200 --ckpt-dir /tmp/ckpt [--simulate-failure 57]
"""
from __future__ import annotations

import argparse
import os
import signal
import sys
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import checkpoint as ck
from repro.configs import EngineConfig, get_config
from repro.data.pipeline import DataConfig, DataIterator, make_source
from repro.launch.mesh import mesh_from_devices
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_train_state, make_train_step


class StragglerMonitor:
    """EMA step-time monitor: flags slow steps (at fleet scale this signal
    feeds re-meshing / hot-spare swap; here it logs)."""

    def __init__(self, factor: float = 2.0, alpha: float = 0.1):
        self.ema = None
        self.factor = factor
        self.alpha = alpha
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        slow = self.ema is not None and dt > self.factor * self.ema
        self.ema = dt if self.ema is None else \
            (1 - self.alpha) * self.ema + self.alpha * dt
        if slow:
            self.flagged += 1
        return slow


def train(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none",
                    choices=["none", "block", "full"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--simulate-failure", type=int, default=0,
                    help="crash (exit 17) once at this step, pre-restore")
    ap.add_argument("--data-seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    rt = Runtime()
    model = Model(cfg, rt)
    acfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 2),
                       total_steps=args.steps)
    eng = EngineConfig(remat=args.remat, microbatches=args.microbatches)

    mesh = mesh_from_devices()
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"mesh {dict(mesh.shape)}")

    params = model.init(jax.random.PRNGKey(0))
    state = init_train_state(params, acfg)
    dcfg = DataConfig(seq_len=args.seq_len, global_batch=args.global_batch,
                      vocab_size=cfg.vocab_size, seed=args.data_seed)
    it = DataIterator(make_source(dcfg))
    start_step = 0

    ckpt = None
    if args.ckpt_dir:
        ckpt = ck.AsyncCheckpointer(args.ckpt_dir, keep=3)
        latest = ck.latest_step(args.ckpt_dir)
        if latest is not None:
            state, extra = ck.restore_checkpoint(args.ckpt_dir, latest,
                                                 state)
            it.restore(extra.get("data_index", latest))
            start_step = latest
            print(f"[train] restored step {latest} "
                  f"(data cursor {it.state()})")

    step_fn = jax.jit(make_train_step(cfg, rt, acfg, eng),
                      donate_argnums=(0,))
    monitor = StragglerMonitor()

    def save_and_exit(signum, frame):   # graceful preemption
        if ckpt:
            ckpt.save(step, jax.device_get(state),
                      extra={"data_index": it.state()})
            ckpt.wait()
        print(f"[train] preempted at step {step}; checkpoint saved")
        sys.exit(0)

    signal.signal(signal.SIGTERM, save_and_exit)

    losses = []
    step = start_step
    with mesh:
        for step in range(start_step, args.steps):
            if args.simulate_failure and step == args.simulate_failure \
                    and start_step < args.simulate_failure:
                print(f"[train] SIMULATED FAILURE at step {step}")
                os._exit(17)
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            if monitor.observe(dt):
                print(f"[train] straggler: step {step} took {dt:.2f}s "
                      f"(ema {monitor.ema:.2f}s)")
            if step % args.log_every == 0:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms",
                      flush=True)
            if ckpt and step and step % args.ckpt_every == 0:
                ckpt.save(step, state, extra={"data_index": it.state()})
    if ckpt:
        ckpt.save(args.steps, state, extra={"data_index": it.state()})
        ckpt.wait()
    print(f"[train] done: loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({monitor.flagged} straggler steps)")
    return losses


if __name__ == "__main__":
    train()
