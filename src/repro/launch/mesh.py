"""Production mesh construction (+ elastic re-derivation).

`make_production_mesh` is a FUNCTION (importing this module never touches
jax device state).  Single-pod: 16×16 = 256 chips; multi-pod: 2×16×16 = 512.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

from repro.distributed.sharding import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def mesh_from_devices(devices=None, model_parallel: int = 0) -> Mesh:
    """Elastic mesh: factor whatever devices are alive into (data, model).

    Used on restart after node loss — checkpoints are topology-agnostic, so
    training resumes on the surviving fleet (DESIGN.md §7).
    """
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    if model_parallel <= 0:
        # largest power-of-two model axis ≤ sqrt(n) that divides n
        model_parallel = 1
        m = 1
        while m * 2 <= n and n % (m * 2) == 0 and (m * 2) ** 2 <= n:
            m *= 2
        model_parallel = m
    assert n % model_parallel == 0, (n, model_parallel)
    import numpy as np
    arr = np.asarray(devices).reshape(n // model_parallel, model_parallel)
    try:
        from jax.sharding import AxisType
        return Mesh(arr, ("data", "model"),
                    axis_types=(AxisType.Auto, AxisType.Auto))
    except (ImportError, TypeError):
        return Mesh(arr, ("data", "model"))


def mesh_axis_size(mesh: Optional[Mesh], name: str) -> int:
    if mesh is None:
        return 1
    return mesh.shape.get(name, 1)
