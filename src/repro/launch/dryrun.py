import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The first two lines above MUST precede any other import (jax locks the
device count at first init) — 512 host devices stand in for the production
fleet so `make_production_mesh` builds 16×16 and 2×16×16 meshes.

For each cell:  jit(step).lower(*ShapeDtypeStructs).compile()  — no array
is ever allocated.  Prints memory_analysis (fits?) + cost_analysis (FLOPs/
bytes) and derives the three roofline terms (launch/roofline.py), writing
one JSON artifact per cell under artifacts/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
  python -m repro.launch.dryrun --all --arch-filter moe
"""
import argparse          # noqa: E402
import json              # noqa: E402
import signal            # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, \
    shape_applicable  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_cell  # noqa: E402

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "..", "..", "..", "artifacts", "dryrun")


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             eng_overrides=None, verbose: bool = True,
             cell_timeout: int = 0):
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    tag = f"{arch} × {shape_name} × {'2x16x16' if multi_pod else '16x16'}"
    if not ok:
        if verbose:
            print(f"SKIP {tag}: {why}")
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skipped", "reason": why}

    t0 = time.time()
    record = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
              "chips": mesh.size}
    try:
        if cell_timeout:
            def _on_alarm(signum, frame):
                raise TimeoutError(f"cell exceeded {cell_timeout}s")
            signal.signal(signal.SIGALRM, _on_alarm)
            signal.alarm(cell_timeout)
        with mesh:
            cell = build_cell(arch, shape_name, mesh, multi_pod=multi_pod,
                              eng_overrides=eng_overrides)
            lowered = cell.jitted.lower(*cell.abstract_args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        mem = rl.memory_summary(compiled)          # proves it fits
        mf = rl.model_flops_estimate(cfg, shape)
        roof = rl.analyze(compiled, mesh.size, mf, cell.fusible_last2)
        record.update(
            status="ok", note=cell.note,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory=mem, roofline=roof.to_dict(),
            bytes_per_device=mem.get("total_bytes"),
        )
        if verbose:
            print(f"OK   {tag}  [{cell.note}]")
            print(f"     mem/device: {mem.get('total_bytes', 0)/2**30:.2f} "
                  f"GiB (args {mem.get('argument_bytes', 0)/2**30:.2f} + "
                  f"temp {mem.get('temp_bytes', 0)/2**30:.2f})")
            print(f"     roofline: compute {roof.compute_s*1e3:.2f} ms | "
                  f"memory {roof.memory_s*1e3:.2f} ms (raw "
                  f"{roof.memory_raw_s*1e3:.2f}) | collective "
                  f"{roof.collective_s*1e3:.2f} ms -> {roof.bottleneck}"
                  f" | useful {roof.useful_ratio:.2f}")
    except BaseException as e:  # noqa: BLE001  (incl. TimeoutError)
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc())
        if verbose:
            print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
        if isinstance(e, (KeyboardInterrupt, SystemExit)):
            raise
    finally:
        signal.alarm(0)
    return record


def save_record(record):
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    name = (f"{record['arch']}__{record['shape']}__"
            f"{'multi' if record['multi_pod'] else 'single'}.json")
    with open(os.path.join(ARTIFACT_DIR, name), "w") as f:
        json.dump(record, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--arch-filter", default=None,
                    help="substring or family filter")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--quant", default=None, choices=["w8a8", "w4a16"])
    ap.add_argument("--variant", default=None,
                    choices=["compact", "discrete"])
    ap.add_argument("--page-tokens", type=int, default=None)
    ap.add_argument("--cell-timeout", type=int, default=1800)
    args = ap.parse_args()

    overrides = {}
    if args.quant:
        overrides["quant"] = args.quant
    if args.variant:
        overrides["variant"] = args.variant
    if args.page_tokens:
        overrides["page_tokens"] = args.page_tokens

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    if args.arch_filter:
        archs = [a for a in archs
                 if args.arch_filter in a
                 or get_config(a).family == args.arch_filter]
    shapes = [args.shape] if args.shape else list(SHAPES)
    pods = []
    if not args.multi_pod_only:
        pods.append(False)
    if not args.single_pod_only:
        pods.append(True)

    results = []
    for arch in archs:
        for shape_name in shapes:
            for mp in pods:
                rec = run_cell(arch, shape_name, mp,
                               eng_overrides=overrides or None,
                               cell_timeout=args.cell_timeout)
                save_record(rec)
                results.append(rec)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n=== dry-run summary: {n_ok} ok, {n_skip} skipped "
          f"(documented), {n_err} errors ===")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
