"""int8 cross-pod gradient all-reduce with error feedback.

At 2+ pods the inter-pod links are the scarcest bandwidth (DCI vs ICI).
Gradients are reduced exactly (bf16/f32 psum) *within* a pod over `data`,
then quantized per-tensor to int8 for the *cross-pod* psum — 4× less DCI
traffic — with an error-feedback residual carried in the optimizer extras
so quantization error is re-injected next step (provably converges for
smooth objectives; Karimireddy et al., 2019).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)


def compressed_cross_pod_psum(grads, ef, *, pod_axis: str = "pod",
                              n_pods: int) -> Tuple[Any, Any]:
    """Inside shard_map (manual over pod axis): returns (mean grads, new ef)."""

    def one(g, e):
        gf = g.astype(jnp.float32) + e.astype(jnp.float32)
        amax = jnp.max(jnp.abs(gf))
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127)
        new_e = (gf - q * scale).astype(jnp.bfloat16)          # error feedback
        # int8 payload on the wire; int32 accumulate; per-pod scales summed
        q_sum = jax.lax.psum(q.astype(jnp.int32), pod_axis)
        s_sum = jax.lax.psum(scale, pod_axis)                  # avg scale
        g_out = q_sum.astype(jnp.float32) * (s_sum / n_pods) / n_pods
        return g_out.astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))
