"""AdamW from scratch (no optax dependency).

Moments may be stored in bfloat16 (`moment_dtype`) — at kimi-k2 scale fp32
m/v alone exceed the fleet's HBM; bf16 moments + fp32 master-free update is
the standard large-MoE recipe and is exposed as an EngineConfig knob.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: Any = jnp.float32
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def _is_quant(leaf) -> bool:
    return type(leaf).__name__ == "QuantizedWeight"


def init_adamw(params, cfg: AdamWConfig) -> AdamWState:
    def zeros(p):
        return jnp.zeros(p.shape, cfg.moment_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def adamw_update(params, grads, state: AdamWState, cfg: AdamWConfig
                 ) -> Tuple[Any, AdamWState, jax.Array]:
    """Returns (new_params, new_state, lr)."""
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        vf = v.astype(jnp.float32) * b2 + jnp.square(gf) * (1 - b2)
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (not norms/biases)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, mf.astype(cfg.moment_dtype), vf.astype(cfg.moment_dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), lr


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm
