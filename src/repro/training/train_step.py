"""Distributed train step: microbatched grads, clipping, AdamW, donation.

Two flavours:
  * `make_train_step` — pure-pjit step (XLA inserts every collective); the
    dry-run and most runs use this.
  * `make_compressed_train_step` — manual DP over (pod, data) via shard_map
    (model axis stays auto/TP) with exact in-pod reduction and int8
    error-feedback cross-pod reduction (grad_compress.py).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import EngineConfig, ModelConfig
from repro.models.transformer import Runtime, loss_fn
from repro.training import optimizer as opt_mod
from repro.training.grad_compress import (
    compressed_cross_pod_psum, init_error_feedback,
)


class TrainState(NamedTuple):
    params: Any
    opt: opt_mod.AdamWState
    ef: Optional[Any] = None      # error-feedback residuals (compressed DP)


def init_train_state(params, acfg: opt_mod.AdamWConfig,
                     compressed: bool = False) -> TrainState:
    return TrainState(params=params, opt=opt_mod.init_adamw(params, acfg),
                      ef=init_error_feedback(params) if compressed else None)


def _split_microbatches(batch: Dict[str, jax.Array], n: int):
    def split(x):
        B = x.shape[0]
        assert B % n == 0, (B, n)
        return x.reshape(n, B // n, *x.shape[1:])
    return jax.tree.map(split, batch)


def grads_and_metrics(params, batch, cfg: ModelConfig, rt: Runtime,
                      remat: str, microbatches: int, layer_constrain=None):
    """Microbatch-accumulated mean grads via lax.scan."""
    def one(p, mb):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(p, cfg, mb, rt, remat, layer_constrain)
        return loss, metrics, grads

    if microbatches <= 1:
        loss, metrics, grads = one(params, batch)
        return grads, dict(metrics, loss=loss)

    mbs = _split_microbatches(batch, microbatches)

    def body(acc, mb):
        loss, metrics, grads = one(params, mb)
        acc_g, acc_l = acc
        acc_g = jax.tree.map(lambda a, g: a + g.astype(jnp.float32),
                             acc_g, grads)
        return (acc_g, acc_l + loss), metrics

    zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (acc_g, acc_l), metrics = jax.lax.scan(body, (zero, 0.0), mbs)
    grads = jax.tree.map(lambda g: g / microbatches, acc_g)
    metrics = jax.tree.map(lambda m: m.mean(), metrics)
    return grads, dict(metrics, loss=acc_l / microbatches)


def make_train_step(cfg: ModelConfig, rt: Runtime, acfg: opt_mod.AdamWConfig,
                    eng: EngineConfig, max_grad_norm: float = 1.0,
                    layer_constrain=None):
    """Pure-pjit train step (donate state for in-place update).

    layer_constrain: ZeRO-3 per-layer gather constraint (see
    models/transformer.run_layers) — built by launch/steps.py when fsdp.
    """

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        grads, metrics = grads_and_metrics(
            state.params, batch, cfg, rt, eng.remat, eng.microbatches,
            layer_constrain)
        grads, gnorm = opt_mod.clip_by_global_norm(grads, max_grad_norm)
        new_params, new_opt, lr = opt_mod.adamw_update(
            state.params, grads, state.opt, acfg)
        metrics.update(grad_norm=gnorm, lr=lr)
        return TrainState(new_params, new_opt, state.ef), metrics

    return train_step


def make_compressed_train_step(cfg: ModelConfig, rt: Runtime,
                               acfg: opt_mod.AdamWConfig, eng: EngineConfig,
                               mesh: Mesh, max_grad_norm: float = 1.0):
    """Manual-DP train step with int8 cross-pod gradient compression.

    shard_map is manual over the DP axes (pod/data) — each shard computes
    grads on its local microbatch — while `model` remains auto (TP inside).
    In-pod reduction is exact; cross-pod uses int8 + error feedback.
    """
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_pods = mesh.shape.get("pod", 1)
    n_data = mesh.shape.get("data", 1)

    def local_grads(params, ef, batch):
        grads, metrics = grads_and_metrics(params, batch, cfg, rt,
                                           eng.remat, eng.microbatches)
        # exact reduction inside the pod (cheap ICI)
        if "data" in dp_axes and n_data > 1:
            grads = jax.tree.map(
                lambda g: jax.lax.pmean(g.astype(jnp.float32), "data"),
                grads)
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "data"),
                                   metrics)
        # compressed reduction across pods (scarce DCI)
        if "pod" in dp_axes and n_pods > 1:
            grads, ef = compressed_cross_pod_psum(grads, ef, n_pods=n_pods)
            metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"),
                                   metrics)
        return grads, ef, metrics

    batch_spec = P(dp_axes if len(dp_axes) > 1 else dp_axes[0])

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        from repro.core.seqpar import shard_map
        fn = shard_map(
            local_grads, mesh=mesh,
            in_specs=(P(), P(), batch_spec),
            out_specs=(P(), P(), P()),
            axis_names=set(dp_axes), check_vma=False)
        grads, ef, metrics = fn(state.params, state.ef, batch)
        grads, gnorm = opt_mod.clip_by_global_norm(grads, max_grad_norm)
        new_params, new_opt, lr = opt_mod.adamw_update(
            state.params, grads, state.opt, acfg)
        metrics.update(grad_norm=gnorm, lr=lr)
        return TrainState(new_params, new_opt, ef), metrics

    return train_step
