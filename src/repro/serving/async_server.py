"""Asyncio HTTP front door over `KVNANDServer` (DESIGN.md §14).

The serving shape ROADMAP item 2 asks for, stdlib-only (no FastAPI /
uvicorn — the container pins its dependency set):

  * an ENGINE THREAD runs the overlapped scheduler loop — dispatch step
    N+1, collect step N — so the device stays busy while the host emits
    tokens, routes stream events, and admits new arrivals;
  * the ASYNCIO THREAD runs a hand-rolled HTTP/1.1 server
    (`asyncio.start_server`): OpenAI-style ``POST /v1/completions``
    (JSON in; one-shot JSON or SSE ``data:`` chunks out),
    ``GET /metrics`` (Prometheus text, serving/metrics.py), and
    ``GET /healthz``;
  * the two sides meet at a thread-safe command queue (submissions and
    aborts hop onto the engine thread — the scheduler is single-
    threaded by design) and per-request `asyncio.Queue`s fed via
    `loop.call_soon_threadsafe` (stream events hop back);
  * ADMISSION BACKPRESSURE: when the scheduler's waiting queue plus
    unprocessed submissions reach ``max_queue``, new completions get
    HTTP 429 with a Retry-After instead of queuing unboundedly —
    deadlines and the page-count admission gate handle the rest;
  * per-request ``priority`` / ``deadline_s`` fields pass straight into
    the scheduler's admission order (`KVNANDServer.submit`).

Prompts are token-id lists (this repo serves token-level models; there
is no tokenizer dependency to bake in).  `BackgroundServer` runs the
whole stack on a side thread for tests, examples, and notebook use:

    with BackgroundServer(ServerConfig(reduced=True)) as srv:
        host, port = srv.address
        ... http.client against (host, port) ...
"""
from __future__ import annotations

import argparse
import asyncio
import contextlib
import dataclasses
import json
import queue
import threading
import traceback
from typing import Dict, List, Optional, Tuple

from repro.serving.api import (KVNANDServer, SamplingParams, ServerConfig,
                               StreamEvent)
from repro.serving.metrics import ServingMetrics

__all__ = ["AsyncServerConfig", "AsyncKVNANDServer", "BackgroundServer",
           "main"]


@dataclasses.dataclass(frozen=True)
class AsyncServerConfig:
    """Front-door knobs (the model/scheduler side lives in
    `ServerConfig`).  ``max_queue`` bounds requests accepted but not yet
    admitted to a slot — beyond it the server answers 429.  ``overlap``
    selects the pipelined engine loop; off is the synchronous ablation
    the serving bench measures against."""
    host: str = "127.0.0.1"
    port: int = 0                   # 0 = ephemeral (CI-friendly)
    max_queue: int = 32
    overlap: bool = True
    default_max_tokens: int = 16
    metrics_window: int = 1024
    idle_poll_s: float = 0.02       # engine-thread block while fully idle


@dataclasses.dataclass
class _Submission:
    """One completion hopping from the asyncio thread to the engine."""
    prompt: List[int]
    params: SamplingParams
    priority: int
    deadline: Optional[float]
    future: "asyncio.Future[int]"           # resolves to the uid
    events: "asyncio.Queue[StreamEvent]"


class AsyncKVNANDServer:
    """The asyncio front door.  Owns the engine thread for its
    `KVNANDServer`; start with `await start()`, stop with `await
    aclose()` (or use `BackgroundServer` from synchronous code)."""

    def __init__(self, server: KVNANDServer,
                 config: Optional[AsyncServerConfig] = None):
        self._server = server
        self._acfg = config or AsyncServerConfig()
        self.metrics = ServingMetrics(window=self._acfg.metrics_window)
        self._cmd: "queue.Queue[Tuple[str, object]]" = queue.Queue()
        self._subs: Dict[int, "asyncio.Queue[StreamEvent]"] = {}
        self._stop = threading.Event()
        self._engine_exc: Optional[BaseException] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._http: Optional[asyncio.base_events.Server] = None
        self._engine: Optional[threading.Thread] = None
        self.address: Optional[Tuple[str, int]] = None

    # -- lifecycle ------------------------------------------------------
    async def start(self):
        self._loop = asyncio.get_running_loop()
        self._engine = threading.Thread(target=self._engine_loop,
                                        name="kvnand-engine", daemon=True)
        self._engine.start()
        self._http = await asyncio.start_server(
            self._handle, self._acfg.host, self._acfg.port)
        self.address = self._http.sockets[0].getsockname()[:2]
        return self

    async def serve_forever(self):
        async with self._http:
            await self._http.serve_forever()

    async def aclose(self):
        if self._http is not None:
            self._http.close()
            await self._http.wait_closed()
        self._stop.set()
        if self._engine is not None:
            await self._loop.run_in_executor(None, self._engine.join)

    # -- engine thread: the overlapped scheduler loop -------------------
    def _engine_loop(self):
        srv, overlap = self._server, self._acfg.overlap
        try:
            while not self._stop.is_set():
                worked = self._drain_commands()
                if not (srv._busy() or srv.pending_steps()):
                    if not worked:
                        self._apply_blocking()      # park until a command
                    continue
                if overlap:
                    # keep one step in flight ahead of the collect: the
                    # host side below (event routing, metrics, admits)
                    # then runs entirely under device compute
                    if srv.pending_steps() == 0 and srv._busy():
                        srv.dispatch()
                    if srv._busy():
                        srv.dispatch()
                    events = srv.collect()
                else:
                    events = srv.step()
                self._route_events(events)
        except BaseException as e:           # noqa: BLE001 — fail loud,
            self._engine_exc = e             # unblock every waiter
            traceback.print_exc()
            self._stop.set()
            self._drain_commands()

    def _apply_blocking(self):
        try:
            kind, payload = self._cmd.get(timeout=self._acfg.idle_poll_s)
        except queue.Empty:
            return
        self._apply(kind, payload)

    def _drain_commands(self) -> bool:
        worked = False
        while True:
            try:
                kind, payload = self._cmd.get_nowait()
            except queue.Empty:
                return worked
            self._apply(kind, payload)
            worked = True

    def _apply(self, kind: str, payload):
        if kind == "abort":
            self._server.abort(payload)
            # the abort's terminal marker event surfaces at the next
            # collect/step via _drain_events; route it even when the
            # scheduler goes idle
            self._route_events(self._server._drain_events())
            return
        sub: _Submission = payload
        if self._engine_exc is not None:
            self._resolve(sub.future,
                          RuntimeError("engine loop died"), exc=True)
            return
        try:
            uid = self._server.submit(sub.prompt, sub.params,
                                      priority=sub.priority,
                                      deadline=sub.deadline)
        except ValueError as e:
            self._resolve(sub.future, e, exc=True)
            return
        self._subs[uid] = sub.events
        self._resolve(sub.future, uid)

    def _resolve(self, fut, value, exc: bool = False):
        setter = fut.set_exception if exc else fut.set_result
        self._loop.call_soon_threadsafe(
            lambda: None if fut.cancelled() else setter(value))

    def _route_events(self, events: List[StreamEvent]):
        for ev in events:
            q = self._subs.get(ev.uid)
            if q is not None:
                self._loop.call_soon_threadsafe(q.put_nowait, ev)
            if ev.finish_reason is not None:
                self._subs.pop(ev.uid, None)
                try:
                    self.metrics.observe(self._server.output(ev.uid))
                    self._server.release(ev.uid)
                except (KeyError, ValueError):
                    pass                     # already released (abort race)

    # -- asyncio thread: HTTP ------------------------------------------
    def _overloaded(self) -> bool:
        return (len(self._server._batcher.queue) + self._cmd.qsize()
                >= self._acfg.max_queue)

    def _gauges(self) -> Dict[str, float]:
        b = self._server._batcher
        g = {"kvnand_queue_depth": float(len(b.queue)),
             "kvnand_running_requests":
                 float(sum(r is not None for r in b.slots)),
             "kvnand_pending_steps": float(b.pending_steps)}
        if b.alloc is not None:
            g["kvnand_pool_live_pages"] = float(b.alloc.live_count)
            g["kvnand_pool_util"] = (b.alloc.live_count
                                     / max(b.alloc.total, 1))
        if b.tier is not None:
            g["kvnand_tier_resident_pages"] = float(b.tier.resident_count)
        return g

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter):
        try:
            line = await reader.readline()
            if not line:
                return
            try:
                method, path, _ = line.decode("latin1").split(None, 2)
            except ValueError:
                return
            headers = {}
            while True:
                h = await reader.readline()
                if h in (b"\r\n", b"\n", b""):
                    break
                k, _, v = h.decode("latin1").partition(":")
                headers[k.strip().lower()] = v.strip()
            body = b""
            n = int(headers.get("content-length") or 0)
            if n:
                body = await reader.readexactly(n)
            await self._route(method, path.split("?")[0], body, writer)
            await writer.drain()
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            with contextlib.suppress(Exception):
                writer.close()

    @staticmethod
    def _respond(writer, status: str, payload: bytes,
                 ctype: str = "application/json",
                 extra: Tuple[str, ...] = ()):
        head = [f"HTTP/1.1 {status}", f"Content-Type: {ctype}",
                f"Content-Length: {len(payload)}", "Connection: close",
                *extra, "", ""]
        writer.write("\r\n".join(head).encode("latin1") + payload)

    def _error(self, writer, status: str, message: str,
               extra: Tuple[str, ...] = ()):
        self._respond(writer, status, json.dumps(
            {"error": {"message": message}}).encode(), extra=extra)

    async def _route(self, method: str, path: str, body: bytes, writer):
        if (method, path) == ("GET", "/healthz"):
            self._respond(writer, "200 OK",
                          b"ok\n" if self._engine_exc is None
                          else b"engine dead\n", ctype="text/plain")
        elif (method, path) == ("GET", "/metrics"):
            text = self.metrics.render(self._server.stats, self._gauges())
            self._respond(writer, "200 OK", text.encode(),
                          ctype="text/plain; version=0.0.4")
        elif (method, path) == ("POST", "/v1/completions"):
            await self._completions(body, writer)
        else:
            self._error(writer, "404 Not Found", f"no route {path}")

    async def _completions(self, body: bytes, writer):
        if self._stop.is_set() or self._engine_exc is not None:
            return self._error(writer, "503 Service Unavailable",
                               "engine loop is not running")
        try:
            payload = json.loads(body or b"{}")
        except json.JSONDecodeError as e:
            return self._error(writer, "400 Bad Request",
                               f"invalid JSON body: {e}")
        prompt = payload.get("prompt")
        if (not isinstance(prompt, list)
                or not all(isinstance(t, int) and not isinstance(t, bool)
                           for t in prompt)):
            return self._error(writer, "400 Bad Request",
                               "prompt must be a list of token ids")
        if self._overloaded():
            self.metrics.observe_rejected()
            return self._error(writer, "429 Too Many Requests",
                               "admission queue is full; retry later",
                               extra=("Retry-After: 1",))
        try:
            params = SamplingParams(
                max_new_tokens=int(payload.get(
                    "max_tokens", self._acfg.default_max_tokens)),
                temperature=float(payload.get("temperature", 0.0)),
                top_k=int(payload.get("top_k", 0)),
                top_p=float(payload.get("top_p", 1.0)),
                seed=payload.get("seed"),
                stop_token_ids=tuple(payload.get("stop_token_ids", ())),
                logprobs=bool(payload.get("logprobs", False)))
            priority = int(payload.get("priority", 0))
            deadline = payload.get("deadline_s")
            deadline = None if deadline is None else float(deadline)
        except (TypeError, ValueError) as e:
            return self._error(writer, "400 Bad Request", str(e))
        sub = _Submission(prompt=prompt, params=params, priority=priority,
                          deadline=deadline,
                          future=self._loop.create_future(),
                          events=asyncio.Queue())
        self._cmd.put(("submit", sub))
        try:
            uid = await sub.future
        except (ValueError, RuntimeError) as e:
            return self._error(writer, "400 Bad Request", str(e))
        if payload.get("stream"):
            await self._stream_response(writer, uid, sub.events)
        else:
            await self._oneshot_response(writer, uid, sub.events,
                                         len(prompt))

    async def _next_event(self, events) -> Optional[StreamEvent]:
        """Wait for the request's next event, giving up if the engine
        thread dies underneath the wait."""
        while True:
            try:
                return await asyncio.wait_for(events.get(), timeout=1.0)
            except asyncio.TimeoutError:
                if self._stop.is_set() or self._engine_exc is not None:
                    return None

    async def _oneshot_response(self, writer, uid: int, events,
                                n_prompt: int):
        token_ids, logprobs, reason = [], [], None
        while reason is None:
            ev = await self._next_event(events)
            if ev is None:
                return self._error(writer, "503 Service Unavailable",
                                   "engine loop died mid-request")
            if ev.token is not None:
                token_ids.append(ev.token)
                if ev.logprob is not None:
                    logprobs.append(ev.logprob)
            reason = ev.finish_reason
        self._respond(writer, "200 OK", json.dumps({
            "id": f"cmpl-{uid}", "object": "text_completion",
            "model": self._server.cfg.name,
            "choices": [{"index": 0, "token_ids": token_ids,
                         "logprobs": logprobs or None,
                         "finish_reason": reason}],
            "usage": {"prompt_tokens": n_prompt,
                      "completion_tokens": len(token_ids),
                      "total_tokens": n_prompt + len(token_ids)}
        }).encode())

    async def _stream_response(self, writer, uid: int, events):
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-cache\r\n"
                     b"Connection: close\r\n\r\n")
        try:
            reason = None
            while reason is None:
                ev = await self._next_event(events)
                if ev is None:
                    break
                chunk = {"id": f"cmpl-{uid}",
                         "object": "text_completion.chunk",
                         "choices": [{"index": 0, "token": ev.token,
                                      "position": ev.index,
                                      "logprob": ev.logprob,
                                      "finish_reason": ev.finish_reason}]}
                writer.write(f"data: {json.dumps(chunk)}\n\n".encode())
                await writer.drain()
                reason = ev.finish_reason
            writer.write(b"data: [DONE]\n\n")
            await writer.drain()
        except ConnectionError:
            # client went away mid-stream: reclaim the slot and pages
            self._cmd.put(("abort", uid))


class BackgroundServer:
    """Run the whole async stack (model + engine thread + HTTP) on a
    side thread — the synchronous-code entry point used by tests,
    examples/serve_http.py, and the README quickstart.  Context-manager
    protocol; `address` is the bound (host, port)."""

    def __init__(self, config: Optional[ServerConfig] = None,
                 async_config: Optional[AsyncServerConfig] = None, *,
                 cfg=None, params=None):
        self._config, self._acfg = config, async_config
        self._cfg, self._params = cfg, params
        self._ready = threading.Event()
        self._startup_exc: Optional[BaseException] = None
        self._aloop: Optional[asyncio.AbstractEventLoop] = None
        self._astop: Optional[asyncio.Event] = None
        self._thread: Optional[threading.Thread] = None
        self.address: Optional[Tuple[str, int]] = None
        self.server: Optional[AsyncKVNANDServer] = None

    async def _amain(self):
        self._aloop = asyncio.get_running_loop()
        self._astop = asyncio.Event()
        try:
            inner = KVNANDServer(self._config, cfg=self._cfg,
                                 params=self._params)
            self.server = AsyncKVNANDServer(inner, self._acfg)
            await self.server.start()
            self.address = self.server.address
        except BaseException as e:           # noqa: BLE001
            self._startup_exc = e
            self._ready.set()
            raise
        self._ready.set()
        await self._astop.wait()
        await self.server.aclose()

    def __enter__(self) -> "BackgroundServer":
        self._thread = threading.Thread(
            target=lambda: asyncio.run(self._amain()),
            name="kvnand-http", daemon=True)
        self._thread.start()
        self._ready.wait()
        if self._startup_exc is not None:
            raise RuntimeError("async server failed to start") \
                from self._startup_exc
        return self

    def __exit__(self, *exc):
        if self._aloop is not None and self._astop is not None:
            self._aloop.call_soon_threadsafe(self._astop.set)
        if self._thread is not None:
            self._thread.join(timeout=30)
        return False


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="KVNAND async HTTP serving front door")
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="CI-scale model dims")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8777)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-context", type=int, default=256)
    ap.add_argument("--max-queue", type=int, default=32)
    ap.add_argument("--no-overlap", action="store_true",
                    help="synchronous engine loop (ablation)")
    args = ap.parse_args(argv)

    async def _run():
        inner = KVNANDServer(ServerConfig(
            arch=args.arch, reduced=args.reduced,
            batch_slots=args.slots, max_context=args.max_context))
        srv = AsyncKVNANDServer(inner, AsyncServerConfig(
            host=args.host, port=args.port, max_queue=args.max_queue,
            overlap=not args.no_overlap))
        await srv.start()
        host, port = srv.address
        print(f"[async_server] listening on http://{host}:{port} "
              f"(overlap={'off' if args.no_overlap else 'on'})")
        try:
            await srv.serve_forever()
        except asyncio.CancelledError:
            pass
        finally:
            await srv.aclose()

    try:
        asyncio.run(_run())
    except KeyboardInterrupt:
        pass
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
