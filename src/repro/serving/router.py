"""Multi-replica request router with disaggregated prefill/decode.

`ReplicaRouter` spreads requests over N `KVNANDServer` replicas
(DESIGN.md §16).  Two modes:

* **routed** (default): every request runs end-to-end on the
  least-loaded replica (queue depth + occupied slots, ties to the
  lowest index).  Priority and deadline pass straight through to each
  replica's admission order, so backpressure, deadline expiry, and
  abort-with-page-conservation behave exactly as on one server.

* **disaggregated** (`disaggregate=True`): replica 0 is the PREFILL
  replica; the rest decode.  A request chunk-prefills on replica 0 with
  its slot HELD (`Request.hold` keeps it out of decode dispatch), then
  its KV state crosses to the least-loaded decode replica as a
  `KVEnvelope` — always through the real wire bytes
  (`to_bytes`/`from_bytes`), so `stats["migration_bytes"]` measures the
  actual transfer cost.  The source keeps its pages until the import
  lands; a destination that cannot take the request yet (no free slot,
  pool or hot-tier pressure) simply retries next step, so no admission
  invariant is ever bypassed.

Cross-replica prefix sharing: a `PrefixPageIndex` collects full-page
chains from whichever replica finishes (or migrates) a prompt and warms
them into a target replica's local prefix cache right before submit, so
system-prompt pages prefilled on replica A admit as prefix hits on
replica B.

The router itself never touches the clock — timing lives in the
replicas' schedulers — so fake-clock soak tests drive it by patching
`scheduler.time`/`api.time` as usual.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.serving.api import KVNANDServer, RequestOutput, StreamEvent
from repro.serving.replica import (KVEnvelope, PrefixPageIndex,
                                   export_request, finish_migrated,
                                   import_request)
from repro.serving.sampler import SamplingParams


class ReplicaRouter:
    """Route requests across replicas; optionally disaggregate prefill
    from decode with parity-proven KV page migration."""

    def __init__(self, replicas: Sequence[KVNANDServer], *,
                 disaggregate: bool = False,
                 prefix_index: Optional[PrefixPageIndex] = None,
                 share_prefix: bool = True):
        if not replicas:
            raise ValueError("ReplicaRouter needs at least one replica")
        if disaggregate and len(replicas) < 2:
            raise ValueError("disaggregated mode needs a prefill replica "
                             "plus at least one decode replica")
        self.servers: List[KVNANDServer] = list(replicas)
        self.disaggregate = disaggregate
        self.index = prefix_index
        if self.index is None and share_prefix:
            for s in self.servers:
                if s._batcher.prefix_cache is not None:
                    self.index = PrefixPageIndex(
                        s._batcher.engine.eng.page_tokens)
                    break
        self._home: Dict[int, int] = {}     # uid -> replica index
        self._rr = 0                        # rotating tie-break cursor
        self._next_uid = 0
        self.stats: Dict[str, int] = {
            "migrations": 0, "migration_bytes": 0,
            "migration_retries": 0, "prefix_warmed_pages": 0,
            "prefix_published_pages": 0,
        }

    # -- placement -------------------------------------------------------

    def _load(self, k: int) -> int:
        b = self.servers[k]._batcher
        return len(b.queue) + sum(r is not None for r in b.slots)

    def _decode_indices(self) -> List[int]:
        return (list(range(1, len(self.servers))) if self.disaggregate
                else list(range(len(self.servers))))

    def _least_loaded(self, candidates: Sequence[int]) -> int:
        """Minimum load; ties rotate (round-robin cursor) so an idle
        fleet still spreads — and cross-replica prefix warming actually
        crosses replicas."""
        n = len(self.servers)
        k = min(candidates,
                key=lambda k: (self._load(k), (k - self._rr) % n))
        self._rr = (k + 1) % n
        return k

    def replica_of(self, uid: int) -> int:
        """The replica currently holding `uid` (its slot, queue entry,
        or finished output)."""
        return self._home[uid]

    # -- request lifecycle ----------------------------------------------

    def submit(self, prompt: Sequence[int],
               params: Optional[SamplingParams] = None, *,
               uid: Optional[int] = None, priority: int = 0,
               deadline: Optional[float] = None) -> int:
        """Queue one prompt on the chosen replica (prefill replica in
        disaggregated mode, else least-loaded); uids are router-global.
        Priority/deadline semantics are the single-server ones."""
        if uid is None:
            uid = self._next_uid
        if uid in self._home:
            raise ValueError(f"uid {uid} already submitted")
        k = 0 if self.disaggregate else self._least_loaded(
            self._decode_indices())
        server = self.servers[k]
        if self.index is not None and not self.disaggregate:
            self.stats["prefix_warmed_pages"] += self.index.warm(
                server._batcher, prompt)
        server.submit(prompt, params, uid=uid, priority=priority,
                      deadline=deadline)
        if self.disaggregate:
            # held through prefill: the slot is excluded from decode
            # dispatch until its KV state migrates to a decode replica
            server._requests[uid].hold = True
        self._home[uid] = k
        self._next_uid = max(self._next_uid, uid + 1)
        return uid

    def abort(self, uid: int) -> bool:
        """Abort wherever the request currently lives; page conservation
        holds per replica (a mid-migration request still owns its source
        pages, so the source-side abort frees everything)."""
        k = self._home.get(uid)
        if k is None:
            return False
        return self.servers[k].abort(uid)

    # -- stepping --------------------------------------------------------

    def step(self) -> List[StreamEvent]:
        """One step of every busy replica, then (disaggregated mode) the
        migration pump.  Events merge in replica order; each uid's
        stream stays contiguous-per-source and gap-free across the
        handoff (the decode replica resumes at the next index)."""
        events: List[StreamEvent] = []
        for s in self.servers:
            if s._busy() or s.pending_steps():
                events.extend(s.step())
        if self.disaggregate:
            self._pump_migrations()
        if self.index is not None:
            self._publish_finished(events)
        return events

    def _pump_migrations(self) -> None:
        pre = self.servers[0]
        b = pre._batcher
        ready = [r.uid for i, r in enumerate(b.slots)
                 if r is not None and r.hold and not r.done
                 and r.output and i not in b._prefill_live]
        for uid in ready:
            env = export_request(b, uid)
            wire = env.to_bytes()
            env = KVEnvelope.from_bytes(wire)
            if self.index is not None:
                self.stats["prefix_published_pages"] += \
                    self.index.publish_from(b, env.arrays["prompt"])
            req = None
            for k in sorted(self._decode_indices(),
                            key=lambda k: (self._load(k), k)):
                req = import_request(self.servers[k]._batcher, env)
                if req is not None:
                    break
            if req is None:             # destination pressure: the source
                self.stats["migration_retries"] += 1
                continue                # keeps its pages; retry next step
            dec = self.servers[k]
            dec._requests[uid] = req
            dec._streamed[uid] = len(req.output)    # handoff token already
            dec._next_uid = max(dec._next_uid, uid + 1)     # streamed
            finish_migrated(b, uid)
            pre.release(uid)            # drops the "migrated" marker too
            self._home[uid] = k
            self.stats["migrations"] += 1
            self.stats["migration_bytes"] += len(wire)

    def _publish_finished(self, events: Sequence[StreamEvent]) -> None:
        for e in events:
            if e.finish_reason not in ("stop", "length", "capacity"):
                continue
            s = self.servers[self._home[e.uid]]
            req = s._requests.get(e.uid)
            if req is not None:
                self.stats["prefix_published_pages"] += \
                    self.index.publish_from(s._batcher, req.prompt)

    def _busy(self) -> bool:
        return any(s._busy() or s.pending_steps() for s in self.servers)

    def run(self, max_steps: int = 10_000) -> List[StreamEvent]:
        """Drain every replica (and every pending migration)."""
        events: List[StreamEvent] = []
        steps = 0
        while self._busy():
            if steps >= max_steps:
                raise RuntimeError(
                    f"ReplicaRouter.run: max_steps={max_steps} exhausted "
                    "with requests still pending")
            events.extend(self.step())
            steps += 1
        return events

    # -- results ---------------------------------------------------------

    def output(self, uid: int) -> RequestOutput:
        return self.servers[self._home[uid]].output(uid)

    def outputs(self) -> List[RequestOutput]:
        return [self.output(u) for u in sorted(self._home)
                if self.servers[self._home[u]]._requests[u].done]

    def release(self, uid: int) -> None:
        k = self._home.pop(uid)
        self.servers[k].release(uid)

    def replica_stats(self) -> List[Dict[str, int]]:
        return [dict(s.stats) for s in self.servers]
