"""Continuous-batching scheduler over the KVNAND engine.

Chunked prefill interleaved with batched decode:

  * fixed decode batch of B slots; finished/empty slots are refilled from
    the queue between steps;
  * an admitted prompt is prefilled CHUNK BY CHUNK (page-aligned chunks of
    `prefill_chunk_tokens`) straight into its slot's stripe of the shared
    paged pool (`engine.prefill_chunk`) — no one-sequence side cache and
    no splice copy, so admission costs O(chunk) instead of O(prompt);
  * every step spends a token budget: the decode batch (one token per
    active slot) is reserved first, the remainder funds prefill chunks —
    so a steady stream of admits can never starve the decoders, and an
    idle decode batch drains the admission queue at full tilt;
  * decode steps carry an `active` mask so slots that are empty or still
    mid-prefill get no append / length advance (the ragged scatter path,
    `uniform_lengths=False`);
  * per-slot prefill progress (cursor into the prompt, sampled-token
    handoff; ring base positions live in the cache) is host bookkeeping —
    `_PrefillState`;
  * recurrent (ssm/hybrid) and prefix-carrying archs (hymba meta tokens
    would break page alignment of later chunks) prefill as ONE exact-
    length whole-prompt chunk — still in place, still spliceless;
  * slot eviction = clearing host bookkeeping — its pages are simply
    overwritten by the next occupant (per-sequence page stripes, the
    access-aware reuse story of §IV-D); the next occupant's first chunk
    rewrites the window-ring base row, so stale pages can never alias.

`SpliceBatcher` keeps the old admit-time full prefill + jit'd slot splice
as the measured baseline (benchmarks/serving_bench.py) and for parity
tests; the interleaved step never touches the splice path.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EngineConfig, ModelConfig
from repro.core.engine import KVNANDEngine
from repro.models.transformer import Runtime
from repro.serving.sampler import sample

MIN_PROMPT_BUCKET = 16


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new: int
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def bucket_length(n: int, lo: int = MIN_PROMPT_BUCKET,
                  hi: Optional[int] = None) -> int:
    """Smallest power-of-two bucket (≥ lo) holding n tokens, clamped to
    `hi` — near-capacity prompts must not round up past the slot stripe
    (the caller rejects n > hi at submit)."""
    b = lo
    while b < n:
        b *= 2
    if hi is not None:
        b = min(b, hi)
    return b


@dataclasses.dataclass
class _PrefillState:
    """Host-side carry-over of one slot's in-progress chunked prefill."""
    req: Request
    tokens: np.ndarray      # prompt, padded to the chunk grid
    n: int                  # true prompt length
    pos: int = 0            # next chunk's first token (prompt-relative)
    order: int = 0          # admission order (FIFO chunk scheduling)


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_context: int = 512, eng: Optional[EngineConfig] = None,
                 rt: Optional[Runtime] = None, temperature: float = 0.0,
                 seed: int = 0, bucket_prompts: bool = True,
                 prefill_chunk_tokens: int = 64,
                 step_token_budget: Optional[int] = None):
        eng = eng or EngineConfig(page_tokens=16, uniform_lengths=False)
        if eng.uniform_lengths:
            raise ValueError(
                "continuous batching needs the ragged append path: pass "
                "an EngineConfig with uniform_lengths=False (slots advance "
                "out of lockstep, and masked decode steps require the "
                "per-sequence scatter)")
        if prefill_chunk_tokens % eng.page_tokens:
            raise ValueError(
                f"prefill_chunk_tokens={prefill_chunk_tokens} must be a "
                f"multiple of page_tokens={eng.page_tokens} so chunk "
                "starts stay page-aligned")
        self.cfg = cfg
        self.engine = KVNANDEngine(cfg, eng, rt or Runtime())
        self.params = params
        self.B = batch_slots
        self.max_context = max_context
        self.temperature = temperature
        # recurrent prefill folds padding into carried state → exact-length
        self.bucket_prompts = (bucket_prompts
                               and cfg.family not in ("ssm", "hybrid"))
        self.chunk_tokens = prefill_chunk_tokens
        # ssm/hybrid carry state (padding pollutes it) and meta-token
        # prefixes break page alignment of later chunks → one exact chunk
        self._whole_prompt = (cfg.family in ("ssm", "hybrid")
                              or cfg.n_meta_tokens > 0)
        self._prefix = cfg.n_meta_tokens
        self.step_token_budget = (step_token_budget
                                  or prefill_chunk_tokens + batch_slots)
        self.rng = jax.random.PRNGKey(seed)
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.cache = self.engine.init_cache(batch_slots, max_context)
        self._lengths = np.zeros(batch_slots, np.int64)
        self._prefill_live: Dict[int, _PrefillState] = {}
        self._admit_seq = 0
        self._decode = jax.jit(
            lambda p, c, t, a: self.engine.decode_step(p, c, t, active=a),
            donate_argnums=(1,))
        self._chunk_first = jax.jit(
            lambda p, c, t, s, st, n: self.engine.prefill_chunk(
                p, c, {"tokens": t}, s, st, n, first=True),
            donate_argnums=(1,))
        self._chunk_cont = jax.jit(
            lambda p, c, t, s, st, n: self.engine.prefill_chunk(
                p, c, {"tokens": t}, s, st, n, first=False),
            donate_argnums=(1,))
        self.completed: Dict[int, Request] = {}
        self.stats = {"steps": 0, "admits": 0, "prefill_chunks": 0,
                      "decode_tokens": 0, "decode_stall_tokens": 0,
                      "compiles": 0}
        self._compile_keys = set()

    # -- host-side slot management ------------------------------------
    def _count_compile(self, name, *key):
        """Host-side compile census: one per distinct jit signature."""
        k = (name,) + key
        if k not in self._compile_keys:
            self._compile_keys.add(k)
            self.stats["compiles"] += 1

    def submit(self, req: Request):
        n = len(req.prompt)
        cap = self.max_context - 1 - self._prefix
        if n == 0:
            raise ValueError(f"request {req.uid}: empty prompt")
        if n > cap:
            raise ValueError(
                f"request {req.uid}: prompt of {n} tokens exceeds the slot "
                f"capacity of {cap} (max_context={self.max_context} minus "
                f"1 decode token minus {self._prefix} prefix tokens); "
                "truncate the prompt or enlarge max_context")
        self.queue.append(req)

    def _admit(self):
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                n = len(req.prompt)
                if self._whole_prompt:
                    toks = np.asarray(req.prompt, np.int32)
                else:
                    C = self.chunk_tokens
                    toks = np.zeros(-(-n // C) * C, np.int32)
                    toks[:n] = req.prompt
                self._prefill_live[i] = _PrefillState(
                    req, toks, n, order=self._admit_seq)
                self._admit_seq += 1
                self.stats["admits"] += 1

    def _prefill_tick(self, i: int, ps: _PrefillState):
        """Process ONE chunk of slot i's prompt into the shared cache."""
        if self._whole_prompt:
            chunk, c0, cl = ps.tokens, 0, ps.n
        else:
            c0 = ps.pos
            chunk, cl = ps.tokens[c0:c0 + self.chunk_tokens], \
                min(self.chunk_tokens, ps.n - c0)
        fn = self._chunk_first if c0 == 0 else self._chunk_cont
        self._count_compile("chunk", c0 == 0, len(chunk))
        logits, self.cache = fn(
            self.params, self.cache, jnp.asarray(chunk)[None],
            jnp.asarray(i, jnp.int32), jnp.asarray(c0, jnp.int32),
            jnp.asarray(cl, jnp.int32))
        ps.pos = c0 + len(chunk)
        self.stats["prefill_chunks"] += 1
        if ps.pos >= ps.n:                         # prompt fully prefilled
            del self._prefill_live[i]
            self._lengths[i] = self._prefix + ps.n
            self.rng, k = jax.random.split(self.rng)
            tok = int(sample(logits, k, true_vocab=self.cfg.vocab_size,
                             temperature=self.temperature)[0])
            ps.req.output.append(tok)

    def step(self) -> int:
        """One interleaved step: a token budget funds the decode batch
        first (one token per active slot), then prefill chunks (FIFO over
        admitted prompts) — admits never starve decoders; returns the
        number of slots that advanced."""
        self._admit()
        n_decoding = sum(1 for i, r in enumerate(self.slots)
                         if r is not None and i not in self._prefill_live)
        budget = self.step_token_budget - n_decoding
        chunks_done = 0
        for i, ps in sorted(self._prefill_live.items(),
                            key=lambda kv: kv[1].order):
            cost = ps.n if self._whole_prompt else self.chunk_tokens
            # always fund at least one chunk (prefill must progress even
            # under a tiny budget); extra chunks only within budget
            if chunks_done and budget < cost:
                break
            self._prefill_tick(i, ps)
            budget -= cost
            chunks_done += 1
        active = [i for i, r in enumerate(self.slots)
                  if r is not None and i not in self._prefill_live]
        decoded = self._decode_batch(active)
        self.stats["steps"] += 1
        return decoded + chunks_done

    def _decode_batch(self, active: List[int]) -> int:
        """One masked decode over `active` slots: sample, advance lengths,
        sweep completions (shared by both schedulers — the parity pair
        must never diverge on this body)."""
        if not active:
            return 0
        tokens = np.zeros((self.B, 1), np.int32)
        mask = np.zeros(self.B, bool)
        for i in active:
            tokens[i, 0] = self.slots[i].output[-1]
            mask[i] = True
        self._count_compile("decode", self.B)
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens),
                                          jnp.asarray(mask))
        self.rng, k = jax.random.split(self.rng)
        next_tokens = sample(logits, k, true_vocab=self.cfg.vocab_size,
                             temperature=self.temperature)
        self._lengths[active] += 1
        self.stats["decode_tokens"] += len(active)
        for i in active:
            req = self.slots[i]
            req.output.append(int(next_tokens[i]))
            if (len(req.output) >= req.max_new
                    or self._lengths[i] + 1 >= self.max_context):
                req.done = True
                self.completed[req.uid] = req
                self.slots[i] = None          # slot pages recycled in place
                self._lengths[i] = 0
        return len(active)

    def run_to_completion(self, max_steps: int = 10_000):
        steps = 0
        while self.queue or any(r is not None for r in self.slots):
            if steps >= max_steps:
                stuck = sorted(
                    [r.uid for r in self.queue]
                    + [r.uid for r in self.slots if r is not None])
                raise RuntimeError(
                    f"run_to_completion: max_steps={max_steps} exhausted "
                    f"with requests still pending (uids {stuck}); raise "
                    "max_steps or check for a wedged slot")
            self.step()
            steps += 1
        return self.completed


class SpliceBatcher(ContinuousBatcher):
    """Admit-time full prefill + jit'd slot splice — the pre-interleave
    baseline.  Kept as the measured reference for `serving_bench` and the
    parity tests; every admit stalls the whole decode batch for the full
    prompt and double-writes its KV pages (one-sequence cache → splice).
    """

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        max_context = self.max_context
        self._prefill1 = jax.jit(
            lambda p, b: self.engine.prefill(p, b, max_context))
        self._prefill1_bucketed = jax.jit(
            lambda p, b, n: self.engine.prefill(p, b, max_context,
                                                prompt_len=n))
        self._splice = jax.jit(_splice_slot, donate_argnums=(0,))

    def _admit(self):
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                # decoders idle for the whole admit: in chunk units, the
                # interleaved scheduler would have run this many decode
                # steps over the currently active slots
                n_dec = sum(1 for j, r in enumerate(self.slots)
                            if r is not None and j != i)
                span = len(self._padded(req))
                self.stats["decode_stall_tokens"] += n_dec * (
                    -(-span // self.chunk_tokens))
                self.stats["admits"] += 1
                self._prefill_slot(i, req)

    def _padded(self, req: Request) -> List[int]:
        n = len(req.prompt)
        if not self.bucket_prompts:
            return req.prompt
        Sb = bucket_length(n, hi=self.max_context - 1)
        return req.prompt + [0] * (Sb - n)

    def _prefill_slot(self, i: int, req: Request):
        """Prefill one sequence and splice its pools/length into slot i."""
        n = len(req.prompt)
        toks = jnp.asarray(self._padded(req), jnp.int32)[None]
        self._count_compile("prefill", toks.shape[1])
        if self.bucket_prompts:
            logits, c1 = self._prefill1_bucketed(
                self.params, {"tokens": toks}, jnp.asarray(n, jnp.int32))
        else:
            logits, c1 = self._prefill1(self.params, {"tokens": toks})
        self._count_compile("splice")
        self.cache = self._splice(self.cache, c1,
                                  jnp.asarray(i, jnp.int32))
        self._lengths[i] = self._prefix + n
        self.rng, k = jax.random.split(self.rng)
        tok = int(sample(logits, k, true_vocab=self.cfg.vocab_size,
                         temperature=self.temperature)[0])
        req.output.append(tok)

    def step(self) -> int:
        """One decode step over all active slots (admits prefill eagerly
        inside `_admit`, stalling the batch)."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        decoded = self._decode_batch(active)
        self.stats["steps"] += 1
        return decoded


_BATCH_AXIS0 = ("page_table_g", "page_pos_w", "lengths")


def _splice_slot(cache, one, i):
    """Copy sequence 0 of a B=1 cache into slot i of the batch cache.

    One `dynamic_update_slice` per leaf: `one` already has a size-1 batch
    dim, so the update writes exactly the slot's stripe.  Jit this with a
    donated `cache` so XLA updates the pools in place instead of copying
    the whole pool per admit.
    """
    updates = {}
    for f in dataclasses.fields(cache):
        cur, new = getattr(cache, f.name), getattr(one, f.name)
        if cur is None:
            continue
        # batch axis position: leaf layouts are [L, B, ...] or [B, ...]
        ax = 0 if f.name in _BATCH_AXIS0 else 1
        start = tuple(jnp.asarray(i if d == ax else 0, jnp.int32)
                      for d in range(cur.ndim))
        updates[f.name] = jax.lax.dynamic_update_slice(
            cur, new.astype(cur.dtype), start)
    return dataclasses.replace(cache, **updates)


def _splice_slot_ref(cache, one, i: int):
    """Eager reference splice (the old O(pool) path) — kept for tests."""
    updates = {}
    for f in dataclasses.fields(cache):
        cur, new = getattr(cache, f.name), getattr(one, f.name)
        if cur is None:
            continue
        if f.name in _BATCH_AXIS0:
            updates[f.name] = cur.at[i].set(new[0])
        else:
            updates[f.name] = cur.at[:, i].set(new[:, 0])
    return dataclasses.replace(cache, **updates)
