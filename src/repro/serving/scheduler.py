"""Continuous-batching scheduler over the KVNAND engine.

The batchers here are INTERNAL engines behind the `KVNANDServer` facade
(`serving/api.py`) — launch/examples/benchmarks construct the facade,
not these classes.  Each request carries its own `SamplingParams`; the
per-slot temperature/top-k/top-p/seed arrays enter the jitted decode
step as traced arguments (one compile for any mix of combinations), and
each request draws from its own `(seed, position)` PRNG stream — see
DESIGN.md §10.

Chunked prefill interleaved with batched decode:

  * fixed decode batch of B slots; finished/empty slots are refilled from
    the queue between steps;
  * an admitted prompt is prefilled CHUNK BY CHUNK (page-aligned chunks of
    `prefill_chunk_tokens`) straight into its slot's stripe of the shared
    paged pool (`engine.prefill_chunk`) — no one-sequence side cache and
    no splice copy, so admission costs O(chunk) instead of O(prompt);
  * every step spends a token budget: the decode batch (one token per
    active slot) is reserved first, the remainder funds prefill chunks —
    so a steady stream of admits can never starve the decoders, and an
    idle decode batch drains the admission queue at full tilt;
  * decode steps carry an `active` mask so slots that are empty or still
    mid-prefill get no append / length advance (the ragged scatter path,
    `uniform_lengths=False`);
  * per-slot prefill progress (cursor into the prompt, sampled-token
    handoff; ring base positions live in the cache) is host bookkeeping —
    `_PrefillState`;
  * recurrent (ssm/hybrid) and prefix-carrying archs (hymba meta tokens
    would break page alignment of later chunks) prefill as ONE exact-
    length whole-prompt chunk — still in place, still spliceless;
  * slot eviction = clearing host bookkeeping — its pages are simply
    overwritten by the next occupant (per-sequence page stripes, the
    access-aware reuse story of §IV-D); the next occupant's first chunk
    rewrites the window-ring base row, so stale pages can never alias.

Shared-pool mode (``EngineConfig.shared_pool``, the §IV-D FTL mapping
proper) replaces the per-slot stripes with ONE physical page pool per
layer-group and moves allocation policy to this host scheduler:

  * admission is by FREE-PAGE COUNT, not free slots: a request is admitted
    when its worst-case footprint ceil((prompt + max_new)/T) pages (plus a
    window-ring allocation for local-attention archs) fits the pool's
    free + cache-evictable pages net of outstanding reservations — so many
    short requests share a pool that could hold only a few max_context
    stripes;
  * global-pool pages are allocated LAZILY as prefill chunks and decode
    appends land; window-ring pages are allocated eagerly at admission
    (the ring is bounded and recycled in place);
  * a radix-style PREFIX CACHE (`core/page_alloc.PrefixCache`) maps a new
    prompt's already-computed full-page prefixes read-only into its table
    (refcount++), and whole-prompt repeats skip prefill entirely (cached
    last-token logits); the first DECODE append into a shared partial
    page triggers COPY-ON-WRITE — the allocator hands the slot a private
    page, the device copies the page bytes, and the table repoints;
  * completion decrements refcounts and returns exclusive pages to the
    free list; pages referenced by the prefix cache survive until LRU
    eviction reclaims them under pressure.

Tiered mode (``EngineConfig.hot_pages``, DESIGN.md §13) splits that pool
into a device-resident HOT tier and a flash-resident CAPACITY tier: the
allocator keeps stable flash page ids, a `HotTier` maps resident ids to
hot slots (the values the page tables actually carry), demoted pages
park their bytes in a host-side store, and a queue-ahead prefetch stage
promotes the next admission's prefix-hit pages at the end of each step
so admissions pin warm pages instead of demand-faulting (faults =
`tier_stall_tokens`).  Pages mapped by a live slot are pinned hot and
never demoted, so decode/chunked-prefill/verify walks cannot fault.

Pipelined stepping (DESIGN.md §14): `step()` is now the back-to-back
composition of two halves —

  * `dispatch()` runs every piece of host bookkeeping step N+1 needs
    BEFORE its device work (admission, prefill chunks, page ensures /
    COWs, table pushes, tier promotions) and then ENQUEUES the jitted
    decode/verify step, keeping the returned token/logprob arrays as
    un-materialized device futures in an `_Inflight` record;
  * `collect()` materializes the OLDEST in-flight step with one
    `jax.device_get` round-trip, emits its tokens (TTFT/TPOT stamps are
    taken here, when tokens are host-visible), sweeps finishes, and
    runs the queue-ahead tier prefetch.

The synchronous schedule (`step()` = dispatch; collect) is bit-identical
to the pre-split loop.  An overlapped driver (serving/api.py `stream()`
with ``ServerConfig.overlap``, serving/async_server.py) instead calls
dispatch(N+1) BEFORE collect(N): the host emission/bookkeeping of step N
then runs concurrently with the device compute of step N+1, because the
dispatch feeds step N+1's token inputs straight from step N's on-device
`toks` array (a `jnp.where` merge against the host staging buffer — the
double-buffered token/mask path) and never blocks.  Stop-token finishes
are host-unpredictable at dispatch time, so an overlapped step may carry
PHANTOM rows for slots that turn out to have finished; collect discards
them by request identity (`_Inflight.reqs`), and the appended garbage
token is memory-safe because appends only land in slot-private pages
within the slot's reservation.  Length/capacity finishes ARE predictable
from host state, and such slots are excluded from the next dispatch.
Verify (speculative) steps consume host-visible history for drafts, so
`dispatch()` drains the pipeline first — speculation runs unoverlapped
but token-identical.

Admission order: `_queue_pick` admits by (priority, deadline, submit
order) — the default priority=0 / no-deadline case degrades to plain
FIFO, and queued requests whose deadline has already passed finish as
``"deadline"`` without costing pages or steps.

`SpliceBatcher` keeps the old admit-time full prefill + jit'd slot splice
as the measured baseline (benchmarks/serving_bench.py) and for parity
tests; the interleaved step never touches the splice path.  The splice
operation is meaningless against a shared pool (a B=1 cache owns a
different pool, and slot stripes no longer exist), so SpliceBatcher
fails fast when handed a shared-pool EngineConfig.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Set

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EngineConfig, ModelConfig
from repro.core import paged_kv
from repro.core.engine import KVNANDEngine
from repro.core.page_alloc import (CacheHit, HotTier, OutOfHotSlots,
                                   OutOfPages, PageAllocator, PrefixCache)
from repro.models.transformer import Runtime
from repro.serving.draft import propose_draft
from repro.serving.sampler import (SamplingParams, request_keys,
                                   sample_with_logprobs,
                                   speculative_accept)

MIN_PROMPT_BUCKET = 16

# One-compiled-signature invariant (DESIGN.md §10/§15): when the test
# suite points this at a list, every batcher registers its decode/verify
# jitted callables here and tests/conftest.py asserts `_cache_size() <= 1`
# after each test — a silent recompile (second traced signature) fails
# the test that triggered it.  `None` (the default) keeps production
# servers free of the bookkeeping.
JIT_WATCH = None


def _watch_jit(name: str, fn) -> None:
    if JIT_WATCH is not None and fn is not None:
        JIT_WATCH.append((name, fn))


@functools.partial(jax.jit, static_argnames=("true_vocab",))
def _sample_one(lg, seeds, pos, t, k, p, *, true_vocab):
    """One-row sampler for the prefill handoff / exact-hit first token.
    Module-level so every batcher in the process shares ONE compile per
    (vocab, shape) — a fresh server does not re-pay the RNG lowering."""
    return sample_with_logprobs(lg, request_keys(seeds, pos),
                                true_vocab=true_vocab, temperature=t,
                                top_k=k, top_p=p)


@dataclasses.dataclass
class Request:
    """One in-flight request.  `params` carries the per-request sampling
    knobs (defaulted from the batcher's `temperature`/`max_new` at submit
    for legacy callers); timing marks feed `RequestOutput`'s TTFT/TPOT;
    the `spec_*` counters feed its acceptance stats when the scheduler
    runs speculative decoding.
    """
    uid: int
    prompt: List[int]
    max_new: int
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    params: Optional[SamplingParams] = None
    logprobs: List[float] = dataclasses.field(default_factory=list)
    # stop|length|capacity|aborted|deadline|migrated
    finish_reason: Optional[str] = None
    # disaggregated prefill (serving/replica.py): a held slot prefills
    # normally but is excluded from decode dispatch, so its KV state can
    # migrate to a decode replica with exactly the prefill handoff token
    # emitted — the decode replica resumes the PRNG stream at position 1
    hold: bool = False
    priority: int = 0         # lower admits first (0 = default class)
    deadline_ts: Optional[float] = None   # monotonic; expired queued
    order: int = 0            # submit sequence (admission tiebreak)
    submit_ts: Optional[float] = None
    first_ts: Optional[float] = None
    finish_ts: Optional[float] = None
    spec_steps: int = 0       # verify steps this request decoded in
    spec_drafted: int = 0     # draft tokens offered for verification
    spec_accepted: int = 0    # draft tokens accepted
    tier_hits: int = 0        # cached pages mapped while hot-resident
    tier_stalls: int = 0      # cached pages demand-promoted from capacity


def bucket_length(n: int, lo: int = MIN_PROMPT_BUCKET,
                  hi: Optional[int] = None) -> int:
    """Smallest power-of-two bucket (≥ lo) holding n tokens, clamped to
    `hi` — near-capacity prompts must not round up past the slot stripe
    (the caller rejects n > hi at submit)."""
    b = lo
    while b < n:
        b *= 2
    if hi is not None:
        b = min(b, hi)
    return b


@dataclasses.dataclass
class _PrefillState:
    """Host-side carry-over of one slot's in-progress chunked prefill."""
    req: Request
    tokens: np.ndarray      # prompt, padded to the chunk grid
    n: int                  # true prompt length
    pos: int = 0            # next chunk's first token (prompt-relative)
    order: int = 0          # admission order (FIFO chunk scheduling)


@dataclasses.dataclass
class _Inflight:
    """One dispatched, not-yet-collected decode/verify step (§14).

    Carries the jitted step's un-materialized device arrays plus the
    host snapshot `collect()` needs to emit without consulting mutable
    scheduler state: the per-slot Request identities at dispatch time
    (a slot whose occupant changed between dispatch and collect — stop
    finish, abort — marks that row a discarded PHANTOM) and the slots
    whose capacity finish was already length-predictable at dispatch."""
    kind: str                       # "decode" | "verify"
    active: List[int]
    reqs: Dict[int, Request]
    toks: jax.Array                 # device future until collect()
    lps: jax.Array
    acc: Optional[jax.Array] = None          # verify: accepted counts
    allowed: Optional[np.ndarray] = None     # verify: per-row draft cap
    cap_finish: Set[int] = dataclasses.field(default_factory=set)


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_context: int = 512, eng: Optional[EngineConfig] = None,
                 rt: Optional[Runtime] = None, temperature: float = 0.0,
                 seed: int = 0, bucket_prompts: bool = True,
                 prefill_chunk_tokens: int = 64,
                 step_token_budget: Optional[int] = None,
                 speculation_k: int = 0, tier_prefetch: bool = True):
        eng = eng or EngineConfig(page_tokens=16, uniform_lengths=False)
        if eng.uniform_lengths:
            raise ValueError(
                "continuous batching needs the ragged append path: pass "
                "an EngineConfig with uniform_lengths=False (slots advance "
                "out of lockstep, and masked decode steps require the "
                "per-sequence scatter)")
        if prefill_chunk_tokens % eng.page_tokens:
            raise ValueError(
                f"prefill_chunk_tokens={prefill_chunk_tokens} must be a "
                f"multiple of page_tokens={eng.page_tokens} so chunk "
                "starts stay page-aligned")
        self.cfg = cfg
        self.engine = KVNANDEngine(cfg, eng, rt or Runtime())
        self.params = params
        self.B = batch_slots
        self.max_context = max_context
        self.temperature = temperature
        # recurrent prefill folds padding into carried state → exact-length
        self.bucket_prompts = (bucket_prompts
                               and cfg.family not in ("ssm", "hybrid"))
        self.chunk_tokens = prefill_chunk_tokens
        # ssm/hybrid carry state (padding pollutes it) and meta-token
        # prefixes break page alignment of later chunks → one exact chunk
        self._whole_prompt = (cfg.family in ("ssm", "hybrid")
                              or cfg.n_meta_tokens > 0)
        self._prefix = cfg.n_meta_tokens
        self.step_token_budget = (step_token_budget
                                  or prefill_chunk_tokens + batch_slots)
        self.seed = seed
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.cache = self.engine.init_cache(batch_slots, max_context)
        self._lengths = np.zeros(batch_slots, np.int64)
        self._prefill_live: Dict[int, _PrefillState] = {}
        self._admit_seq = 0
        self._submit_seq = 0
        # dispatched-but-uncollected steps (DESIGN.md §14): depth 0 in
        # the synchronous schedule, briefly 2 in the overlapped one
        # (dispatch N+1 lands before collect N pops)
        self._inflight: Deque[_Inflight] = deque()
        # host-observed device idleness: set when a collect leaves no
        # step in flight, cleared (and accumulated) at the next device
        # enqueue — exact in the synchronous schedule, ~0 when overlapped
        self._idle_since: Optional[float] = None
        self.shared = eng.shared_pool
        self.alloc: Optional[PageAllocator] = None
        self.alloc_w: Optional[PageAllocator] = None
        self.prefix_cache: Optional[PrefixCache] = None
        # tiered flash KV hierarchy (DESIGN.md §13): hot-tier residency
        # map + host-side capacity store, built by _init_shared_pool
        # when EngineConfig.hot_pages > 0
        self.tier: Optional[HotTier] = None
        self.tier_prefetch = tier_prefetch
        # per-slot sampling params, consumed as TRACED arrays inside the
        # jitted decode step: any mix of per-request temperatures / top-k /
        # top-p / seeds shares the one compiled signature
        self._temps = np.zeros(batch_slots, np.float32)
        self._topk = np.zeros(batch_slots, np.int32)
        self._topp = np.ones(batch_slots, np.float32)
        self._seeds = np.zeros(batch_slots, np.uint32)
        # draft-and-verify speculative decoding (DESIGN.md §11): every
        # decode step becomes a k-token prompt-lookup draft + one-pass
        # verification; 0 keeps the sequential decode path
        if speculation_k < 0:
            raise ValueError(f"speculation_k must be >= 0, "
                             f"got {speculation_k}")
        if speculation_k > 0 and (cfg.family in ("ssm", "hybrid")
                                  or cfg.is_encoder_decoder):
            raise ValueError(
                f"{cfg.name}: speculative decoding needs rollback-able "
                "paged KV; recurrent/encoder-decoder state cannot roll "
                "back — run with speculation_k=0")
        self.spec_k = speculation_k

        def _decode_fn(p, c, t, chain, prev_t, a, temps, tk, tp, seeds,
                       pos):
            # double-buffered feed merge (DESIGN.md §14): rows chained
            # on an uncollected step take that step's device token;
            # folding the select into the step keeps the overlapped
            # dispatch free of eager per-step ops on the host path
            t = jnp.where(chain[:, None], prev_t[:, None], t)
            logits, c = self.engine.decode_step(p, c, t, active=a)
            toks, lps = sample_with_logprobs(
                logits, request_keys(seeds, pos),
                true_vocab=self.cfg.vocab_size, temperature=temps,
                top_k=tk, top_p=tp)
            return toks, lps, c

        self._decode = jax.jit(_decode_fn, donate_argnums=(1,))
        self._no_chain = (np.zeros(self.B, bool),
                          jnp.zeros(self.B, jnp.int32))

        def _verify_fn(p, c, t, a, allowed, temps, tk, tp, seeds, pos):
            # sampling stays a scheduler concern: the engine calls back
            # into `speculative_accept` with the span logits, so the one
            # jitted step covers forward + accept + gated span append
            def _accept(logits):
                toks, lps, acc = speculative_accept(
                    logits, t[:, 1:], seeds, pos, allowed,
                    true_vocab=self.cfg.vocab_size, temperature=temps,
                    top_k=tk, top_p=tp)
                return acc, (toks, lps, acc)

            aux, c = self.engine.verify_step(p, c, t, accept=_accept,
                                             active=a)
            return aux, c

        self._verify = (jax.jit(_verify_fn, donate_argnums=(1,))
                        if speculation_k > 0 else None)
        _watch_jit(f"{type(self).__name__}._decode", self._decode)
        _watch_jit(f"{type(self).__name__}._verify", self._verify)
        self._chunk_first = jax.jit(
            lambda p, c, t, s, st, n: self.engine.prefill_chunk(
                p, c, {"tokens": t}, s, st, n, first=True),
            donate_argnums=(1,))
        self._chunk_cont = jax.jit(
            lambda p, c, t, s, st, n: self.engine.prefill_chunk(
                p, c, {"tokens": t}, s, st, n, first=False),
            donate_argnums=(1,))
        self.completed: Dict[int, Request] = {}
        self.stats = {"steps": 0, "admits": 0, "prefill_chunks": 0,
                      "decode_tokens": 0, "decode_stall_tokens": 0,
                      "compiles": 0, "prefix_hit_pages": 0,
                      "prompt_pages": 0, "cow_copies": 0,
                      "pool_peak_pages": 0, "pool_total_pages": 0,
                      "spec_steps": 0, "spec_drafted": 0,
                      "spec_accepted": 0,
                      "tier_hot_slots": 0, "tier_hit_pages": 0,
                      "tier_miss_pages": 0, "tier_stall_tokens": 0,
                      "tier_promotes": 0, "tier_demotes": 0,
                      "tier_prefetch_pages": 0, "tier_peak_hot": 0,
                      "phantom_tokens": 0, "deadline_drops": 0,
                      "device_idle_s": 0.0}
        self._compile_keys = set()
        if self.shared:
            self._init_shared_pool(eng)

    # -- shared-pool bookkeeping (allocator, tables, prefix cache) -----
    def _init_shared_pool(self, eng: EngineConfig):
        cfg, T = self.cfg, eng.page_tokens
        c = self.cache
        if c.k_pages_g is not None:
            self._NPg = c.page_table_g.shape[1]
            H = c.k_pages_g.shape[2]        # device-resident pages
            if eng.hot_pages > 0:
                # tiered hierarchy (DESIGN.md §13): the allocator spans
                # the FLASH page space (stable ids for tables/caches);
                # only H of those pages are device-resident at a time
                total_flash = eng.total_pages or self.B * self._NPg
                if H > total_flash:
                    raise ValueError(
                        f"hot_pages={eng.hot_pages} (rounded to {H}) "
                        f"exceeds the flash pool of {total_flash} pages; "
                        "shrink hot_pages or grow total_pages")
                if c.k_pages_w is not None:
                    raise ValueError(
                        f"{cfg.name}: tiered pools cover the GLOBAL layer "
                        "group only — window rings recycle their pages in "
                        "place and never cool down; run local-attention "
                        "archs with hot_pages=0")
                self.alloc = PageAllocator(total_flash)
                self.tier = HotTier(H, total_flash)
                # capacity tier: demoted pages' bytes, flash id -> one
                # host array per pool leaf
                self._store: Dict[int, Dict[str, np.ndarray]] = {}
                self.alloc.add_release_hook(self._tier_release)
                self._hot_resv = np.zeros(self.B, np.int64)
                self._hot_out = 0           # sum of per-slot hot footprints
                self.stats["tier_hot_slots"] = H
            else:
                self.alloc = PageAllocator(H)
            self._table_np = np.zeros((self.B, self._NPg), np.int32)
            self.stats["pool_total_pages"] = self.alloc.total
        if c.k_pages_w is not None:
            self._NPw = c.page_table_w.shape[1]
            self.alloc_w = PageAllocator(c.k_pages_w.shape[2])
            self._table_w_np = np.zeros((self.B, self._NPw), np.int32)
        # per-slot maps: logical page -> physical; shared = mapped with
        # refcount > 1 (read-only until COW); ring pages owned outright
        self._slot_pages: List[Dict[int, int]] = [dict()
                                                  for _ in range(self.B)]
        self._slot_shared: List[Set[int]] = [set() for _ in range(self.B)]
        self._slot_ring: List[List[int]] = [[] for _ in range(self.B)]
        self._resv = np.zeros(self.B, np.int64)   # reserved, not yet alloc'd
        self._outstanding = 0
        # prefix sharing needs a pure global-pool arch with no frontend
        # prefix and no recurrent state (window rings recycle pages; meta
        # tokens shift page alignment; ssm/hybrid carry state)
        if (self.alloc is not None and self.alloc_w is None
                and not self._whole_prompt and self._prefix == 0
                and not cfg.is_encoder_decoder):
            self.prefix_cache = PrefixCache(self.alloc, T)
        self._tables_dirty = True
        self._push_tables()

        def cow_copy(cache, src, dst):
            upd = {}
            for name in ("k_pages_g", "v_pages_g", "k_scale_g",
                         "v_scale_g"):
                leaf = getattr(cache, name)
                if leaf is not None:
                    upd[name] = paged_kv.copy_page_shared(leaf, src, dst)
            return dataclasses.replace(cache, **upd)

        self._cow_jit = jax.jit(cow_copy, donate_argnums=(0,))

        # tiered staging: one donated dynamic_update_slice per pool leaf
        # writes a promoted page's bytes into its freshly bound hot slot
        # (the jax.device_put-style upload of DESIGN.md §13); the writer
        # itself lives with the rest of the pool-leaf writers (KV004)
        self._pool_leaves = [n for n in ("k_pages_g", "v_pages_g",
                                         "k_scale_g", "v_scale_g")
                             if getattr(c, n) is not None]
        self._stage_jit = jax.jit(paged_kv.stage_hot_slot,
                                  donate_argnums=(0,))

    # -- tiered flash KV hierarchy (DESIGN.md §13) ---------------------
    def _read_hot(self, slot: int) -> Dict[str, np.ndarray]:
        """Pull one hot slot's bytes to the host (demotion / COW save)."""
        return {n: np.asarray(getattr(self.cache, n)[:, :, slot])
                for n in self._pool_leaves}

    def _tier_release(self, page: int):
        """Allocator release hook: flash page `page` hit refcount 0 on
        ANY free path (slot teardown, cache eviction, speculative
        rollback) — retire its hot slot and capacity-store bytes."""
        self.tier.release(int(page))
        self._store.pop(int(page), None)

    def _bind_slot(self, page: int, avoid: frozenset = frozenset()) -> int:
        """Acquire a hot slot for flash page `page`, demoting the LRU
        unpinned resident to the capacity store when the tier is full
        (its bytes are read back BEFORE the slot is overwritten)."""
        slot, victim = self.tier.bind(page, avoid=avoid)
        if victim is not None:
            self._store[victim] = self._read_hot(slot)
            self.stats["tier_demotes"] += 1
        self.stats["tier_peak_hot"] = max(self.stats["tier_peak_hot"],
                                          self.tier.resident_count)
        return slot

    def _promote(self, page: int, avoid: frozenset = frozenset()) -> int:
        """Stage a capacity-tier page's bytes into a hot slot.  Every
        live non-resident page has bytes in the store (pages leave
        residency only by demotion); fresh allocations bind without a
        copy and never come through here."""
        slot = self._bind_slot(page, avoid=avoid)
        vals = self._store.pop(int(page))
        self._count_compile("tier_stage")
        self.cache = self._stage_jit(
            self.cache, jnp.asarray(slot, jnp.int32),
            {n: jnp.asarray(v) for n, v in vals.items()})
        self.stats["tier_promotes"] += 1
        return slot

    def _tier_prefetch_tick(self):
        """Queue-ahead async prefetch: at the END of a step, promote the
        capacity-tier pages the next admission's prefix hit will map, so
        the admission pins already-resident pages instead of demand-
        faulting.  The staging overlaps the in-flight step's compute
        (flashsim charges it as hidden — DESIGN.md §13); only demand
        promotions count as stall tokens.  Uses the side-effect-free
        cache PEEK and binds around the working set being staged, and
        backs off when every remaining slot is pinned."""
        if (self.tier is None or not self.tier_prefetch or not self.queue
                or self.prefix_cache is None):
            return
        # peek the next ADMISSION candidate (priority/deadline order,
        # not the deque head) — the side-effect-free twin of _queue_pick
        head = min(self.queue, key=self._admission_key)
        hit = self.prefix_cache.lookup(head.prompt, record=False)
        pages = (hit.exact.pages if hit.exact is not None
                 else hit.full_pages)
        if not pages:
            return
        avoid = frozenset(int(p) for p in pages)
        for p in pages:
            if self.tier.is_resident(p):
                self.tier.touch(p)      # keep warm until admission pins
            else:
                try:
                    self._promote(p, avoid=avoid)
                except OutOfHotSlots:
                    break
                self.stats["tier_prefetch_pages"] += 1

    def _push_tables(self):
        """Mirror the host page tables into the device cache leaves (only
        when a mapping actually changed — steady-state decode steps that
        stay inside a page skip the upload entirely)."""
        if not self._tables_dirty:
            return
        upd = {}
        if self.alloc is not None:
            upd["page_table_g"] = jnp.asarray(self._table_np)
        if self.alloc_w is not None:
            upd["page_table_w"] = jnp.asarray(self._table_w_np)
        if upd:
            self.cache = dataclasses.replace(self.cache, **upd)
        self._tables_dirty = False

    def _alloc_g(self, logical: int) -> int:
        """One global-pool page, evicting prefix-cache LRU entries under
        pressure (their pages are the only reclaimable slack)."""
        while True:
            try:
                p = self.alloc.alloc_for_logical(logical)
                self.stats["pool_peak_pages"] = max(
                    self.stats["pool_peak_pages"], self.alloc.live_count)
                return p
            except OutOfPages:
                if self.prefix_cache is None or \
                        not self.prefix_cache.evict_lru():
                    raise RuntimeError(
                        "shared page pool exhausted despite admission "
                        "reservations — allocator accounting bug") from None

    def _ensure_page(self, i: int, lp: int):
        """Slot i is about to WRITE logical page lp: allocate it fresh if
        unmapped, COW it if currently shared (refcount > 1).  Tiered
        pools additionally pin the page hot — a fresh allocation binds a
        slot with no byte traffic (its contents are written before the
        length ever covers them), a COW round-trips the shared bytes
        through the host so the fresh binding may demote the old page
        itself when it was the last unpinned resident."""
        pages = self._slot_pages[i]
        if lp not in pages:
            p = self._alloc_g(lp)
            pages[lp] = p
            if self.tier is not None:
                self._table_np[i, lp] = self._bind_slot(p)
                self.tier.pin(p)
            else:
                self._table_np[i, lp] = p
            self._tables_dirty = True
            self._resv[i] -= 1
            self._outstanding -= 1
            return
        if lp in self._slot_shared[i]:
            old = pages[lp]
            fresh = self.alloc.cow(old)
            if fresh != old:
                self._count_compile("cow")
                if self.tier is not None:
                    # `old` is pinned (this slot maps it): snapshot its
                    # bytes, drop this slot's pin, then bind+stage the
                    # fresh copy — in that order, so the bind may pick
                    # `old` as its own demotion victim without losing
                    # the copy source
                    src = self.tier.slot_of(old)
                    self._store[fresh] = self._read_hot(src)
                    self.tier.unpin(old)
                    self._promote(fresh)
                    self.tier.pin(fresh)
                    self._table_np[i, lp] = self.tier.slot_of(fresh)
                else:
                    self.cache = self._cow_jit(self.cache,
                                               jnp.asarray(old, jnp.int32),
                                               jnp.asarray(fresh, jnp.int32))
                    self._table_np[i, lp] = fresh
                pages[lp] = fresh
                self._tables_dirty = True
                self.stats["cow_copies"] += 1
                self._resv[i] -= 1
                self._outstanding -= 1
            self._slot_shared[i].discard(lp)
            self.stats["pool_peak_pages"] = max(
                self.stats["pool_peak_pages"], self.alloc.live_count)

    def _free_slot_pages(self, i: int):
        if not self.shared:
            return
        if self.alloc is not None and self._slot_pages[i]:
            if self.tier is not None:
                # unpin before the refcount drop: pages the prefix cache
                # still references stay resident (LRU demotion candidates),
                # dead pages release their slot via the allocator hook
                for p in self._slot_pages[i].values():
                    self.tier.unpin(p)
            self.alloc.free(list(self._slot_pages[i].values()))
        if self.alloc_w is not None and self._slot_ring[i]:
            self.alloc_w.free(self._slot_ring[i])
        if self.tier is not None:
            self._hot_out -= int(self._hot_resv[i])
            self._hot_resv[i] = 0
        self._slot_pages[i] = {}
        self._slot_shared[i] = set()
        self._slot_ring[i] = []
        self._outstanding -= int(self._resv[i])
        self._resv[i] = 0

    def _pages_needed(self, req: Request) -> int:
        total = min(self._prefix + len(req.prompt) + req.max_new,
                    self.max_context)
        return -(-total // self.engine.eng.page_tokens)

    def _map_cached_pages(self, i: int, pages) -> int:
        """Map cached pages read-only into slot i's logical pages 0..len:
        one allocator reference each, marked shared (COW before write).

        Tiered pools pin each page hot first: a page the prefetcher (or
        recency) kept resident is a TIER HIT; a page demoted to the
        capacity store demand-faults — promoted on the spot and counted
        as a stall token, the observable cost of the DRAM-free story."""
        req = self.slots[i]
        for j, p in enumerate(pages):
            self.alloc.share([p])
            self._slot_pages[i][j] = p
            self._slot_shared[i].add(j)
            if self.tier is not None:
                if self.tier.is_resident(p):
                    self.stats["tier_hit_pages"] += 1
                    req.tier_hits += 1
                else:
                    self._promote(p)
                    self.stats["tier_miss_pages"] += 1
                    self.stats["tier_stall_tokens"] += 1
                    req.tier_stalls += 1
                self.tier.pin(p)
                self._table_np[i, j] = self.tier.slot_of(p)
            else:
                self._table_np[i, j] = p
        return len(pages)

    def _register_prefix(self, i: int, ps: _PrefillState,
                         logits: np.ndarray):
        """Publish a freshly prefilled prompt's pages into the prefix
        cache.  Full pages are always safe to share (the slot never
        rewrites them).  The trailing PARTIAL page becomes shared too —
        making this slot's own first decode append copy-on-write it — but
        only when the pool has a free page of slack to fund that copy
        (the reservation grows by one to keep admission accounting
        exact)."""
        T = self.engine.eng.page_tokens
        n_pages = -(-ps.n // T)
        pages = [self._slot_pages[i][j] for j in range(n_pages)]
        partial = ps.n % T != 0
        slack = self.alloc.free_count - self._outstanding
        if self.tier is not None:
            # hot-tier slack, not whole-pool slack: the repeat that hits
            # this exact entry must re-pin every page hot AND fund the
            # partial page's COW with a hot slot — against a cold
            # capacity tier the flash pool can have plenty of free pages
            # while the hot tier has none to give, which would publish
            # an unservable hit
            slack = min(slack, self.tier.free_slot_count
                        + self.tier.demotable_count)
        include_exact = (not partial) or slack >= 1
        added = self.prefix_cache.register(
            ps.req.prompt, pages, logits, include_exact=include_exact)
        if added and partial and include_exact:
            self._resv[i] += 1
            self._outstanding += 1
        for j, p in enumerate(pages):
            if self.alloc.refcount[p] > 1:
                self._slot_shared[i].add(j)

    # -- host-side slot management ------------------------------------
    def _count_compile(self, name, *key):
        """Host-side compile census: one per distinct jit signature."""
        k = (name,) + key
        if k not in self._compile_keys:
            self._compile_keys.add(k)
            self.stats["compiles"] += 1

    # -- per-request sampling / lifecycle ------------------------------
    def _seed_of(self, req: Request) -> np.uint32:
        """The request's PRNG-stream seed: its explicit `params.seed`, or
        a (batcher seed, uid) hash — in both cases independent of batch
        composition and admission order, so a request's stream never
        consumes from (or perturbs) any other request's."""
        if req.params is not None and req.params.seed is not None:
            return np.uint32(req.params.seed & 0xFFFFFFFF)
        return np.uint32((self.seed * 0x9E3779B1 + req.uid * 0x85EBCA77
                          + 0x165667B1) & 0xFFFFFFFF)

    def _set_slot_params(self, i: int, req: Request):
        p = req.params
        self._temps[i] = p.temperature
        self._topk[i] = p.top_k
        self._topp[i] = p.top_p
        self._seeds[i] = self._seed_of(req)

    def _sample_row(self, logits, req: Request):
        """Sample ONE request's next token (prefill handoff / exact-hit
        paths) through the same per-request stream the batched decode
        uses: key = fold(seed, tokens emitted so far)."""
        p = req.params
        self._count_compile("sample_row")
        toks, lps = _sample_one(
            jnp.asarray(logits),
            np.asarray([self._seed_of(req)], np.uint32),
            np.asarray([len(req.output)], np.int32),
            np.float32(p.temperature), np.int32(p.top_k),
            np.float32(p.top_p), true_vocab=self.cfg.vocab_size)
        return int(toks[0]), float(lps[0])

    def _finish(self, i: int, reason: str):
        """Retire slot i's request: record the finish reason/timestamp and
        recycle the slot (shared pool: refcounts returned, reservations
        released)."""
        req = self.slots[i]
        req.done = True
        req.finish_reason = reason
        req.finish_ts = time.monotonic()
        self.completed[req.uid] = req
        self.slots[i] = None              # slot pages recycled in place
        self._lengths[i] = 0
        self._free_slot_pages(i)          # shared pool: refcount--

    def _emit_token(self, i: int, req: Request, tok: int, lp: float):
        """Append one sampled token and apply the finish rules (stop
        token beats length; capacity is checked by the decode sweep)."""
        req.output.append(tok)
        if req.params.logprobs:
            req.logprobs.append(lp)
        if req.first_ts is None:
            req.first_ts = time.monotonic()
        if tok in req.params.stop_token_ids:
            self._finish(i, "stop")
        elif len(req.output) >= req.max_new:
            self._finish(i, "length")

    def abort(self, uid: int) -> bool:
        """Cancel a request wherever it is: still queued, mid-chunked-
        prefill, or decoding.  Running requests release their shared-pool
        pages (refcounts intact — prefix-cache references survive) and
        free the slot immediately.  Returns False for unknown/finished
        uids."""
        for r in self.queue:
            if r.uid == uid:
                self.queue.remove(r)
                r.done = True
                r.finish_reason = "aborted"
                r.finish_ts = time.monotonic()
                self.completed[uid] = r
                return True
        for i, r in enumerate(self.slots):
            if r is not None and r.uid == uid:
                self._prefill_live.pop(i, None)
                self._finish(i, "aborted")
                return True
        return False

    def submit(self, req: Request):
        if req.params is None:
            # legacy surface: batcher-global temperature, greedy filters
            req.params = SamplingParams(temperature=self.temperature,
                                        max_new_tokens=req.max_new)
        else:
            req.max_new = req.params.max_new_tokens
        if req.submit_ts is None:
            req.submit_ts = time.monotonic()
        req.order = self._submit_seq
        self._submit_seq += 1
        n = len(req.prompt)
        cap = self.max_context - 1 - self._prefix
        if n == 0:
            raise ValueError(f"request {req.uid}: empty prompt")
        if n > cap:
            raise ValueError(
                f"request {req.uid}: prompt of {n} tokens exceeds the slot "
                f"capacity of {cap} (max_context={self.max_context} minus "
                f"1 decode token minus {self._prefix} prefix tokens); "
                "truncate the prompt or enlarge max_context")
        if self.shared and self.alloc is not None:
            need = self._pages_needed(req)
            if need > self.alloc.total:
                raise ValueError(
                    f"request {req.uid}: worst-case footprint of {need} "
                    f"pages exceeds the shared pool of "
                    f"{self.alloc.total} pages; shrink the prompt/max_new "
                    "or grow EngineConfig.total_pages")
            if self.tier is not None and need > self.tier.hot_slots:
                raise ValueError(
                    f"request {req.uid}: worst-case footprint of {need} "
                    f"pages exceeds the hot tier of "
                    f"{self.tier.hot_slots} pages (mapped pages stay "
                    "pinned hot); shrink the prompt/max_new or grow "
                    "EngineConfig.hot_pages")
        self.queue.append(req)

    @staticmethod
    def _admission_key(r: Request):
        """Admission order: lowest priority class first, then nearest
        deadline, then submit order — all defaults degrade to FIFO."""
        return (r.priority,
                r.deadline_ts if r.deadline_ts is not None else float("inf"),
                r.order)

    def _queue_pick(self) -> Optional[Request]:
        """Sweep queued requests whose deadline already passed (they
        finish as ``"deadline"`` without costing pages or steps), then
        return — without removing — the best admission candidate."""
        now = time.monotonic()
        for r in [r for r in self.queue
                  if r.deadline_ts is not None and now >= r.deadline_ts]:
            self.queue.remove(r)
            r.done = True
            r.finish_reason = "deadline"
            r.finish_ts = now
            self.completed[r.uid] = r
            self.stats["deadline_drops"] += 1
        if not self.queue:
            return None
        return min(self.queue, key=self._admission_key)

    def _admit(self):
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                req = self._queue_pick()
                if req is None:
                    break
                if self.shared:
                    if not self._admit_shared(i, req):
                        break          # best candidate waits for pages
                    continue
                self.queue.remove(req)
                self.slots[i] = req
                self._set_slot_params(i, req)
                self._start_prefill(i, req)
                self.stats["admits"] += 1

    def _start_prefill(self, i: int, req: Request, pos: int = 0):
        n = len(req.prompt)
        if self._whole_prompt:
            toks = np.asarray(req.prompt, np.int32)
        else:
            C = self.chunk_tokens
            toks = np.zeros(-(-n // C) * C, np.int32)
            toks[:n] = req.prompt
        self._prefill_live[i] = _PrefillState(
            req, toks, n, pos=pos, order=self._admit_seq)
        self._admit_seq += 1

    def _admit_shared(self, i: int, req: Request) -> bool:
        """Admission by KV footprint: reserve the request's worst-case
        pages against the pool; map any cached prefix read-only; admit
        only if the remainder fits free + evictable pages."""
        n = len(req.prompt)
        T = self.engine.eng.page_tokens
        need_g = self._pages_needed(req) if self.alloc is not None else 0
        need_w = 0
        if self.alloc_w is not None:
            total = min(self._prefix + n + req.max_new, self.max_context)
            need_w = min(-(-total // T), self._NPw)
        hit = CacheHit()
        if self.prefix_cache is not None:
            hit = self.prefix_cache.lookup(req.prompt)
        if self.alloc is not None:
            hit_pages = (hit.exact.pages if hit.exact is not None
                         else hit.full_pages)
            evictable = (self.prefix_cache.evictable_pages()
                         if self.prefix_cache is not None else 0)
            # mapping the hit PINS its pages: whatever part of `evictable`
            # they are stops being reclaimable the moment this request is
            # admitted, so discount them all (conservative — some may
            # already be pinned by another slot)
            avail = (self.alloc.free_count
                     + max(0, evictable - len(hit_pages))
                     - self._outstanding)
            # fresh pages this slot may still allocate: decode growth,
            # plus the COW of an exact hit's shared partial page
            resv_needed = need_g - (n // T if hit.exact is not None
                                    else len(hit.full_pages))
            if resv_needed > avail:
                return False
            # tiered pool: the request's worst-case footprint must ALSO
            # fit the hot tier net of every live slot's reservation —
            # mapped pages stay pinned for the slot's lifetime, so this
            # bound guarantees allocations/promotions always find a free
            # or demotable slot (never OutOfHotSlots mid-flight)
            if self.tier is not None \
                    and self._hot_out + need_g > self.tier.hot_slots:
                return False
        if self.alloc_w is not None and need_w > self.alloc_w.free_count:
            return False

        self.queue.remove(req)
        self.slots[i] = req
        self._set_slot_params(i, req)
        self.stats["admits"] += 1
        self.stats["prompt_pages"] += -(-n // T)
        if self.tier is not None:
            self._hot_resv[i] = need_g
            self._hot_out += need_g
        # eager window-ring allocation (bounded, recycled in place)
        if self.alloc_w is not None:
            for j in range(need_w):
                p = self.alloc_w.alloc_for_logical(j)
                self._slot_ring[i].append(p)
                self._table_w_np[i, j] = p
            self._tables_dirty = self._tables_dirty or need_w > 0
        if hit.exact is not None:
            # whole-prompt repeat: map EVERY page (incl. the trailing
            # partial one) read-only and skip prefill; the first decode
            # append into the partial page copy-on-writes it
            mapped = self._map_cached_pages(i, hit.exact.pages)
            self._resv[i] = need_g - (n // T)   # partial page may COW
            self._lengths[i] = n
            self.cache = dataclasses.replace(
                self.cache,
                lengths=self.cache.lengths.at[i].set(n))
        else:
            mapped = self._map_cached_pages(i, hit.full_pages)
            self._resv[i] = need_g - mapped     # full pages never rewritten
            self._start_prefill(i, req, pos=mapped * T)
        self._outstanding += int(self._resv[i])
        self.stats["prefix_hit_pages"] += mapped
        self._tables_dirty = self._tables_dirty or mapped > 0
        self._push_tables()
        if hit.exact is not None:
            # first token from the cached last-token logits, through the
            # request's OWN params and PRNG stream (accounting above is
            # final first: a stop/length finish frees the slot cleanly)
            tok, lp = self._sample_row(
                jnp.asarray(hit.exact.logits)[None], req)
            self._emit_token(i, req, tok, lp)
        return True

    def _prefill_tick(self, i: int, ps: _PrefillState):
        """Process ONE chunk of slot i's prompt into the shared cache."""
        if self._whole_prompt:
            chunk, c0, cl = ps.tokens, 0, ps.n
        else:
            c0 = ps.pos
            chunk, cl = ps.tokens[c0:c0 + self.chunk_tokens], \
                min(self.chunk_tokens, ps.n - c0)
        if self.shared:
            # lazy page allocation: back every page this chunk will write
            T = self.engine.eng.page_tokens
            span = c0 + cl + (self._prefix if c0 == 0 else 0)
            if self.alloc is not None:
                for lp in range(c0 // T, -(-span // T)):
                    self._ensure_page(i, lp)
            self._push_tables()
        fn = self._chunk_first if c0 == 0 else self._chunk_cont
        self._count_compile("chunk", c0 == 0, len(chunk))
        logits, self.cache = fn(
            self.params, self.cache, jnp.asarray(chunk)[None],
            jnp.asarray(i, jnp.int32), jnp.asarray(c0, jnp.int32),
            jnp.asarray(cl, jnp.int32))
        ps.pos = c0 + len(chunk)
        self.stats["prefill_chunks"] += 1
        if ps.pos >= ps.n:                         # prompt fully prefilled
            del self._prefill_live[i]
            self._lengths[i] = self._prefix + ps.n
            if self.prefix_cache is not None:
                self._register_prefix(i, ps, np.asarray(logits[0]))
            tok, lp = self._sample_row(logits, ps.req)
            self._emit_token(i, ps.req, tok, lp)

    def step(self) -> int:
        """One interleaved step — `dispatch()` then `collect()` back to
        back, the synchronous schedule (bit-identical to the pre-split
        loop).  An overlapped driver instead primes one dispatch and
        then runs dispatch(N+1); collect(N) so host post-processing of
        step N overlaps device compute of step N+1 (DESIGN.md §14).
        Returns the number of slots that advanced."""
        chunks = self.dispatch()
        return chunks + self.collect()

    def _mark_device_busy(self):
        """Close the host-observed device-idle window at the first
        device enqueue after a pipeline-empty collect."""
        if self._idle_since is not None:
            self.stats["device_idle_s"] += time.monotonic() - self._idle_since
            self._idle_since = None

    def _will_finish(self, i: int, pend: int) -> bool:
        """True when slot i's request is already CERTAIN to finish once
        the pipeline drains — `pend` uncollected tokens ahead of it hit
        its max_new budget, or an in-flight step predicted its capacity
        finish.  Such slots are excluded from the next dispatch instead
        of becoming guaranteed phantoms.  (Stop-token finishes are not
        host-predictable; those rows dispatch and may be discarded.)"""
        req = self.slots[i]
        if len(req.output) + pend >= req.max_new:
            return True
        return any(i in inf.cap_finish and inf.reqs.get(i) is req
                   for inf in self._inflight)

    def dispatch(self) -> int:
        """Host half of one scheduler step: admissions, prefill chunks
        (budgeted — decode batch funded first), page ensures and table
        pushes, then the jitted decode/verify ENQUEUE.  The step's
        token/logprob outputs stay un-materialized device futures in
        `self._inflight` until `collect()`.  Returns the number of
        prefill chunks processed."""
        if self._inflight and (self.spec_k > 0 or len(self._inflight) >= 2):
            # verify steps draft from host-visible history, and the
            # pipeline is one step deep — drain before dispatching again
            self.collect()
        self._admit()
        n_decoding = sum(1 for i, r in enumerate(self.slots)
                         if r is not None and i not in self._prefill_live
                         and not r.hold)
        # a verify step processes spec_k+1 query tokens per decoding
        # slot — charge the budget what the step actually computes, so
        # prefill-chunk packing doesn't overshoot under speculation
        per_slot = self.spec_k + 1 if self.spec_k > 0 else 1
        budget = self.step_token_budget - n_decoding * per_slot
        chunks_done = 0
        for i, ps in sorted(self._prefill_live.items(),
                            key=lambda kv: kv[1].order):
            cost = ps.n if self._whole_prompt else self.chunk_tokens
            # always fund at least one chunk (prefill must progress even
            # under a tiny budget); extra chunks only within budget
            if chunks_done and budget < cost:
                break
            self._prefill_tick(i, ps)
            budget -= cost
            chunks_done += 1
        pending = {i for inf in self._inflight for i in inf.active
                   if self.slots[i] is inf.reqs[i]}
        active = [i for i, r in enumerate(self.slots)
                  if r is not None and i not in self._prefill_live
                  and not r.hold
                  and not self._will_finish(i, int(i in pending))]
        if active:
            if self.spec_k > 0:
                self._dispatch_verify(active)
            else:
                self._dispatch_sequential(active)
        self.stats["steps"] += 1
        return chunks_done

    def collect(self) -> int:
        """Host half of step N's completion: materialize the OLDEST
        in-flight step (ONE `jax.device_get` round-trip for all of its
        arrays), emit its tokens through the finish rules — TTFT/TPOT
        timestamps are stamped here, when tokens are host-visible — then
        run the queue-ahead tier prefetch.  Returns slots advanced; a
        no-op (apart from the prefetch tick) when nothing is in flight."""
        emitted = 0
        if self._inflight:
            inf = self._inflight.popleft()
            if inf.kind == "verify":
                emitted = self._collect_verify(inf)
            else:
                emitted = self._collect_decode(inf)
        self._tier_prefetch_tick()
        if not self._inflight:
            self._idle_since = time.monotonic()
        return emitted

    @property
    def pending_steps(self) -> int:
        """Dispatched-but-uncollected steps (0 outside overlap mode)."""
        return len(self._inflight)

    def _decode_batch(self, active: List[int]) -> int:
        """One SYNCHRONOUS decode step over `active` slots (shared by
        both schedulers — the parity pair must never diverge on this
        body): dispatch immediately followed by its collect.  With
        ``speculation_k > 0`` the step runs draft-and-verify — same
        streams, same emitted tokens, up to k+1 of them per slot;
        otherwise (or when no row may accept) the sequential step."""
        if not active:
            return 0
        if self.spec_k > 0:
            self._dispatch_verify(active)
        else:
            self._dispatch_sequential(active)
        inf = self._inflight.popleft()
        return (self._collect_verify(inf) if inf.kind == "verify"
                else self._collect_decode(inf))

    def _dispatch_sequential(self, active: List[int]):
        """Enqueue one masked decode over `active` slots, sampling each
        row through its OWN params/PRNG stream inside the jitted step.
        Double-buffered token staging: a row whose previous token is
        still on device (the overlapped schedule dispatches step N+1
        before collecting step N) takes its input from the in-flight
        step's `toks` future via an on-device merge, so the host never
        syncs to build the feed; every other row is staged host-side
        from `output[-1]` exactly as before."""
        prev = self._inflight[-1] if self._inflight else None
        tokens = np.zeros((self.B, 1), np.int32)
        mask = np.zeros(self.B, bool)
        positions = np.zeros(self.B, np.int32)
        chain = np.zeros(self.B, bool)
        for i in active:
            req = self.slots[i]
            mask[i] = True
            if prev is not None and prev.reqs.get(i) is req:
                # feed comes from the uncollected step's device token;
                # the PRNG position accounts for that pending emission
                chain[i] = True
                positions[i] = len(req.output) + 1
            else:
                tokens[i, 0] = req.output[-1]
                positions[i] = len(req.output)
        if self.shared and self.alloc is not None:
            # every active slot appends at its current position: make that
            # page exclusively writable (lazy alloc, or COW off a shared
            # prefix/partial page) before the jitted step runs
            T = self.engine.eng.page_tokens
            for i in active:
                self._ensure_page(i, int(self._lengths[i]) // T)
            self._push_tables()
        ch, prev_t = ((chain, prev.toks) if chain.any()
                      else self._no_chain)
        self._mark_device_busy()
        self._count_compile("decode", self.B)
        # sampling params ride as traced per-slot arrays: any mix of
        # per-request combinations hits this one compiled signature
        toks, lps, self.cache = self._decode(
            self.params, self.cache, tokens, ch, prev_t,
            jnp.asarray(mask), jnp.asarray(self._temps),
            jnp.asarray(self._topk), jnp.asarray(self._topp),
            jnp.asarray(self._seeds), jnp.asarray(positions))
        self._lengths[active] += 1
        cap = {i for i in active
               if self._lengths[i] + 1 >= self.max_context}
        self._inflight.append(_Inflight(
            "decode", list(active),
            {i: self.slots[i] for i in active}, toks, lps,
            cap_finish=cap))

    def _collect_decode(self, inf: _Inflight) -> int:
        """Emit one collected sequential step: a single host transfer
        fetches tokens and logprobs together, then each surviving row
        advances through the finish rules."""
        toks, lps = jax.device_get((inf.toks, inf.lps))
        emitted = 0
        for i in inf.active:
            req = inf.reqs[i]
            if self.slots[i] is not req:
                # PHANTOM row (§14): the occupant stop-finished or
                # aborted after this step dispatched — its appended
                # token sits in pages `_finish` already recycled and is
                # rewritten by the next occupant before becoming valid
                self.stats["phantom_tokens"] += 1
                continue
            self._emit_token(i, req, int(toks[i]), float(lps[i]))
            self.stats["decode_tokens"] += 1
            emitted += 1
            if self.slots[i] is req and i in inf.cap_finish:
                self._finish(i, "capacity")
        return emitted

    def _rollback_pages(self, i: int):
        """Host half of the speculative rollback: logical pages allocated
        for the span but never reached by an accepted token go back to
        the allocator, and the slot's worst-case reservation is restored
        — refcounts and `_outstanding` exactly as if the pages had never
        been handed out.  (The device half is the write gate: rejected
        positions were dropped, so the freed pages hold no live data;
        the stale table entries they leave sit past `lengths` and stay
        data-invalid until `_ensure_page` remaps them.)"""
        if not self.shared or self.alloc is None:
            return
        last = (int(self._lengths[i]) - 1) // self.engine.eng.page_tokens
        for lp in [p for p in self._slot_pages[i] if p > last]:
            p = self._slot_pages[i].pop(lp)
            if self.tier is not None:
                self.tier.unpin(p)      # release hook retires the slot
            self.alloc.free([p])
            self._slot_shared[i].discard(lp)
            self._resv[i] += 1
            self._outstanding += 1

    def _dispatch_verify(self, active: List[int]):
        """Enqueue one draft-and-verify step over `active` slots: each
        drafts up to `spec_k` tokens by prompt lookup over its own
        history and the engine scores the whole span in ONE jitted pass.
        Drafts, positions, and the span's page ensures all consume the
        requests' host-visible emitted history, which is why `dispatch`
        drains the pipeline before building a verify step — speculation
        runs unoverlapped but token-identical (DESIGN.md §14)."""
        assert not self._inflight, "verify dispatch needs a drained pipeline"
        S = self.spec_k + 1
        T = self.engine.eng.page_tokens
        tokens = np.zeros((self.B, S), np.int32)
        mask = np.zeros(self.B, bool)
        allowed = np.zeros(self.B, np.int32)
        positions = np.zeros(self.B, np.int32)
        reqs: Dict[int, Request] = {}
        for i in active:
            req = self.slots[i]
            reqs[i] = req
            cap = req.params.speculation
            k_eff = self.spec_k if cap is None else min(cap, self.spec_k)
            # a slot may accept only as many drafts as its remaining
            # max_new budget (minus the guaranteed correction token) and
            # its slot capacity allow — so the span can never write past
            # the reservation sequential decode would have used
            allowed[i] = max(0, min(
                k_eff, req.max_new - len(req.output) - 1,
                self.max_context - 2 - int(self._lengths[i])))
            draft = (propose_draft(req.prompt + req.output, self.spec_k)
                     if allowed[i] > 0 else [0] * self.spec_k)
            tokens[i, 0] = req.output[-1]
            tokens[i, 1:] = draft
            mask[i] = True
            positions[i] = len(req.output)
        if not allowed.any():
            # no row may accept anything (per-request opt-outs, or every
            # slot at its max_new/capacity edge): the span forward would
            # be a k+1×-wide way to emit one token per slot — take the
            # sequential step instead
            return self._dispatch_sequential(active)
        if self.shared and self.alloc is not None:
            # back every page the span MAY write (positions up to
            # lengths + allowed): lazy alloc or COW, exactly like the
            # sequential path — just up to ceil(S/T)+1 pages at once
            for i in active:
                lo = int(self._lengths[i]) // T
                hi = (int(self._lengths[i]) + int(allowed[i])) // T
                for lp in range(lo, hi + 1):
                    self._ensure_page(i, lp)
            self._push_tables()
        self._mark_device_busy()
        self._count_compile("verify", self.B, S)
        (toks, lps, acc), self.cache = self._verify(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(mask), jnp.asarray(allowed),
            jnp.asarray(self._temps), jnp.asarray(self._topk),
            jnp.asarray(self._topp), jnp.asarray(self._seeds),
            jnp.asarray(positions))
        self._inflight.append(_Inflight(
            "verify", list(active), reqs, toks, lps, acc=acc,
            allowed=allowed))

    def _collect_verify(self, inf: _Inflight) -> int:
        """Emit one collected verify step: every slot emits its accepted
        prefix plus the correction/bonus token through the same
        `_emit_token` finish rules and per-request PRNG streams as the
        sequential path — outputs identical token for token, only the
        tokens-per-step changes.  Length advance and span rollback are
        acceptance-dependent, so they live here on the collect side."""
        toks, lps, acc = jax.device_get((inf.toks, inf.lps, inf.acc))
        allowed = inf.allowed
        emitted = 0
        for i in inf.active:
            req = inf.reqs[i]
            if self.slots[i] is not req:
                self.stats["phantom_tokens"] += 1
                continue
            n = int(acc[i]) + 1           # tokens the device appended
            # spec accounting counts ROW-steps that actually offered a
            # draft (matching the per-request counter): the fleet-level
            # accepted_tokens_per_step is then the weighted mean of the
            # per-request values, undiluted by opt-out rows and not
            # inflated by the slot count
            if int(allowed[i]) > 0:
                req.spec_steps += 1
                req.spec_drafted += int(allowed[i])
                self.stats["spec_steps"] += 1
                self.stats["spec_drafted"] += int(allowed[i])
            emitted_i = 0
            for j in range(n):
                if self.slots[i] is not req:
                    break                 # stop token finished mid-span
                self._emit_token(i, req, int(toks[i, j]), float(lps[i, j]))
                emitted_i += 1
            emitted += emitted_i
            # count only EMITTED accepted drafts (a stop-token finish
            # truncates the span): every counted verify step thus
            # contributes exactly spec_accepted + 1 tokens
            if int(allowed[i]) > 0:
                req.spec_accepted += emitted_i - 1
                self.stats["spec_accepted"] += emitted_i - 1
            if self.slots[i] is req:
                self._lengths[i] += n
                self._rollback_pages(i)
                if self._lengths[i] + 1 >= self.max_context:
                    self._finish(i, "capacity")
        self.stats["decode_tokens"] += emitted
        return emitted

    def run_to_completion(self, max_steps: int = 10_000):
        steps = 0
        while self.queue or any(r is not None for r in self.slots):
            if steps >= max_steps:
                stuck = sorted(
                    [r.uid for r in self.queue]
                    + [r.uid for r in self.slots if r is not None])
                raise RuntimeError(
                    f"run_to_completion: max_steps={max_steps} exhausted "
                    f"with requests still pending (uids {stuck}); raise "
                    "max_steps or check for a wedged slot")
            self.step()
            steps += 1
        return self.completed


class SpliceBatcher(ContinuousBatcher):
    """Admit-time full prefill + jit'd slot splice — the pre-interleave
    baseline.  Kept as the measured reference for `serving_bench` and the
    parity tests; every admit stalls the whole decode batch for the full
    prompt and double-writes its KV pages (one-sequence cache → splice).
    """

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        if self.shared:
            raise ValueError(
                "SpliceBatcher is the stripe-layout baseline: a shared "
                "pool has no per-slot stripe to splice into (a B=1 "
                "prefill cache owns a different pool entirely); use "
                "ContinuousBatcher with shared_pool=True, or the stripe "
                "layout for splice-baseline measurements")
        max_context = self.max_context
        self._prefill1 = jax.jit(
            lambda p, b: self.engine.prefill(p, b, max_context))
        self._prefill1_bucketed = jax.jit(
            lambda p, b, n: self.engine.prefill(p, b, max_context,
                                                prompt_len=n))
        self._splice = jax.jit(_splice_slot, donate_argnums=(0,))

    def _admit(self):
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                self._set_slot_params(i, req)
                # decoders idle for the whole admit: in chunk units, the
                # interleaved scheduler would have run this many decode
                # steps over the currently active slots
                n_dec = sum(1 for j, r in enumerate(self.slots)
                            if r is not None and j != i)
                span = len(self._padded(req))
                self.stats["decode_stall_tokens"] += n_dec * (
                    -(-span // self.chunk_tokens))
                self.stats["admits"] += 1
                self._prefill_slot(i, req)

    def _padded(self, req: Request) -> List[int]:
        n = len(req.prompt)
        if not self.bucket_prompts:
            return req.prompt
        Sb = bucket_length(n, hi=self.max_context - 1)
        return req.prompt + [0] * (Sb - n)

    def _prefill_slot(self, i: int, req: Request):
        """Prefill one sequence and splice its pools/length into slot i."""
        n = len(req.prompt)
        toks = jnp.asarray(self._padded(req), jnp.int32)[None]
        self._count_compile("prefill", toks.shape[1])
        if self.bucket_prompts:
            logits, c1 = self._prefill1_bucketed(
                self.params, {"tokens": toks}, jnp.asarray(n, jnp.int32))
        else:
            logits, c1 = self._prefill1(self.params, {"tokens": toks})
        self._count_compile("splice")
        self.cache = self._splice(self.cache, c1,
                                  jnp.asarray(i, jnp.int32))
        self._lengths[i] = self._prefix + n
        tok, lp = self._sample_row(logits, req)
        self._emit_token(i, req, tok, lp)

    def step(self) -> int:
        """One decode step over all active slots (admits prefill eagerly
        inside `_admit`, stalling the batch)."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        decoded = self._decode_batch(active)
        self.stats["steps"] += 1
        return decoded


# module-level aliases so tests can monkeypatch `sched._splice_slot`
# (the writers themselves live with the pool-leaf writer family in
# core/paged_kv.py — KV004 discipline, DESIGN.md §15)
_splice_slot = paged_kv.splice_slot
_splice_slot_ref = paged_kv.splice_slot_ref
