"""Continuous-batching scheduler over the KVNAND engine.

Host-side request management around the jit'd decode step:
  * fixed decode batch of B slots; finished/empty slots are refilled from
    the queue between steps (per-slot prefill into the paged pools);
  * per-slot lengths are ragged → the engine's general (scatter) append
    path (`uniform_lengths=False`);
  * slot eviction = clearing host bookkeeping — its pages are simply
    overwritten by the next occupant (per-sequence page stripes, the
    access-aware reuse story of §IV-D).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EngineConfig, ModelConfig
from repro.core.engine import KVNANDEngine
from repro.models.transformer import Runtime
from repro.serving.sampler import sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new: int
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_context: int = 512, eng: Optional[EngineConfig] = None,
                 rt: Optional[Runtime] = None, temperature: float = 0.0,
                 seed: int = 0):
        eng = eng or EngineConfig(page_tokens=16, uniform_lengths=False)
        self.cfg = cfg
        self.engine = KVNANDEngine(cfg, eng, rt or Runtime())
        self.params = params
        self.B = batch_slots
        self.max_context = max_context
        self.temperature = temperature
        self.rng = jax.random.PRNGKey(seed)
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.cache = self.engine.init_cache(batch_slots, max_context)
        self._lengths = np.zeros(batch_slots, np.int64)
        self._decode = jax.jit(
            lambda p, c, t: self.engine.decode_step(p, c, t))
        self._prefill1 = jax.jit(
            lambda p, b: self.engine.prefill(p, b, max_context),
            static_argnames=())
        self.completed: Dict[int, Request] = {}

    # -- host-side slot management ------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                self._prefill_slot(i, req)

    def _prefill_slot(self, i: int, req: Request):
        """Prefill one sequence and splice its pools/length into slot i."""
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, c1 = self._prefill1(self.params, {"tokens": toks})
        self.cache = _splice_slot(self.cache, c1, i)
        self._lengths[i] = len(req.prompt)
        self.rng, k = jax.random.split(self.rng)
        tok = int(sample(logits, k, true_vocab=self.cfg.vocab_size,
                         temperature=self.temperature)[0])
        req.output.append(tok)

    def step(self) -> int:
        """One decode step over all active slots; returns #active."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        tokens = np.zeros((self.B, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].output[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens))
        self.rng, k = jax.random.split(self.rng)
        next_tokens = sample(logits, k, true_vocab=self.cfg.vocab_size,
                             temperature=self.temperature)
        self._lengths[active] += 1
        for i in active:
            req = self.slots[i]
            req.output.append(int(next_tokens[i]))
            if (len(req.output) >= req.max_new
                    or self._lengths[i] + 1 >= self.max_context):
                req.done = True
                self.completed[req.uid] = req
                self.slots[i] = None          # slot pages recycled in place
                self._lengths[i] = 0
        return len(active)

    def run_to_completion(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(self.slots)) and steps < max_steps:
            self.step()
            steps += 1
        return self.completed


def _splice_slot(cache, one, i: int):
    """Copy sequence 0 of a B=1 cache into slot i of the batch cache."""
    import dataclasses as dc

    updates = {}
    for f in dc.fields(cache):
        cur, new = getattr(cache, f.name), getattr(one, f.name)
        if cur is None:
            continue
        # batch axis position: leaf layouts are [L, B, ...] or [B, ...]
        if f.name in ("page_table_g", "page_pos_w", "lengths"):
            updates[f.name] = cur.at[i].set(new[0])
        else:
            updates[f.name] = cur.at[:, i].set(new[:, 0])
    return dc.replace(cache, **updates)
