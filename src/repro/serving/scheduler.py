"""Continuous-batching scheduler over the KVNAND engine.

Host-side request management around the jit'd decode step:
  * fixed decode batch of B slots; finished/empty slots are refilled from
    the queue between steps (per-slot prefill into the paged pools);
  * per-slot lengths are ragged → the engine's general (scatter) append
    path (`uniform_lengths=False`);
  * admits splice the one-sequence prefill cache into its slot with a
    single jit'd `dynamic_update_slice` per leaf (donated cache, so XLA
    aliases the pools in place) — the eager `.at[:, i].set` path copied
    the ENTIRE pool per admit;
  * prompts are padded to power-of-two buckets before prefill so the
    jit'd prefill compiles once per bucket, not once per distinct prompt
    length (the engine masks padding via its `prompt_len` argument);
  * slot eviction = clearing host bookkeeping — its pages are simply
    overwritten by the next occupant (per-sequence page stripes, the
    access-aware reuse story of §IV-D).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import EngineConfig, ModelConfig
from repro.core.engine import KVNANDEngine
from repro.models.transformer import Runtime
from repro.serving.sampler import sample

MIN_PROMPT_BUCKET = 16


@dataclasses.dataclass
class Request:
    uid: int
    prompt: List[int]
    max_new: int
    output: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def bucket_length(n: int, lo: int = MIN_PROMPT_BUCKET) -> int:
    """Smallest power-of-two bucket (≥ lo) holding n tokens."""
    b = lo
    while b < n:
        b *= 2
    return b


class ContinuousBatcher:
    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_context: int = 512, eng: Optional[EngineConfig] = None,
                 rt: Optional[Runtime] = None, temperature: float = 0.0,
                 seed: int = 0, bucket_prompts: bool = True):
        eng = eng or EngineConfig(page_tokens=16, uniform_lengths=False)
        self.cfg = cfg
        self.engine = KVNANDEngine(cfg, eng, rt or Runtime())
        self.params = params
        self.B = batch_slots
        self.max_context = max_context
        self.temperature = temperature
        # recurrent prefill folds padding into carried state → exact-length
        self.bucket_prompts = (bucket_prompts
                               and cfg.family not in ("ssm", "hybrid"))
        self.rng = jax.random.PRNGKey(seed)
        self.queue: Deque[Request] = deque()
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.cache = self.engine.init_cache(batch_slots, max_context)
        self._lengths = np.zeros(batch_slots, np.int64)
        self._decode = jax.jit(
            lambda p, c, t: self.engine.decode_step(p, c, t))
        self._prefill1 = jax.jit(
            lambda p, b: self.engine.prefill(p, b, max_context))
        self._prefill1_bucketed = jax.jit(
            lambda p, b, n: self.engine.prefill(p, b, max_context,
                                                prompt_len=n))
        self._splice = jax.jit(_splice_slot, donate_argnums=(0,))
        self.completed: Dict[int, Request] = {}

    # -- host-side slot management ------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                req = self.queue.popleft()
                self.slots[i] = req
                self._prefill_slot(i, req)

    def _prefill_slot(self, i: int, req: Request):
        """Prefill one sequence and splice its pools/length into slot i."""
        n = len(req.prompt)
        if self.bucket_prompts:
            Sb = min(bucket_length(n), max(self.max_context - 1, n))
            toks = jnp.asarray(req.prompt + [0] * (Sb - n), jnp.int32)[None]
            logits, c1 = self._prefill1_bucketed(
                self.params, {"tokens": toks}, jnp.asarray(n, jnp.int32))
        else:
            toks = jnp.asarray(req.prompt, jnp.int32)[None]
            logits, c1 = self._prefill1(self.params, {"tokens": toks})
        self.cache = self._splice(self.cache, c1,
                                  jnp.asarray(i, jnp.int32))
        self._lengths[i] = n
        self.rng, k = jax.random.split(self.rng)
        tok = int(sample(logits, k, true_vocab=self.cfg.vocab_size,
                         temperature=self.temperature)[0])
        req.output.append(tok)

    def step(self) -> int:
        """One decode step over all active slots; returns #active."""
        self._admit()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return 0
        tokens = np.zeros((self.B, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slots[i].output[-1]
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens))
        self.rng, k = jax.random.split(self.rng)
        next_tokens = sample(logits, k, true_vocab=self.cfg.vocab_size,
                             temperature=self.temperature)
        self._lengths[active] += 1
        for i in active:
            req = self.slots[i]
            req.output.append(int(next_tokens[i]))
            if (len(req.output) >= req.max_new
                    or self._lengths[i] + 1 >= self.max_context):
                req.done = True
                self.completed[req.uid] = req
                self.slots[i] = None          # slot pages recycled in place
                self._lengths[i] = 0
        return len(active)

    def run_to_completion(self, max_steps: int = 10_000):
        steps = 0
        while (self.queue or any(self.slots)) and steps < max_steps:
            self.step()
            steps += 1
        return self.completed


_BATCH_AXIS0 = ("page_table_g", "page_pos_w", "lengths")


def _splice_slot(cache, one, i):
    """Copy sequence 0 of a B=1 cache into slot i of the batch cache.

    One `dynamic_update_slice` per leaf: `one` already has a size-1 batch
    dim, so the update writes exactly the slot's stripe.  Jit this with a
    donated `cache` so XLA updates the pools in place instead of copying
    the whole pool per admit.
    """
    updates = {}
    for f in dataclasses.fields(cache):
        cur, new = getattr(cache, f.name), getattr(one, f.name)
        if cur is None:
            continue
        # batch axis position: leaf layouts are [L, B, ...] or [B, ...]
        ax = 0 if f.name in _BATCH_AXIS0 else 1
        start = tuple(jnp.asarray(i if d == ax else 0, jnp.int32)
                      for d in range(cur.ndim))
        updates[f.name] = jax.lax.dynamic_update_slice(
            cur, new.astype(cur.dtype), start)
    return dataclasses.replace(cache, **updates)


def _splice_slot_ref(cache, one, i: int):
    """Eager reference splice (the old O(pool) path) — kept for tests."""
    updates = {}
    for f in dataclasses.fields(cache):
        cur, new = getattr(cache, f.name), getattr(one, f.name)
        if cur is None:
            continue
        if f.name in _BATCH_AXIS0:
            updates[f.name] = cur.at[i].set(new[0])
        else:
            updates[f.name] = cur.at[:, i].set(new[:, 0])
    return dataclasses.replace(cache, **updates)
