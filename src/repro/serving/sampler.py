"""Vectorized token sampling: per-ROW greedy / temperature / top-k /
top-p over a batch, with per-request PRNG streams.

Every knob (`temperature`, `top_k`, `top_p`) can be a scalar or a
per-row `[B]` array, so a mixed-params decode batch — greedy rows next
to hot-temperature nucleus rows — runs as ONE traced computation: the
serving scheduler passes the per-slot arrays straight into its jitted
decode step, and a new combination of request params never costs a
recompile.

Randomness is the exponential-race (Gumbel-argmax) form of categorical
sampling, drawn per row from that row's own key (`request_keys`: fold
``(seed, position)`` into a stream).  A request's tokens therefore
depend only on its own `(seed, position)` pairs — never on batch
composition, admission order, or a batcher-global RNG — which is what
makes seeded requests bit-reproducible across schedulers.  With a single
(legacy) key the draw degrades to `jax.random.categorical`'s exact
stream, so pre-existing call sites keep their token sequences.

`SamplingParams` lives here (not in `serving/api.py`) so the scheduler
can consume it without a circular import; the API facade re-exports it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG = -1e9


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs (the public serving surface).

    temperature <= 0 is greedy (argmax); `top_k=0` / `top_p=1.0` disable
    their filters.  `seed=None` derives a per-request stream from the
    server seed and the request uid; an explicit seed makes the output
    bit-reproducible regardless of batch composition or scheduler.
    `stop_token_ids`: generation finishes (reason ``"stop"``) the step a
    listed id is sampled; the stop token IS included in the output.
    `logprobs=True` records the log-probability (from the raw, pad-masked
    distribution — independent of temperature/filters) of each sampled
    token.
    `speculation` caps how many prompt-lookup draft tokens may be
    verified for THIS request per step when the server runs speculative
    decoding (``ServerConfig.speculation_k``): None accepts the server
    default, 0 opts the request out of drafting entirely.  The knob only
    changes how many tokens a step can emit — never which tokens: the
    accept rule samples the target distribution from the request's own
    PRNG stream (see `speculative_accept`).
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    seed: Optional[int] = None
    max_new_tokens: int = 16
    stop_token_ids: Tuple[int, ...] = ()
    logprobs: bool = False
    speculation: Optional[int] = None

    def __post_init__(self):
        if self.temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, "
                             f"got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 disables), "
                             f"got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {self.max_new_tokens}")
        if self.speculation is not None and self.speculation < 0:
            raise ValueError(f"speculation must be >= 0 (0 disables, "
                             f"None takes the server default), "
                             f"got {self.speculation}")
        object.__setattr__(self, "stop_token_ids",
                           tuple(int(t) for t in self.stop_token_ids))


def request_keys(seeds: jax.Array, positions: jax.Array) -> jax.Array:
    """Per-request PRNG streams: row i's key is
    ``fold_in(PRNGKey(seeds[i]), positions[i])`` — a pure function of the
    request's seed and how many tokens it has emitted, so the stream is
    identical whatever batch the request happens to share.  Traceable
    (used inside the scheduler's jitted decode step)."""
    def one(s, p):
        return jax.random.fold_in(jax.random.PRNGKey(s), p)
    return jax.vmap(one)(jnp.asarray(seeds, jnp.uint32),
                         jnp.asarray(positions, jnp.int32))


def _noise(rng: jax.Array, shape) -> jax.Array:
    """Gumbel noise: per-row draws for batched `[B, 2]` keys, a single
    batch-wide draw (== `jax.random.categorical`'s stream) otherwise."""
    rng = jnp.asarray(rng)
    if rng.ndim == 2:
        return jax.vmap(lambda k: jax.random.gumbel(k, shape[1:]))(rng)
    return jax.random.gumbel(rng, shape)


def sample_with_logprobs(logits: jax.Array, rng: jax.Array, *,
                         true_vocab: int, temperature=0.0, top_k=0,
                         top_p=1.0):
    """logits: [B, V_padded] -> (token ids [B], logprobs [B]).

    temperature/top_k/top_p: scalars or per-row [B] arrays; rng: one key
    (batch-shared stream) or per-row keys [B, 2] from `request_keys`.
    Per row: temperature <= 0 takes the argmax; otherwise the logits are
    temperature-scaled, top-k filtered, then top-p filtered over the
    RENORMALIZED top-k survivors (the standard sequential composition;
    0 / 1.0 are exact per-row no-ops), and sampled by Gumbel-argmax from
    that row's stream.  Vocab
    padding (ids >= true_vocab) can never be sampled at any temperature:
    invalid lanes hold a temperature-independent floor.  The returned
    logprob is `log_softmax` of the raw pad-masked logits at the chosen
    token — a stable per-token score that does not move with the
    sampling knobs.
    """
    B, V = logits.shape
    logits = logits.astype(jnp.float32)
    invalid = jnp.zeros((1, V), bool)
    if true_vocab < V:
        invalid = (jnp.arange(V) >= true_vocab)[None]
        logits = jnp.where(invalid, NEG, logits)
    temps = jnp.broadcast_to(jnp.asarray(temperature, jnp.float32), (B,))
    tks = jnp.broadcast_to(jnp.asarray(top_k, jnp.int32), (B,))
    tps = jnp.broadcast_to(jnp.asarray(top_p, jnp.float32), (B,))

    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _stochastic(_):
        # scale stochastic rows; re-floor invalid lanes AFTER the division
        # so huge temperatures cannot lift padding into Gumbel-noise range
        safe_t = jnp.where(temps > 0.0, temps, 1.0)[:, None]
        scaled = jnp.where(invalid, NEG, logits / safe_t)
        # top-k: kth-largest threshold per row; top_k=0 rows keep
        # everything (the gather still needs a valid index, hence the clip)
        sorted_desc = -jnp.sort(-scaled, axis=-1)
        kth = jnp.take_along_axis(
            sorted_desc, jnp.clip(tks - 1, 0, V - 1)[:, None], axis=-1)
        keep_k = (tks <= 0)[:, None] | (scaled >= kth)
        # top-p (nucleus) runs SEQUENTIALLY on the top-k survivors —
        # softmax over the filtered logits renormalizes their mass,
        # matching the standard top-k-then-top-p composition.  The sorted
        # survivor distribution is the rank-masked first sort (no second
        # sort).  Keep the smallest prefix whose mass reaches top_p: a
        # token survives iff the mass BEFORE it is < top_p, so the
        # per-row argmax always survives and top_p=1.0 rows are exact
        # no-ops.
        eff_k = jnp.where(tks <= 0, V, tks)[:, None]
        sorted_f = jnp.where(jnp.arange(V)[None] < eff_k, sorted_desc, NEG)
        p_sorted = jax.nn.softmax(sorted_f, axis=-1)
        mass_before = jnp.cumsum(p_sorted, axis=-1) - p_sorted
        n_keep = jnp.sum(mass_before < tps[:, None], axis=-1)
        pth = jnp.take_along_axis(
            sorted_f, jnp.clip(n_keep - 1, 0, V - 1)[:, None], axis=-1)
        keep = keep_k & ((tps >= 1.0)[:, None] | (scaled >= pth))
        masked = jnp.where(keep & ~invalid, scaled, NEG)
        stoch = jnp.argmax(masked + _noise(rng, (B, V)), axis=-1)
        return jnp.where(temps > 0.0, stoch.astype(jnp.int32), greedy)

    # an all-greedy batch (the serving default) skips the sort / nucleus /
    # RNG machinery at RUNTIME; lax.cond keeps it ONE compiled signature,
    # so the decode step's compile count stays invariant to the params mix
    toks = jax.lax.cond(jnp.any(temps > 0.0), _stochastic,
                        lambda _: greedy, operand=None)
    lps = jnp.take_along_axis(jax.nn.log_softmax(logits, axis=-1),
                              toks[:, None].astype(jnp.int32),
                              axis=-1)[:, 0]
    return toks, lps


def sample(logits: jax.Array, rng: jax.Array, *, true_vocab: int,
           temperature=0.0, top_k=0, top_p=1.0) -> jax.Array:
    """logits: [B, V_padded] -> token ids [B] (see sample_with_logprobs)."""
    toks, _ = sample_with_logprobs(logits, rng, true_vocab=true_vocab,
                                   temperature=temperature, top_k=top_k,
                                   top_p=top_p)
    return toks


def speculative_accept(logits: jax.Array, drafts: jax.Array,
                       seeds: jax.Array, positions: jax.Array,
                       allowed: jax.Array, *, true_vocab: int,
                       temperature=0.0, top_k=0, top_p=1.0):
    """Draft-and-verify acceptance over a k-token span (traceable).

    logits: [B, S, V] — span logits from `KVNANDEngine.verify_step`;
    position j scored the j-th span input token (the last emitted token
    for j = 0, drafts thereafter), so logits[:, j] is the target
    distribution of output token ``positions + j``.
    drafts: [B, S-1] drafted token ids; seeds/positions: [B] per-request
    stream state (tokens emitted so far); allowed: [B] per-row cap on
    accepted drafts (0 degrades the row to a plain decode step).

    Accept rule: sample EVERY span position from the request's own
    ``fold_in(seed, positions + j)`` stream — exactly the key sequential
    decode would use at that position — and accept draft j while the
    sampled token equals it.  The emitted tokens are the SAMPLED ones
    (``acc`` accepted drafts, which equal their samples, plus the first
    mismatching sample as the correction / bonus token), so the output
    sequence is distributed identically to non-speculative decoding —
    bit-exact greedy-equivalent at temperature 0 (argmax ignores the
    keys), same-stream sampling otherwise — and drafts can only change
    how MANY tokens a step emits, never which.

    Returns (tokens [B, S], logprobs [B, S], acc [B]): row i emits
    ``tokens[i, :acc[i] + 1]``.
    """
    B, S, V = logits.shape
    seeds_f = jnp.repeat(jnp.asarray(seeds, jnp.uint32), S)
    pos_f = (jnp.asarray(positions, jnp.int32)[:, None]
             + jnp.arange(S, dtype=jnp.int32)[None]).reshape(-1)
    rep = lambda a: jnp.repeat(jnp.broadcast_to(              # noqa: E731
        jnp.asarray(a), (B,)), S)
    toks, lps = sample_with_logprobs(
        logits.reshape(B * S, V), request_keys(seeds_f, pos_f),
        true_vocab=true_vocab, temperature=rep(temperature),
        top_k=rep(top_k), top_p=rep(top_p))
    toks = toks.reshape(B, S)
    lps = lps.reshape(B, S)
    match = (toks[:, :-1] == drafts) & \
        (jnp.arange(S - 1, dtype=jnp.int32)[None]
         < jnp.asarray(allowed, jnp.int32)[:, None])
    acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1), axis=1)
    return toks, lps, acc
