"""Token sampling: greedy / temperature / top-k (vocab-mask aware)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits: jax.Array, rng: jax.Array, *, true_vocab: int,
           temperature: float = 0.0, top_k: int = 0) -> jax.Array:
    """logits: [B, V_padded] -> token ids [B]."""
    V = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if true_vocab < V:
        pad = jnp.arange(V) >= true_vocab
        logits = jnp.where(pad[None], -1e9, logits)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / temperature
    if top_k > 0:
        kth = jnp.sort(logits, axis=-1)[:, -top_k][:, None]
        logits = jnp.where(logits < kth, -1e9, logits)
    return jax.random.categorical(rng, logits, axis=-1).astype(jnp.int32)
