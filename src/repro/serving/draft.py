"""Prompt-lookup (n-gram) self-drafting for speculative decoding.

KVNAND's premise is that single-batch decode is bandwidth-bound: every
emitted token pays a full weight load and KV walk.  Draft-and-verify
speculative decoding amortizes that traffic — the engine verifies k
drafted tokens in ONE forward pass (`KVNANDEngine.verify_step`), so a
step that accepts a tokens emits a+1 for one weight load instead of
a+1 of them.  On-device there is no room for a second draft model, so
the drafter is the cheapest one that works: PROMPT LOOKUP.  The
request's own token history is scanned for the most recent earlier
occurrence of its trailing n-gram, and the tokens that followed that
occurrence become the draft — free to propose, and highly effective on
the repetitive spans (code, quoted context, structured output) where
decode spends most of its tokens.

Drafts carry no probabilities: verification samples the TARGET
distribution at every span position from the request's own
``fold_in(seed, position)`` stream and accepts a draft token only when
the sampled token equals it (`serving.sampler.speculative_accept`).
The emitted sequence is therefore distributed exactly as non-speculative
decoding — bit-equal greedy at temperature 0, same-stream sampling
otherwise — whatever the drafter proposes; draft quality only changes
how many tokens each verify step emits.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np


def propose_draft(tokens: Sequence[int], k: int, *, max_ngram: int = 3,
                  min_ngram: int = 1) -> List[int]:
    """Propose ``k`` draft tokens continuing ``tokens`` by prompt lookup.

    Scans for the most recent earlier occurrence of the longest trailing
    n-gram (``max_ngram`` down to ``min_ngram``) and returns the tokens
    that followed it, padded by repeating the last token when the match
    sits near the end.  With no match the draft is the last token
    repeated — still correct (verification rejects bad drafts), and the
    right guess on degenerate repetitive tails.

    The scan is vectorized (one shifted-slice comparison per n-gram
    position) — it runs once per active slot per verify step, so the
    per-step host cost stays a handful of numpy passes over the
    history, not a Python loop.
    """
    n = len(tokens)
    if k <= 0 or n == 0:
        return []
    arr = np.asarray(tokens, np.int64)
    for g in range(min(max_ngram, n - 1), min_ngram - 1, -1):
        pat = arr[-g:]
        # candidate starts 0..n-g-1 (strictly before the trailing
        # n-gram itself, so at least one continuation token exists)
        ok = np.ones(n - g, bool)
        for j in range(g):
            ok &= arr[j:n - g + j] == pat[j]
        hits = np.flatnonzero(ok)
        if hits.size:
            i = int(hits[-1])                  # most recent occurrence
            cont = arr[i + g:i + g + k].tolist()
            return cont + [int(arr[-1])] * (k - len(cont))
    return [int(arr[-1])] * k
