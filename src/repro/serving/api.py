"""Request-centric serving API — the single front door to the engine.

Everything a caller needs to serve mixed multi-user traffic lives here:

  * `SamplingParams` (re-exported from `serving/sampler.py`) — frozen
    per-request knobs: temperature / top-k / top-p / seed /
    max_new_tokens / stop_token_ids / logprobs;
  * `RequestOutput` — the finished request: token ids, optional
    per-token logprobs, a `finish_reason` in {stop, length, capacity,
    aborted, deadline}, and submit/first-token/finish timestamps with
    derived TTFT (time to first token) and TPOT (time per output token);
  * `StreamEvent` — one incrementally generated token, as yielded by
    `KVNANDServer.step()` / `stream()`; the events of a request
    concatenate exactly to its final `RequestOutput.token_ids`;
  * `ServerConfig` + `KVNANDServer` — the facade.  Constructing the
    server is the ONLY supported way to stand up serving: it builds the
    model, the engine and the scheduler (`interleaved` chunked-prefill
    continuous batching, or the `splice` baseline; shared-pool paged KV
    comes from `ServerConfig.engine`), and offers `generate()` for
    batch-synchronous use, `submit()`/`step()`/`stream()` for
    incremental use, and `abort()` for cancellation at any stage —
    queued, mid-chunked-prefill, or decoding — with shared-pool pages
    returned through the allocator, refcounts intact.
    `ServerConfig.speculation_k` turns decode steps into draft-and-
    verify steps (DESIGN.md §11) with per-request acceptance stats on
    `RequestOutput`.

The deep half of the design — per-slot sampling params consumed as
traced arrays INSIDE the jitted decode step, so a batch mixing any
number of distinct `SamplingParams` costs exactly one compile — lives in
`serving/sampler.py` and `serving/scheduler.py`; see DESIGN.md §10.
The full reference for this surface is docs/api.md.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterator, List, Optional, Sequence, Union

import jax
import numpy as np

from repro.configs import EngineConfig, get_config
from repro.configs.base import ModelConfig
from repro.models.registry import Model
from repro.models.transformer import Runtime
from repro.serving.sampler import SamplingParams
from repro.serving.scheduler import (ContinuousBatcher, Request,
                                     SpliceBatcher)

__all__ = ["SamplingParams", "RequestOutput", "StreamEvent",
           "ServerConfig", "KVNANDServer", "latency_percentile",
           "accepted_tokens_per_step"]


def accepted_tokens_per_step(accepted: int, steps: int) -> Optional[float]:
    """Mean tokens emitted per verify step: `steps` spans each emitted
    their accepted drafts plus the correction/bonus token.  None when
    nothing decoded speculatively — the ONE definition shared by
    `RequestOutput`, `launch/serve.py`'s report, and the
    `serving/spec/accepted_per_step` bench row."""
    if steps == 0:
        return None
    return (accepted + steps) / steps

_SCHEDULERS = {"interleaved": ContinuousBatcher, "splice": SpliceBatcher}


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Everything needed to stand up a `KVNANDServer`.

    ``speculation_k`` turns every decode step into a draft-and-verify
    step: each running request drafts up to k tokens by prompt lookup
    over its own history and the engine verifies the span in one
    forward pass (DESIGN.md §11).  Outputs are token-identical to
    sequential decoding (greedy and seeded sampling alike); only the
    tokens-per-step changes.  (For quantized kv8/kv4 pools the span
    logits match sequential decode up to the format's own quantization
    noise — DESIGN.md §11 — so identity there is empirical, not a
    floating-point guarantee.)  ``None`` defers to
    ``EngineConfig.speculation_k`` (which `core.dse
    .recommend_engine_config` can set from the flash model); ``0``
    forces sequential decode.  Per-request opt-out / tighter caps:
    `SamplingParams.speculation`.
    """
    arch: str = "qwen1.5-0.5b"
    reduced: bool = False           # paper-scale vs CI-scale model dims
    engine: Optional[EngineConfig] = None   # None -> paged ragged default
    scheduler: str = "interleaved"  # "interleaved" | "splice" (baseline)
    batch_slots: int = 4
    max_context: int = 256
    prefill_chunk_tokens: int = 64
    step_token_budget: Optional[int] = None
    seed: int = 0                   # params init + default request streams
    max_steps: int = 100_000        # drain guard for generate()/run()
    speculation_k: Optional[int] = None     # None -> engine.speculation_k
    # tiered pool (EngineConfig.hot_pages > 0, DESIGN.md §13): promote
    # the next admission's prefix-hit pages at the end of each step so
    # the admission pins warm pages instead of demand-faulting; off =
    # every capacity-tier map-in stalls (the ablation serving_bench
    # measures).  Ignored by single-tier pools.
    tier_prefetch: bool = True
    # overlapped host/device pipeline (DESIGN.md §14): stream()/run()/
    # generate() dispatch step N+1 before collecting step N, so host
    # token emission and bookkeeping hide behind device compute.
    # Outputs are token-identical to the synchronous schedule (same
    # per-request PRNG streams, same in-order per-request emission);
    # speculative decoding degrades to the synchronous schedule
    # automatically.
    overlap: bool = False

    def __post_init__(self):
        if self.scheduler not in _SCHEDULERS:
            raise ValueError(
                f"unknown scheduler {self.scheduler!r}; pick one of "
                f"{sorted(_SCHEDULERS)}")
        if self.speculation_k is not None and self.speculation_k < 0:
            raise ValueError(f"speculation_k must be >= 0, "
                             f"got {self.speculation_k}")


@dataclasses.dataclass(frozen=True)
class StreamEvent:
    """One incrementally generated token of one request.  Every request
    ends with exactly one event carrying `finish_reason`: normally its
    last token; a request aborted without a fresh token gets a terminal
    marker event with `token=None` (and `index` = tokens emitted)."""
    uid: int
    token: Optional[int]
    index: int                      # position within the request's output
    logprob: Optional[float] = None         # when the request asked
    finish_reason: Optional[str] = None     # set on the terminal event


@dataclasses.dataclass
class RequestOutput:
    """A finished request, with timing counters for serving metrics and
    — when the server ran speculative decoding — per-request acceptance
    stats (`spec_steps` verify steps, `spec_drafted` offered drafts,
    `spec_accepted` accepted drafts; all 0 under sequential decode).

    Under a TIERED pool (DESIGN.md §13), `tier_hit_pages` counts cached
    pages this request mapped while they were hot-resident and
    `tier_stall_tokens` the pages it had to demand-promote from the
    capacity tier at admission (its share of the fleet's stall tokens);
    both stay 0 for single-tier pools and cache-miss prompts."""
    uid: int
    prompt: List[int]
    token_ids: List[int]
    logprobs: Optional[List[float]]
    finish_reason: str      # stop | length | capacity | aborted | deadline
    submit_time: float
    first_token_time: Optional[float]   # None: aborted before any token
    finish_time: float
    spec_steps: int = 0
    spec_drafted: int = 0
    spec_accepted: int = 0
    tier_hit_pages: int = 0
    tier_stall_tokens: int = 0

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (seconds), None if none was generated."""
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.submit_time

    @property
    def accepted_tokens_per_step(self) -> Optional[float]:
        """Mean tokens emitted per verify step (accepted drafts + the
        correction/bonus token); 1.0 means drafting never helped, None
        when the request never decoded speculatively (steps where the
        request could offer no draft — opt-out, last-token budget —
        run sequentially and are not counted)."""
        return accepted_tokens_per_step(self.spec_accepted,
                                        self.spec_steps)

    @property
    def tpot(self) -> Optional[float]:
        """Mean time per output token AFTER the first (seconds); None
        for zero- or one-token outputs."""
        if self.first_token_time is None or len(self.token_ids) < 2:
            return None
        return ((self.finish_time - self.first_token_time)
                / (len(self.token_ids) - 1))


class KVNANDServer:
    """Facade over engine + runtime + scheduler construction and the
    request lifecycle.  `cfg`/`params`/`rt` overrides let callers serve
    a model they already built (e.g. freshly trained weights)."""

    def __init__(self, config: Optional[ServerConfig] = None, *,
                 cfg: Optional[ModelConfig] = None, params=None,
                 rt: Optional[Runtime] = None):
        self.config = config = config or ServerConfig()
        if cfg is None:
            cfg = get_config(config.arch)
            if config.reduced:
                cfg = cfg.reduced()
        self.cfg = cfg
        rt = rt or Runtime()
        if params is None:
            params = Model(cfg, rt).init(jax.random.PRNGKey(config.seed))
        spec_k = config.speculation_k
        if spec_k is None:
            spec_k = (config.engine.speculation_k
                      if config.engine is not None else 0)
        self._batcher = _SCHEDULERS[config.scheduler](
            cfg, params, batch_slots=config.batch_slots,
            max_context=config.max_context, eng=config.engine, rt=rt,
            seed=config.seed,
            prefill_chunk_tokens=config.prefill_chunk_tokens,
            step_token_budget=config.step_token_budget,
            speculation_k=spec_k, tier_prefetch=config.tier_prefetch)
        self._requests: Dict[int, Request] = {}
        self._streamed: Dict[int, int] = {}
        self._done_emitted: set = set()
        self._next_uid = 0

    # -- introspection --------------------------------------------------
    @property
    def stats(self) -> Dict[str, int]:
        return self._batcher.stats

    @property
    def engine(self):
        return self._batcher.engine

    def _busy(self) -> bool:
        b = self._batcher
        return bool(b.queue) or any(r is not None for r in b.slots)

    # -- request lifecycle ----------------------------------------------
    def submit(self, prompt: Sequence[int],
               params: Optional[SamplingParams] = None, *,
               uid: Optional[int] = None, priority: int = 0,
               deadline: Optional[float] = None) -> int:
        """Queue one prompt; returns its uid.  Raises (and records
        nothing) on invalid prompts — empty, over slot/pool capacity.

        `priority` (lower admits first; default class 0) and `deadline`
        (seconds from now) shape the scheduler's ADMISSION order:
        waiting requests admit by (priority, nearest deadline, submit
        order), and a request still queued when its deadline passes
        finishes as ``"deadline"`` without consuming pages or steps.
        Neither preempts already-running requests."""
        if uid is None:
            uid = self._next_uid
        if uid in self._requests:
            raise ValueError(f"uid {uid} already submitted")
        if deadline is not None and deadline <= 0:
            raise ValueError(f"deadline must be > 0 seconds, "
                             f"got {deadline}")
        params = params or SamplingParams()
        req = Request(uid=uid, prompt=list(prompt),
                      max_new=params.max_new_tokens, params=params,
                      priority=priority,
                      deadline_ts=(time.monotonic() + deadline
                                   if deadline is not None else None))
        self._batcher.submit(req)
        self._requests[uid] = req
        self._streamed[uid] = 0
        self._next_uid = max(self._next_uid, uid + 1)
        return uid

    def abort(self, uid: int) -> bool:
        """Cancel a queued or running request (`finish_reason="aborted"`,
        shared-pool pages returned).  False for unknown/finished uids."""
        req = self._requests.get(uid)
        if req is None or req.done:
            return False
        return self._batcher.abort(uid)

    def step(self) -> List[StreamEvent]:
        """One scheduler step (admissions + prefill chunks + the decode
        batch); returns the tokens that became available, in request
        submission order, plus terminal marker events for requests that
        finished WITHOUT a fresh token (aborts)."""
        self._batcher.step()
        return self._drain_events()

    def dispatch(self) -> int:
        """Pipelined driver surface (DESIGN.md §14): enqueue the next
        step's device work without materializing its tokens.  Pair every
        dispatch with a later `collect()`; `step()` is the synchronous
        composition of the two."""
        return self._batcher.dispatch()

    def collect(self) -> List[StreamEvent]:
        """Materialize the oldest dispatched step and return its events
        (same shape as `step()`'s)."""
        self._batcher.collect()
        return self._drain_events()

    def pending_steps(self) -> int:
        """Dispatched-but-uncollected scheduler steps (0 outside the
        pipelined driver)."""
        return self._batcher.pending_steps

    def _drain_events(self) -> List[StreamEvent]:
        events: List[StreamEvent] = []
        for uid, req in self._requests.items():
            n0 = self._streamed[uid]
            out = req.output
            done_now = req.done and uid not in self._done_emitted
            if n0 == len(out) and not done_now:
                continue
            want_lp = req.params.logprobs
            for j in range(n0, len(out)):
                last = done_now and j == len(out) - 1
                events.append(StreamEvent(
                    uid=uid, token=out[j], index=j,
                    logprob=req.logprobs[j] if want_lp else None,
                    finish_reason=req.finish_reason if last else None))
            self._streamed[uid] = len(out)
            if done_now:
                if n0 == len(out):      # finished with no fresh token:
                    events.append(StreamEvent(  # aborted -> marker event
                        uid=uid, token=None, index=len(out),
                        finish_reason=req.finish_reason))
                self._done_emitted.add(uid)
        return events

    def stream(self) -> Iterator[StreamEvent]:
        """Iterate stepwise until every submitted request finishes,
        yielding each new token as its step produces it.  With
        ``ServerConfig.overlap`` the loop software-pipelines the
        scheduler — dispatch step N+1, then collect step N — so the
        host-side emission each iteration yields from overlaps the
        device compute already in flight; each request's token stream
        is identical either way (only the cross-request interleaving
        may shift by one step around prefill handoffs)."""
        steps = 0
        if not self.config.overlap:
            while self._busy():
                if steps >= self.config.max_steps:
                    raise RuntimeError(
                        f"stream: max_steps={self.config.max_steps} "
                        "exhausted with requests still pending")
                yield from self.step()
                steps += 1
            yield from self._drain_events()
            return
        if self._busy():
            self._batcher.dispatch()    # prime the pipeline (step 0)
        while self._busy() or self._batcher.pending_steps:
            if steps >= self.config.max_steps:
                raise RuntimeError(
                    f"stream: max_steps={self.config.max_steps} exhausted "
                    "with requests still pending")
            if self._busy():
                self._batcher.dispatch()    # step N+1 onto the device
            yield from self.collect()       # step N's tokens (host sync)
            steps += 1
        # aborts between steps retire requests without a scheduler step:
        # flush their terminal marker events
        yield from self._drain_events()

    def run(self) -> List[StreamEvent]:
        """Drain every pending request; returns all events (generate()
        without the per-uid bookkeeping)."""
        return list(self.stream())

    def generate(self, prompts: Sequence[Sequence[int]],
                 params: Union[SamplingParams, Sequence[SamplingParams],
                               None] = None) -> List[RequestOutput]:
        """Submit `prompts` (each a token-id list) and drain to
        completion.  `params`: one SamplingParams for all, a list
        (paired with prompts), or None (greedy defaults).  Returns
        outputs in prompt order."""
        if isinstance(params, SamplingParams) or params is None:
            plist = [params] * len(prompts)
        else:
            plist = list(params)
            if len(plist) != len(prompts):
                raise ValueError(
                    f"{len(plist)} SamplingParams for "
                    f"{len(prompts)} prompts")
        uids = [self.submit(p, sp) for p, sp in zip(prompts, plist)]
        self.run()
        outs = [self.output(u) for u in uids]
        for u in uids:                 # batch-synchronous callers never
            self.release(u)            # re-read: keep the server bounded
        return outs

    def output(self, uid: int) -> RequestOutput:
        """The finished request's RequestOutput (raises if unknown or
        still in flight)."""
        req = self._requests.get(uid)
        if req is None:
            raise KeyError(f"unknown uid {uid}")
        if not req.done:
            raise ValueError(f"request {uid} still in flight")
        return RequestOutput(
            uid=uid, prompt=list(req.prompt), token_ids=list(req.output),
            logprobs=list(req.logprobs) if req.params.logprobs else None,
            finish_reason=req.finish_reason, submit_time=req.submit_ts,
            first_token_time=req.first_ts, finish_time=req.finish_ts,
            spec_steps=req.spec_steps, spec_drafted=req.spec_drafted,
            spec_accepted=req.spec_accepted,
            tier_hit_pages=req.tier_hits,
            tier_stall_tokens=req.tier_stalls)

    def outputs(self) -> List[RequestOutput]:
        """Every finished, unreleased request, in uid order."""
        return [self.output(u) for u in sorted(self._requests)
                if self._requests[u].done]

    def release(self, uid: int) -> None:
        """Drop a FINISHED request's host bookkeeping (server and
        scheduler).  Incremental (`submit`/`step`) callers serving
        long-lived traffic should release requests once consumed, or
        per-step event scans and completed-request maps grow with the
        server's lifetime; `generate()` releases its own."""
        req = self._requests.get(uid)
        if req is None:
            return
        if not req.done:
            raise ValueError(f"request {uid} still in flight")
        del self._requests[uid]
        del self._streamed[uid]
        self._done_emitted.discard(uid)
        self._batcher.completed.pop(uid, None)


def latency_percentile(vals: Sequence[float], q: float) -> float:
    """Percentile over TTFT/TPOT samples (NaN when none exist — e.g.
    every request aborted before its first token)."""
    vals = [v for v in vals if v is not None]
    if not vals:
        return float("nan")
    return float(np.percentile(np.asarray(vals, np.float64), q))
