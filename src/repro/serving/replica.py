"""Disaggregated prefill/decode: KV page migration between replicas.

This module is the data plane of the multi-replica story (DESIGN.md
§16).  A request that chunk-prefilled on one `ContinuousBatcher` can
move to another — typically a dedicated PREFILL replica handing off to a
DECODE replica — by serializing everything the decode side needs into a
`KVEnvelope`:

  * the request's page-table slice as PAGE BYTES in logical order (the
    physical ids are replica-local and never travel): one `[L, K, T, dh]`
    block per mapped global-pool page, per pool leaf — quantized kv8/kv4
    codes and their per-page scales ride as leaves like any other;
  * window-ring pages (local-attention archs) plus the slot's
    `page_pos_w` ring-base row;
  * recurrent state rows (rwkv / ssm / hybrid families);
  * the scalar `lengths` entry, the emitted output so far (the prefill
    handoff token), per-token logprobs, and the request's
    `SamplingParams` with its RESOLVED PRNG seed — `_seed_of` folds the
    batcher seed and uid, so the envelope pins the stream explicitly and
    the decode replica continues `fold_in(seed, position)` exactly where
    prefill stopped.  Token identity across the migration is therefore a
    consequence of PR 4's stream design, not a new mechanism.

The leaves are flattened with the checkpoint machinery
(`checkpoint._flatten_with_paths`) into a flat ``{path: np.ndarray}``
dict; `to_bytes`/`from_bytes` give the wire form (npz payload + JSON
header) the router actually ships, so migration cost is measurable in
real bytes.

Import allocates FRESH physical pages on the destination (admission
accounting mirrors `_admit_shared`: worst-case footprint against free
pages, hot-tier reservations under DESIGN.md §13 tiering) and splices
the bytes through the `paged_kv` writers only — `stage_hot_slot` for
page bytes (the flat-pool physical index plays the hot-slot role),
`import_slot_rows` for per-slot rows — keeping kvlint's KV004 invariant
intact: no pool-leaf write outside `core/paged_kv.py`.

`PrefixPageIndex` is the cross-replica prefix-cache index: full-page KV
bytes keyed by their token chain, published from any replica's local
`PrefixCache` and importable into another's pool so system-prompt pages
warmed on replica A admit as prefix hits on replica B.
"""
from __future__ import annotations

import dataclasses
import io
import json
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import _flatten_with_paths
from repro.core import paged_kv
from repro.core.page_alloc import OutOfPages
from repro.serving.sampler import SamplingParams
from repro.serving.scheduler import (ContinuousBatcher, Request,
                                     _watch_jit)

ENVELOPE_VERSION = 1

# per-slot rows with a [L, B, ...] layout (batch axis 1) that migrate as
# [L, ...] stacks; page-table / ring-base / lengths rows are batch-axis 0
_STATE_ROW_LEAVES = ("rwkv_state", "rwkv_shift", "rwkv_shift2",
                     "ssm_state", "conv_tail")
_WINDOW_LEAVES = ("k_pages_w", "v_pages_w", "k_scale_w", "v_scale_w")


def _window_leaves(cache) -> List[str]:
    return [n for n in _WINDOW_LEAVES if getattr(cache, n) is not None]


def _page_bytes(batcher: ContinuousBatcher, phys: int,
                leaves: Sequence[str]) -> Dict[str, np.ndarray]:
    """One physical page's bytes per pool leaf, wherever they live: the
    device pool (flat), the hot tier (tiered resident — mapped pages are
    pinned hot, so a live slot's pages always read here), or the host
    capacity store (tiered demoted — prefix-cache pages between uses)."""
    if batcher.tier is not None:
        if batcher.tier.is_resident(phys):
            s = batcher.tier.slot_of(phys)
            return {n: np.asarray(getattr(batcher.cache, n)[:, :, s])
                    for n in leaves}
        return {n: np.array(v) for n, v in batcher._store[phys].items()}
    return {n: np.asarray(getattr(batcher.cache, n)[:, :, phys])
            for n in leaves}


@dataclasses.dataclass
class KVEnvelope:
    """One migratable request: a JSON-able header plus the flat
    ``{path: array}`` leaf dict produced by the checkpoint flattener.

    Array paths: ``prompt`` / ``output`` / ``logprobs``,
    ``pages_g/<j>/<leaf>`` and ``pages_w/<j>/<leaf>`` per logical page j,
    ``page_pos_w``, and ``state/<leaf>`` rows."""
    meta: Dict[str, Any]
    arrays: Dict[str, np.ndarray]

    @property
    def uid(self) -> int:
        return int(self.meta["uid"])

    def nbytes(self) -> int:
        return int(sum(a.nbytes for a in self.arrays.values()))

    def to_bytes(self) -> bytes:
        """Wire form: 8-byte header length, JSON header, npz payload."""
        buf = io.BytesIO()
        np.savez(buf, **self.arrays)
        header = json.dumps(self.meta, sort_keys=True).encode()
        return (len(header).to_bytes(8, "little") + header
                + buf.getvalue())

    @classmethod
    def from_bytes(cls, data: bytes) -> "KVEnvelope":
        hlen = int.from_bytes(data[:8], "little")
        meta = json.loads(data[8:8 + hlen].decode())
        if meta.get("version") != ENVELOPE_VERSION:
            raise ValueError(
                f"KVEnvelope version {meta.get('version')} != "
                f"{ENVELOPE_VERSION}")
        npz = np.load(io.BytesIO(data[8 + hlen:]))
        return cls(meta=meta, arrays={k: npz[k] for k in npz.files})


def _slot_of(batcher: ContinuousBatcher, uid: int) -> int:
    for i, r in enumerate(batcher.slots):
        if r is not None and r.uid == uid:
            return i
    raise KeyError(f"uid {uid} occupies no slot (queued, finished, or "
                   "unknown)")


def export_request(batcher: ContinuousBatcher, uid: int) -> KVEnvelope:
    """Serialize one slot-resident request's KV state.  Read-only: the
    source keeps its pages until `finish_migrated` — the router releases
    only after the destination import succeeded, so a failed import
    retries without losing the request."""
    if not batcher.shared:
        raise ValueError(
            "KV migration needs the shared-pool layout (physical pages "
            "addressed through tables); stripe caches have no per-page "
            "identity to serialize — run replicas with "
            "EngineConfig(shared_pool=True)")
    i = _slot_of(batcher, uid)
    req = batcher.slots[i]
    if i in batcher._prefill_live:
        raise ValueError(f"uid {uid} is mid-chunked-prefill; export "
                         "after the prefill handoff token")
    if not req.output:
        raise ValueError(f"uid {uid} has no emitted token yet")
    c = batcher.cache
    T = batcher.engine.eng.page_tokens
    length = int(batcher._lengths[i])

    tree: Dict[str, Any] = {
        "prompt": np.asarray(req.prompt, np.int32),
        "output": np.asarray(req.output, np.int32),
        "logprobs": np.asarray(req.logprobs, np.float64),
    }
    n_pg = 0
    if batcher.alloc is not None:
        pages = batcher._slot_pages[i]
        n_pg = len(pages)
        assert sorted(pages) == list(range(n_pg)), \
            f"non-contiguous logical pages {sorted(pages)}"
        assert n_pg == -(-length // T), (n_pg, length, T)
        tree["pages_g"] = {
            f"{j:04d}": _page_bytes(batcher, pages[j],
                                    batcher._pool_leaves)
            for j in range(n_pg)}
    n_pw = 0
    if batcher.alloc_w is not None:
        ring = batcher._slot_ring[i]
        n_pw = len(ring)
        wl = _window_leaves(c)
        tree["pages_w"] = {
            f"{j:04d}": {n: np.asarray(getattr(c, n)[:, :, p])
                         for n in wl}
            for j, p in enumerate(ring)}
    tree["page_pos_w"] = (np.asarray(c.page_pos_w[i])
                          if c.page_pos_w is not None else None)
    state = {n: np.asarray(getattr(c, n)[:, i])
             for n in _STATE_ROW_LEAVES if getattr(c, n) is not None}
    tree["state"] = state or None

    p = req.params
    meta = {
        "version": ENVELOPE_VERSION,
        "uid": req.uid,
        "length": length,
        "n_pages_g": n_pg,
        "n_pages_w": n_pw,
        "page_tokens": T,
        "kv_quant": batcher.engine.eng.kv_quant,
        "seed": int(batcher._seed_of(req)),
        "priority": req.priority,
        "deadline_ts": req.deadline_ts,
        "submit_ts": req.submit_ts,
        "first_ts": req.first_ts,
        "params": {
            "temperature": p.temperature, "top_k": p.top_k,
            "top_p": p.top_p, "max_new_tokens": p.max_new_tokens,
            "stop_token_ids": list(p.stop_token_ids),
            "logprobs": p.logprobs, "speculation": p.speculation,
        },
    }
    return KVEnvelope(meta=meta, arrays=_flatten_with_paths(tree))


def finish_migrated(batcher: ContinuousBatcher, uid: int) -> None:
    """Release the source half of a completed migration: the slot
    retires with ``finish_reason="migrated"`` and its pages go back
    through the allocator (prefix-cache references survive, exactly as
    on any other finish)."""
    i = _slot_of(batcher, uid)
    batcher._prefill_live.pop(i, None)
    batcher._finish(i, "migrated")
    batcher.stats["migrations_out"] = (
        batcher.stats.get("migrations_out", 0) + 1)


def _migrate_jits(batcher: ContinuousBatcher):
    """Lazily attach (and JIT_WATCH-register) the import writers: page
    staging reuses the batcher's `_stage_jit` (global leaves — the same
    compiled signature the tiered promoter uses); window pages and the
    per-slot rows get their own one-signature callables."""
    if getattr(batcher, "_migrate_rows_jit", None) is None:
        # per-batcher closures (not the bare module function): jax keys
        # the compile cache by function identity, so batchers of
        # different shapes would otherwise share — and grow — one cache
        def _rows(cache, i, rows):
            return paged_kv.import_slot_rows(cache, i, rows)

        batcher._migrate_rows_jit = jax.jit(_rows, donate_argnums=(0,))
        _watch_jit(f"{type(batcher).__name__}._migrate_rows",
                   batcher._migrate_rows_jit)
    if (batcher.alloc_w is not None
            and getattr(batcher, "_stage_w_jit", None) is None):
        def _stage_w(cache, slot, vals):
            return paged_kv.stage_hot_slot(cache, slot, vals)

        batcher._stage_w_jit = jax.jit(_stage_w, donate_argnums=(0,))
        _watch_jit(f"{type(batcher).__name__}._migrate_stage_w",
                   batcher._stage_w_jit)
    return batcher._migrate_rows_jit


def _stage_page(batcher: ContinuousBatcher, dst: int,
                vals: Dict[str, np.ndarray], *, window: bool) -> None:
    fn = batcher._stage_w_jit if window else batcher._stage_jit
    batcher._count_compile("migrate_stage_w" if window
                           else "tier_stage")
    batcher.cache = fn(batcher.cache, jnp.asarray(dst, jnp.int32),
                       {n: jnp.asarray(v) for n, v in vals.items()})


def import_request(batcher: ContinuousBatcher,
                   env: KVEnvelope) -> Optional[Request]:
    """Splice a migrated request into a free slot of `batcher`.

    Returns the (fresh) Request now decoding here, or None when the
    destination cannot take it YET — no free slot, or the worst-case
    footprint does not fit the pool / hot tier net of reservations (the
    same bound `_admit_shared` enforces, so an admitted import can never
    run out of pages or hot slots mid-decode).  Config mismatches raise:
    migration is only defined between replicas serving the same model
    and cache layout."""
    if not batcher.shared:
        raise ValueError("KV migration import needs a shared-pool "
                         "batcher (EngineConfig.shared_pool=True)")
    m = env.meta
    T = batcher.engine.eng.page_tokens
    if m["page_tokens"] != T or m["kv_quant"] != batcher.engine.eng.kv_quant:
        raise ValueError(
            f"KVEnvelope layout (page_tokens={m['page_tokens']}, "
            f"kv_quant={m['kv_quant']!r}) does not match this replica "
            f"(page_tokens={T}, "
            f"kv_quant={batcher.engine.eng.kv_quant!r})")
    free = [i for i, r in enumerate(batcher.slots)
            if r is None and i not in batcher._prefill_live]
    if not free:
        return None
    i = free[0]

    params = SamplingParams(seed=int(m["seed"]), **m["params"])
    req = Request(
        uid=int(m["uid"]), prompt=[int(t) for t in env.arrays["prompt"]],
        max_new=params.max_new_tokens, params=params,
        output=[int(t) for t in env.arrays["output"]],
        logprobs=[float(v) for v in env.arrays["logprobs"]],
        priority=int(m["priority"]), deadline_ts=m["deadline_ts"],
        submit_ts=m["submit_ts"], first_ts=m["first_ts"])
    length = int(m["length"])
    n_pg, n_pw = int(m["n_pages_g"]), int(m["n_pages_w"])

    # -- admission accounting (mirror of _admit_shared): every imported
    # page is a FRESH allocation here, so the whole worst-case footprint
    # must fit free + cache-evictable pages net of reservations
    need_g = batcher._pages_needed(req) if batcher.alloc is not None else 0
    if batcher.alloc is not None:
        assert n_pg == -(-length // T), (n_pg, length, T)
        evictable = (batcher.prefix_cache.evictable_pages()
                     if batcher.prefix_cache is not None else 0)
        avail = (batcher.alloc.free_count + evictable
                 - batcher._outstanding)
        if need_g > avail:
            return None
        if batcher.tier is not None \
                and batcher._hot_out + need_g > batcher.tier.hot_slots:
            return None
    if batcher.alloc_w is not None and n_pw > batcher.alloc_w.free_count:
        return None

    _migrate_jits(batcher)
    # -- page bytes: allocate destination-local physical pages and stage
    # each logical page's leaves through the one staging writer
    if batcher.alloc is not None:
        for j in range(n_pg):
            p = batcher._alloc_g(j)
            batcher._slot_pages[i][j] = p
            if batcher.tier is not None:
                batcher._table_np[i, j] = batcher._bind_slot(p)
                batcher.tier.pin(p)
                dst = int(batcher._table_np[i, j])
            else:
                batcher._table_np[i, j] = p
                dst = p
            vals = {n: env.arrays[f"pages_g/{j:04d}/{n}"]
                    for n in batcher._pool_leaves}
            _stage_page(batcher, dst, vals, window=False)
    if batcher.alloc_w is not None:
        wl = _window_leaves(batcher.cache)
        for j in range(n_pw):
            p = batcher.alloc_w.alloc_for_logical(j)
            batcher._slot_ring[i].append(p)
            batcher._table_w_np[i, j] = p
            vals = {n: env.arrays[f"pages_w/{j:04d}/{n}"] for n in wl}
            _stage_page(batcher, p, vals, window=True)

    # -- per-slot rows: lengths, ring bases, recurrent state
    rows: Dict[str, np.ndarray] = {"lengths": np.asarray(length)}
    if batcher.cache.page_pos_w is not None:
        rows["page_pos_w"] = env.arrays["page_pos_w"]
    for n in _STATE_ROW_LEAVES:
        if getattr(batcher.cache, n) is not None:
            rows[n] = env.arrays[f"state/{n}"]
    batcher._count_compile("migrate_rows")
    batcher.cache = batcher._migrate_rows_jit(
        batcher.cache, jnp.asarray(i, jnp.int32), rows)

    # -- host bookkeeping: the slot now looks exactly like one whose
    # chunked prefill just handed off
    req.order = batcher._submit_seq
    batcher._submit_seq += 1
    batcher.slots[i] = req
    batcher._set_slot_params(i, req)
    batcher._lengths[i] = length
    batcher._resv[i] = need_g - n_pg
    batcher._outstanding += need_g - n_pg
    if batcher.tier is not None:
        batcher._hot_resv[i] = need_g
        batcher._hot_out += need_g
    batcher._tables_dirty = True
    batcher._push_tables()
    batcher.stats["migrations_in"] = (
        batcher.stats.get("migrations_in", 0) + 1)
    batcher.stats["admits"] += 1
    return req


def build_replica(config=None, *, cfg=None, params=None, rt=None,
                  device=None):
    """Construct a `KVNANDServer` whose weights and KV cache live on
    `device` (replica placement for multi-device fleets — e.g. CI's
    ``--xla_force_host_platform_device_count=4`` harness, or one model
    per accelerator).  Migration and the prefix index move bytes
    through the host, so envelopes cross device boundaries without any
    collective; `device=None` builds on the default device."""
    from repro.serving.api import KVNANDServer
    if device is None:
        return KVNANDServer(config, cfg=cfg, params=params, rt=rt)
    if params is not None:
        params = jax.device_put(params, device)
    with jax.default_device(device):
        return KVNANDServer(config, cfg=cfg, params=params, rt=rt)


class PrefixPageIndex:
    """Cross-replica prefix-cache index (DESIGN.md §16).

    Maps full-page token chains — the same radix keys `PrefixCache`
    uses — to host-side page BYTES per pool leaf.  `publish_from` reads
    a replica's local cache chain for a prompt and records pages the
    index lacks; `warm` imports the chain's missing tail into another
    replica's pool and registers it in that replica's local cache, so
    the next admission of the prompt maps warm pages (a prefix hit)
    instead of re-prefilling.  Tiered destinations land imported bytes
    in the CAPACITY store: the map-in path (or the queue-ahead
    prefetcher) promotes them exactly like any other demoted page.

    Bounded LRU over pages; eviction only drops index bytes, never a
    replica's own cache entries."""

    def __init__(self, page_tokens: int, max_pages: int = 512):
        self.T = page_tokens
        self.max_pages = max_pages
        self._pages: "OrderedDict[Tuple[int, ...], Dict[str, np.ndarray]]" \
            = OrderedDict()
        self.published_pages = 0
        self.warmed_pages = 0

    def __len__(self) -> int:
        return len(self._pages)

    def publish_from(self, batcher: ContinuousBatcher,
                     prompt: Sequence[int]) -> int:
        """Record the full-page chain the replica's local cache holds for
        `prompt`; returns pages newly added to the index."""
        if batcher.prefix_cache is None or batcher.alloc is None:
            return 0
        hit = batcher.prefix_cache.lookup(prompt, record=False)
        n_full = len(prompt) // self.T
        pages = (hit.exact.pages[:n_full] if hit.exact is not None
                 else hit.full_pages)
        toks = tuple(int(t) for t in prompt)
        added = 0
        for j, p in enumerate(pages):
            key = toks[:(j + 1) * self.T]
            if key in self._pages:
                self._pages.move_to_end(key)
                continue
            self._pages[key] = _page_bytes(batcher, int(p),
                                           batcher._pool_leaves)
            added += 1
        while len(self._pages) > self.max_pages:
            self._pages.popitem(last=False)
        self.published_pages += added
        return added

    def chain(self, prompt: Sequence[int]) -> List[Dict[str, np.ndarray]]:
        """The deepest contiguous full-page chain the index holds for
        `prompt` (strict h·T < len, matching `PrefixCache.lookup`)."""
        toks = tuple(int(t) for t in prompt)
        out: List[Dict[str, np.ndarray]] = []
        while (len(out) + 1) * self.T < len(toks):
            key = toks[:(len(out) + 1) * self.T]
            vals = self._pages.get(key)
            if vals is None:
                break
            self._pages.move_to_end(key)
            out.append(vals)
        return out

    def warm(self, batcher: ContinuousBatcher,
             prompt: Sequence[int]) -> int:
        """Import into `batcher` the chain pages its local cache lacks:
        allocate a page, stage the bytes (flat pool) or park them in the
        capacity store (tiered), register the extended chain, and drop
        the import reference so the local cache is the sole owner.
        Returns pages imported; backs off silently under page pressure
        (warming is an optimization, never an obligation)."""
        if batcher.prefix_cache is None or batcher.alloc is None:
            return 0
        local = batcher.prefix_cache.lookup(prompt, record=False)
        if local.exact is not None:
            return 0
        have = len(local.full_pages)
        chain = self.chain(prompt)
        if len(chain) <= have:
            return 0
        _migrate_jits(batcher)
        new_pages: List[int] = []
        for j in range(have, len(chain)):
            if batcher.alloc.free_count - batcher._outstanding <= 0:
                break
            try:
                p = batcher._alloc_g(j)
            except (OutOfPages, RuntimeError):
                break
            if batcher.tier is not None:
                batcher._store[p] = {n: np.array(v)
                                     for n, v in chain[j].items()}
            else:
                _stage_page(batcher, p, chain[j], window=False)
            new_pages.append(p)
        if not new_pages:
            return 0
        n_reg = have + len(new_pages)
        pages = [int(p) for p in local.full_pages] + new_pages
        batcher.prefix_cache.register(list(prompt)[:n_reg * self.T],
                                      pages, None, include_exact=False)
        batcher.alloc.free(new_pages)     # the cache reference remains
        self.warmed_pages += len(new_pages)
        return len(new_pages)
