"""Prometheus-format serving metrics for the async front door.

`ServingMetrics` aggregates the per-request latency surface
(`RequestOutput.ttft` / `.tpot` over a sliding window) plus lifecycle
counters the HTTP layer owns (finishes by reason, 429 rejections), and
`render()` joins them with the scheduler's live `stats` dict and a few
caller-supplied gauges into the Prometheus text exposition format — the
same numbers `benchmarks/serving_bench.py` computes per drain, exported
live at ``GET /metrics`` (serving/async_server.py).

Everything is stdlib: counters behind one lock (the engine thread
observes finishes, the asyncio thread renders scrapes), quantiles via
`latency_percentile` over a bounded deque.  Metric names are part of
the public surface — documented in docs/api.md — so dashboards keep
working across PRs.
"""
from __future__ import annotations

import threading
import time
from collections import Counter, deque
from typing import Dict, Optional, Sequence

from repro.serving.api import RequestOutput, latency_percentile

__all__ = ["ServingMetrics"]

_QUANTILES = (50.0, 95.0, 99.0)

# scheduler stats exported verbatim as monotonic counters
_STAT_COUNTERS = (
    ("steps", "kvnand_scheduler_steps_total",
     "Scheduler steps (dispatch/collect pairs) executed"),
    ("decode_tokens", "kvnand_decode_tokens_total",
     "Tokens emitted by decode/verify steps"),
    ("admits", "kvnand_admits_total",
     "Requests admitted into a batch slot"),
    ("prefill_chunks", "kvnand_prefill_chunks_total",
     "Chunked-prefill ticks processed"),
    ("spec_drafted", "kvnand_spec_drafted_total",
     "Draft tokens offered for verification"),
    ("spec_accepted", "kvnand_spec_accepted_total",
     "Draft tokens accepted by verification"),
    ("cow_copies", "kvnand_cow_copies_total",
     "Copy-on-write page forks"),
    ("tier_hit_pages", "kvnand_tier_hit_pages_total",
     "Cached pages mapped while hot-resident (tiered pool)"),
    ("tier_miss_pages", "kvnand_tier_miss_pages_total",
     "Cached pages demand-promoted at admission (tiered pool)"),
    ("tier_stall_tokens", "kvnand_tier_stall_tokens_total",
     "Demand promotions charged as decode stalls (tiered pool)"),
    ("tier_promotes", "kvnand_tier_promotes_total",
     "Capacity-to-hot page promotions (tiered pool)"),
    ("tier_demotes", "kvnand_tier_demotes_total",
     "Hot-to-capacity page demotions (tiered pool)"),
    ("tier_prefetch_pages", "kvnand_tier_prefetch_pages_total",
     "Pages promoted ahead of admission by the prefetch tick"),
    ("phantom_tokens", "kvnand_phantom_tokens_total",
     "Overlapped-pipeline rows discarded at collect (DESIGN.md §14)"),
    ("deadline_drops", "kvnand_deadline_drops_total",
     "Queued requests expired past their deadline"),
)


def _fmt(v: float) -> str:
    """Prometheus float formatting: plain repr, no exponent surprises."""
    return repr(float(v)) if v == v else "NaN"


class ServingMetrics:
    """Sliding-window latency + lifecycle counters, rendered on scrape."""

    def __init__(self, window: int = 1024):
        self._lock = threading.Lock()
        self._ttft = deque(maxlen=window)
        self._tpot = deque(maxlen=window)
        self._finished: Counter = Counter()
        self._rejected = 0
        self._t0 = time.monotonic()

    # -- observation (engine / HTTP threads) ---------------------------
    def observe(self, out: RequestOutput) -> None:
        """Record one finished request."""
        with self._lock:
            self._finished[out.finish_reason] += 1
            if out.ttft is not None:
                self._ttft.append(out.ttft)
            if out.tpot is not None:
                self._tpot.append(out.tpot)

    def observe_rejected(self) -> None:
        """Record one admission rejection (HTTP 429)."""
        with self._lock:
            self._rejected += 1

    # -- rendering (scrape thread) -------------------------------------
    def _summary(self, lines: list, name: str, help_: str,
                 vals: Sequence[float]) -> None:
        lines.append(f"# HELP {name} {help_}")
        lines.append(f"# TYPE {name} summary")
        for q in _QUANTILES:
            lines.append(f'{name}{{quantile="{q / 100:g}"}} '
                         f"{_fmt(latency_percentile(list(vals), q))}")
        lines.append(f"{name}_count {len(vals)}")

    def render(self, stats: Optional[Dict] = None,
               gauges: Optional[Dict[str, float]] = None) -> str:
        """The /metrics payload.  `stats` is the scheduler's live stats
        dict; `gauges` adds caller-computed point-in-time values (e.g.
        ``kvnand_pool_util``, ``kvnand_queue_depth``) exported verbatim
        with a ``kvnand_`` prefix expected already in the key."""
        stats = stats or {}
        with self._lock:
            lines: list = []
            self._summary(lines, "kvnand_ttft_seconds",
                          "Time to first token (sliding window)",
                          list(self._ttft))
            self._summary(lines, "kvnand_tpot_seconds",
                          "Time per output token after the first "
                          "(sliding window)", list(self._tpot))
            lines.append("# HELP kvnand_requests_finished_total "
                         "Finished requests by finish_reason")
            lines.append("# TYPE kvnand_requests_finished_total counter")
            for reason in sorted(self._finished):
                lines.append(
                    f'kvnand_requests_finished_total{{reason="{reason}"}} '
                    f"{self._finished[reason]}")
            lines.append("# HELP kvnand_rejected_total "
                         "Requests rejected with HTTP 429 (backpressure)")
            lines.append("# TYPE kvnand_rejected_total counter")
            lines.append(f"kvnand_rejected_total {self._rejected}")
        for key, name, help_ in _STAT_COUNTERS:
            if key in stats:
                lines.append(f"# HELP {name} {help_}")
                lines.append(f"# TYPE {name} counter")
                lines.append(f"{name} {stats[key]}")
        # derived rates the benches also report
        prompt_pages = stats.get("prompt_pages", 0)
        if prompt_pages:
            lines.append("# HELP kvnand_prefix_hit_rate "
                         "Prompt pages served from the prefix cache")
            lines.append("# TYPE kvnand_prefix_hit_rate gauge")
            lines.append("kvnand_prefix_hit_rate "
                         f"{_fmt(stats.get('prefix_hit_pages', 0) / prompt_pages)}")
        idle = stats.get("device_idle_s")
        if idle is not None:
            elapsed = max(time.monotonic() - self._t0, 1e-9)
            lines.append("# HELP kvnand_device_idle_fraction "
                         "Host-observed fraction of wall time with no "
                         "step in flight (DESIGN.md §14)")
            lines.append("# TYPE kvnand_device_idle_fraction gauge")
            lines.append("kvnand_device_idle_fraction "
                         f"{_fmt(min(idle / elapsed, 1.0))}")
        for name, val in sorted((gauges or {}).items()):
            lines.append(f"# TYPE {name} gauge")
            lines.append(f"{name} {_fmt(val)}")
        return "\n".join(lines) + "\n"
