"""Pallas API compatibility across jax versions.

jax 0.4.x names the TPU compiler-params struct ``TPUCompilerParams``;
newer releases renamed it ``CompilerParams``.  Kernels import the alias
from here so they run on whichever jax the container ships.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams
