"""Public wrapper for partial paged decode attention with impl dispatch."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_pallas
from repro.kernels.paged_attention.ref import (paged_attention_partial_ref,
                                               paged_chunk_attention_ref)


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def paged_chunk_attention(q, k_pages, v_pages, page_base, start, q_pos, *,
                          window: Optional[int] = None, impl: str = "auto",
                          kv_quant: str = "none", k_scale=None,
                          v_scale=None):
    """Impl dispatch for the chunked-prefill past-context partial.

    Mirrors `paged_attention_partial` so `EngineConfig.attn_impl` stays
    authoritative for both partials.  There is no Pallas chunk kernel yet
    (the natural follow-up): every impl — including "pallas" — currently
    lowers to the jnp oracle, which materializes O(S·NP·T) scores per
    layer; `impl` is accepted now so call sites don't change when the
    kernel lands.
    """
    del impl                      # single implementation today (see above)
    return paged_chunk_attention_ref(
        q, k_pages, v_pages, page_base, start, q_pos, window=window,
        kv_quant=kv_quant, k_scale=k_scale, v_scale=v_scale)


def paged_attention_partial(
    q: jax.Array,          # [B, H, dh]
    k_pages: jax.Array,    # [B, K, NP, T, dh] (kv4: packed [B, K, NP, T/2, dh])
    v_pages: jax.Array,
    page_base: jax.Array,  # [B, NP]
    length: jax.Array,     # [B]
    *,
    window: Optional[int] = None,
    is_global=None,
    impl: str = "auto",
    pages_per_block: int = 8,
    kv_quant: str = "none",
    k_scale: Optional[jax.Array] = None,   # [B, K, NP] per-page×head scales
    v_scale: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (ō [B,H,dh] locally normalized, m [B,H], ℓ [B,H])."""
    if impl == "auto":
        impl = default_impl()
    if impl == "ref" or is_global is not None:
        # dynamic local/global flags (scanned layers) take the jnp path
        return paged_attention_partial_ref(
            q, k_pages, v_pages, page_base, length,
            window=window, is_global=is_global, kv_quant=kv_quant,
            k_scale=k_scale, v_scale=v_scale)

    B, H, dh = q.shape
    K = k_pages.shape[1]
    G = H // K
    ppb = pages_per_block
    NP = k_pages.shape[2]
    while NP % ppb:
        ppb //= 2
    o, m, l = paged_attention_pallas(
        q.reshape(B, K, G, dh), k_pages, v_pages,
        page_base.astype(jnp.int32), length.astype(jnp.int32),
        window=window, pages_per_block=max(ppb, 1),
        interpret=(impl == "interpret"),
        kv_quant=kv_quant, k_scale=k_scale, v_scale=v_scale)
    return (o.reshape(B, H, dh).astype(q.dtype),
            m.reshape(B, H), l.reshape(B, H))
