"""Public wrappers for paged attention: impl dispatch + split-page walk.

Both entry points — the decode partial and the multi-token chunk partial —
accept a `partitions` axis (paper §IV-B head-group parallelism × §IV-D
page-level mapping: independent partition walks whose partials the NPU
aggregates).  The page walk splits into `partitions` contiguous page
ranges, each producing a locally-normalized `(ō, m, ℓ)` partial, and the
partials recombine through the one N-partial merge core
(`merge.merge_partials`).  In the jnp ref path the split is a scanned
blocked walk — each partition's score tensor and dequantized pages stay
1/P-sized and cache-resident, which is where the CPU decode win at long
context comes from (see BENCH_kernels.json `kernels/paged_attention_100k`).
In the Pallas path the split is a real grid axis (kernel.py).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import (
    paged_attention_pallas, paged_attention_pallas_shared)
from repro.kernels.paged_attention.merge import (merge_partials,
                                                resolve_partitions)
from repro.kernels.paged_attention.ref import (gather_table_pages,
                                               paged_attention_partial_ref,
                                               paged_chunk_attention_ref)

VALID_IMPLS = ("auto", "ref", "pallas", "interpret")


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _check_impl(impl: str) -> None:
    if impl not in VALID_IMPLS:
        raise ValueError(f"unknown attention impl {impl!r}; "
                         f"expected one of {VALID_IMPLS}")


def _partition_walk(num_pages: int, partitions: int, piece):
    """Scan `piece(page_lo, pages_per_partition)` over contiguous page
    ranges and merge the stacked partials.  A scan (not a vmap) is
    deliberate: partitions evaluate one at a time, so each partition's
    intermediates are bounded at 1/P of the monolithic walk's."""
    npp = num_pages // partitions

    def body(carry, i):
        return carry, piece(i * npp, npp)

    _, (o, m, l) = jax.lax.scan(body, 0, jnp.arange(partitions))
    return merge_partials(o, m, l, axis=0)


def _resolve_ppb(pages_per_block: int, num_pages: int) -> int:
    """Largest power-of-two-halving of the request that divides the walk.

    Degrading to single-page blocks is never silent: a request for real
    blocking (ppb > 1) against a page count with no even divisor raises,
    instead of quietly serializing the kernel one page at a time."""
    want = min(pages_per_block, num_pages)
    ppb = want
    while num_pages % ppb:
        ppb //= 2
    if ppb < 1:
        ppb = 1
    if ppb == 1 and want > 1 and num_pages > 1:
        raise ValueError(
            f"pages_per_block={pages_per_block} cannot block a walk of "
            f"{num_pages} pages ({num_pages} has no power-of-two divisor "
            f"<= {want}); pass pages_per_block=1 explicitly for "
            "single-page blocks, or page-align the context length")
    return ppb


def paged_chunk_attention(q, k_pages, v_pages, page_base, start, q_pos, *,
                          window: Optional[int] = None, impl: str = "auto",
                          kv_quant: str = "none", k_scale=None,
                          v_scale=None, page_table=None,
                          partitions: int = 0):
    """Impl dispatch for the past-context partial of a multi-token span.

    Serves both chunked prefill (scalar `start`, `q_pos` [S]) and
    speculative-decode verification (per-row `start` [B], `q_pos`
    [B, S] — every slot of the decode batch sits at its own length).
    Mirrors `paged_attention_partial` so `EngineConfig.attn_impl` stays
    authoritative for both partials.  Unknown impl strings raise; every
    known impl — there is no Pallas chunk kernel yet (the natural
    follow-up) — lowers to the partitioned jnp walk: `partitions`
    contiguous page ranges scored independently and merged through
    `merge_partials`, so the per-partition score tensor is
    O(S·NP·T / partitions) instead of the monolithic O(S·NP·T).

    page_table: [B, NP] shared-pool indirection — k/v_pages (and scales)
    are then the GLOBAL [K, P_total, ...] pool and each partition gathers
    only its own table slice (1/P of the stripe) before the oracle runs.
    """
    _check_impl(impl)
    shared = page_table is not None
    NP = page_table.shape[1] if shared else k_pages.shape[2]
    P = resolve_partitions(partitions, NP)

    def piece(lo, npp):
        sl = lambda a, axis: jax.lax.dynamic_slice_in_dim(a, lo, npp, axis)
        if shared:
            tbl = sl(page_table, 1)
            kp = gather_table_pages(k_pages, tbl)
            vp = gather_table_pages(v_pages, tbl)
            ks = vs = None
            if kv_quant != "none":
                ks = gather_table_pages(k_scale, tbl)
                vs = gather_table_pages(v_scale, tbl)
        else:
            kp, vp = sl(k_pages, 2), sl(v_pages, 2)
            ks = None if k_scale is None else sl(k_scale, 2)
            vs = None if v_scale is None else sl(v_scale, 2)
        return paged_chunk_attention_ref(
            q, kp, vp, sl(page_base, 1), start, q_pos, window=window,
            kv_quant=kv_quant, k_scale=ks, v_scale=vs)

    if P == 1:
        return piece(0, NP)
    return _partition_walk(NP, P, piece)


def paged_attention_partial(
    q: jax.Array,          # [B, H, dh]
    k_pages: jax.Array,    # [B, K, NP, T, dh] (kv4: packed [B, K, NP, T/2, dh])
    v_pages: jax.Array,
    page_base: jax.Array,  # [B, NP]
    length: jax.Array,     # [B]
    *,
    window: Optional[int] = None,
    is_global=None,
    impl: str = "auto",
    pages_per_block: int = 8,
    kv_quant: str = "none",
    k_scale: Optional[jax.Array] = None,   # [B, K, NP] per-page×head scales
    v_scale: Optional[jax.Array] = None,
    page_table: Optional[jax.Array] = None,  # [B, NP] shared-pool tables
    partitions: int = 0,   # 0 = auto from page count; must divide NP
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (ō [B,H,dh] locally normalized, m [B,H], ℓ [B,H]).

    With `page_table`, k/v_pages (and scales) are the shared GLOBAL pool
    [K, P_total, ...]: the ref path gathers the slot's stripe view through
    the table; the Pallas path scalar-prefetches the table and lets the
    block index map address the P_total axis directly (no gather).

    `partitions` splits the page walk into that many contiguous ranges
    merged via `merge_partials` (0 resolves per `resolve_partitions`):
    the ref path scans them (1/P-bounded intermediates), the Pallas path
    runs them as a parallel grid axis per kv-head group.
    """
    _check_impl(impl)
    if impl == "auto":
        impl = default_impl()
    B, H, dh = q.shape
    shared = page_table is not None
    K = k_pages.shape[0] if shared else k_pages.shape[1]
    G = H // K
    NP = page_table.shape[1] if shared else k_pages.shape[2]
    P = resolve_partitions(partitions, NP)

    if impl == "ref" or is_global is not None:
        # dynamic local/global flags (scanned layers) take the jnp path
        def piece(lo, npp):
            sl = lambda a, axis: jax.lax.dynamic_slice_in_dim(a, lo, npp,
                                                              axis)
            if shared:
                tbl = sl(page_table, 1)
                kp = gather_table_pages(k_pages, tbl)
                vp = gather_table_pages(v_pages, tbl)
                ks = vs = None
                if kv_quant != "none":
                    ks = gather_table_pages(k_scale, tbl)
                    vs = gather_table_pages(v_scale, tbl)
            else:
                kp, vp = sl(k_pages, 2), sl(v_pages, 2)
                ks = None if k_scale is None else sl(k_scale, 2)
                vs = None if v_scale is None else sl(v_scale, 2)
            return paged_attention_partial_ref(
                q, kp, vp, sl(page_base, 1), length, window=window,
                is_global=is_global, kv_quant=kv_quant,
                k_scale=ks, v_scale=vs)

        if P == 1:
            return piece(0, NP)
        return _partition_walk(NP, P, piece)

    if shared:
        o, m, l = paged_attention_pallas_shared(
            q.reshape(B, K, G, dh), k_pages, v_pages,
            page_table.astype(jnp.int32), page_base.astype(jnp.int32),
            length.astype(jnp.int32), window=window,
            interpret=(impl == "interpret"),
            kv_quant=kv_quant, k_scale=k_scale, v_scale=v_scale,
            partitions=P)
        if P > 1:
            o, m, l = merge_partials(o, m, l, axis=2)
        return (o.reshape(B, H, dh).astype(q.dtype),
                m.reshape(B, H), l.reshape(B, H))

    ppb = _resolve_ppb(pages_per_block, NP // P)
    o, m, l = paged_attention_pallas(
        q.reshape(B, K, G, dh), k_pages, v_pages,
        page_base.astype(jnp.int32), length.astype(jnp.int32),
        window=window, pages_per_block=ppb,
        interpret=(impl == "interpret"),
        kv_quant=kv_quant, k_scale=k_scale, v_scale=v_scale,
        partitions=P)
    if P > 1:
        o, m, l = merge_partials(o, m, l, axis=2)
    return (o.reshape(B, H, dh).astype(q.dtype),
            m.reshape(B, H), l.reshape(B, H))
