"""Public wrapper for partial paged decode attention with impl dispatch."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import (
    paged_attention_pallas, paged_attention_pallas_shared)
from repro.kernels.paged_attention.ref import (gather_table_pages,
                                               paged_attention_partial_ref,
                                               paged_chunk_attention_ref)


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def paged_chunk_attention(q, k_pages, v_pages, page_base, start, q_pos, *,
                          window: Optional[int] = None, impl: str = "auto",
                          kv_quant: str = "none", k_scale=None,
                          v_scale=None, page_table=None):
    """Impl dispatch for the past-context partial of a multi-token span.

    Serves both chunked prefill (scalar `start`, `q_pos` [S]) and
    speculative-decode verification (per-row `start` [B], `q_pos`
    [B, S] — every slot of the decode batch sits at its own length).
    Mirrors `paged_attention_partial` so `EngineConfig.attn_impl` stays
    authoritative for both partials.  There is no Pallas chunk kernel yet
    (the natural follow-up): every impl — including "pallas" — currently
    lowers to the jnp oracle, which materializes O(S·NP·T) scores per
    layer; `impl` is accepted now so call sites don't change when the
    kernel lands.

    page_table: [B, NP] shared-pool indirection — k/v_pages (and scales)
    are then the GLOBAL [K, P_total, ...] pool and the slot's pages are
    gathered through the table before the oracle runs.
    """
    del impl                      # single implementation today (see above)
    if page_table is not None:
        k_pages = gather_table_pages(k_pages, page_table)
        v_pages = gather_table_pages(v_pages, page_table)
        if kv_quant != "none":
            k_scale = gather_table_pages(k_scale, page_table)
            v_scale = gather_table_pages(v_scale, page_table)
    return paged_chunk_attention_ref(
        q, k_pages, v_pages, page_base, start, q_pos, window=window,
        kv_quant=kv_quant, k_scale=k_scale, v_scale=v_scale)


def paged_attention_partial(
    q: jax.Array,          # [B, H, dh]
    k_pages: jax.Array,    # [B, K, NP, T, dh] (kv4: packed [B, K, NP, T/2, dh])
    v_pages: jax.Array,
    page_base: jax.Array,  # [B, NP]
    length: jax.Array,     # [B]
    *,
    window: Optional[int] = None,
    is_global=None,
    impl: str = "auto",
    pages_per_block: int = 8,
    kv_quant: str = "none",
    k_scale: Optional[jax.Array] = None,   # [B, K, NP] per-page×head scales
    v_scale: Optional[jax.Array] = None,
    page_table: Optional[jax.Array] = None,  # [B, NP] shared-pool tables
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (ō [B,H,dh] locally normalized, m [B,H], ℓ [B,H]).

    With `page_table`, k/v_pages (and scales) are the shared GLOBAL pool
    [K, P_total, ...]: the ref path gathers the slot's stripe view through
    the table; the Pallas path scalar-prefetches the table and lets the
    block index map address the P_total axis directly (no gather).
    """
    if impl == "auto":
        impl = default_impl()
    B, H, dh = q.shape
    K = k_pages.shape[0] if page_table is not None else k_pages.shape[1]
    G = H // K
    if impl == "ref" or is_global is not None:
        # dynamic local/global flags (scanned layers) take the jnp path
        if page_table is not None:
            k_pages = gather_table_pages(k_pages, page_table)
            v_pages = gather_table_pages(v_pages, page_table)
            if kv_quant != "none":
                k_scale = gather_table_pages(k_scale, page_table)
                v_scale = gather_table_pages(v_scale, page_table)
        return paged_attention_partial_ref(
            q, k_pages, v_pages, page_base, length,
            window=window, is_global=is_global, kv_quant=kv_quant,
            k_scale=k_scale, v_scale=v_scale)

    if page_table is not None:
        o, m, l = paged_attention_pallas_shared(
            q.reshape(B, K, G, dh), k_pages, v_pages,
            page_table.astype(jnp.int32), page_base.astype(jnp.int32),
            length.astype(jnp.int32), window=window,
            interpret=(impl == "interpret"),
            kv_quant=kv_quant, k_scale=k_scale, v_scale=v_scale)
        return (o.reshape(B, H, dh).astype(q.dtype),
                m.reshape(B, H), l.reshape(B, H))

    ppb = pages_per_block
    NP = k_pages.shape[2]
    while NP % ppb:
        ppb //= 2
    o, m, l = paged_attention_pallas(
        q.reshape(B, K, G, dh), k_pages, v_pages,
        page_base.astype(jnp.int32), length.astype(jnp.int32),
        window=window, pages_per_block=max(ppb, 1),
        interpret=(impl == "interpret"),
        kv_quant=kv_quant, k_scale=k_scale, v_scale=v_scale)
    return (o.reshape(B, H, dh).astype(q.dtype),
            m.reshape(B, H), l.reshape(B, H))
