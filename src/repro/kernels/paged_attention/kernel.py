"""Pallas TPU paged decode-attention kernel.

Grid: (B, K, page_blocks) — page_blocks innermost/sequential so VMEM scratch
carries the online softmax across the sequence-striped page pool.  Each step
streams `pages_per_block` whole pages [ppb·T, dh] HBM→VMEM (the layout
guarantees pages are head-major and physically sequential — paper §IV-D:
"sequential page order ... preserved for high read speed") and computes the
G-query-head group against them (the paper's head-group granule).

page_base [B, NP] and length [B] arrive via scalar prefetch (SMEM): token
validity is data-derived, so there is no gather and no page-table walk in
the inner loop.

Outputs are the per-shard partials (ō, m, ℓ) consumed by the cross-device
combine (core/seqpar.py).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

NEG_INF = -1e30


def _load_pages(ref, ppb: int, T: int, dh: int, kv_quant: str):
    """VMEM page block -> [ppb*T, dh] f32 raw codes (unscaled for quant).

    kv4 stores two tokens per byte along the token dim (high nibble first,
    the `quant_gemv` packing order); the unpack happens in-register after
    the 2-4× smaller block has streamed HBM→VMEM — that is the whole win.
    """
    if kv_quant == "kv4":
        qp = ref[0, 0]                                       # [ppb, T/2, dh]
        hi = ((qp >> 4) & 0xF).astype(jnp.int8) - 8
        lo = (qp & 0xF).astype(jnp.int8) - 8
        x = jnp.stack([hi, lo], axis=2)                      # [ppb, T/2, 2, dh]
        return x.reshape(ppb * T, dh).astype(jnp.float32)
    return ref[0, 0].reshape(ppb * T, dh).astype(jnp.float32)


def _kernel(base_ref, len_ref,                       # scalar prefetch (SMEM)
            q_ref, k_ref, v_ref, *refs,              # VMEM blocks (+scales)
            T: int, ppb: int, n_blocks: int, window: Optional[int],
            scale: float, kv_quant: str, partitioned: bool = False):
    if kv_quant == "none":
        ks_ref = vs_ref = None
        o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr = refs
    else:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr = refs
    b = pl.program_id(0)
    # partitioned grid (B, K, P, blocks-per-partition): each partition is
    # an independent walk over its own page range — the scratch online
    # softmax re-initializes at ITS first block and finalizes into ITS
    # output slot, and `blk` addresses the global page-block axis
    if partitioned:
        ib = pl.program_id(3)
        blk = pl.program_id(2) * n_blocks + ib
    else:
        ib = pl.program_id(2)
        blk = ib

    @pl.when(ib == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    G, dh = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32) * scale              # [G, dh]
    k = _load_pages(k_ref, ppb, T, dh, kv_quant)
    v = _load_pages(v_ref, ppb, T, dh, kv_quant)

    # per-page × per-head dequant scales, broadcast to score columns: the
    # K scale folds into s AFTER the MXU dot, the V scale folds into p
    # BEFORE the attend dot — no dequantized page copy ever materializes.
    if kv_quant != "none":
        k_cols = jnp.broadcast_to(ks_ref[0, 0][:, None],
                                  (ppb, T)).reshape(ppb * T)
        v_cols = jnp.broadcast_to(vs_ref[0, 0][:, None],
                                  (ppb, T)).reshape(ppb * T)

    # data-derived validity from prefetched page bases
    length = len_ref[b]
    slots = jax.lax.broadcasted_iota(jnp.int32, (ppb, T), 1)
    bases = base_ref[b, pl.dslice(blk * ppb, ppb)]           # [ppb]
    pos = bases[:, None] + slots                             # [ppb, T]
    valid = (bases[:, None] >= 0) & (pos < length)
    if window is not None:
        valid &= pos > (length - 1 - window)
    valid = valid.reshape(ppb * T)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [G, ppb*T]
    if kv_quant != "none":
        s = s * k_cols[None, :]
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]                                      # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(valid[None, :], p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
    pv = p * v_cols[None, :] if kv_quant != "none" else p
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        pv, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ib == n_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        if partitioned:
            o_ref[0, 0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)
            m_ref[0, 0, 0] = m_scr[...]
            l_ref[0, 0, 0] = l_scr[...]
        else:
            o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)
            m_ref[0, 0] = m_scr[...]
            l_ref[0, 0] = l_scr[...]


def _load_page_shared(ref, T: int, dh: int, kv_quant: str):
    """VMEM single-page block [1, 1, Ts, dh] -> [T, dh] f32 raw codes."""
    if kv_quant == "kv4":
        qp = ref[0, 0]                                       # [T/2, dh]
        hi = ((qp >> 4) & 0xF).astype(jnp.int8) - 8
        lo = (qp & 0xF).astype(jnp.int8) - 8
        x = jnp.stack([hi, lo], axis=1)                      # [T/2, 2, dh]
        return x.reshape(T, dh).astype(jnp.float32)
    return ref[0, 0].reshape(T, dh).astype(jnp.float32)


def _kernel_shared(tbl_ref, base_ref, len_ref,       # scalar prefetch (SMEM)
                   q_ref, k_ref, v_ref, *refs,       # VMEM blocks (+scales)
                   T: int, n_blocks: int, window: Optional[int],
                   scale: float, kv_quant: str, partitioned: bool = False):
    """Shared-pool body: identical online softmax to `_kernel`, but each
    grid step streams ONE pool page picked by the prefetched page table
    (the block index map below) — the §IV-D logical→physical walk happens
    in SMEM before the DMA, never in the inner loop."""
    if kv_quant == "none":
        ks_ref = vs_ref = None
        o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr = refs
    else:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr = refs
    b = pl.program_id(0)
    if partitioned:
        ib = pl.program_id(3)
        blk = pl.program_id(2) * n_blocks + ib       # global logical page
    else:
        ib = pl.program_id(2)
        blk = ib

    @pl.when(ib == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    G, dh = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32) * scale              # [G, dh]
    k = _load_page_shared(k_ref, T, dh, kv_quant)            # [T, dh]
    v = _load_page_shared(v_ref, T, dh, kv_quant)

    length = len_ref[b]
    base = base_ref[b, blk]
    pos = base + jax.lax.broadcasted_iota(jnp.int32, (1, T), 1)[0]
    valid = (base >= 0) & (pos < length)
    if window is not None:
        valid &= pos > (length - 1 - window)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [G, T]
    if kv_quant != "none":
        s = s * ks_ref[0, 0]
    s = jnp.where(valid[None, :], s, NEG_INF)

    m_prev = m_scr[...]                                      # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(valid[None, :], p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
    pv = p * vs_ref[0, 0] if kv_quant != "none" else p
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        pv, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ib == n_blocks - 1)
    def _finalize():
        ll = jnp.maximum(l_scr[...], 1e-30)
        if partitioned:
            o_ref[0, 0, 0] = (acc_scr[...] / ll).astype(o_ref.dtype)
            m_ref[0, 0, 0] = m_scr[...]
            l_ref[0, 0, 0] = l_scr[...]
        else:
            o_ref[0, 0] = (acc_scr[...] / ll).astype(o_ref.dtype)
            m_ref[0, 0] = m_scr[...]
            l_ref[0, 0] = l_scr[...]


def paged_attention_pallas_shared(
    q: jax.Array,          # [B, K, G, dh]
    k_pages: jax.Array,    # [K, P_total, T, dh] (kv4: [K, P, T/2, dh])
    v_pages: jax.Array,
    page_table: jax.Array,  # [B, NP] int32 physical indices (in range)
    page_base: jax.Array,  # [B, NP] absolute pos of slot 0 (<0 = unwritten)
    length: jax.Array,     # [B] int32
    *,
    window: Optional[int] = None,
    interpret: bool = False,
    kv_quant: str = "none",
    k_scale: Optional[jax.Array] = None,   # [K, P_total] f32
    v_scale: Optional[jax.Array] = None,
    partitions: int = 1,
):
    """Shared-pool paged decode attention: grid (B, K, NP) with the page
    table scalar-prefetched so the BLOCK INDEX MAP addresses the global
    P_total axis directly — one arbitrary pool page per step, no gathered
    copy of the slot's stripe ever materializes.

    partitions > 1 splits the logical page walk into a PARALLEL grid axis
    — grid (B, K, partitions, NP/partitions) — emitting per-partition
    partials [B, K, partitions, ...] for the caller to merge
    (`merge.merge_partials`); the sequential scratch accumulation then
    only spans one partition's pages (the paper's head-group × split-page
    parallel read, with NPU-side aggregation)."""
    K, P, Ts, dh = k_pages.shape
    T = 2 * Ts if kv_quant == "kv4" else Ts
    B, NP = page_table.shape
    G = q.shape[2]
    scale = dh ** -0.5
    assert NP % partitions == 0, (NP, partitions)
    npp = NP // partitions

    if partitions == 1:
        qspec = pl.BlockSpec((1, 1, G, dh), lambda b, k, ib, *_:
                             (b, k, 0, 0))
        pspec = pl.BlockSpec((1, 1, Ts, dh), lambda b, k, ib, tbl, base, ln:
                             (k, tbl[b, ib], 0, 0))
        sspec = pl.BlockSpec((1, 1), lambda b, k, ib, tbl, base, ln:
                             (k, tbl[b, ib]))
        grid = (B, K, NP)
        out_shape = [(B, K, G, dh), (B, K, G, 1), (B, K, G, 1)]
        out_specs = [
            pl.BlockSpec((1, 1, G, dh), lambda b, k, ib, *_: (b, k, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, k, ib, *_: (b, k, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, k, ib, *_: (b, k, 0, 0)),
        ]
        semantics = ("parallel", "parallel", "arbitrary")
    else:
        qspec = pl.BlockSpec((1, 1, G, dh), lambda b, k, pt, ib, *_:
                             (b, k, 0, 0))
        pspec = pl.BlockSpec((1, 1, Ts, dh),
                             lambda b, k, pt, ib, tbl, base, ln:
                             (k, tbl[b, pt * npp + ib], 0, 0))
        sspec = pl.BlockSpec((1, 1), lambda b, k, pt, ib, tbl, base, ln:
                             (k, tbl[b, pt * npp + ib]))
        grid = (B, K, partitions, npp)
        out_shape = [(B, K, partitions, G, dh), (B, K, partitions, G, 1),
                     (B, K, partitions, G, 1)]
        out_specs = [
            pl.BlockSpec((1, 1, 1, G, dh), lambda b, k, pt, ib, *_:
                         (b, k, pt, 0, 0)),
            pl.BlockSpec((1, 1, 1, G, 1), lambda b, k, pt, ib, *_:
                         (b, k, pt, 0, 0)),
            pl.BlockSpec((1, 1, 1, G, 1), lambda b, k, pt, ib, *_:
                         (b, k, pt, 0, 0)),
        ]
        semantics = ("parallel", "parallel", "parallel", "arbitrary")

    in_specs = [qspec, pspec, pspec]
    inputs = [q, k_pages, v_pages]
    if kv_quant != "none":
        assert k_scale is not None and v_scale is not None, kv_quant
        in_specs += [sspec, sspec]
        inputs += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dh), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel_shared, T=T, n_blocks=npp,
                               window=window, scale=scale, kv_quant=kv_quant,
                               partitioned=(partitions > 1))
    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(s, jnp.float32) for s in out_shape],
        interpret=interpret,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=semantics),
    )(page_table.astype(jnp.int32), page_base, length, *inputs)
    return o, m[..., 0], l[..., 0]


def paged_attention_pallas(
    q: jax.Array,          # [B, K, G, dh]
    k_pages: jax.Array,    # [B, K, NP, T, dh] (kv4: [B, K, NP, T/2, dh])
    v_pages: jax.Array,
    page_base: jax.Array,  # [B, NP] int32
    length: jax.Array,     # [B] int32
    *,
    window: Optional[int] = None,
    pages_per_block: int = 8,
    interpret: bool = False,
    kv_quant: str = "none",
    k_scale: Optional[jax.Array] = None,   # [B, K, NP] f32 per-page scales
    v_scale: Optional[jax.Array] = None,
    partitions: int = 1,
):
    """Sequence-striped paged decode attention.

    partitions > 1 turns the page-block walk into grid
    (B, K, partitions, blocks-per-partition): the block axis stays the
    sequential ("arbitrary") scratch-carrying dim but now only spans one
    partition's pages, while the partition axis is PARALLEL — each
    (kv-head, partition) pair is an independent walk whose partial lands
    in [B, K, partitions, ...] outputs for the caller's
    `merge.merge_partials`."""
    B, K, NP, Ts, dh = k_pages.shape
    T = 2 * Ts if kv_quant == "kv4" else Ts
    G = q.shape[2]
    assert NP % partitions == 0, (NP, partitions)
    npp = NP // partitions
    ppb = min(pages_per_block, npp)
    assert npp % ppb == 0, (npp, ppb)
    n_blocks = npp // ppb
    scale = dh ** -0.5

    if partitions == 1:
        qspec = pl.BlockSpec((1, 1, G, dh), lambda b, k, ib, *_:
                             (b, k, 0, 0))
        pspec = pl.BlockSpec((1, 1, ppb, Ts, dh),
                             lambda b, k, ib, *_: (b, k, ib, 0, 0))
        sspec = pl.BlockSpec((1, 1, ppb), lambda b, k, ib, *_: (b, k, ib))
        grid = (B, K, n_blocks)
        out_shape = [(B, K, G, dh), (B, K, G, 1), (B, K, G, 1)]
        out_specs = [
            pl.BlockSpec((1, 1, G, dh), lambda b, k, ib, *_: (b, k, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, k, ib, *_: (b, k, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, k, ib, *_: (b, k, 0, 0)),
        ]
        semantics = ("parallel", "parallel", "arbitrary")
    else:
        qspec = pl.BlockSpec((1, 1, G, dh), lambda b, k, pt, ib, *_:
                             (b, k, 0, 0))
        pspec = pl.BlockSpec((1, 1, ppb, Ts, dh), lambda b, k, pt, ib, *_:
                             (b, k, pt * n_blocks + ib, 0, 0))
        sspec = pl.BlockSpec((1, 1, ppb), lambda b, k, pt, ib, *_:
                             (b, k, pt * n_blocks + ib))
        grid = (B, K, partitions, n_blocks)
        out_shape = [(B, K, partitions, G, dh), (B, K, partitions, G, 1),
                     (B, K, partitions, G, 1)]
        out_specs = [
            pl.BlockSpec((1, 1, 1, G, dh), lambda b, k, pt, ib, *_:
                         (b, k, pt, 0, 0)),
            pl.BlockSpec((1, 1, 1, G, 1), lambda b, k, pt, ib, *_:
                         (b, k, pt, 0, 0)),
            pl.BlockSpec((1, 1, 1, G, 1), lambda b, k, pt, ib, *_:
                         (b, k, pt, 0, 0)),
        ]
        semantics = ("parallel", "parallel", "parallel", "arbitrary")

    in_specs = [qspec, pspec, pspec]
    inputs = [q, k_pages, v_pages]
    if kv_quant != "none":
        assert k_scale is not None and v_scale is not None, kv_quant
        in_specs += [sspec, sspec]
        inputs += [k_scale.astype(jnp.float32), v_scale.astype(jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, dh), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, T=T, ppb=ppb, n_blocks=n_blocks,
                               window=window, scale=scale, kv_quant=kv_quant,
                               partitioned=(partitions > 1))
    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct(s, jnp.float32) for s in out_shape],
        interpret=interpret,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=semantics),
    )(page_base, length, *inputs)
    return o, m[..., 0], l[..., 0]
