"""N-partial log-sum-exp merge core (the paper's NPU softmax aggregation).

Every attention entry point — decode (`paged_attention_partial`), chunked
prefill / speculative verify (`paged_chunk_attention`) and the split-page
Pallas grids — produces locally-normalized partials `(ō, m, ℓ)` over some
subset of the KV pages.  This module is the single place those partials
recombine: `merge_partials` tree-merges ANY number of partials along one
axis with log-sum-exp renormalization.

The reduction is written in its order-free form (one global max, one
weighted sum) rather than as a fold of two-way merges, so the result is
invariant under permutation and re-bracketing of the partition axis —
the property that lets the same core serve a vmapped ref split, a Pallas
partition grid and the cross-device psum combine interchangeably.

Empty partitions are the identity: a partial holding no valid tokens
carries `m = NEG_INF` (−1e30, kept finite so `exp` never produces NaN)
and `ℓ = 0`, giving it zero weight; if EVERY partial is empty the merged
output is all-zeros with `ℓ = 0`, matching what a single partial over an
empty page set returns.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def merge_partials(o: jax.Array, m: jax.Array, l: jax.Array,
                   axis: int = 0) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Merge N locally-normalized attention partials stacked on `axis`.

    o: [..., P on axis, ..., dh] partial outputs (each already divided by
    its own ℓ); m/l: matching stats without the trailing dh dim.  Returns
    the merged (ō, m, ℓ) with the partition axis reduced away — the same
    contract a single `paged_attention_partial` call over the union of
    the partitions' pages would produce.
    """
    m = jnp.moveaxis(m, axis, 0)
    l = jnp.moveaxis(l, axis, 0)
    o = jnp.moveaxis(o, axis, 0)
    m_all = jnp.max(m, axis=0)
    w = l * jnp.exp(m - m_all[None])             # ℓ re-scaled to global max
    l_all = jnp.sum(w, axis=0)
    o_all = jnp.sum(o * w[..., None], axis=0) \
        / jnp.maximum(l_all, 1e-30)[..., None]
    return o_all, m_all, l_all


def resolve_partitions(partitions: int, num_pages: int) -> int:
    """Resolve a partition request against a concrete page count.

    partitions > 0 is an explicit request and must divide `num_pages`
    exactly — a non-divisor raises rather than silently rebalancing, so a
    DSE-chosen split can't quietly degrade.  partitions == 0 means auto:
    contexts short enough that the page walk fits cache stay sequential,
    long walks split 16 ways (halved down to the nearest divisor), which
    is where the split-page walk pays for its merge (see DESIGN.md §12).
    """
    if num_pages <= 0:
        raise ValueError(f"num_pages must be positive, got {num_pages}")
    if partitions < 0:
        raise ValueError(f"partitions must be >= 0, got {partitions}")
    if partitions:
        if num_pages % partitions:
            raise ValueError(
                f"partitions={partitions} does not divide the page count "
                f"{num_pages}; pick a divisor (or 0 for auto)")
        return partitions
    p = 1 if num_pages < 256 else 16
    while p > 1 and num_pages % p:
        p //= 2
    return p
