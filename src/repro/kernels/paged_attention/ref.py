"""Pure-jnp oracle for partial paged decode attention.

Computes one decode token's attention against a sequence-striped page pool
(one shard's worth), returning locally-normalized output + (m, ℓ) softmax
stats for the cross-shard combine (paper: per-die Logit/Attend partials that
the NPU aggregates).

Key property of the page layout (paper §IV-D): pages are (head)-major and
physically sequential, so validity is *data-derived* (page_base + slot vs
length/window) — reads are streaming, never gathered.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_partial_ref(
    q: jax.Array,          # [B, H, dh]
    k_pages: jax.Array,    # [B, K, NP, T, dh]   (local shard)
    v_pages: jax.Array,    # [B, K, NP, T, dh]
    page_base: jax.Array,  # [B, NP] absolute pos of slot 0 (<0 = unwritten)
    length: jax.Array,     # [B] context length incl. current token
    *,
    window: Optional[int] = None,
    is_global=None,        # traced bool: overrides window (gemma3 scan)
    kv_quant: str = "none",
    k_scale: Optional[jax.Array] = None,   # [B, K, NP] per-page×head scales
    v_scale: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, K, NP = k_pages.shape[:3]
    dh = k_pages.shape[-1]
    T = 2 * k_pages.shape[3] if kv_quant == "kv4" else k_pages.shape[3]
    H = q.shape[1]
    G = H // K
    scale = dh ** -0.5

    # compute in the POOL dtype with f32 accumulation: casting the pool to
    # f32 would materialize a 2× copy of the entire local KV every layer
    # (measured: dominant HLO bytes) — exactly what a TPU kernel avoids by
    # feeding bf16 into the MXU with an f32 accumulator.  Quantized pools
    # contract their int codes in f32 and fold the per-page scale into the
    # score / probability matrices (mirroring the Pallas kernel's math).
    # NB: the f32 cast of the codes below DOES materialize a dequant-width
    # copy — this path is the correctness oracle; the bandwidth win is the
    # Pallas kernel's, which streams the packed codes into VMEM.
    if kv_quant != "none":
        from repro.core.quant import unpack_int4_tokens
        if kv_quant == "kv4":
            k_pages = unpack_int4_tokens(k_pages)
            v_pages = unpack_int4_tokens(v_pages)
        k_pages = k_pages.astype(jnp.float32)
        v_pages = v_pages.astype(jnp.float32)
    dt = k_pages.dtype
    qg = (q.astype(jnp.float32) * scale).astype(dt).reshape(B, K, G, dh)

    pos = page_base[:, :, None] + jnp.arange(T)[None, None, :]   # [B, NP, T]
    valid = (page_base >= 0)[:, :, None] & (pos < length[:, None, None])
    if window is not None:
        in_w = pos > (length[:, None, None] - 1 - window)
        if is_global is not None:
            in_w = in_w | is_global
        valid &= in_w

    s = jnp.einsum("bkgd,bkntd->bkgnt", qg, k_pages,
                   preferred_element_type=jnp.float32)           # [B,K,G,NP,T]
    if kv_quant != "none":
        s = s * k_scale[:, :, None, :, None]
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    m = jnp.max(s, axis=(-2, -1))                                # [B, K, G]
    p = jnp.exp(s - m[..., None, None])
    p = jnp.where(valid[:, None, None], p, 0.0)
    l = jnp.sum(p, axis=(-2, -1))                                # [B, K, G]
    pv = p * v_scale[:, :, None, :, None] if kv_quant != "none" else p
    o = jnp.einsum("bkgnt,bkntd->bkgd", pv.astype(dt), v_pages,
                   preferred_element_type=jnp.float32)
    o = o / jnp.maximum(l, 1e-30)[..., None]

    return (o.reshape(B, H, dh), m.reshape(B, H), l.reshape(B, H))


def paged_chunk_attention_ref(
    q: jax.Array,          # [B, S, H, dh] chunk queries (B = one slot)
    k_pages: jax.Array,    # [B, K, NP, T, dh] the slot's page stripe
    v_pages: jax.Array,
    page_base: jax.Array,  # [B, NP] absolute pos of slot 0 (<0 = unwritten)
    start: jax.Array,      # scalar or [B]: absolute position of the span's
                           # first token — only keys strictly BELOW attend
    q_pos: jax.Array,      # [S] or [B, S] absolute query positions
    *,
    window: Optional[int] = None,
    kv_quant: str = "none",
    k_scale: Optional[jax.Array] = None,   # [B, K, NP] per-page×head scales
    v_scale: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Past-context partial attention for a multi-token span (validation
    ref).

    Multi-query generalization of `paged_attention_partial_ref`: every
    query of an S-token span attends the slot's already-written pages.
    The span's own K/V are handled by the in-span causal partial
    (`seqpar._attn_block_partial`), so keys at positions ≥ `start` — which
    may hold a recycled occupant's stale pages — are masked here, and the
    two partials merge via log-sum-exp (`seqpar.merge_two`).

    Two callers share this oracle: chunked prefill (one slot per call —
    scalar `start`, `q_pos` [S]) and speculative-decode verification
    (the whole decode batch at once — ragged per-row `start` [B] and
    `q_pos` [B, S], since every slot sits at its own length).

    Returns locally-normalized (o [B,S,H,dh], m [B,S,H], ℓ [B,S,H]); a
    query whose whole window lies inside the span gets ℓ = 0 and thus
    zero weight in the merge.
    """
    B, K, NP = k_pages.shape[:3]
    dh = k_pages.shape[-1]
    T = 2 * k_pages.shape[3] if kv_quant == "kv4" else k_pages.shape[3]
    S, H = q.shape[1], q.shape[2]
    G = H // K
    scale = dh ** -0.5

    if kv_quant != "none":
        from repro.core.quant import unpack_int4_tokens
        if kv_quant == "kv4":
            k_pages = unpack_int4_tokens(k_pages)
            v_pages = unpack_int4_tokens(v_pages)
        k_pages = k_pages.astype(jnp.float32)
        v_pages = v_pages.astype(jnp.float32)
    dt = k_pages.dtype
    qg = (q.astype(jnp.float32) * scale).astype(dt).reshape(B, S, K, G, dh)

    start = jnp.broadcast_to(jnp.asarray(start, jnp.int32), (B,))
    q_pos = jnp.asarray(q_pos, jnp.int32)
    if q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos[None], (B, S))

    pos = page_base[:, :, None] + jnp.arange(T)[None, None, :]   # [B, NP, T]
    valid = (page_base >= 0)[:, :, None] & (pos < start[:, None, None])
    mask = valid[:, None, None, None]                  # [B, 1, 1, 1, NP, T]
    if window is not None:
        in_w = (pos[:, None]                           # [B, S, NP, T]
                > (q_pos[:, :, None, None] - window))
        mask = mask & in_w[:, None, None]              # [B, 1, 1, S, NP, T]

    s = jnp.einsum("bskgd,bkntd->bkgsnt", qg, k_pages,
                   preferred_element_type=jnp.float32)  # [B,K,G,S,NP,T]
    if kv_quant != "none":
        s = s * k_scale[:, :, None, None, :, None]
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=(-2, -1))                       # [B, K, G, S]
    p = jnp.exp(s - m[..., None, None])
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=(-2, -1))                       # [B, K, G, S]
    pv = p * v_scale[:, :, None, None, :, None] if kv_quant != "none" else p
    o = jnp.einsum("bkgsnt,bkntd->bskgd", pv.astype(dt), v_pages,
                   preferred_element_type=jnp.float32)
    o = o / jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return (o.reshape(B, S, H, dh),
            m.transpose(0, 3, 1, 2).reshape(B, S, H),
            l.transpose(0, 3, 1, 2).reshape(B, S, H))


def gather_table_pages(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """Shared-pool view: gather each slot's pages through its table.

    pages: [K, P_total, ...] pool (code pages [K, P, Ts, dh] or scales
    [K, P]); page_table: [B, NP] physical indices.  Returns the per-slot
    stripe view [B, K, NP, ...] the stripe-layout oracle consumes — the
    correctness reference for the Pallas kernel's table-indexed block maps
    (which stream pages directly from the pool and never materialize this
    gather).
    """
    return jnp.moveaxis(jnp.take(pages, page_table, axis=1), 1, 0)


def paged_to_dense(k_pages, page_base, max_len: int):
    """Test helper: reassemble [B, S, K, dh] from pages by position."""
    B, K, NP, T, dh = k_pages.shape
    pos = (page_base[:, :, None] + jnp.arange(T)[None, None, :]).reshape(B, -1)
    flat = k_pages.transpose(0, 2, 3, 1, 4).reshape(B, NP * T, K, dh)
    dense = jnp.zeros((B, max_len, K, dh), k_pages.dtype)
    idx = jnp.clip(pos, 0, max_len - 1)
    ok = (pos >= 0) & (pos < max_len)
    upd = jnp.where(ok[..., None, None], flat, 0)
    return dense.at[jnp.arange(B)[:, None], idx].add(upd)
