from repro.kernels.paged_attention.merge import (  # noqa: F401
    merge_partials,
    resolve_partitions,
)
from repro.kernels.paged_attention.ops import (  # noqa: F401
    paged_attention_partial,
    paged_chunk_attention,
)
from repro.kernels.paged_attention.ref import (  # noqa: F401
    gather_table_pages,
    paged_attention_partial_ref,
    paged_chunk_attention_ref,
    paged_to_dense,
)
