from repro.kernels.paged_attention.ops import paged_attention_partial  # noqa
from repro.kernels.paged_attention.ref import (  # noqa: F401
    paged_attention_partial_ref,
    paged_to_dense,
)
