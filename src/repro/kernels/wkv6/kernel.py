"""Pallas TPU kernel for the RWKV6 wkv recurrence (chunked).

Grid: (B, H, n_chunks) with chunks innermost/sequential — the [dh, dh]
state matrix lives in VMEM scratch across chunks (never touching HBM
between chunks, unlike the jnp chunked form whose carried state and
per-chunk cumulative-decay tensors round-trip).  Within a chunk the
cumprod factorization of models/rwkv6.py runs on MXU dots:

    out = (A ⊙ tril) v  +  diag-bonus  +  (r·a_t) S_chunk_start
    S'  = e^{total} S + (k e^{total-cum})ᵀ v

Inputs arrive pre-transposed [B, H, S, dh] (ops.py), decay as log values.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, o_ref, sT_ref,
            state_scr, *, chunk: int, n_chunks: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)          # [T, dh]
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)        # log-decay per k-channel
    u = u_ref[0].astype(jnp.float32)             # [1, dh] bonus

    cum = jnp.cumsum(lw, axis=0)                 # inclusive
    cum_excl = cum - lw
    total = cum[-1:, :]                          # [1, dh]

    r_a = r * jnp.exp(cum_excl)                  # r_t · a_t
    k_b = k * jnp.exp(-cum)                      # k_i / (a_i w_i)
    k_last = k * jnp.exp(total - cum)

    # intra-chunk: A[t, i] = (r_t a_t)·(k_i e^{-cum_i}) for i < t
    A = jax.lax.dot_general(r_a, k_b, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [T, T]
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.where(tj < ti, A, 0.0)
    intra = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    diag = jnp.sum(r * u * k, axis=-1, keepdims=True)        # [T, 1]
    intra = intra + diag * v

    # inter-chunk: (r_t a_t) · S_chunk_start
    S = state_scr[...]                                        # [dh, dh]
    inter = jax.lax.dot_general(r_a, S, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0, 0] = (intra + inter).astype(o_ref.dtype)

    kv = jax.lax.dot_general(k_last, v, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    state_scr[...] = jnp.exp(total).T * S + kv

    @pl.when(c == n_chunks - 1)
    def _final():
        sT_ref[0, 0] = state_scr[...]


def wkv6_pallas(r, k, v, logw, u, s0, *, chunk: int = 32,
                interpret: bool = False):
    """r/k/v/logw: [B, H, S, dh]; u: [H, dh]; s0: [B, H, dh, dh].

    Returns (out [B, H, S, dh] f32, sT [B, H, dh, dh] f32).
    """
    B, H, S, dh = r.shape
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    grid = (B, H, n_chunks)
    seq_spec = pl.BlockSpec((1, 1, chunk, dh),
                            lambda b, h, c: (b, h, c, 0))
    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, dh), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, dh, dh), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, dh, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(r, k, v, logw, u, s0)
