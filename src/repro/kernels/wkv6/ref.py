"""Oracles for the wkv6 Pallas kernel.

The module-of-record for the math is models/rwkv6.py (recurrent form =
ground truth, chunked form = parallel validation); re-exported here so the
kernel package follows the kernel/ops/ref contract.
"""
from repro.models.rwkv6 import wkv_chunked, wkv_recurrent  # noqa: F401
