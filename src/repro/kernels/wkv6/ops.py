"""Public wkv6 wrapper with impl dispatch (layout adaptation included)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.kernel import wkv6_pallas
from repro.kernels.wkv6.ref import wkv_chunked, wkv_recurrent


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def wkv6(r, k, v, logw, u, state, *, impl: str = "auto", chunk: int = 32):
    """r/k/v/logw: [B, S, H, dh]; u: [H, dh]; state: [B, H, dh, dh].

    Returns (out [B, S, H, dh], new_state [B, H, dh, dh]).
    """
    if impl == "auto":
        impl = default_impl()
    if impl == "ref":
        return wkv_chunked(r, k, v, logw, u, state, chunk=chunk)
    if impl == "recurrent":
        return wkv_recurrent(r, k, v, logw, u, state)

    # cumprod factorization is f32-safe for |logw|·chunk ≲ 88: with the
    # model's bounded decay (|logw| < 4.05) that caps the chunk at 32
    chunk = min(chunk, 32)
    B, S, H, dh = r.shape
    pad = (-S) % chunk
    tr = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0))  # noqa
                           ).transpose(0, 2, 1, 3)
    out, sT = wkv6_pallas(tr(r), tr(k), tr(v), tr(logw),
                          u.astype(jnp.float32),
                          state.astype(jnp.float32), chunk=chunk,
                          interpret=(impl == "interpret"))
    out = out.transpose(0, 2, 1, 3)[:, :S]
    return out.astype(r.dtype), sT
