"""Public quantized-matmul wrapper with impl dispatch."""
from __future__ import annotations

from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp

from repro.kernels.quant_gemv.kernel import quant_gemv_pallas
from repro.kernels.quant_gemv.ref import quant_gemv_ref

if TYPE_CHECKING:  # avoid circular import at runtime
    from repro.core.quant import QuantizedWeight  # noqa: F401


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def quant_gemv(x: jax.Array, qw: "QuantizedWeight", *,
               impl: str = "auto") -> jax.Array:
    """x: [..., D] @ quantized [D, F] -> [..., F] in x.dtype."""
    if impl == "auto":
        impl = default_impl()
    if qw.q.ndim != 2 or impl == "ref":
        # expert-batched (MoE) or ref path: dequant-then-matmul (XLA fuses)
        return quant_gemv_ref(x, qw.q, qw.scale, qw.scheme)

    lead = x.shape[:-1]
    D = x.shape[-1]
    M = 1
    for s in lead:
        M *= s
    x2 = x.reshape(M, D)
    if qw.scheme == "w8a8":
        from repro.core.quant import quantize_activations_int8
        xq, xs = quantize_activations_int8(x2)
        out = quant_gemv_pallas(xq, qw.q, qw.scale, "w8a8",
                                interpret=(impl == "interpret"))
        out = out * xs
    else:
        out = quant_gemv_pallas(x2.astype(jnp.bfloat16), qw.q, qw.scale,
                                "w4a16", interpret=(impl == "interpret"))
    return out.reshape(*lead, qw.q.shape[-1]).astype(x.dtype)
