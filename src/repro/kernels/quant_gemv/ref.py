"""Pure-jnp oracle for quantized GEMV/GEMM (w8a8 / w4a16).

2D weights only ([D, F] + per-channel scale [F]); MoE (expert-batched)
weights take the dequantize-then-einsum path in layers.py, which XLA fuses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def unpack_int4(q: jax.Array) -> jax.Array:
    """[..., D/2, F] uint8 -> [..., D, F] int32 in [-8, 7]."""
    hi = ((q >> 4) & 0xF).astype(jnp.int32) - 8
    lo = (q & 0xF).astype(jnp.int32) - 8
    D2 = q.shape[-2]
    out = jnp.stack([hi, lo], axis=-2)
    return out.reshape(q.shape[:-2] + (2 * D2,) + q.shape[-1:])


def quant_gemv_ref(x: jax.Array, q: jax.Array, scale: jax.Array,
                   scheme: str) -> jax.Array:
    """x: [..., D]; q: [D, F] int8 (w8) or [D/2, F] uint8 (w4); scale: [F]."""
    if scheme == "w4a16":
        w = unpack_int4(q).astype(jnp.bfloat16)
        y = jnp.einsum("...d,df->...f", x.astype(jnp.bfloat16), w)
        return (y.astype(jnp.float32) *
                scale.astype(jnp.float32)).astype(x.dtype)
    elif scheme == "w8a8":
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
        xs = jnp.maximum(amax, 1e-8) / 127.0
        xq = jnp.clip(jnp.round(x.astype(jnp.float32) / xs), -127,
                      127).astype(jnp.int8)
        acc = jnp.einsum("...d,df->...f", xq.astype(jnp.int32),
                         q.astype(jnp.int32))
        return (acc.astype(jnp.float32) * xs *
                scale.astype(jnp.float32)).astype(x.dtype)
    raise ValueError(scheme)
