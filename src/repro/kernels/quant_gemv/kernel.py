"""Pallas TPU quantized-GEMV kernel (the IFC weight-GEMV analogue).

The decode-phase GEMV is pure weight streaming: arithmetic intensity ≈ 1
op/byte at bf16, ≈ 4 ops/byte at int4.  The kernel tiles the weight matrix
[D, F] into (bd × bf) VMEM blocks, dequantizes in-register (nibble unpack +
per-channel scale), and accumulates x·W in an f32 VMEM scratch across the
sequential D dimension — weights are read exactly once, the activation
block is tiny, so HBM traffic ≈ quantized weight bytes (the paper's W4A16
bandwidth win, §V Takeaway 2).

Grid: (F_tiles, D_tiles), D innermost/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat


def _kernel_w4(x_ref, q_ref, s_ref, o_ref, acc_scr, *, n_d: int):
    idx = pl.program_id(1)

    @pl.when(idx == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[...].astype(jnp.bfloat16)                      # [M, bd]
    qp = q_ref[...]                                          # [bd/2, bf] uint8
    hi = ((qp >> 4) & 0xF).astype(jnp.int8) - 8
    lo = (qp & 0xF).astype(jnp.int8) - 8
    bd2, bf = qp.shape
    w = jnp.stack([hi, lo], axis=1).reshape(2 * bd2, bf)     # [bd, bf]
    acc_scr[...] += jax.lax.dot_general(
        x, w.astype(jnp.bfloat16), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(idx == n_d - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...] * s_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


def _kernel_w8(x_ref, q_ref, s_ref, o_ref, acc_scr, *, n_d: int):
    idx = pl.program_id(1)

    @pl.when(idx == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # int8 × int8 → int32 accumulate (MXU int path); x pre-quantized upstream
    x = x_ref[...].astype(jnp.int8)
    w = q_ref[...].astype(jnp.int8)
    acc_scr[...] += jax.lax.dot_general(
        x.astype(jnp.int32), w.astype(jnp.int32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32).astype(jnp.float32)

    @pl.when(idx == n_d - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...] * s_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


def quant_gemv_pallas(x, q, scale, scheme: str, *, block_d: int = 512,
                      block_f: int = 512, interpret: bool = False,
                      out_dtype=jnp.float32):
    """x: [M, D] (bf16 for w4, int8 for w8); q: packed weights; scale: [F]."""
    M, D = x.shape
    F = q.shape[-1]
    bd = min(block_d, D)
    bf = min(block_f, F)
    assert D % bd == 0 and F % bf == 0, (D, bd, F, bf)
    n_d = D // bd

    if scheme == "w4a16":
        kernel = functools.partial(_kernel_w4, n_d=n_d)
        q_spec = pl.BlockSpec((bd // 2, bf), lambda f, d: (d, f))
    else:
        kernel = functools.partial(_kernel_w8, n_d=n_d)
        q_spec = pl.BlockSpec((bd, bf), lambda f, d: (d, f))

    return pl.pallas_call(
        kernel,
        grid=(F // bf, n_d),
        in_specs=[
            pl.BlockSpec((M, bd), lambda f, d: (0, d)),
            q_spec,
            pl.BlockSpec((bf,), lambda f, d: (f,)),
        ],
        out_specs=pl.BlockSpec((M, bf), lambda f, d: (0, f)),
        out_shape=jax.ShapeDtypeStruct((M, F), out_dtype),
        scratch_shapes=[pltpu.VMEM((M, bf), jnp.float32)],
        interpret=interpret,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(x, q, scale)
