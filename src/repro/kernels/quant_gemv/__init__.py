from repro.kernels.quant_gemv.ops import quant_gemv  # noqa: F401
from repro.kernels.quant_gemv.ref import quant_gemv_ref, unpack_int4  # noqa
