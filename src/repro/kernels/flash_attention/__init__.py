from repro.kernels.flash_attention.ops import flash_attention  # noqa: F401
from repro.kernels.flash_attention.ref import (  # noqa: F401
    dense_attention_ref,
    flash_attention_ref,
)
