"""jit'd public wrapper for flash attention with impl dispatch.

impl:
  auto      -> pallas on TPU backends, ref elsewhere (CPU dry-run / tests)
  pallas    -> force the TPU kernel
  interpret -> Pallas interpret mode (kernel body on CPU; used by tests)
  ref       -> pure-jnp blocked oracle
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "ref"


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "impl", "block_q", "block_k"))
def flash_attention(
    q: jax.Array,                  # [B, Sq, H, dh]
    k: jax.Array,                  # [B, Sk, K, dh]
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,
    is_global=None,
    impl: str = "auto",
    block_q: int = 512,
    block_k: int = 512,
) -> jax.Array:
    if impl == "auto":
        impl = default_impl()
    if impl == "ref" or is_global is not None or not isinstance(q_offset, int):
        # dynamic window toggles / traced offsets take the jnp path
        return flash_attention_ref(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, is_global=is_global,
                                   chunk_k=block_k)

    B, Sq, H, dh = q.shape
    _, Sk, K, _ = k.shape
    scale = dh ** -0.5

    # [B, S, H, dh] -> [B, H, S, dh]; pad dh to the 128-lane MXU width and
    # sequence dims to block multiples (zero keys are masked via sk_valid).
    qt = _pad_to(q.transpose(0, 2, 1, 3), 3, 128)
    kt = _pad_to(k.transpose(0, 2, 1, 3), 3, 128)
    vt = _pad_to(v.transpose(0, 2, 1, 3), 3, 128)
    bq = min(block_q, max(Sq, 16))
    bk = min(block_k, max(Sk, 16))
    qt = _pad_to(qt, 2, bq)
    kt = _pad_to(kt, 2, bk)
    vt = _pad_to(vt, 2, bk)

    out = flash_attention_pallas(
        qt, kt, vt, causal=causal, window=window, scale=scale,
        sq_valid=Sq, sk_valid=Sk, block_q=bq, block_k=bk,
        interpret=(impl == "interpret"))
    return out[:, :, :Sq, :dh].transpose(0, 2, 1, 3)
