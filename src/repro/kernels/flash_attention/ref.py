"""Pure-jnp oracle for blocked causal attention (online softmax).

This is both the correctness reference for the Pallas kernel and the
CPU/dry-run lowering path (`impl="ref"`): it computes identical math with a
`lax.scan` over KV chunks, so HLO FLOPs/bytes match the real workload without
materializing the [Sq, Sk] score matrix.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _expand_kv(x: jax.Array, groups: int) -> jax.Array:
    """[B, S, K, dh] -> [B, S, K*G, dh] by repeating each KV head G times."""
    if groups == 1:
        return x
    B, S, K, dh = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (B, S, K, groups, dh)).reshape(
        B, S, K * groups, dh)


def flash_attention_ref(
    q: jax.Array,                  # [B, Sq, H, dh]
    k: jax.Array,                  # [B, Sk, K, dh]
    v: jax.Array,                  # [B, Sk, K, dh]
    *,
    causal: bool = True,
    window: Optional[int] = None,  # sliding window (tokens), None = full
    q_offset=0,                    # absolute position of q[0] (int or array)
    chunk_k: int = 512,
    is_global=None,                # optional scalar bool overriding window
) -> jax.Array:
    """Blocked attention with online softmax; supports GQA + sliding window.

    `is_global` (traced bool) disables the window dynamically — used by the
    gemma3 local:global scan-over-layers where the pattern is a scanned input.
    """
    B, Sq, H, dh = q.shape
    _, Sk, K, _ = k.shape
    groups = H // K
    k = _expand_kv(k, groups)
    v = _expand_kv(v, groups)

    orig_dtype = q.dtype
    scale = dh ** -0.5
    qf = (q.astype(jnp.float32) * scale).transpose(0, 2, 1, 3)  # [B, H, Sq, dh]
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)            # [B, H, Sk, dh]
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)

    q_pos = q_offset + jnp.arange(Sq)                           # [Sq]

    chunk_k = min(chunk_k, Sk)
    n_chunks = -(-Sk // chunk_k)
    pad = n_chunks * chunk_k - Sk
    if pad:
        kf = jnp.pad(kf, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vf = jnp.pad(vf, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kc = kf.reshape(B, H, n_chunks, chunk_k, dh)
    vc = vf.reshape(B, H, n_chunks, chunk_k, dh)

    def step(carry, inputs):
        m, l, acc = carry
        kb, vb, c = inputs                                      # [B,H,ck,dh]
        k_pos = c * chunk_k + jnp.arange(chunk_k)               # [ck]
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kb)               # [B,H,Sq,ck]
        mask = k_pos[None, :] < Sk                              # padding
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            in_window = k_pos[None, :] > q_pos[:, None] - window
            if is_global is not None:
                in_window = in_window | is_global
            mask &= in_window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))             # [B,H,Sq]
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
        return (m_new, l, acc), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    acc0 = jnp.zeros((B, H, Sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (kc.transpose(2, 0, 1, 3, 4), vc.transpose(2, 0, 1, 3, 4),
         jnp.arange(n_chunks)))

    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(orig_dtype)        # [B, Sq, H, dh]


def dense_attention_ref(q, k, v, *, causal=True, window=None, q_offset=0,
                        is_global=None):
    """Naive dense softmax attention — oracle-of-the-oracle for tests."""
    B, Sq, H, dh = q.shape
    _, Sk, K, _ = k.shape
    k = _expand_kv(k, H // K)
    v = _expand_kv(v, H // K)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * dh ** -0.5
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        in_w = k_pos[None, :] > q_pos[:, None] - window
        if is_global is not None:
            in_w = in_w | is_global
        mask &= in_w
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
