"""Pallas TPU flash-attention (prefill/train) kernel.

Grid: (batch, q_heads, q_blocks, k_blocks) with the k dimension innermost and
"arbitrary" (sequential) so VMEM scratch accumulators carry the online
softmax across k blocks.  GQA is handled in the BlockSpec index map
(k/v blocks are fetched from head h // group), so KV is never expanded —
each KV block is read once per q-head group member, straight HBM→VMEM.

Causal/window block skipping happens at the `pl.when` level: fully-masked
(q_block, k_block) pairs skip the MXU work entirely.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compat

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: Optional[int],
            bq: int, bk: int, n_kb: int, sq_valid: int, sk_valid: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = ik * bk

    # static-shape block skip conditions (evaluated on traced grid ids)
    relevant = k_start < sk_valid
    if causal:
        relevant &= k_start <= q_start + bq - 1
    if window is not None:
        relevant &= k_start + bk - 1 > q_start - window

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale           # [bq, dh]
        k = k_ref[0, 0].astype(jnp.float32)                   # [bk, dh]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # [bq,bk]

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < sk_valid
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                   # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                                # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)                       # [bq, 1]
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == n_kb - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,                 # [B, H, Sq, dh]  (dh padded to 128 upstream)
    k: jax.Array,                 # [B, K, Sk, dh]
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: float,
    sq_valid: int,
    sk_valid: int,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, dh = q.shape
    _, K, Sk, _ = k.shape
    groups = H // K
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    n_kb = Sk // bk

    grid = (B, H, Sq // bq, n_kb)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_kb=n_kb, sq_valid=sq_valid, sk_valid=sk_valid)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, dh), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, iq, ik: (b, h // groups, ik, 0)),
            pl.BlockSpec((1, 1, bk, dh),
                         lambda b, h, iq, ik: (b, h // groups, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, dh),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running sum-exp l
            pltpu.VMEM((bq, dh), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
        compiler_params=_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q, k, v)
