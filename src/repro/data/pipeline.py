"""Deterministic, restart-safe data pipeline.

Index-based: batch `i` is a pure function of (seed, i), so any host can
produce any shard and resuming from a checkpointed step cursor is exact —
no iterator state to persist, no skip-ahead replay cost (the paper-scale
fault-tolerance requirement).

Sources:
  * SyntheticLM — zipf-ish token stream with structure (next-token
    correlations) so smoke-training visibly learns.
  * TokenFile   — memory-mapped flat token file (np.memmap), strided
    deterministically.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np



@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    path: Optional[str] = None     # None -> synthetic


class SyntheticLM:
    """Markov-ish synthetic stream: token_{t+1} = f(token_t) + noise.

    Learnable structure: each token deterministically prefers a successor
    (permutation) with 80% probability — a model that trains will drop
    loss well below ln(V).
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.perm = rng.permutation(cfg.vocab_size)

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, index))
        B, S = cfg.global_batch, cfg.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, B)
        follow = rng.random((B, S)) < 0.8
        noise = rng.integers(0, cfg.vocab_size, (B, S))
        for t in range(S):
            nxt = self.perm[toks[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, noise[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


class TokenFile:
    """Flat int32 token file; batch i reads a deterministic stride."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=np.int32, mode="r")
        self.n_windows = (len(self.data) - 1) // cfg.seq_len

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, index))
        starts = rng.integers(0, self.n_windows, cfg.global_batch) \
            * cfg.seq_len
        toks = np.stack([self.data[s:s + cfg.seq_len + 1] for s in starts])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def make_source(cfg: DataConfig):
    return TokenFile(cfg) if cfg.path else SyntheticLM(cfg)


class DataIterator:
    """Cursor-based iterator; `state()`/`restore()` are just an int."""

    def __init__(self, source, start_index: int = 0):
        self.source = source
        self.index = start_index

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.source.batch(self.index)
        self.index += 1
        return b

    def state(self) -> int:
        return self.index

    def restore(self, index: int):
        self.index = index
