from repro.configs.base import (  # noqa: F401
    ASSIGNED_ARCHS,
    PAPER_ARCHS,
    SHAPES,
    EngineConfig,
    ModelConfig,
    ShapeConfig,
    get_config,
    list_configs,
    register,
    shape_applicable,
)
