"""Whisper-base — encoder-decoder audio backbone; conv frontend stubbed.

[arXiv:2212.04356; unverified] — ``input_specs()`` supplies precomputed frame
embeddings (frontend_stub=True); encoder is bidirectional (no KV cache), the
decoder autoregresses with self- + cross-attention.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,                 # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_head=64,
    d_ff=2048,
    vocab_size=51_865,
    is_encoder_decoder=True,
    encoder_layers=6,
    gated_mlp=False,
    tie_embeddings=True,
    frontend_stub=True,
    source="arXiv:2212.04356",
))
