"""LLaMA2-7B — paper evaluation model (MHA). [arXiv:2307.09288]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_head=128,
    d_ff=11008,
    vocab_size=32_000,
    source="arXiv:2307.09288 (paper eval model)",
))
