"""Qwen1.5-4B — dense MHA (kv == q heads) with QKV bias.

[hf:Qwen/Qwen1.5-0.5B family; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_head=128,
    d_ff=6912,
    vocab_size=151_936,
    attn_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-4B",
))
