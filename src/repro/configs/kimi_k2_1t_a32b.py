"""Kimi K2 — trillion-parameter fine-grained MoE, 384 experts top-8.

[arXiv:2501.kimi2; unverified] — paper-table config: 61L, d_model=7168,
64 query heads (GQA kv=8), per-expert d_ff=2048, vocab 163840.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=112,           # d_model // n_heads (spec-exact; kernels pad to 128)
    d_ff=2048,            # per-expert (fine-grained)
    vocab_size=163_840,
    n_experts=384,
    top_k=8,
    rope_theta=50_000.0,
    source="arXiv:2501.kimi2",
))
