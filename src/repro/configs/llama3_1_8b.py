"""LLaMA3.1-8B — paper evaluation model (GQA). [arXiv:2407.21783]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama3.1-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=128_256,
    rope_theta=500_000.0,
    source="arXiv:2407.21783 (paper eval model)",
))
