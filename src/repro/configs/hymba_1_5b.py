"""Hymba-1.5B — hybrid-head: parallel attention + Mamba heads per layer.

[arXiv:2411.13676; hf] — 25 query heads (GQA kv=5), ssm_state=16, sliding
window attention on most layers with a few global layers, 128 meta tokens.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_head=64,
    d_ff=5504,
    vocab_size=32_001,
    ssm_state=16,
    window=1024,
    global_every=16,        # sparse global layers
    n_meta_tokens=128,
    source="arXiv:2411.13676",
))
