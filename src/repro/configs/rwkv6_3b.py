"""RWKV6-3B (Finch) — attention-free, data-dependent decay.

[arXiv:2404.05892; hf] — internal wkv heads of size 64 (40 heads at
d_model=2560); the assignment lists the arch as attention-free.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,            # wkv heads (head_size 64), not attention heads
    n_kv_heads=40,
    d_head=64,
    d_ff=8960,
    vocab_size=65_536,
    source="arXiv:2404.05892",
))
