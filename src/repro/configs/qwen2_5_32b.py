"""Qwen2.5-32B — dense GQA with QKV bias.

[hf:Qwen/Qwen2.5-0.5B family; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=27648,
    vocab_size=152_064,
    attn_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-32B",
))
