"""Gemma3-12B — 5:1 local:global attention, 128K context.

[hf:google/gemma-3-1b-pt family; unverified] — every 6th layer is global
(full) attention; the rest use a 1024-token sliding window.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_head=256,
    d_ff=15360,
    vocab_size=262_144,
    window=1024,
    global_every=6,        # 5 local : 1 global
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:google/gemma-3-12b-pt",
))
