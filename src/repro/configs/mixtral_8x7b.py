"""Mixtral-8×7B — paper §III-B case-study MoE model. [arXiv:2401.04088]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=32_000,
    n_experts=8,
    top_k=2,
    source="arXiv:2401.04088 (paper eval model)",
))
