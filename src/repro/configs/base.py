"""Model / shape / engine configuration system.

Every assigned architecture is a :class:`ModelConfig` (exact public-literature
hyperparameters) registered under its ``--arch`` id.  Shapes are the four
assignment-wide :class:`ShapeConfig` cells.  ``reduced()`` derives the smoke-test
config of the same family (small widths / few experts / tiny vocab).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "vlm", "audio", "ssm", "hybrid")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int                     # query heads (0 for attention-free)
    n_kv_heads: int                  # KV heads (GQA); == n_heads for MHA
    d_ff: int                        # FFN hidden (per-expert for MoE)
    vocab_size: int                  # true vocab (padded internally)

    # Derived / optional
    d_head: int = 0                  # 0 -> d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # attention flavour
    attn_bias: bool = False          # Qwen-style QKV bias
    window: Optional[int] = None     # sliding-window size (local attention)
    global_every: int = 0            # gemma3: every Nth layer is global
    rope_theta: float = 10_000.0
    # ssm / hybrid
    ssm_state: int = 0
    n_meta_tokens: int = 0           # hymba learnable meta tokens
    # encoder-decoder
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    # frontend stubs (vlm/audio): inputs are precomputed embeddings
    frontend_stub: bool = False
    # misc
    gated_mlp: bool = True           # SwiGLU-style (False: 2-matrix GELU MLP)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    source: str = ""                 # provenance note

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.n_heads and not self.d_head:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.n_heads and self.n_kv_heads and self.n_heads % self.n_kv_heads:
            raise ValueError(
                f"{self.name}: n_heads={self.n_heads} not divisible by "
                f"n_kv_heads={self.n_kv_heads}")

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (sharding + MXU alignment)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def group_size(self) -> int:
        """Q heads per KV head (the paper's head-group width)."""
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic context handling: SSM / hybrid / local-global."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.window is not None  # local(:global) attention

    @property
    def has_decode(self) -> bool:
        """All assigned archs autoregress (whisper via its decoder)."""
        return True

    def is_global_layer(self, layer: int) -> bool:
        """gemma3-style local:global pattern; True -> full attention."""
        if self.window is None:
            return True
        if self.global_every <= 0:
            return False
        return (layer + 1) % self.global_every == 0

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Exact dense-equivalent parameter count (all experts)."""
        d, dh = self.d_model, self.d_head
        qkv = d * (self.q_dim + 2 * self.kv_dim)
        if self.attn_bias:
            qkv += self.q_dim + 2 * self.kv_dim
        o = self.q_dim * d
        attn = qkv + o
        ffn_one = (3 if self.gated_mlp else 2) * d * self.d_ff
        if self.is_moe:
            ffn = self.n_experts * ffn_one + d * self.n_experts  # + router
        else:
            ffn = ffn_one
        norms = 2 * d
        per_layer = attn + ffn + norms

        if self.family == "ssm":  # rwkv6: replace attn with time-mix
            # r,k,v,g,o projections + decay/bonus params (approx faithful)
            per_layer = 5 * d * d + 2 * d + ffn_one + norms
        if self.family == "hybrid":  # parallel attn + mamba heads share width
            ssm = 2 * d * d + d * (2 * self.ssm_state) + d  # in/out, B/C, dt
            per_layer = attn + ssm + ffn_one + norms

        total = self.n_layers * per_layer
        total += self.padded_vocab * d  # embed
        if not self.tie_embeddings:
            total += self.padded_vocab * d  # lm head
        total += d  # final norm
        if self.is_encoder_decoder:
            enc_layer = attn + ffn_one + norms
            total += self.encoder_layers * enc_layer
            total += self.n_layers * (qkv + o + d)  # cross-attention + norm
        if self.n_meta_tokens:
            total += self.n_meta_tokens * d
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        ffn_all = self.n_experts * 3 * d * self.d_ff
        ffn_act = self.top_k * 3 * d * self.d_ff
        return self.param_count() - self.n_layers * (ffn_all - ffn_act)

    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        if self.is_attention_free:
            return 0
        return 2 * self.n_layers * self.kv_dim * dtype_bytes

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        if self.family == "ssm":
            # wkv heads must tile d_model exactly (d=128, dh=32 -> 4 heads)
            n_heads = n_kv = 4
        elif self.n_kv_heads:
            n_kv = min(self.n_kv_heads, 2)
            n_heads = n_kv * min(self.group_size, 2)
        else:
            n_kv = n_heads = 0
        return replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_head=32 if self.n_heads else 0,
            d_ff=256,
            vocab_size=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            window=min(self.window, 64) if self.window else None,
            global_every=min(self.global_every, 2) if self.global_every else 0,
            ssm_state=min(self.ssm_state, 8),
            n_meta_tokens=min(self.n_meta_tokens, 8),
            encoder_layers=min(self.encoder_layers, 2),
        )


# ---------------------------------------------------------------------------
# Shape configuration (the 4 assignment-wide input-shape cells)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "pure full-attention arch: long_500k skipped (DESIGN.md §5)"
    return True, ""


# ---------------------------------------------------------------------------
# Engine (KVNAND) configuration — Track B runtime knobs, DSE-selectable
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class EngineConfig:
    variant: str = "compact"        # "compact" (KVNAND-C) | "discrete" (KVNAND-D)
    hg_pipeline: bool = False       # head-group pipelining (KVNAND-D dataflow)
    page_tokens: int = 64           # tokens per KV page (flash-page analogue)
    quant: str = "none"             # "none" | "w8a8" | "w4a16"
    kv_quant: str = "none"          # "none" | "kv8" | "kv4" paged-KV format
    max_pages_per_seq: int = 0      # 0 -> derived from context length
    kv_dtype: str = "bfloat16"      # KV cache storage dtype (kv_quant=none)
    # shared-pool paged KV (§IV-D FTL mapping): one physical page pool per
    # layer-group, addressed through per-slot page tables, instead of a
    # private per-slot stripe of ceil(max_context / page_tokens) pages
    shared_pool: bool = False
    total_pages: int = 0            # global-pool physical pages (0 -> B·NPg)
    total_pages_w: int = 0          # window-pool physical pages (0 -> B·NPw)
    # tiered flash KV hierarchy (DESIGN.md §13): keep only `hot_pages`
    # of the shared global pool device-resident (the HOT tier); the
    # remaining `total_pages - hot_pages` flash pages form the CAPACITY
    # tier, staged in/out by the scheduler's promote/demote machinery.
    # 0 = single tier (the whole pool is hot).  DSE-selectable via
    # `core.dse.recommend_hot_pages`.
    hot_pages: int = 0
    uniform_lengths: bool = True    # static batching: lockstep appends
    # draft-and-verify speculative decoding: tokens drafted per decode
    # step (prompt lookup) and verified in one pass; 0 = sequential.
    # DSE-selectable (`recommend_engine_config`) like the other knobs;
    # `ServerConfig.speculation_k` overrides per server.
    speculation_k: int = 0
    attn_impl: str = "auto"         # "auto" | "pallas" | "ref" | "interpret"
    # split-page attention: contiguous page-walk partitions merged via
    # the LSE merge core (0 = auto from the page count; must divide the
    # per-device page count when set).  DSE-searchable like kv_quant.
    attn_partitions: int = 0
    gemv_impl: str = "auto"
    # training-side knobs
    remat: str = "block"            # "none" | "block" | "full"
    microbatches: int = 1
    grad_compress: bool = False     # int8 cross-pod gradient compression
    optimizer_dtype: str = "float32"  # "float32" | "bfloat16" moments
    fsdp: bool = False              # shard params over data axis too

    def __post_init__(self):
        if self.kv_quant not in ("none", "kv8", "kv4"):
            raise ValueError(f"unknown kv_quant {self.kv_quant!r}")
        if self.kv_quant == "kv4" and self.page_tokens % 2:
            raise ValueError("kv4 packs token pairs: page_tokens must be "
                             f"even, got {self.page_tokens}")
        if self.speculation_k < 0:
            raise ValueError(f"speculation_k must be >= 0, "
                             f"got {self.speculation_k}")
        if self.attn_partitions < 0:
            raise ValueError(f"attn_partitions must be >= 0 (0 = auto), "
                             f"got {self.attn_partitions}")
        if self.hot_pages < 0:
            raise ValueError(f"hot_pages must be >= 0 (0 = single tier), "
                             f"got {self.hot_pages}")
        if self.hot_pages and not self.shared_pool:
            raise ValueError("hot_pages tiers the SHARED page pool: set "
                             "shared_pool=True (DESIGN.md §13)")
        if self.hot_pages and self.total_pages \
                and self.hot_pages > self.total_pages:
            raise ValueError(f"hot_pages ({self.hot_pages}) cannot exceed "
                             f"total_pages ({self.total_pages})")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch id {cfg.name!r}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(_REGISTRY)}") from None


def list_configs() -> Dict[str, ModelConfig]:
    _ensure_loaded()
    return dict(_REGISTRY)


ASSIGNED_ARCHS = (
    "dbrx-132b", "kimi-k2-1t-a32b", "pixtral-12b", "qwen1.5-4b",
    "qwen2.5-32b", "gemma3-12b", "qwen1.5-0.5b", "whisper-base",
    "rwkv6-3b", "hymba-1.5b",
)

PAPER_ARCHS = (
    "opt-30b", "llama2-7b", "llama3.1-8b", "llama3.1-70b", "mixtral-8x7b",
)

_loaded = False


def _ensure_loaded():
    global _loaded
    if _loaded:
        return
    _loaded = True
    from repro.configs import archs  # noqa: F401  (registers everything)
