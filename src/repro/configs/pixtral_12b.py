"""Pixtral-12B — pixtral-ViT frontend (stub) + Mistral-Nemo text backbone.

[hf:mistralai/Pixtral-12B-2409; unverified] — the assignment specifies the
transformer BACKBONE only; ``input_specs()`` supplies precomputed patch
embeddings (frontend_stub=True).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_head=160,
    d_ff=14336,
    vocab_size=131_072,
    rope_theta=1_000_000.0,
    frontend_stub=True,
    source="hf:mistralai/Pixtral-12B-2409",
))
