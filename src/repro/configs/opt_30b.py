"""OPT-30B — paper evaluation model (MHA). [arXiv:2205.01068]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="opt-30b",
    family="dense",
    n_layers=48,
    d_model=7168,
    n_heads=56,
    n_kv_heads=56,
    d_head=128,
    d_ff=28672,
    vocab_size=50_272,
    gated_mlp=False,
    tie_embeddings=True,
    source="arXiv:2205.01068 (paper eval model)",
))
