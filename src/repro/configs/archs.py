"""Imports every per-arch config module so registration side-effects run."""
# Assigned architectures (10)
from repro.configs import dbrx_132b        # noqa: F401
from repro.configs import kimi_k2_1t_a32b  # noqa: F401
from repro.configs import pixtral_12b      # noqa: F401
from repro.configs import qwen1_5_4b       # noqa: F401
from repro.configs import qwen2_5_32b      # noqa: F401
from repro.configs import gemma3_12b       # noqa: F401
from repro.configs import qwen1_5_0_5b     # noqa: F401
from repro.configs import whisper_base     # noqa: F401
from repro.configs import rwkv6_3b         # noqa: F401
from repro.configs import hymba_1_5b       # noqa: F401
# Paper evaluation models (Track A / benchmarks)
from repro.configs import opt_30b          # noqa: F401
from repro.configs import llama2_7b        # noqa: F401
from repro.configs import llama3_1_8b      # noqa: F401
from repro.configs import llama3_1_70b     # noqa: F401
from repro.configs import mixtral_8x7b     # noqa: F401
