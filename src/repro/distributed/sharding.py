"""Logical-axis sharding system (t5x/MaxText-style, dependency-free).

Every parameter/activation carries a tuple of *logical* axis names; a rules
dict maps logical names to mesh axes.  This keeps model code mesh-agnostic —
the same model lowers on 1 CPU device, a 16×16 pod, or a 2×16×16 multi-pod
mesh just by swapping rules.
"""
from __future__ import annotations

from typing import Any, Dict, Mapping, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Mapping[str, Union[str, Tuple[str, ...], None]]


def make_mesh_compat(shape: Sequence[int], axes: Sequence[str],
                     devices=None) -> Mesh:
    """`jax.make_mesh` with Auto axis types where the jax version has them
    (>= 0.5); plain mesh otherwise (0.4.x has no axis_types kwarg)."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(tuple(shape), tuple(axes), devices=devices,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.make_mesh(tuple(shape), tuple(axes), devices=devices)

# ---------------------------------------------------------------------------
# Rule sets
# ---------------------------------------------------------------------------

# Tensor-parallel baseline: weights TP over "model", replicated over data/pod.
BASE_RULES: Dict[str, Any] = {
    # parameter axes
    "layer": None,          # stacked-layer leading axis (scanned)
    "vocab": "model",
    "embed": None,          # d_model
    "heads": "model",       # flattened G*d_head (head-group-major)
    "head_dim": "model",    # per-kv-head d_head columns
    "kv": "model",          # flattened n_kv*d_head
    "mlp": "model",         # dense FFN hidden
    "expert": "model",      # MoE expert axis (EP)
    "moe_mlp": None,        # per-expert FFN hidden (expert axis already TP)
    "norm": None,
    "ssm": None,            # small SSM/decay params
    # activation axes
    "batch": ("data",),
    "act_seq": "model",     # sequence-parallel activations
    "act_heads": "model",
    "act_embed": None,
    "act_mlp": "model",
    "kv_pages": "model",    # paged KV cache page axis (the paper's G2 shards)
}

# FSDP addition: shard the d_model axis of params over "data" as well
# (ZeRO-3 style; XLA inserts the all-gathers).  Used for ≥30B configs.
FSDP_RULES: Dict[str, Any] = dict(BASE_RULES, embed="data", moe_mlp="data")


def make_rules(*, fsdp: bool = False, multi_pod: bool = False,
               overrides: Optional[Rules] = None) -> Dict[str, Any]:
    rules = dict(FSDP_RULES if fsdp else BASE_RULES)
    if multi_pod:
        # batch data-parallel over both pod and data axes
        rules["batch"] = ("pod", "data")
    if overrides:
        rules.update(overrides)
    return rules


# ---------------------------------------------------------------------------
# Spec resolution
# ---------------------------------------------------------------------------

def logical_to_spec(axes: Sequence[Optional[str]], rules: Rules) -> P:
    """Map a tuple of logical axis names to a PartitionSpec."""
    mesh_axes = []
    used: set = set()
    for ax in axes:
        if ax is None:
            mesh_axes.append(None)
            continue
        m = rules.get(ax, None)
        if m is None:
            mesh_axes.append(None)
            continue
        flat = (m,) if isinstance(m, str) else tuple(m)
        # a mesh axis may appear only once in a PartitionSpec
        avail = tuple(a for a in flat if a not in used)
        used.update(avail)
        if not avail:
            mesh_axes.append(None)
        elif len(avail) == 1:
            mesh_axes.append(avail[0])
        else:
            mesh_axes.append(avail)
    return P(*mesh_axes)


def _divisible(dim: int, n_shards: int) -> bool:
    return n_shards > 0 and dim % n_shards == 0


def spec_for_shape(shape: Sequence[int], axes: Sequence[Optional[str]],
                   rules: Rules, mesh: Mesh) -> P:
    """logical_to_spec + divisibility fallback: drop sharding on any dim the
    mesh does not divide (keeps odd vocab/head counts compiling)."""
    spec = logical_to_spec(axes, rules)
    fixed = []
    for dim, m in zip(shape, spec):
        if m is None:
            fixed.append(None)
            continue
        names = (m,) if isinstance(m, str) else tuple(m)
        n = 1
        for name in names:
            n *= mesh.shape[name]
        fixed.append(m if _divisible(dim, n) else None)
    return P(*fixed)


def tree_shardings(abstract_tree: Any, spec_tree: Any, rules: Rules,
                   mesh: Mesh) -> Any:
    """Build a NamedSharding pytree for (abstract params, logical-axes) trees.

    QuantizedWeight leaves expand into matching QuantizedWeight sharding
    nodes (q + per-channel scale)."""
    def one(leaf, axes):
        if type(leaf).__name__ == "QuantizedWeight":
            from repro.core.quant import QuantizedWeight
            q_sh = one(leaf.q, tuple(axes.q))
            s_sh = one(leaf.scale, tuple(axes.scale))
            return QuantizedWeight(q_sh, s_sh, leaf.scheme, leaf.orig_shape)
        if axes is None:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, spec_for_shape(leaf.shape, axes, rules, mesh))

    is_leaf = lambda x: x is None or type(x).__name__ == "QuantizedWeight"  # noqa
    return jax.tree.map(one, abstract_tree, spec_tree, is_leaf=is_leaf)


def constrain(x, axes: Sequence[Optional[str]], rules: Rules,
              mesh: Optional[Mesh] = None):
    """with_sharding_constraint by logical axes (no-op off-mesh)."""
    mesh = mesh or get_current_mesh()
    if mesh is None or mesh.empty or mesh.size == 1:
        return x
    spec = spec_for_shape(x.shape, axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def get_current_mesh() -> Optional[Mesh]:
    try:
        env = jax.interpreters.pxla.thread_resources.env
        m = env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None
